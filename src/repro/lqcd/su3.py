"""SU(3) gauge-field helpers."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def random_su3(key, shape: Tuple[int, ...]) -> jnp.ndarray:
    """Random SU(3) matrices of shape (*shape, 3, 3) complex64.

    Gram-Schmidt (QR) of a random complex matrix, phase-fixed to det=1.
    """
    kr, ki = jax.random.split(key)
    m = (jax.random.normal(kr, shape + (3, 3))
         + 1j * jax.random.normal(ki, shape + (3, 3))).astype(jnp.complex64)
    q, r = jnp.linalg.qr(m)
    # make R's diagonal real-positive so Q is uniquely unitary
    d = jnp.diagonal(r, axis1=-2, axis2=-1)
    ph = d / jnp.abs(d)
    q = q * jnp.conj(ph)[..., None, :]
    # project U(3) -> SU(3): divide by cube root of determinant
    det = jnp.linalg.det(q)
    q = q * (jnp.conj(det) ** (1.0 / 3.0))[..., None, None]
    return q.astype(jnp.complex64)


def random_su3_field(key, lattice_shape: Tuple[int, int, int, int],
                     ) -> jnp.ndarray:
    """Gauge field U_mu(x): shape (4, X, Y, Z, T, 3, 3)."""
    return random_su3(key, (4,) + tuple(lattice_shape))


def su3_project(m: jnp.ndarray) -> jnp.ndarray:
    """Project arbitrary 3x3 matrices back onto SU(3) (reunitarization)."""
    q, r = jnp.linalg.qr(m)
    d = jnp.diagonal(r, axis1=-2, axis2=-1)
    ph = d / jnp.abs(d)
    q = q * jnp.conj(ph)[..., None, :]
    det = jnp.linalg.det(q)
    return q * (jnp.conj(det) ** (1.0 / 3.0))[..., None, None]


def unitarity_defect(u: jnp.ndarray) -> jnp.ndarray:
    """max |U U† − 1| — 0 for exact SU(3)."""
    eye = jnp.eye(3, dtype=u.dtype)
    uu = jnp.einsum("...ab,...cb->...ac", u, jnp.conj(u))
    return jnp.max(jnp.abs(uu - eye))
