"""Wilson-Dirac operator.

D-slash is the sparse stencil at the heart of LQCD (paper §Introduction):

  (D ψ)(x) = Σ_μ [ (1 − γ_μ) U_μ(x) ψ(x+μ̂) + (1 + γ_μ) U†_μ(x−μ̂) ψ(x−μ̂) ]

with periodic boundaries.  The full Wilson operator is M = 1 − κ D.
It is memory-bandwidth-bound: 1320 flops/site against ~1.4 KB/site of
streamed spinors+links in fp32 — exactly why L-CSC was built around GPU
memory bandwidth.

Fields:
  psi: (X, Y, Z, T, 4, 3) complex64   (spin, color)
  U:   (4, X, Y, Z, T, 3, 3) complex64 (direction-major)
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

# Dirac gamma matrices (Dirac basis), complex64
_g0 = np.array([[1, 0, 0, 0], [0, 1, 0, 0], [0, 0, -1, 0], [0, 0, 0, -1]],
               np.complex64)
_g1 = np.array([[0, 0, 0, -1j], [0, 0, -1j, 0], [0, 1j, 0, 0],
                [1j, 0, 0, 0]], np.complex64)
_g2 = np.array([[0, 0, 0, -1], [0, 0, 1, 0], [0, 1, 0, 0], [-1, 0, 0, 0]],
               np.complex64)
_g3 = np.array([[0, 0, -1j, 0], [0, 0, 0, 1j], [1j, 0, 0, 0],
                [0, -1j, 0, 0]], np.complex64)
GAMMA = jnp.stack([jnp.asarray(_g1), jnp.asarray(_g2), jnp.asarray(_g3),
                   jnp.asarray(_g0)])   # order: x, y, z, t
EYE4 = jnp.eye(4, dtype=jnp.complex64)


def dslash_flops_per_site() -> int:
    """Standard Wilson D-slash flop count (real ops) per lattice site."""
    return 1320


def dslash_bytes_per_site(real_bytes: int = 8,
                          compressed_links: bool = True) -> int:
    """Streaming traffic per site: 8 neighbor spinor loads + read/write of
    the output spinor (24 reals each) + 8 gauge links.

    CL2QCD stores links compressed to 8 reals and reconstructs SU(3) on the
    fly (Bach et al. [1]) — that compression is what puts the published
    135 GFLOPS at ~80% of the 320 GB/s S9150 bandwidth in fp64."""
    link_reals = 8 if compressed_links else 18
    reals = 8 * 24 + 24 + 24 + 8 * link_reals
    return reals * real_bytes


def dslash(U: jnp.ndarray, psi: jnp.ndarray) -> jnp.ndarray:
    """Apply D-slash with periodic boundaries via jnp.roll (reference)."""
    out = jnp.zeros_like(psi)
    for mu in range(4):
        axis = mu
        g = GAMMA[mu]
        proj_m = EYE4 - g                       # (1 - γ_mu)
        proj_p = EYE4 + g                       # (1 + γ_mu)
        u = U[mu]
        # forward: U_mu(x) psi(x+mu)
        psi_fwd = jnp.roll(psi, -1, axis=axis)
        hop_f = jnp.einsum("...ab,...sb->...sa", u, psi_fwd)
        out = out + jnp.einsum("st,...ta->...sa", proj_m, hop_f)
        # backward: U†_mu(x-mu) psi(x-mu)
        u_bwd = jnp.roll(u, 1, axis=axis)
        psi_bwd = jnp.roll(psi, 1, axis=axis)
        hop_b = jnp.einsum("...ba,...sb->...sa", jnp.conj(u_bwd), psi_bwd)
        out = out + jnp.einsum("st,...ta->...sa", proj_p, hop_b)
    return out


def wilson_matvec(U: jnp.ndarray, psi: jnp.ndarray,
                  kappa: float) -> jnp.ndarray:
    """M ψ = ψ − κ D ψ."""
    return psi - kappa * dslash(U, psi)


# γ5 = γ0 γ1 γ2 γ3 in the Dirac basis: off-diagonal identity blocks
GAMMA5 = jnp.asarray(np.array(
    [[0, 0, 1, 0], [0, 0, 0, 1], [1, 0, 0, 0], [0, 1, 0, 0]], np.complex64))


def wilson_matvec_dagger(U: jnp.ndarray, psi: jnp.ndarray,
                         kappa: float) -> jnp.ndarray:
    """M† ψ via γ5-hermiticity: M† = γ5 M γ5."""
    p = jnp.einsum("st,...ta->...sa", GAMMA5, psi)
    p = wilson_matvec(U, p, kappa)
    return jnp.einsum("st,...ta->...sa", GAMMA5, p)


# ---------------------------------------------------------------------------
# Even-odd (red-black) preconditioning (paper: CL2QCD uses it)
# ---------------------------------------------------------------------------

def parity_mask(shape: Tuple[int, int, int, int]) -> jnp.ndarray:
    """Boolean mask, True on even sites ((x+y+z+t) % 2 == 0)."""
    grids = jnp.meshgrid(*[jnp.arange(s) for s in shape], indexing="ij")
    return (sum(grids) % 2) == 0


def eo_matvec(U: jnp.ndarray, psi_e: jnp.ndarray, kappa: float,
              mask_e: jnp.ndarray) -> jnp.ndarray:
    """Even-odd preconditioned operator  A = 1 − κ² D_eo D_oe  acting on
    even-site spinors (odd entries of psi_e are kept zero)."""
    d1 = dslash(U, psi_e)
    d1 = jnp.where(mask_e[..., None, None], 0.0, d1)   # keep odd part
    d2 = dslash(U, d1)
    d2 = jnp.where(mask_e[..., None, None], d2, 0.0)   # back to even
    return psi_e - (kappa * kappa) * d2


# ---------------------------------------------------------------------------
# Dense cross-check helper (tiny lattices only)
# ---------------------------------------------------------------------------

def dslash_dense_matrix(U: jnp.ndarray) -> np.ndarray:
    """Build the explicit dense D-slash matrix by applying it to basis
    vectors — O((V·12)²) memory; use on ≤ 4⁴ lattices in tests."""
    shape = U.shape[1:5]
    vol = int(np.prod(shape)) * 12
    cols = []
    for i in range(vol):
        e = np.zeros((vol,), np.complex64)
        e[i] = 1.0
        psi = jnp.asarray(e.reshape(shape + (4, 3)))
        cols.append(np.asarray(dslash(U, psi)).reshape(-1))
    return np.stack(cols, axis=1)
