"""Conjugate-gradient inversion of the Dirac operator (paper §Introduction:
'inversion of the Dirac operator ... usually performed by a conjugate
gradient algorithm, which involves a sparse matrix-vector-multiplication
called D-slash').

CGNE on the normal equations M†M x = M† b (M is not hermitian), with the
γ5-hermitian adjoint.  ``jax.lax.while_loop`` keeps it jittable.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.lqcd.dirac import wilson_matvec, wilson_matvec_dagger


class CGResult(NamedTuple):
    x: jnp.ndarray
    iters: jnp.ndarray
    rel_residual: jnp.ndarray
    converged: jnp.ndarray


def _dot(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(jnp.conj(a) * b).real


def cg_solve(matvec: Callable[[jnp.ndarray], jnp.ndarray], b: jnp.ndarray,
             *, tol: float = 1e-6, max_iters: int = 1000) -> CGResult:
    """CG for hermitian positive-definite ``matvec``."""
    b_norm = jnp.sqrt(_dot(b, b))
    x0 = jnp.zeros_like(b)
    r0 = b
    p0 = r0
    rs0 = _dot(r0, r0)

    def cond(state):
        _, _, _, rs, it = state
        return (jnp.sqrt(rs) > tol * b_norm) & (it < max_iters)

    def body(state):
        x, r, p, rs, it = state
        ap = matvec(p)
        alpha = rs / jnp.maximum(_dot(p, ap), 1e-30)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = _dot(r, r)
        beta = rs_new / jnp.maximum(rs, 1e-30)
        p = r + beta * p
        return x, r, p, rs_new, it + 1

    x, r, p, rs, it = jax.lax.while_loop(
        cond, body, (x0, r0, p0, rs0, jnp.zeros((), jnp.int32)))
    rel = jnp.sqrt(rs) / jnp.maximum(b_norm, 1e-30)
    return CGResult(x, it, rel, rel <= tol)


def solve_wilson(U: jnp.ndarray, b: jnp.ndarray, kappa: float, *,
                 tol: float = 1e-6, max_iters: int = 1000) -> CGResult:
    """Solve M x = b for the Wilson operator via CGNE (M†M x = M† b)."""

    def normal_op(v):
        return wilson_matvec_dagger(U, wilson_matvec(U, v, kappa), kappa)

    rhs = wilson_matvec_dagger(U, b, kappa)
    res = cg_solve(normal_op, rhs, tol=tol, max_iters=max_iters)
    # report the true residual of M x = b
    true_r = b - wilson_matvec(U, res.x, kappa)
    rel = jnp.sqrt(_dot(true_r, true_r)) / jnp.sqrt(_dot(b, b))
    return CGResult(res.x, res.iters, rel, rel <= tol * 10)


# ---------------------------------------------------------------------------
# Even-odd preconditioned, mixed-precision solver (paper: CL2QCD strategy)
# ---------------------------------------------------------------------------

class EOCGResult(NamedTuple):
    """Result of the even-odd / mixed-precision solve.

    ``iters`` counts normal-op (A†A) applications — directly comparable to
    ``CGResult.iters`` of the unpreconditioned CGNE, since one Schur normal
    op costs the same D-slash traffic as one full-lattice normal op (two
    half-lattice hops ≡ one full hop, applied twice)."""

    x: jnp.ndarray
    iters: int                   # inner normal-op applications (total)
    outer_iters: int             # defect-correction (reliable-update) steps
    rel_residual: float          # true ‖b − M x‖ / ‖b‖
    converged: bool


def _round_complex(v: jnp.ndarray, dtype) -> jnp.ndarray:
    """Round a complex field through a reduced-precision real dtype.

    JAX has no complex bfloat16, so reduced precision is emulated by
    rounding the re/im planes through ``dtype`` — the storage/traffic model
    of CL2QCD's low-precision inner solver — while arithmetic stays f32."""
    if dtype is None:
        return v
    if jnp.issubdtype(dtype, jnp.complexfloating):
        return v.astype(dtype)
    re = jnp.real(v).astype(dtype).astype(jnp.float32)
    im = jnp.imag(v).astype(dtype).astype(jnp.float32)
    return (re + 1j * im).astype(jnp.complex64)


def solve_wilson_eo(U: jnp.ndarray, b: jnp.ndarray, kappa: float, *,
                    tol: float = 1e-6, max_iters: int = 1000,
                    inner_dtype=None, inner_tol: float = 1e-2,
                    max_outer: int = 30, mesh=None,
                    axis_name: str = "model", overlap: bool = True,
                    backend: str = "jnp") -> EOCGResult:
    """Solve M x = b via the even-odd Schur complement with an (optionally
    mixed-precision) defect-correction CG.

    The Schur system A x_e = b_e + κ D_eo b_o (A = 1 − κ² D_eo D_oe) is
    solved by CGNE on the even half-lattice; odd sites are reconstructed
    exactly as x_o = b_o + κ D_oe x_e, so the full-lattice residual equals
    the even-system residual.  With ``inner_dtype`` set (e.g.
    ``jnp.bfloat16``), the inner CG streams fields rounded through that
    dtype and the outer loop re-computes the residual in f32 and restarts —
    the reliable-update scheme the paper's single/double CG uses.

    With ``mesh`` set, the Schur operators and the whole inner CG run
    T-sharded over the mesh's ``axis_name`` axis
    (:class:`repro.lqcd.multichip_eo.ShardedWilsonEO`): halos overlap
    interior compute (``overlap``), the inner ``while_loop`` stays inside
    one ``shard_map`` with ``psum`` reductions only, and
    ``backend="pallas"`` routes local hops through the autotuned Pallas
    kernel on halo-padded blocks.
    """
    from repro.lqcd.eo import (eo_pack, eo_rhs, eo_unpack, pack_gauge,
                               reconstruct_odd, schur_matvec,
                               schur_matvec_dagger)

    U_e, U_o = pack_gauge(U)
    b_e, b_o = eo_pack(b, 0), eo_pack(b, 1)
    b_norm = float(jnp.sqrt(_dot(b, b)))
    # no low-precision pass gets below its own roundoff; full precision
    # drives straight to tol in one outer sweep
    eta = inner_tol if inner_dtype is not None else tol

    if mesh is not None:
        from repro.lqcd.multichip_eo import ShardedWilsonEO
        hi = ShardedWilsonEO(U_e, U_o, kappa, mesh, axis_name=axis_name,
                             overlap=overlap, backend=backend)
        # the inner CG streams the *rounded* gauge field, like the
        # single-device normal_lo path
        lo = hi if inner_dtype is None else ShardedWilsonEO(
            _round_complex(U_e, inner_dtype), _round_complex(U_o, inner_dtype),
            kappa, mesh, axis_name=axis_name, overlap=overlap,
            backend=backend)
        rhs_e = hi.rhs(b_e, b_o)
        schur = hi.schur
        schur_dagger = hi.schur_dagger

        def run_inner(rhs_n, cap):
            return lo.cg_normal(rhs_n, tol=eta, max_iters=cap,
                                inner_dtype=inner_dtype)
    else:
        rhs_e = eo_rhs(U_e, U_o, b_e, b_o, kappa)

        def schur(v):
            return schur_matvec(U_e, U_o, v, kappa)

        def schur_dagger(v):
            return schur_matvec_dagger(U_e, U_o, v, kappa)

        def normal_hi(v):
            return schur_dagger(schur(v))

        if inner_dtype is not None:
            U_e_lo = _round_complex(U_e, inner_dtype)
            U_o_lo = _round_complex(U_o, inner_dtype)

            def normal_lo(v):
                v = _round_complex(v, inner_dtype)
                av = schur_matvec(U_e_lo, U_o_lo, v, kappa)
                av = _round_complex(av, inner_dtype)
                out = schur_matvec_dagger(U_e_lo, U_o_lo, av, kappa)
                return _round_complex(out, inner_dtype)
        else:
            normal_lo = normal_hi

        def run_inner(rhs_n, cap):
            return cg_solve(normal_lo, rhs_n, tol=eta, max_iters=cap)

    x_e = jnp.zeros_like(rhs_e)
    r_s = rhs_e                              # Schur-system residual
    total_inner = 0
    outer = 0
    while outer < max_outer and total_inner < max_iters:
        rel = float(jnp.sqrt(_dot(r_s, r_s))) / max(b_norm, 1e-30)
        if rel <= tol:
            break
        # inner CG on the defect equation A†A e = A† r_s, reduced precision.
        # Cap each low-precision restart so a stalled inner solve (roundoff
        # plateau above inner_tol) can't eat the whole budget in one round.
        remaining = max_iters - total_inner
        round_cap = (remaining if inner_dtype is None
                     else min(remaining, max(10, max_iters // 5)))
        rhs_n = schur_dagger(r_s)
        inner = run_inner(rhs_n, round_cap)
        total_inner += int(inner.iters)
        x_e = x_e + inner.x
        r_s = rhs_e - schur(x_e)             # recompute in full precision
        outer += 1

    x_o = reconstruct_odd(U_e, U_o, x_e, b_o, kappa)
    x = eo_unpack(x_e, x_o)
    true_r = b - wilson_matvec(U, x, kappa)
    rel = float(jnp.sqrt(_dot(true_r, true_r))) / max(b_norm, 1e-30)
    return EOCGResult(x, total_inner, outer, rel, rel <= tol)


def solve_dirac(U: jnp.ndarray, b: jnp.ndarray, kappa: float, cfg, *,
                mesh=None, axis_name: str = "model", overlap: bool = True,
                backend: str = "jnp"):
    """Config-driven entry point: dispatch on a ``repro.config.SolverConfig``.

    Returns a ``CGResult`` for the plain path and an ``EOCGResult`` for the
    even-odd paths (both expose ``.x``, ``.iters``, ``.rel_residual``,
    ``.converged``).  ``mesh`` routes the even-odd paths through the
    T-sharded multi-chip solver.
    """
    if cfg.preconditioner == "none":
        if mesh is not None:
            raise ValueError("mesh= requires an even-odd preconditioner "
                             "(cfg.preconditioner != 'none')")
        return solve_wilson(U, b, kappa, tol=cfg.tol,
                            max_iters=cfg.max_iters)
    # float32 inner == working precision: not a mixed-precision solve
    inner = None if not cfg.mixed_precision else jnp.dtype(cfg.inner_dtype)
    return solve_wilson_eo(U, b, kappa, tol=cfg.tol,
                           max_iters=cfg.max_iters, inner_dtype=inner,
                           inner_tol=cfg.inner_tol, max_outer=cfg.max_outer,
                           mesh=mesh, axis_name=axis_name, overlap=overlap,
                           backend=backend)
