"""Conjugate-gradient inversion of the Dirac operator (paper §Introduction:
'inversion of the Dirac operator ... usually performed by a conjugate
gradient algorithm, which involves a sparse matrix-vector-multiplication
called D-slash').

CGNE on the normal equations M†M x = M† b (M is not hermitian), with the
γ5-hermitian adjoint.  ``jax.lax.while_loop`` keeps it jittable.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.lqcd.dirac import wilson_matvec, wilson_matvec_dagger


class CGResult(NamedTuple):
    x: jnp.ndarray
    iters: jnp.ndarray
    rel_residual: jnp.ndarray
    converged: jnp.ndarray


def _dot(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(jnp.conj(a) * b).real


def cg_solve(matvec: Callable[[jnp.ndarray], jnp.ndarray], b: jnp.ndarray,
             *, tol: float = 1e-6, max_iters: int = 1000) -> CGResult:
    """CG for hermitian positive-definite ``matvec``."""
    b_norm = jnp.sqrt(_dot(b, b))
    x0 = jnp.zeros_like(b)
    r0 = b
    p0 = r0
    rs0 = _dot(r0, r0)

    def cond(state):
        _, _, _, rs, it = state
        return (jnp.sqrt(rs) > tol * b_norm) & (it < max_iters)

    def body(state):
        x, r, p, rs, it = state
        ap = matvec(p)
        alpha = rs / jnp.maximum(_dot(p, ap), 1e-30)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = _dot(r, r)
        beta = rs_new / jnp.maximum(rs, 1e-30)
        p = r + beta * p
        return x, r, p, rs_new, it + 1

    x, r, p, rs, it = jax.lax.while_loop(
        cond, body, (x0, r0, p0, rs0, jnp.zeros((), jnp.int32)))
    rel = jnp.sqrt(rs) / jnp.maximum(b_norm, 1e-30)
    return CGResult(x, it, rel, rel <= tol)


def solve_wilson(U: jnp.ndarray, b: jnp.ndarray, kappa: float, *,
                 tol: float = 1e-6, max_iters: int = 1000) -> CGResult:
    """Solve M x = b for the Wilson operator via CGNE (M†M x = M† b)."""

    def normal_op(v):
        return wilson_matvec_dagger(U, wilson_matvec(U, v, kappa), kappa)

    rhs = wilson_matvec_dagger(U, b, kappa)
    res = cg_solve(normal_op, rhs, tol=tol, max_iters=max_iters)
    # report the true residual of M x = b
    true_r = b - wilson_matvec(U, res.x, kappa)
    rel = jnp.sqrt(_dot(true_r, true_r)) / jnp.sqrt(_dot(b, b))
    return CGResult(res.x, res.iters, rel, rel <= tol * 10)
