"""Lattice QCD substrate — the L-CSC cluster's primary workload (paper C1).

Wilson-Dirac D-slash (the memory-bound hotspot), even-odd preconditioning,
and a conjugate-gradient solver for the Dirac equation, in JAX.  The Pallas
TPU kernel for D-slash lives in ``repro.kernels.dslash``.
"""
from repro.lqcd.su3 import random_su3_field, su3_project  # noqa: F401
from repro.lqcd.dirac import (  # noqa: F401
    GAMMA,
    dslash,
    wilson_matvec,
    dslash_flops_per_site,
    dslash_bytes_per_site,
)
from repro.lqcd.cg import (  # noqa: F401
    cg_solve,
    solve_dirac,
    solve_wilson,
    solve_wilson_eo,
)
from repro.lqcd.eo import (  # noqa: F401
    dslash_half,
    eo_pack,
    eo_unpack,
    pack_gauge,
    schur_matvec,
)
from repro.lqcd.multichip_eo import (  # noqa: F401
    LQCDCalibration,
    ShardedWilsonEO,
    analytic_lqcd_calibration,
    dslash_half_sharded,
    measured_lqcd_calibration,
)
