"""Even-odd (red-black) site decomposition of the Wilson operator.

The paper's solver-level bandwidth optimization (§Introduction, CL2QCD):
color the lattice by site parity p = (x+y+z+t) mod 2.  D-slash only couples
opposite parities, so in the parity basis the Wilson operator is

    M = [[ 1,        -kappa D_eo ],
         [ -kappa D_oe,        1 ]]

and the Schur complement of the odd block,

    A = M_ee - M_eo M_oo^{-1} M_oe = 1 - kappa^2 D_eo D_oe ,

acts on even sites only.  Solving A x_e = b_e + kappa D_eo b_o and
reconstructing x_o = b_o + kappa D_oe x_e is exactly equivalent to solving
M x = b, but every CG vector is half as long (half the memory traffic of
the bandwidth-bound axpy/dot stream) and A is better conditioned than M,
so CG needs fewer iterations on top.

Compact storage ("checkerboard" layout along x, X even):

    half[i, y, z, t] = full[2*i + ((y + z + t + p) % 2), y, z, t]

i.e. each half-field has shape (X//2, Y, Z, T, ...).  With this layout the
hops of D-slash become:

    y/z/t hops : plain rolls along that axis (the compact x-index of the
                 neighbour is unchanged — see ``_hop_parity`` note);
    x hops     : a roll that applies only where s = (y+z+t+p) % 2 says the
                 neighbour wrapped past a cell boundary.

All functions below are jittable; parities are 0 = even, 1 = odd.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.lqcd.dirac import EYE4, GAMMA, GAMMA5

PROJ_M = jnp.stack([EYE4 - GAMMA[mu] for mu in range(4)])   # (1 - gamma_mu)
PROJ_P = jnp.stack([EYE4 + GAMMA[mu] for mu in range(4)])   # (1 + gamma_mu)


def mv(u, v):                         # U_ab psi_sb -> psi_sa
    return jnp.einsum("...ab,...sb->...sa", u, v)


def mv_dag(u, v):                     # (U^dagger)_ab psi_sb
    return jnp.einsum("...ba,...sb->...sa", jnp.conj(u), v)


def spin(proj, v):
    return jnp.einsum("st,...ta->...sa", proj, v)


def _sublattice_offset(shape: Tuple[int, ...], parity: int) -> np.ndarray:
    """s(y,z,t) = (y+z+t+parity) % 2 — the x offset of the first site of
    ``parity`` on each (y,z,t) line.  Static numpy, shape (1, Y, Z, T)."""
    _, Y, Z, T = shape[:4]
    y, z, t = np.indices((Y, Z, T))
    return ((y + z + t + parity) % 2)[None]


def eo_pack(field: jnp.ndarray, parity: int) -> jnp.ndarray:
    """Gather the ``parity`` sites of a full-lattice field (site axes lead)
    into the compact (X//2, Y, Z, T, ...) layout."""
    X = field.shape[0]
    if X % 2:
        raise ValueError(
            f"even-odd packing needs an even x extent, got X={X}")
    s = _sublattice_offset(field.shape, parity)
    x_idx = 2 * np.arange(X // 2)[:, None, None, None] + s[0]
    y, z, t = np.indices(field.shape[1:4])
    return field[x_idx, y[None], z[None], t[None]]


def eo_unpack(half_e: jnp.ndarray, half_o: jnp.ndarray) -> jnp.ndarray:
    """Interleave compact even/odd half-fields back into a full field."""
    Xh, Y, Z, T = half_e.shape[:4]
    full = jnp.zeros((2 * Xh,) + half_e.shape[1:], half_e.dtype)
    y, z, t = np.indices((Y, Z, T))
    for parity, half in ((0, half_e), (1, half_o)):
        s = _sublattice_offset((2 * Xh, Y, Z, T), parity)
        x_idx = 2 * np.arange(Xh)[:, None, None, None] + s[0]
        full = full.at[x_idx, y[None], z[None], t[None]].set(half)
    return full


def pack_gauge(U: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Split a (4, X, Y, Z, T, 3, 3) gauge field into per-parity halves of
    shape (4, X//2, Y, Z, T, 3, 3)."""
    Ue = jnp.stack([eo_pack(U[mu], 0) for mu in range(4)])
    Uo = jnp.stack([eo_pack(U[mu], 1) for mu in range(4)])
    return Ue, Uo


def _x_neighbors(src: jnp.ndarray, s_out: jnp.ndarray):
    """Compact +x / -x neighbours of the opposite-parity field ``src`` as
    seen from output sites with offset pattern ``s_out``.

    Output site x = 2i + s_out; its +x neighbour lives at compact index
    i + s_out in the source half-field, its -x neighbour at i + s_out - 1.
    """
    cond = s_out[..., None, None].astype(bool)
    fwd = jnp.where(cond, jnp.roll(src, -1, axis=0), src)
    bwd = jnp.where(cond, src, jnp.roll(src, 1, axis=0))
    return fwd, bwd


def hops_spatial(U_out: jnp.ndarray, U_src: jnp.ndarray, psi: jnp.ndarray,
                 s_out: jnp.ndarray) -> jnp.ndarray:
    """x/y/z hop contributions of one parity block (compact layout).

    ``s_out`` is the output-parity offset pattern: static numpy on the
    single-device path, a traced (global-t aware) array on the T-sharded
    path (:mod:`repro.lqcd.multichip_eo`) — x/y/z hops never cross the
    sharded T axis, so they are identical in both settings.
    """
    # x direction: s-conditional rolls for spinors and the backward link
    psi_fwd, psi_bwd = _x_neighbors(psi, s_out)
    # the -x link sits at the source site = the bwd neighbour's own site
    cond = s_out[..., None, None].astype(bool)
    u_bwd_x = jnp.where(cond, U_src[0], jnp.roll(U_src[0], 1, axis=0))
    out = spin(PROJ_M[0], mv(U_out[0], psi_fwd))
    out = out + spin(PROJ_P[0], mv_dag(u_bwd_x, psi_bwd))

    # y/z directions: plain rolls (axis 1..2 of the compact layout)
    for mu in (1, 2):
        psi_f = jnp.roll(psi, -1, axis=mu)
        psi_b = jnp.roll(psi, 1, axis=mu)
        u_b = jnp.roll(U_src[mu], 1, axis=mu)
        out = out + spin(PROJ_M[mu], mv(U_out[mu], psi_f))
        out = out + spin(PROJ_P[mu], mv_dag(u_b, psi_b))
    return out


def dslash_half(U_out: jnp.ndarray, U_src: jnp.ndarray, psi: jnp.ndarray,
                src_parity: int) -> jnp.ndarray:
    """One parity block of D-slash: input ``psi`` lives on ``src_parity``
    sites, output on the opposite parity.  ``U_out``/``U_src`` are the
    packed gauge halves of the output/source parity.

    y/z/t hops are plain rolls because a unit hop in those directions flips
    the parity but leaves the compact x-index unchanged (the offset pattern
    s absorbs the parity flip).  x hops use the s-conditional roll.
    """
    out_parity = 1 - src_parity
    s_out = jnp.asarray(_sublattice_offset(
        (2 * psi.shape[0],) + psi.shape[1:4], out_parity)[0])

    out = hops_spatial(U_out, U_src, psi, s_out)

    # t direction: plain rolls (axis 3 of the compact layout)
    mu = 3
    psi_f = jnp.roll(psi, -1, axis=mu)
    psi_b = jnp.roll(psi, 1, axis=mu)
    u_b = jnp.roll(U_src[mu], 1, axis=mu)
    out = out + spin(PROJ_M[mu], mv(U_out[mu], psi_f))
    out = out + spin(PROJ_P[mu], mv_dag(u_b, psi_b))
    return out


def schur_matvec(U_e: jnp.ndarray, U_o: jnp.ndarray, psi_e: jnp.ndarray,
                 kappa: float) -> jnp.ndarray:
    """A psi_e = (1 - kappa^2 D_eo D_oe) psi_e on the even half-lattice."""
    d_oe = dslash_half(U_o, U_e, psi_e, src_parity=0)   # even -> odd
    d_eo = dslash_half(U_e, U_o, d_oe, src_parity=1)    # odd -> even
    return psi_e - (kappa * kappa) * d_eo


def schur_matvec_dagger(U_e: jnp.ndarray, U_o: jnp.ndarray,
                        psi_e: jnp.ndarray, kappa: float) -> jnp.ndarray:
    """A^dagger via gamma5-hermiticity: A^dagger = gamma5 A gamma5 (the
    parity projection commutes with gamma5, so the identity survives the
    Schur reduction)."""
    g5 = lambda v: jnp.einsum("st,...ta->...sa", GAMMA5, v)  # noqa: E731
    return g5(schur_matvec(U_e, U_o, g5(psi_e), kappa))


def eo_rhs(U_e: jnp.ndarray, U_o: jnp.ndarray, b_e: jnp.ndarray,
           b_o: jnp.ndarray, kappa: float) -> jnp.ndarray:
    """Even-system right-hand side b'_e = b_e + kappa D_eo b_o."""
    return b_e + kappa * dslash_half(U_e, U_o, b_o, src_parity=1)


def reconstruct_odd(U_e: jnp.ndarray, U_o: jnp.ndarray, x_e: jnp.ndarray,
                    b_o: jnp.ndarray, kappa: float) -> jnp.ndarray:
    """Back-substitute the odd sites: x_o = b_o + kappa D_oe x_e."""
    return b_o + kappa * dslash_half(U_o, U_e, x_e, src_parity=0)
