"""Multi-chip D-slash: lattice time-axis sharded over the model axis with
halo exchange via ``collective_permute`` (the paper's multi-GPU lattice mode;
published observation: ~20% slowdown vs single-GPU — our ICI roofline model
re-derives that in ``benchmarks/paper_tables.py::dslash_bw``).

Wire-traffic optimization (CL2QCD does the same on PCIe): the Wilson
projector ``(1 ∓ γ_t)`` in the Dirac basis is ``diag(0,0,2,2)`` /
``diag(2,2,0,0)``, so only two of the four spin components of a halo
slice ever enter the t-direction hop.  With ``compress=True`` (default)
only those two components cross the wire — half the spinor halo bytes —
and the result is **bit-identical** in f32, because the dropped einsum
terms were exact zero-adds.
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.lqcd.dirac import EYE4, GAMMA

T_AX = 3


@lru_cache(maxsize=None)
def halo_perms(n: int):
    """Static ``ppermute`` permutation tables for a ring of ``n`` shards.

    ``fwd`` sends each shard's first T-slice to its predecessor (so every
    shard *receives from its successor*); ``bwd`` the reverse.  Cached per
    axis size so the traced halo exchange stays allocation-free instead of
    rebuilding the Python pair lists on every call.
    """
    fwd = tuple((i, (i - 1) % n) for i in range(n))   # to prev
    bwd = tuple((i, (i + 1) % n) for i in range(n))   # to next
    return fwd, bwd


def _halo_exchange(x: jnp.ndarray, axis_name: str, t_axis: int):
    """Returns (from_next_first_slice, from_prev_last_slice)."""
    from repro.compat import axis_size
    fwd_perm, bwd_perm = halo_perms(axis_size(axis_name))
    first = jax.lax.slice_in_dim(x, 0, 1, axis=t_axis)
    last = jax.lax.slice_in_dim(x, x.shape[t_axis] - 1, x.shape[t_axis],
                                axis=t_axis)
    from_next = jax.lax.ppermute(first, axis_name, fwd_perm)
    from_prev = jax.lax.ppermute(last, axis_name, bwd_perm)
    return from_next, from_prev


def scatter_spin(v: jnp.ndarray, lo: int) -> jnp.ndarray:
    """Expand a 2-spin-component field ``(..., 2, 3)`` back to 4 spin
    components, placing it at spin positions ``lo:lo+2`` (zeros elsewhere)."""
    z = jnp.zeros(v.shape[:-2] + (4,) + v.shape[-1:], v.dtype)
    return jax.lax.dynamic_update_slice_in_dim(z, v, lo, axis=-2)


def _dslash_local(U_loc: jnp.ndarray, psi_loc: jnp.ndarray,
                  axis_name: str, compress: bool) -> jnp.ndarray:
    """D-slash body on a T-sharded block: x/y/z via local rolls; T via halos."""
    out = jnp.zeros_like(psi_loc)
    # spatial directions: fully local (periodic within the global lattice —
    # x/y/z are unsharded)
    for mu in range(3):
        g = GAMMA[mu]
        u = U_loc[mu]
        psi_f = jnp.roll(psi_loc, -1, axis=mu)
        hop_f = jnp.einsum("...ab,...sb->...sa", u, psi_f)
        out = out + jnp.einsum("st,...ta->...sa", EYE4 - g, hop_f)
        u_b = jnp.roll(u, 1, axis=mu)
        psi_b = jnp.roll(psi_loc, 1, axis=mu)
        hop_b = jnp.einsum("...ba,...sb->...sa", jnp.conj(u_b), psi_b)
        out = out + jnp.einsum("st,...ta->...sa", EYE4 + g, hop_b)
    # time direction: halo exchange over the mesh axis
    g = GAMMA[3]
    u_t = U_loc[3]
    Tl = psi_loc.shape[T_AX]

    if compress:
        # spin-projected halos: the +t hop applies (1 - γ_t) = diag(0,0,2,2)
        # so the neighbour slice only contributes spin components 2,3; the
        # -t hop applies (1 + γ_t) = diag(2,2,0,0) → components 0,1.  Send
        # exactly those (half the spinor wire bytes), zero-fill the dropped
        # components on arrival, and run the *identical* hop assembly below
        # — the projector annihilates the zero-filled components, so the
        # result is bit-compatible with the full-slice exchange.  Bonus:
        # only one gauge ppermute (the -t hop's last link slice) instead of
        # the uncompressed path's two.
        from repro.compat import axis_size
        fwd_perm, bwd_perm = halo_perms(axis_size(axis_name))
        send_f = jax.lax.slice_in_dim(psi_loc, 0, 1, axis=T_AX)[..., 2:4, :]
        send_b = jax.lax.slice_in_dim(psi_loc, Tl - 1, Tl,
                                      axis=T_AX)[..., 0:2, :]
        psi_next = scatter_spin(
            jax.lax.ppermute(send_f, axis_name, fwd_perm), 2)
        psi_prev = scatter_spin(
            jax.lax.ppermute(send_b, axis_name, bwd_perm), 0)
        u_last = jax.lax.slice_in_dim(u_t, Tl - 1, Tl, axis=T_AX)
        u_prev_last = jax.lax.ppermute(u_last, axis_name, bwd_perm)
    else:
        psi_next, psi_prev = _halo_exchange(psi_loc, axis_name, T_AX)
        u_prev_last = _halo_exchange(u_t, axis_name, T_AX)[1]
    psi_f = jnp.concatenate(
        [jax.lax.slice_in_dim(psi_loc, 1, Tl, axis=T_AX), psi_next],
        axis=T_AX)
    hop_f = jnp.einsum("...ab,...sb->...sa", u_t, psi_f)
    out = out + jnp.einsum("st,...ta->...sa", EYE4 - g, hop_f)
    psi_b = jnp.concatenate(
        [psi_prev,
         jax.lax.slice_in_dim(psi_loc, 0, Tl - 1, axis=T_AX)], axis=T_AX)
    u_b = jnp.concatenate(
        [u_prev_last,
         jax.lax.slice_in_dim(u_t, 0, Tl - 1, axis=T_AX)], axis=T_AX)
    hop_b = jnp.einsum("...ba,...sb->...sa", jnp.conj(u_b), psi_b)
    out = out + jnp.einsum("st,...ta->...sa", EYE4 + g, hop_b)
    return out


def dslash_sharded(U: jnp.ndarray, psi: jnp.ndarray, mesh,
                   axis_name: str = "model",
                   compress: bool = True) -> jnp.ndarray:
    """D-slash with the lattice T axis sharded over ``axis_name``.

    ``compress=False`` keeps the full-4-spinor halo exchange (reference
    for the bit-compatibility test); the default sends the two
    spin-projected components only.
    """
    u_spec = P(None, None, None, None, axis_name, None, None)
    psi_spec = P(None, None, None, axis_name, None, None)
    from repro.compat import shard_map
    return shard_map(
        partial(_dslash_local, axis_name=axis_name, compress=compress),
        mesh=mesh, in_specs=(u_spec, psi_spec), out_specs=psi_spec,
        check_vma=False)(U, psi)
