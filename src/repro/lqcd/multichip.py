"""Multi-chip D-slash: lattice time-axis sharded over the model axis with
halo exchange via ``collective_permute`` (the paper's multi-GPU lattice mode;
published observation: ~20% slowdown vs single-GPU — our ICI roofline model
re-derives that in benchmarks/dslash_bw.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.lqcd.dirac import EYE4, GAMMA


def _halo_exchange(x: jnp.ndarray, axis_name: str, t_axis: int):
    """Returns (from_next_first_slice, from_prev_last_slice)."""
    from repro.compat import axis_size
    n = axis_size(axis_name)
    fwd_perm = [(int(i), int((i - 1) % n)) for i in range(n)]   # to prev
    bwd_perm = [(int(i), int((i + 1) % n)) for i in range(n)]   # to next
    first = jax.lax.slice_in_dim(x, 0, 1, axis=t_axis)
    last = jax.lax.slice_in_dim(x, x.shape[t_axis] - 1, x.shape[t_axis],
                                axis=t_axis)
    from_next = jax.lax.ppermute(first, axis_name, fwd_perm)
    from_prev = jax.lax.ppermute(last, axis_name, bwd_perm)
    return from_next, from_prev


def _dslash_local(U_loc: jnp.ndarray, psi_loc: jnp.ndarray,
                  axis_name: str) -> jnp.ndarray:
    """D-slash body on a T-sharded block: x/y/z via local rolls; T via halos."""
    out = jnp.zeros_like(psi_loc)
    # spatial directions: fully local (periodic within the global lattice —
    # x/y/z are unsharded)
    for mu in range(3):
        g = GAMMA[mu]
        u = U_loc[mu]
        psi_f = jnp.roll(psi_loc, -1, axis=mu)
        hop_f = jnp.einsum("...ab,...sb->...sa", u, psi_f)
        out = out + jnp.einsum("st,...ta->...sa", EYE4 - g, hop_f)
        u_b = jnp.roll(u, 1, axis=mu)
        psi_b = jnp.roll(psi_loc, 1, axis=mu)
        hop_b = jnp.einsum("...ba,...sb->...sa", jnp.conj(u_b), psi_b)
        out = out + jnp.einsum("st,...ta->...sa", EYE4 + g, hop_b)
    # time direction: halo exchange over the mesh axis
    T_AX = 3
    g = GAMMA[3]
    u_t = U_loc[3]
    psi_next, psi_prev = _halo_exchange(psi_loc, axis_name, T_AX)
    u_prev_last = _halo_exchange(u_t, axis_name, T_AX)[1]
    psi_f = jnp.concatenate(
        [jax.lax.slice_in_dim(psi_loc, 1, psi_loc.shape[T_AX], axis=T_AX),
         psi_next], axis=T_AX)
    hop_f = jnp.einsum("...ab,...sb->...sa", u_t, psi_f)
    out = out + jnp.einsum("st,...ta->...sa", EYE4 - g, hop_f)
    psi_b = jnp.concatenate(
        [psi_prev,
         jax.lax.slice_in_dim(psi_loc, 0, psi_loc.shape[T_AX] - 1,
                              axis=T_AX)], axis=T_AX)
    u_b = jnp.concatenate(
        [u_prev_last,
         jax.lax.slice_in_dim(u_t, 0, u_t.shape[T_AX] - 1, axis=T_AX)],
        axis=T_AX)
    hop_b = jnp.einsum("...ba,...sb->...sa", jnp.conj(u_b), psi_b)
    out = out + jnp.einsum("st,...ta->...sa", EYE4 + g, hop_b)
    return out


def dslash_sharded(U: jnp.ndarray, psi: jnp.ndarray, mesh,
                   axis_name: str = "model") -> jnp.ndarray:
    """D-slash with the lattice T axis sharded over ``axis_name``."""
    u_spec = P(None, None, None, None, axis_name, None, None)
    psi_spec = P(None, None, None, axis_name, None, None)
    from repro.compat import shard_map
    return shard_map(
        partial(_dslash_local, axis_name=axis_name),
        mesh=mesh, in_specs=(u_spec, psi_spec), out_specs=psi_spec,
        check_vma=False)(U, psi)
