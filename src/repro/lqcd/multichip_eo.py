"""Multi-chip even-odd D-slash and CG: the compact checkerboarded
half-lattices T-sharded over the device mesh, with the halo exchange
*overlapped* against interior compute.

This is the paper's production configuration — multi-GPU LQCD chosen for
memory bandwidth — applied to the even-odd solver of :mod:`repro.lqcd.eo`:

  * Each T-shard owns a ``(X/2, Y, Z, T/n)`` block of both parity
    half-fields.  x/y/z hops never cross the shard boundary (those axes
    are unsharded), so they are **interior** work; only the ±t hops touch
    neighbour shards.
  * Per half-hop, exactly two ``ppermute`` messages cross the wire — the
    two *spin-projected* components the Wilson projector keeps
    (``(1 ∓ γ_t)`` is ``diag(0,0,2,2)`` / ``diag(2,2,0,0)`` in the Dirac
    basis), i.e. half a spinor slice each way and **no gauge traffic**:
    the neighbour's last +t link slice is loop-invariant and gathered
    host-side once per gauge field (``_prev_t_links``).
  * With ``overlap=True`` (default) the ``ppermute``\\ s are issued first,
    the interior terms (x/y/z hops plus the on-shard part of the t hops)
    are computed while the halos are in flight, and the two boundary
    T-rows are filled in when the results land.  ``overlap=False`` is the
    halo-then-compute baseline: full-spinor halos, an
    ``optimization_barrier`` pinning all compute behind the exchange, and
    concat-assembled neighbour arrays — the shape QCDOC
    (hep-lat/0306023) and Ibrahim et al. (arXiv:0808.0391) show you must
    *not* ship at scale.  The boundary rows re-apply the identical
    projector∘link composition on the zero-filled halo, so both variants
    agree to f32 roundoff (bitwise, in practice, on the CPU test mesh).

The inner CG runs **fully sharded**: the entire ``while_loop`` executes
inside one ``shard_map``, with ``psum`` only for the reduction scalars
(dot products and norms) — vectors never leave their shards.

``measured_lqcd_calibration`` closes the loop with the cluster layer:
it times the executed sharded normal op, emits the run onto the PR-3
telemetry bus, and returns an :class:`LQCDCalibration` that
``repro.cluster.workload.LQCDSolveWorkload`` can consume in place of the
analytic S9150 roofline.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.lqcd.cg import CGResult, _round_complex
from repro.lqcd.dirac import (GAMMA5, dslash_bytes_per_site,
                              dslash_flops_per_site)
from repro.lqcd.eo import (PROJ_M, PROJ_P, _sublattice_offset, hops_spatial,
                           mv, mv_dag, spin)
from repro.lqcd.multichip import T_AX, halo_perms, scatter_spin

__all__ = [
    "LQCDCalibration",
    "ShardedWilsonEO",
    "analytic_lqcd_calibration",
    "dslash_half_sharded",
    "measured_lqcd_calibration",
]


# ---------------------------------------------------------------------------
# Host-side, loop-invariant preparation
# ---------------------------------------------------------------------------

def _prev_t_links(U_half: jnp.ndarray, n_shards: int) -> jnp.ndarray:
    """Per-shard copy of the *previous* shard's last +t link slice.

    The -t hop at a shard's first T-row needs the source-parity gauge link
    at global ``t = j*T_local - 1``.  The gauge field is constant across a
    solve, so this is a host-side gather of shape ``(Xh, Y, Z, n, 3, 3)``
    (sharded over its n axis) — no gauge ``ppermute`` per matvec, unlike
    the full-lattice path in :mod:`repro.lqcd.multichip`.
    """
    T = U_half.shape[4]
    t_local = T // n_shards
    idx = (np.arange(n_shards) * t_local - 1) % T
    return U_half[3][:, :, :, idx]


def _padded_gauge(U_half: jnp.ndarray, n_shards: int) -> jnp.ndarray:
    """Halo-padded gauge half for the Pallas backend: each shard's local
    T extent grows to ``T_local + 2`` with the periodic neighbour slices
    baked in (global shape ``(4, Xh, Y, Z, n*(T_local+2), 3, 3)``)."""
    T = U_half.shape[4]
    t_local = T // n_shards
    idx = np.concatenate(
        [np.r_[(s - 1) % T, np.arange(s, s + t_local), (s + t_local) % T]
         for s in np.arange(n_shards) * t_local])
    return U_half[:, :, :, :, idx]


# ---------------------------------------------------------------------------
# Local (per-shard) hop bodies
# ---------------------------------------------------------------------------

def _half_hop_local(U_out: jnp.ndarray, U_src: jnp.ndarray,
                    u_prev: jnp.ndarray, psi: jnp.ndarray, *,
                    out_parity: int, axis_name: str, n_shards: int,
                    overlap: bool) -> jnp.ndarray:
    """One parity block of D-slash on a T-shard (compact layout).

    ``u_prev`` is the precomputed previous-shard last +t link slice of the
    *source* parity, local shape ``(Xh, Y, Z, 1, 3, 3)``.
    """
    Xh, Y, Z, Tl = psi.shape[:4]
    # the parity offset pattern s = (y+z+t+parity) % 2 depends on *global*
    # t; shift the local pattern by this shard's T offset (traced — shards
    # with odd T_local alternate patterns, e.g. 8^4 over 8 devices)
    s_base = jnp.asarray(_sublattice_offset((2 * Xh, Y, Z, Tl),
                                            out_parity)[0])
    t0 = jax.lax.axis_index(axis_name) * Tl
    s_out = (s_base + t0) % 2

    fwd_perm, bwd_perm = halo_perms(n_shards)

    if overlap:
        # launch the wire traffic first: spin-projected half-spinor slices
        send_f = jax.lax.slice_in_dim(psi, 0, 1, axis=T_AX)[..., 2:4, :]
        send_b = jax.lax.slice_in_dim(psi, Tl - 1, Tl, axis=T_AX)[..., 0:2, :]
        from_next = jax.lax.ppermute(send_f, axis_name, fwd_perm)
        from_prev = jax.lax.ppermute(send_b, axis_name, bwd_perm)

        # interior: x/y/z hops and the on-shard t hops, while halos fly
        u_t = U_out[3]
        u_last = jax.lax.slice_in_dim(u_t, Tl - 1, Tl, axis=T_AX)
        out = hops_spatial(U_out, U_src, psi, s_out)
        f_int = spin(PROJ_M[3], mv(
            jax.lax.slice_in_dim(u_t, 0, Tl - 1, axis=T_AX),
            jax.lax.slice_in_dim(psi, 1, Tl, axis=T_AX)))
        b_int = spin(PROJ_P[3], mv_dag(
            jax.lax.slice_in_dim(U_src[3], 0, Tl - 1, axis=T_AX),
            jax.lax.slice_in_dim(psi, 0, Tl - 1, axis=T_AX)))

        # boundary rows as the halos land: zero-fill the dropped spin
        # components and apply the same projector∘link composition as the
        # interior — the projector annihilates the zero fill exactly
        f_bnd = spin(PROJ_M[3], mv(u_last, scatter_spin(from_next, 2)))
        b_bnd = spin(PROJ_P[3], mv_dag(u_prev, scatter_spin(from_prev, 0)))
        out = out + jnp.concatenate([f_int, f_bnd], axis=T_AX)
        out = out + jnp.concatenate([b_bnd, b_int], axis=T_AX)
        return out

    # halo-then-compute baseline: full-spinor halos, everything serialized
    # behind the exchange, neighbour arrays materialized by concat
    first = jax.lax.slice_in_dim(psi, 0, 1, axis=T_AX)
    last = jax.lax.slice_in_dim(psi, Tl - 1, Tl, axis=T_AX)
    from_next = jax.lax.ppermute(first, axis_name, fwd_perm)
    from_prev = jax.lax.ppermute(last, axis_name, bwd_perm)
    psi, from_next, from_prev, U_out, U_src, u_prev = \
        jax.lax.optimization_barrier(
            (psi, from_next, from_prev, U_out, U_src, u_prev))
    u_t = U_out[3]
    out = hops_spatial(U_out, U_src, psi, s_out)
    psi_f = jnp.concatenate(
        [jax.lax.slice_in_dim(psi, 1, Tl, axis=T_AX), from_next], axis=T_AX)
    out = out + spin(PROJ_M[3], mv(u_t, psi_f))
    psi_b = jnp.concatenate(
        [from_prev, jax.lax.slice_in_dim(psi, 0, Tl - 1, axis=T_AX)],
        axis=T_AX)
    u_b = jnp.concatenate(
        [u_prev, jax.lax.slice_in_dim(U_src[3], 0, Tl - 1, axis=T_AX)],
        axis=T_AX)
    out = out + spin(PROJ_P[3], mv_dag(u_b, psi_b))
    return out


def _half_hop_pallas_local(U_out_pad: jnp.ndarray, U_src_pad: jnp.ndarray,
                           psi: jnp.ndarray, *, src_parity_eff: int,
                           t_block: int, interpret: bool, axis_name: str,
                           n_shards: int) -> jnp.ndarray:
    """Per-shard hop through the Pallas EO kernel on halo-padded fields.

    The spinor halos still cross the wire spin-projected (half slices);
    the dropped components are zero-filled before padding — exact, since
    the kernel's t-projectors annihilate them.  The kernel's periodic
    halo index maps only wrap on the pad rows, which are cropped.
    ``src_parity_eff`` absorbs the pad's t-shift of 1 (requires even
    ``T_local`` so every shard sees the same static parity).
    """
    from repro.kernels.dslash.kernel import dslash_eo_split
    from repro.kernels.dslash.ref import from_split, to_split

    Tl = psi.shape[T_AX]
    fwd_perm, bwd_perm = halo_perms(n_shards)
    send_f = jax.lax.slice_in_dim(psi, 0, 1, axis=T_AX)[..., 2:4, :]
    send_b = jax.lax.slice_in_dim(psi, Tl - 1, Tl, axis=T_AX)[..., 0:2, :]
    from_next = jax.lax.ppermute(send_f, axis_name, fwd_perm)
    from_prev = jax.lax.ppermute(send_b, axis_name, bwd_perm)
    psi_pad = jnp.concatenate(
        [scatter_spin(from_prev, 0), psi, scatter_spin(from_next, 2)],
        axis=T_AX)
    out_pad = from_split(dslash_eo_split(
        to_split(U_out_pad), to_split(U_src_pad), to_split(psi_pad),
        src_parity_eff, t_block=t_block, interpret=interpret))
    return jax.lax.slice_in_dim(out_pad, 1, Tl + 1, axis=T_AX)


# ---------------------------------------------------------------------------
# The gauge-bound sharded operator set
# ---------------------------------------------------------------------------

class ShardedWilsonEO:
    """T-sharded even-odd Wilson operator set, bound to one gauge field.

    Construction precomputes everything loop-invariant: the
    previous-shard +t link slices (jnp backend) or the halo-padded gauge
    halves plus the autotuned ``t_block`` for the padded local volume
    (``backend="pallas"``).  All public methods take and return *global*
    compact arrays; the inner CG (:meth:`cg_normal`) runs its whole
    ``while_loop`` inside one ``shard_map`` with ``psum`` reductions.
    """

    def __init__(self, U_e: jnp.ndarray, U_o: jnp.ndarray, kappa: float,
                 mesh, *, axis_name: str = "model", overlap: bool = True,
                 backend: str = "jnp"):
        if backend not in ("jnp", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        self.mesh, self.axis_name = mesh, axis_name
        self.kappa = float(kappa)
        self.overlap = bool(overlap)
        self.backend = backend
        self.n = int(np.prod(mesh.shape[axis_name]))
        T = int(U_e.shape[4])
        if T % self.n:
            raise ValueError(
                f"lattice T extent {T} is not divisible by the "
                f"{self.n}-way mesh axis {axis_name!r}")
        self.t_local = T // self.n
        self.U_e, self.U_o = U_e, U_o

        from repro.distributed.sharding import lattice_eo_specs
        self._u_spec, self._p_spec = lattice_eo_specs(axis_name)
        if backend == "pallas":
            if self.t_local % 2:
                raise ValueError(
                    "backend='pallas' needs an even local T extent (the "
                    f"halo pad shifts parity per shard); got T_local="
                    f"{self.t_local}")
            from repro.kernels.dslash.ops import sharded_t_block
            self._t_block = sharded_t_block(
                tuple(U_e.shape[1:4]) + (self.t_local + 2,))
            self._interpret = jax.default_backend() != "tpu"
            self._gauge_args = (_padded_gauge(U_e, self.n),
                                _padded_gauge(U_o, self.n))
            self._gauge_specs = (self._u_spec, self._u_spec)
        else:
            self._gauge_args = (U_e, U_o,
                                _prev_t_links(U_e, self.n),
                                _prev_t_links(U_o, self.n))
            self._gauge_specs = (self._u_spec, self._u_spec,
                                 self._p_spec, self._p_spec)
        self._jit_cache: dict = {}

    # -- local-body plumbing ------------------------------------------------

    def _make_hop(self, gauge_local):
        """Per-shard ``hop(v, src_parity)`` closure over local gauge."""
        if self.backend == "pallas":
            U_e_pad, U_o_pad = gauge_local

            def hop(v, src_parity):
                u_out, u_src = ((U_o_pad, U_e_pad) if src_parity == 0
                                else (U_e_pad, U_o_pad))
                return _half_hop_pallas_local(
                    u_out, u_src, v, src_parity_eff=1 - src_parity,
                    t_block=self._t_block, interpret=self._interpret,
                    axis_name=self.axis_name, n_shards=self.n)
            return hop

        U_e, U_o, up_e, up_o = gauge_local

        def hop(v, src_parity):
            u_out, u_src, u_prev = ((U_o, U_e, up_e) if src_parity == 0
                                    else (U_e, U_o, up_o))
            return _half_hop_local(
                u_out, u_src, u_prev, v, out_parity=1 - src_parity,
                axis_name=self.axis_name, n_shards=self.n,
                overlap=self.overlap)
        return hop

    def _schur_from_hop(self, hop, v):
        d = hop(v, 0)                        # even -> odd
        d = hop(d, 1)                        # odd -> even
        return v - (self.kappa * self.kappa) * d

    def _shmap(self, f, in_specs, out_specs):
        from repro.compat import shard_map
        return shard_map(f, mesh=self.mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)

    def _jitted(self, key, build):
        fn = self._jit_cache.get(key)
        if fn is None:
            fn = self._jit_cache[key] = build()
        return fn

    def _vec_fn(self, kind: str):
        """Jitted shard_map for one of the vector→vector operators."""
        def build():
            ng = len(self._gauge_specs)

            def body(*args):
                hop = self._make_hop(args[:ng])
                v = args[ng]
                g5 = lambda w: spin(GAMMA5, w)              # noqa: E731
                if kind == "hop_e":
                    return hop(v, 0)
                if kind == "hop_o":
                    return hop(v, 1)
                if kind == "schur":
                    return self._schur_from_hop(hop, v)
                if kind == "schur_dagger":
                    return g5(self._schur_from_hop(hop, g5(v)))
                # normal op A†A — the unit the calibration times
                av = self._schur_from_hop(hop, v)
                return g5(self._schur_from_hop(hop, g5(av)))

            return jax.jit(self._shmap(
                body, in_specs=self._gauge_specs + (self._p_spec,),
                out_specs=self._p_spec))
        return self._jitted(kind, build)

    # -- public operators (global compact arrays) ---------------------------

    def dslash_half(self, psi: jnp.ndarray, src_parity: int) -> jnp.ndarray:
        """Sharded equivalent of :func:`repro.lqcd.eo.dslash_half` (with
        the gauge halves bound at construction)."""
        kind = "hop_e" if src_parity == 0 else "hop_o"
        return self._vec_fn(kind)(*self._gauge_args, psi)

    def schur(self, psi_e: jnp.ndarray) -> jnp.ndarray:
        return self._vec_fn("schur")(*self._gauge_args, psi_e)

    def schur_dagger(self, psi_e: jnp.ndarray) -> jnp.ndarray:
        return self._vec_fn("schur_dagger")(*self._gauge_args, psi_e)

    def normal(self, psi_e: jnp.ndarray) -> jnp.ndarray:
        """A†A in one fused sharded call (calibration/benchmark unit)."""
        return self._vec_fn("normal")(*self._gauge_args, psi_e)

    def rhs(self, b_e: jnp.ndarray, b_o: jnp.ndarray) -> jnp.ndarray:
        """Even-system right-hand side b'_e = b_e + κ D_eo b_o."""
        return b_e + self.kappa * self.dslash_half(b_o, 1)

    def reconstruct(self, x_e: jnp.ndarray, b_o: jnp.ndarray) -> jnp.ndarray:
        """Back-substitute the odd sites: x_o = b_o + κ D_oe x_e."""
        return b_o + self.kappa * self.dslash_half(x_e, 0)

    # -- fully-sharded inner CG --------------------------------------------

    def cg_normal(self, b: jnp.ndarray, *, tol: float, max_iters: int,
                  inner_dtype=None) -> CGResult:
        """CGNE on A†A with the entire iteration inside one ``shard_map``:
        vectors stay sharded for the whole ``while_loop``; only the
        reduction scalars cross the mesh (``psum``).  ``inner_dtype``
        rounds fields exactly like the single-device ``normal_lo`` path.
        """
        dt_key = None if inner_dtype is None else jnp.dtype(inner_dtype).name

        def build():
            ng = len(self._gauge_specs)
            ax = self.axis_name

            def body(*args):
                hop = self._make_hop(args[:ng])
                b_loc, tol_a, cap_a = args[ng:]
                g5 = lambda w: spin(GAMMA5, w)              # noqa: E731

                def schur(v):
                    return self._schur_from_hop(hop, v)

                def normal(v):
                    if inner_dtype is None:
                        return g5(schur(g5(schur(v))))
                    v = _round_complex(v, inner_dtype)
                    av = _round_complex(schur(v), inner_dtype)
                    out = g5(schur(g5(av)))
                    return _round_complex(out, inner_dtype)

                def pdot(a, c):
                    return jax.lax.psum(jnp.sum(jnp.conj(a) * c).real, ax)

                b_norm = jnp.sqrt(pdot(b_loc, b_loc))
                x0 = jnp.zeros_like(b_loc)
                rs0 = pdot(b_loc, b_loc)

                def cond(state):
                    _, _, _, rs, it = state
                    return (jnp.sqrt(rs) > tol_a * b_norm) & (it < cap_a)

                def loop(state):
                    x, r, p, rs, it = state
                    ap = normal(p)
                    alpha = rs / jnp.maximum(pdot(p, ap), 1e-30)
                    x = x + alpha * p
                    r = r - alpha * ap
                    rs_new = pdot(r, r)
                    beta = rs_new / jnp.maximum(rs, 1e-30)
                    p = r + beta * p
                    return x, r, p, rs_new, it + 1

                x, r, p, rs, it = jax.lax.while_loop(
                    cond, loop, (x0, b_loc, b_loc, rs0,
                                 jnp.zeros((), jnp.int32)))
                rel = jnp.sqrt(rs) / jnp.maximum(b_norm, 1e-30)
                return x, it, rel

            return jax.jit(self._shmap(
                body,
                in_specs=self._gauge_specs + (self._p_spec, P(), P()),
                out_specs=(self._p_spec, P(), P())))

        fn = self._jitted(("cg", dt_key), build)
        x, it, rel = fn(*self._gauge_args, b, jnp.float32(tol),
                        jnp.int32(max_iters))
        return CGResult(x, it, rel, rel <= tol)


def dslash_half_sharded(U_e: jnp.ndarray, U_o: jnp.ndarray,
                        psi: jnp.ndarray, src_parity: int, mesh, *,
                        axis_name: str = "model", overlap: bool = True,
                        backend: str = "jnp") -> jnp.ndarray:
    """One-shot sharded EO hop on global compact arrays (test/bench entry
    point; for repeated application build a :class:`ShardedWilsonEO`)."""
    ops = ShardedWilsonEO(U_e, U_o, 0.0, mesh, axis_name=axis_name,
                          overlap=overlap, backend=backend)
    return ops.dslash_half(psi, src_parity)


# ---------------------------------------------------------------------------
# Measured calibration — executed multi-chip GFLOPS/W on the telemetry bus
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LQCDCalibration:
    """Multi-chip LQCD operating figures for the cluster layer.

    ``source="measured"`` entries come from timing the executed sharded
    normal op (:func:`measured_lqcd_calibration`); ``source="analytic"``
    restates the S9150 roofline (:func:`analytic_lqcd_calibration`) in
    the same shape so :class:`~repro.cluster.workload.LQCDSolveWorkload`
    can consume either and report the delta.
    """

    lattice: Tuple[int, int, int, int]
    n_devices: int
    gflops: float                # sustained over the timed normal ops
    eff_bw_gbs: float            # executed aggregate streaming bandwidth
    busy_w: float                # aggregate device power at the op point
    wall_s: float
    energy_j: float              # integrated from the telemetry bus
    source: str = "measured"
    trace: Optional[Any] = field(default=None, repr=False, compare=False)

    @property
    def gflops_per_w(self) -> float:
        return self.gflops / max(self.busy_w, 1e-9)


def _busy_watts(op=None, n_devices: int = 1) -> float:
    from repro.power.model import OperatingPoint, gpu_power_throttled
    op = op or OperatingPoint.green500()
    return n_devices * gpu_power_throttled(op.f_mhz, op.vid,
                                           temp_c=op.temperature(), util=1.0)


def analytic_lqcd_calibration(lattice: Tuple[int, int, int, int],
                              n_devices: int = 1, op=None,
                              ) -> LQCDCalibration:
    """The S9150 roofline restated as a calibration (fallback path)."""
    from repro.configs.lcsc_lqcd import (DSLASH_BW_FRACTION,
                                         MULTI_GPU_SLOWDOWN, S9150_BW_GBS)
    volume = int(np.prod(lattice))
    slowdown = 1.0 - (MULTI_GPU_SLOWDOWN if n_devices > 1 else 0.0)
    eff_bw = S9150_BW_GBS * DSLASH_BW_FRACTION * n_devices * slowdown
    bytes_op = 2 * volume * dslash_bytes_per_site(4)
    flops_op = 2 * volume * dslash_flops_per_site()
    wall = bytes_op / (eff_bw * 1e9)
    busy_w = _busy_watts(op, n_devices)
    return LQCDCalibration(tuple(lattice), n_devices, flops_op / wall / 1e9,
                           eff_bw, busy_w, wall, busy_w * wall,
                           source="analytic")


def measured_lqcd_calibration(lattice: Tuple[int, int, int, int] = (8, 8, 8, 16),
                              *, kappa: float = 0.12, mesh=None,
                              axis_name: str = "model", reps: int = 5,
                              op=None, recorder=None, overlap: bool = True,
                              backend: str = "jnp", seed: int = 0,
                              ) -> LQCDCalibration:
    """Time the executed sharded normal op and put it on the telemetry bus.

    Runs ``reps`` applications of the fused A†A on the real device mesh
    (all local devices by default), converts wall time into sustained
    multi-chip GFLOPS and effective streaming bandwidth, takes busy watts
    from the power model at ``op`` (Green500 point by default), emits the
    run into ``recorder`` (or a private bus) exactly like
    ``solver_energy`` does, and integrates joules from the trace.
    """
    from repro.distributed.sharding import lattice_mesh
    from repro.lqcd.eo import eo_pack, pack_gauge
    from repro.lqcd.su3 import random_su3_field
    from repro.power.trace import TraceRecorder

    if mesh is None:
        mesh = lattice_mesh(lattice[3], axis_name=axis_name)
    n_dev = int(np.prod(mesh.shape[axis_name]))

    ku, kr, ki = jax.random.split(jax.random.PRNGKey(seed), 3)
    U = random_su3_field(ku, tuple(lattice))
    b = (jax.random.normal(kr, tuple(lattice) + (4, 3))
         + 1j * jax.random.normal(ki, tuple(lattice) + (4, 3))
         ).astype(jnp.complex64)
    U_e, U_o = pack_gauge(U)
    b_e = eo_pack(b, 0)
    ops = ShardedWilsonEO(U_e, U_o, kappa, mesh, axis_name=axis_name,
                          overlap=overlap, backend=backend)

    v = ops.normal(b_e)                      # compile + warm
    jax.block_until_ready(v)
    t_start = time.perf_counter()
    for _ in range(reps):
        v = ops.normal(v)
    jax.block_until_ready(v)
    wall = max(time.perf_counter() - t_start, 1e-9)

    volume = int(np.prod(lattice))
    flops = reps * 2 * volume * dslash_flops_per_site()
    streamed = reps * 2 * volume * dslash_bytes_per_site(4)
    gflops = flops / wall / 1e9
    busy_w = _busy_watts(op, n_dev)

    rec = recorder if recorder is not None \
        else TraceRecorder(source="lqcd-calibration")
    t0 = rec.t_last
    for t in (t0, t0 + wall):
        rec.emit(t, {"gpu": busy_w}, flops_rate=gflops, util=1.0)
    trace = rec.trace()
    energy_j = trace.energy_j(t0=t0, t1=t0 + wall)
    return LQCDCalibration(tuple(lattice), n_dev, gflops,
                           streamed / wall / 1e9, busy_w, wall, energy_j,
                           source="measured", trace=trace)
