"""Typed configuration system for the repro framework.

Every runnable entity (model architecture, mesh, training/serving shape,
energy plan) is described by a frozen dataclass.  Architectures register
themselves in ``ARCH_REGISTRY`` via ``src/repro/configs/<id>.py`` modules;
``get_arch(id)`` returns the full published config and
``get_arch(id).smoke()`` a reduced same-family config for CPU tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, List, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration (capacity-based dispatch)."""

    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    expert_d_ff: int = 0          # per-expert hidden size
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.001

    @property
    def enabled(self) -> bool:
        return self.n_experts > 0


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2)."""

    q_lora_rank: int = 0          # 0 = no q compression
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def enabled(self) -> bool:
        return self.kv_lora_rank > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 style SSD (state-space duality) configuration."""

    d_state: int = 0
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256
    # hybrid (hymba): number of SSM heads running parallel to attention
    n_groups: int = 1

    @property
    def enabled(self) -> bool:
        return self.d_state > 0


@dataclass(frozen=True)
class ModelConfig:
    """Unified architecture description covering all assigned families."""

    name: str
    family: str                   # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0               # 0 -> d_model // n_heads
    moe: MoEConfig = MoEConfig()
    mla: MLAConfig = MLAConfig()
    ssm: SSMConfig = SSMConfig()
    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0       # 0 = full attention
    # MLP details
    mlp_variant: str = "swiglu"   # swiglu | gelu | relu2 | geglu
    norm_variant: str = "rmsnorm"  # rmsnorm | layernorm | nonparametric_ln
    tie_embeddings: bool = False
    # enc-dec (whisper)
    n_encoder_layers: int = 0
    encoder_ratio: int = 4        # dec_len / enc_len for the audio stub
    # modality frontend stub
    frontend: str = "none"        # none | audio | vlm
    n_patches: int = 0            # vlm: patch embeddings prepended
    # numerics
    dtype: str = "bfloat16"
    # notes for DESIGN.md provenance
    source: str = ""

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.d_head == 0 and self.n_heads > 0:
            object.__setattr__(self, "d_head", self.d_model // max(self.n_heads, 1))

    # -- derived sizes ------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 256 (Megatron-style) so the vocab
        dim shards evenly on any mesh axis; loss masks the padded tail."""
        return -(-self.vocab_size // 256) * 256

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm.enabled else 0

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner_ssm // self.ssm.head_dim if self.ssm.enabled else 0

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model FLOPs)."""
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        # attention
        if not self.attn_free:
            if self.mla.enabled:
                m = self.mla
                qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
                q_in = m.q_lora_rank if m.q_lora_rank else d
                per_layer += (d * m.q_lora_rank if m.q_lora_rank else 0)
                per_layer += q_in * self.n_heads * qk_dim
                per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                per_layer += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                per_layer += self.n_heads * m.v_head_dim * d
            else:
                dh = self.d_head
                per_layer += d * self.n_heads * dh            # Q
                per_layer += 2 * d * self.n_kv_heads * dh     # K, V
                per_layer += self.n_heads * dh * d            # O
                if self.qkv_bias:
                    per_layer += (self.n_heads + 2 * self.n_kv_heads) * dh
        # ssm (pure or hybrid)
        if self.ssm.enabled:
            di, ds = self.d_inner_ssm, self.ssm.d_state
            nh = self.n_ssm_heads
            per_layer += d * (2 * di + 2 * self.ssm.n_groups * ds + nh)  # in_proj
            per_layer += di * self.ssm.d_conv                           # conv
            per_layer += nh * 2                                         # A, D
            per_layer += di * d                                         # out_proj
        # mlp / moe
        if self.moe.enabled:
            e = self.moe
            per_layer += d * e.n_experts                                 # router
            per_layer += e.n_experts * 3 * d * e.expert_d_ff             # gated experts
            per_layer += e.n_shared_experts * 3 * d * e.expert_d_ff
        elif self.d_ff > 0:
            mult = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
            per_layer += mult * d * self.d_ff
        # norms (rms scale) — negligible but counted
        if self.norm_variant != "nonparametric_ln":
            per_layer += 2 * d
        total = emb + L * per_layer
        if self.n_encoder_layers:
            # encoder layers: self-attn + mlp; decoder additionally has cross-attn
            enc_layer = 4 * d * d + (3 if self.mlp_variant in ("swiglu", "geglu") else 2) * d * self.d_ff
            total += self.n_encoder_layers * enc_layer
            total += self.n_layers * 4 * d * d  # decoder cross-attention
        return total

    def active_param_count(self) -> int:
        """Active (per-token) params — differs from total only for MoE."""
        if not self.moe.enabled:
            return self.param_count()
        e = self.moe
        dense_like = replace(
            self, moe=MoEConfig(), d_ff=e.expert_d_ff * (e.top_k + e.n_shared_experts),
            mlp_variant="swiglu")
        return dense_like.param_count() + self.n_layers * self.d_model * e.n_experts


# ---------------------------------------------------------------------------
# Shapes (the four assigned input shapes)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs; returns (ok, reason-if-skipped)."""
    if shape.name == "long_500k":
        sub_quadratic = model.family in ("ssm", "hybrid") or model.sliding_window > 0
        if not sub_quadratic:
            return False, ("pure full-attention arch: 500k decode requires "
                           "sub-quadratic attention (assignment: skip)")
    return True, ""


# ---------------------------------------------------------------------------
# Mesh / run configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axis_names: Tuple[str, ...] = ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axis_names

    @property
    def data_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axis_names if a in ("pod", "data"))

    @property
    def data_size(self) -> int:
        return self.n_devices // self.model_size

    @property
    def model_size(self) -> int:
        return self.shape[self.axis_names.index("model")]


SINGLE_POD_MESH = MeshConfig((16, 16), ("data", "model"))
MULTI_POD_MESH = MeshConfig((2, 16, 16), ("pod", "data", "model"))


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    remat: str = "layer"          # none | layer | block (sqrt-remat)
    microbatches: int = 1         # grad-accumulation steps per global batch
    moment_dtype: str = "float32"  # AdamW m/v storage (bf16 for huge models)
    grad_accum_dtype: str = "float32"
    grad_compress: bool = False   # int8 cross-pod DP compression
    seed: int = 0


@dataclass(frozen=True)
class EnergyConfig:
    """Energy-plan settings (the paper's technique, C3/C5)."""

    enabled: bool = True
    mode: str = "efficiency"      # performance | efficiency
    max_perf_loss: float = 0.015  # paper: D-slash loses <1.5%
    freq_grid: Tuple[float, ...] = tuple(round(0.5 + 0.025 * i, 3) for i in range(21))


@dataclass(frozen=True)
class SolverConfig:
    """Dirac-inversion solver knobs (the paper's C1 workload: CL2QCD's
    even-odd preconditioned, mixed-precision CG).

    ``inner_dtype`` is the storage/traffic precision of the inner
    (defect-correction) CG; ``"none"`` disables mixed precision and runs
    the whole solve at working precision.  Dtypes are strings so this
    module stays importable without jax.
    """

    preconditioner: str = "even_odd"   # none | even_odd
    inner_dtype: str = "bfloat16"      # none | bfloat16 | float16 | float32
    tol: float = 1e-6
    max_iters: int = 1000
    inner_tol: float = 1e-2            # reliable-update restart threshold
    max_outer: int = 30

    _INNER_DTYPES = ("none", "", "float32", "bfloat16", "float16", "float64")

    def __post_init__(self):
        if self.preconditioner not in ("none", "even_odd"):
            raise ValueError(f"unknown preconditioner {self.preconditioner!r}")
        if self.inner_dtype not in self._INNER_DTYPES:
            raise ValueError(f"unknown inner_dtype {self.inner_dtype!r}; "
                             f"one of {self._INNER_DTYPES}")

    @property
    def mixed_precision(self) -> bool:
        return self.inner_dtype not in ("none", "", "float32")


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = SINGLE_POD_MESH
    train: TrainConfig = TrainConfig()
    energy: EnergyConfig = EnergyConfig()


# ---------------------------------------------------------------------------
# Architecture registry
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArchEntry:
    arch_id: str
    full: Callable[[], ModelConfig]
    smoke: Callable[[], ModelConfig]


ARCH_REGISTRY: Dict[str, ArchEntry] = {}

ARCH_IDS: List[str] = [
    "whisper-small",
    "grok-1-314b",
    "deepseek-v2-236b",
    "qwen1.5-32b",
    "minitron-8b",
    "olmo-1b",
    "llama3-8b",
    "mamba2-370m",
    "llava-next-mistral-7b",
    "hymba-1.5b",
]

_MODULE_FOR_ID = {
    "whisper-small": "whisper_small",
    "grok-1-314b": "grok1_314b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "qwen1.5-32b": "qwen15_32b",
    "minitron-8b": "minitron_8b",
    "olmo-1b": "olmo_1b",
    "llama3-8b": "llama3_8b",
    "mamba2-370m": "mamba2_370m",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "hymba-1.5b": "hymba_1_5b",
}


def register_arch(arch_id: str, full: Callable[[], ModelConfig],
                  smoke: Callable[[], ModelConfig]) -> None:
    ARCH_REGISTRY[arch_id] = ArchEntry(arch_id, full, smoke)


def _ensure_loaded(arch_id: str) -> None:
    if arch_id in ARCH_REGISTRY:
        return
    mod = _MODULE_FOR_ID.get(arch_id)
    if mod is None:
        raise KeyError(f"unknown architecture {arch_id!r}; known: {ARCH_IDS}")
    importlib.import_module(f"repro.configs.{mod}")


def get_arch(arch_id: str) -> ArchEntry:
    _ensure_loaded(arch_id)
    return ARCH_REGISTRY[arch_id]


def full_config(arch_id: str) -> ModelConfig:
    return get_arch(arch_id).full()


def smoke_config(arch_id: str) -> ModelConfig:
    return get_arch(arch_id).smoke()


def all_cells() -> List[Tuple[str, str]]:
    """All 40 (arch, shape) cells, including SKIP cells."""
    return [(a, s) for a in ARCH_IDS for s in SHAPES]


# ---------------------------------------------------------------------------
# Small CLI helper shared by launch scripts
# ---------------------------------------------------------------------------

def add_common_args(parser) -> None:
    parser.add_argument("--arch", choices=ARCH_IDS, required=True)
    parser.add_argument("--shape", choices=list(SHAPES), default="train_4k")
    parser.add_argument("--multi-pod", action="store_true")
    parser.add_argument("--smoke", action="store_true",
                        help="use the reduced smoke config")


def run_config_from_args(args) -> RunConfig:
    entry = get_arch(args.arch)
    model = entry.smoke() if args.smoke else entry.full()
    mesh = MULTI_POD_MESH if args.multi_pod else SINGLE_POD_MESH
    return RunConfig(model=model, shape=SHAPES[args.shape], mesh=mesh)


def asdict(cfg: Any) -> Dict[str, Any]:
    return dataclasses.asdict(cfg)
