"""Sharding rules: FSDP (data/pod axes) × TP (model axis) × EP.

Every rule is a *candidate list*: the first PartitionSpec whose sharded dims
all divide evenly on the mesh wins (JAX rejects uneven shards).  This is what
makes one rule set serve whisper (12 heads, 51865 vocab) and grok (48 heads,
8 KV heads) alike: e.g. attention K/V projections prefer head sharding and
fall back to head-dim sharding when KVH < model-axis size.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import MeshConfig, ModelConfig
from repro.models.moe import moe_sharding_plan

TP = "model"


def data_axes_of(mesh_cfg: MeshConfig) -> Tuple[str, ...]:
    return mesh_cfg.data_axes


def _axis_size(mesh_cfg: MeshConfig, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= _axis_size(mesh_cfg, a)
        return n
    return mesh_cfg.shape[mesh_cfg.axis_names.index(axis)]


def fits(shape: Sequence[int], spec: P, mesh_cfg: MeshConfig) -> bool:
    for dim, axis in zip(shape, tuple(spec) + (None,) * len(shape)):
        size = _axis_size(mesh_cfg, axis)
        if size > 1 and dim % size != 0:
            return False
    return True


def pick(shape: Sequence[int], candidates: List[P],
         mesh_cfg: MeshConfig) -> P:
    for c in candidates:
        if fits(shape, c, mesh_cfg):
            return c
    return P()


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

def _param_rule(cfg: ModelConfig, mesh_cfg: MeshConfig, path: Tuple[str, ...],
                shape: Sequence[int]) -> P:
    dp = data_axes_of(mesh_cfg)
    name = path[-1]
    parent = path[-2] if len(path) >= 2 else ""

    if parent == "embed":                         # (V, D)
        return pick(shape, [P(TP, dp), P(TP, None), P(None, TP), P(dp, None)],
                    mesh_cfg)
    if parent == "lm_head":                       # (D, V)
        return pick(shape, [P(dp, TP), P(None, TP), P(dp, None)], mesh_cfg)
    if parent == "frontend":
        if name == "proj_w":
            return pick(shape, [P(dp, TP), P(None, TP)], mesh_cfg)
        return P()

    if parent in ("attn", "xattn"):
        if name == "wq":                          # (D, H, dh)
            return pick(shape, [P(dp, TP, None), P(dp, None, TP),
                                P(None, None, TP)], mesh_cfg)
        if name in ("wk", "wv"):                  # (D, KVH, dh)
            return pick(shape, [P(dp, TP, None), P(dp, None, TP),
                                P(None, None, TP)], mesh_cfg)
        if name == "wo":                          # (H, dh, D)
            return pick(shape, [P(TP, None, dp), P(None, TP, dp),
                                P(None, TP, None)], mesh_cfg)
        if name in ("bq", "bk", "bv"):            # (H, dh)
            return pick(shape, [P(TP, None), P(None, TP)], mesh_cfg)
        # MLA
        if name in ("wq_a", "wkv_a"):             # (D, r)
            return pick(shape, [P(dp, None)], mesh_cfg)
        if name == "wq_b":                        # (r, H, qk)
            return pick(shape, [P(dp, TP, None), P(None, TP, None)], mesh_cfg)
        if name in ("wkv_b_nope", "wkv_b_v"):     # (r, H, x)
            return pick(shape, [P(dp, TP, None), P(None, TP, None)], mesh_cfg)
        return P()                                # norms

    if parent == "moe":
        if name == "router":
            return P()
        plan = moe_sharding_plan(cfg, _axis_size(mesh_cfg, TP))
        if name in ("w_gate", "w_up"):            # (E, D, F)
            if plan == "expert":
                return pick(shape, [P(TP, dp, None), P(TP, None, None)],
                            mesh_cfg)
            return pick(shape, [P(None, dp, TP), P(None, None, TP)], mesh_cfg)
        if name == "w_down":                      # (E, F, D)
            if plan == "expert":
                return pick(shape, [P(TP, None, dp), P(TP, None, None)],
                            mesh_cfg)
            return pick(shape, [P(None, TP, dp), P(None, TP, None)], mesh_cfg)
        if name in ("shared_gate", "shared_up"):  # (D, F)
            return pick(shape, [P(dp, TP), P(None, TP)], mesh_cfg)
        if name == "shared_down":                 # (F, D)
            return pick(shape, [P(TP, dp), P(TP, None)], mesh_cfg)

    if parent == "mlp":
        if name in ("w_gate", "w_up"):            # (D, F)
            return pick(shape, [P(dp, TP), P(None, TP), P(dp, None)],
                        mesh_cfg)
        if name == "w_down":                      # (F, D)
            return pick(shape, [P(TP, dp), P(TP, None), P(None, dp)],
                        mesh_cfg)

    if parent == "ssm":
        if name == "w_in":                        # (D, E)
            return pick(shape, [P(dp, None)], mesh_cfg)
        if name == "w_out":                       # (E, D)
            return pick(shape, [P(None, dp)], mesh_cfg)
        return P()

    return P()                                    # norms, scalars


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return tuple(out)


SERVE_TP_ONLY_BUDGET = 12 * 2**30   # leave headroom below 16 GiB HBM


def param_bytes(params_shapes: Any) -> int:
    return sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(params_shapes))


def _strip_dp(spec: P, dp: Tuple[str, ...]) -> P:
    drop = set(dp)

    def clean(axis):
        if axis is None:
            return None
        if isinstance(axis, (tuple, list)):
            kept = tuple(a for a in axis if a not in drop)
            return kept if len(kept) > 1 else (kept[0] if kept else None)
        return None if axis in drop else axis

    return P(*[clean(a) for a in spec])


def param_pspecs(cfg: ModelConfig, params_shapes: Any,
                 mesh_cfg: MeshConfig, mode: str = "train",
                 serve_tp_only: "Optional[bool]" = None,
                 moe_ep_data: bool = False) -> Any:
    """PartitionSpec pytree matching a params (shape) pytree.

    Works on both concrete arrays and ShapeDtypeStructs.  Stacked layer
    leaves carry a leading L axis which is never sharded — rules apply to
    ``shape[1:]`` for anything under ``layers``/``enc_layers``.

    ``mode='serve'``: when the TP-sharded weights fit the per-chip budget,
    drop the FSDP (data/pod) factors so serving never all-gathers weights
    per step; models too large for TP-only (grok, deepseek) keep FSDP.
    """
    tp_only = False
    if mode == "serve":
        if serve_tp_only is not None:
            tp_only = serve_tp_only
        else:
            tp_only = (param_bytes(params_shapes)
                       // _axis_size(mesh_cfg, TP) <= SERVE_TP_ONLY_BUDGET)
    dp = data_axes_of(mesh_cfg)

    def rule(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        stacked = any(n in ("layers", "enc_layers") for n in names)
        body = shape[1:] if stacked else shape
        spec = _param_rule(cfg, mesh_cfg, names, body)
        if moe_ep_data and len(names) >= 2 and names[-2] == "moe":
            # serve-EP: experts over data, FFN over model, fully resident
            if names[-1] in ("w_gate", "w_up"):
                spec = pick(body, [P(dp, None, TP), P(dp, None, None)],
                            mesh_cfg)
            elif names[-1] == "w_down":
                spec = pick(body, [P(dp, TP, None), P(dp, None, None)],
                            mesh_cfg)
        elif tp_only:
            spec = _strip_dp(spec, dp)
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(rule, params_shapes)


def named_shardings(mesh, pspecs: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Lattice (LQCD) rules — T-axis sharding for the even-odd solver
# ---------------------------------------------------------------------------

def lattice_mesh(t_extent: int, n_devices: Optional[int] = None,
                 axis_name: str = TP):
    """1-D device mesh for lattice T-sharding.

    Picks the largest device count (≤ ``n_devices`` or all local devices)
    that divides ``t_extent`` — JAX rejects uneven shards, and the halo
    ring in ``repro.lqcd.multichip_eo`` assumes equal local T blocks.
    """
    avail = n_devices or jax.device_count()
    n = max(d for d in range(1, avail + 1) if t_extent % d == 0)
    return jax.make_mesh((n,), (axis_name,))


def lattice_eo_specs(axis_name: str = TP) -> Tuple[P, P]:
    """(gauge-half, spinor-half) PartitionSpecs for the compact even-odd
    layout: gauge ``(4, X/2, Y, Z, T, 3, 3)`` and spinor
    ``(X/2, Y, Z, T, 4, 3)``, both sharded on the T axis."""
    return (P(None, None, None, None, axis_name, None, None),
            P(None, None, None, axis_name, None, None))


# ---------------------------------------------------------------------------
# Batch / cache rules
# ---------------------------------------------------------------------------

def batch_pspecs(cfg: ModelConfig, batch_shapes: Dict[str, Any],
                 mesh_cfg: MeshConfig) -> Dict[str, P]:
    dp = data_axes_of(mesh_cfg)
    out = {}
    for k, v in batch_shapes.items():
        cands = [P(dp, *([None] * (len(v.shape) - 1))), P()]
        out[k] = pick(v.shape, cands, mesh_cfg)
    return out


def cache_pspecs(cfg: ModelConfig, cache_shapes: Dict[str, Any],
                 mesh_cfg: MeshConfig) -> Dict[str, P]:
    """Decode-cache sharding: batch over data, sequence (or heads) over model.

    Sequence-sharding the KV cache over the model axis is the TPU-native
    analogue of paged/context-parallel decode: softmax reductions over the
    sharded axis lower to psums.
    """
    dp = data_axes_of(mesh_cfg)
    out: Dict[str, P] = {}
    for k, v in cache_shapes.items():
        if k == "pos":
            out[k] = P()
        elif k in ("k", "v", "xk", "xv"):          # (L, B, S, KVH, dh)
            kvh = v.shape[3]
            cands = [
                P(None, dp, TP, None, None),
                P(None, None, TP, None, None),
                P(None, dp, None, None, None),
            ]
            if kvh % _axis_size(mesh_cfg, TP) != 0:
                # heads don't shard: dynamic cache updates on a seq-sharded
                # dim force GSPMD rematerialization — shard head_dim instead
                cands.insert(0, P(None, dp, None, None, TP))
            out[k] = pick(v.shape, cands, mesh_cfg)
        elif k in ("ckv", "krope"):                # (L, B, S, r)
            out[k] = pick(v.shape, [
                P(None, dp, TP, None),
                P(None, None, TP, None),
            ], mesh_cfg)
        elif k == "ssm":                           # (L, B, H, P, N)
            out[k] = pick(v.shape, [
                P(None, dp, TP, None, None),
                P(None, dp, None, None, None),
                P(None, None, TP, None, None),
            ], mesh_cfg)
        elif k in ("k_s", "v_s"):                  # (L, B, S) per-token
            out[k] = pick(v.shape, [P(None, dp, None)], mesh_cfg)
        elif k == "conv":                          # (L, B, K-1, C)
            out[k] = pick(v.shape, [P(None, dp, None, None)], mesh_cfg)
        else:
            out[k] = P()
    return out
