"""Fault tolerance & straggler mitigation for the training loop.

At 1000+ nodes the failure modes this handles (paper-informed):
  * hard node loss      -> checkpoint/restart, elastically resharded onto
                           the surviving mesh (CheckpointManager.restore)
  * numerics blow-up    -> NaN/inf step detection, rollback + LR cut
  * stragglers          -> per-step wall-time EWMA; persistent outliers
                           trigger the scheduler's frequency-floor plan
                           (the paper's flat-774 profile) or pod drop
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np


@dataclass
class StepHealth:
    step: int
    wall_s: float
    loss: float
    ok: bool
    reason: str = ""


@dataclass
class FaultPolicy:
    max_retries: int = 2
    nan_lr_cut: float = 0.5
    straggler_ewma: float = 0.9
    straggler_threshold: float = 1.25   # x median step time
    checkpoint_every: int = 100


class FaultTolerantLoop:
    """Wraps a step callable with detection/rollback bookkeeping.

    The step fn is pure (params, opt, batch) -> (params, opt, metrics); the
    loop owns the last-good snapshot reference (a checkpoint step id).
    """

    def __init__(self, policy: FaultPolicy = FaultPolicy()):
        self.policy = policy
        self.ewma_wall: Optional[float] = None
        self.history: List[StepHealth] = []
        self.rollbacks = 0

    def observe(self, step: int, wall_s: float, loss: float) -> StepHealth:
        ok = math.isfinite(loss)
        reason = "" if ok else "non-finite loss"
        if self.ewma_wall is None:
            self.ewma_wall = wall_s
        else:
            a = self.policy.straggler_ewma
            self.ewma_wall = a * self.ewma_wall + (1 - a) * wall_s
        h = StepHealth(step, wall_s, loss, ok, reason)
        self.history.append(h)
        return h

    def is_straggling(self, wall_s: float) -> bool:
        return (self.ewma_wall is not None
                and wall_s > self.policy.straggler_threshold * self.ewma_wall)

    def should_rollback(self, h: StepHealth) -> bool:
        if h.ok:
            return False
        self.rollbacks += 1
        return self.rollbacks <= self.policy.max_retries

    def straggler_report(self) -> Dict[str, float]:
        walls = np.asarray([h.wall_s for h in self.history] or [0.0])
        return {
            "median_step_s": float(np.median(walls)),
            "p99_step_s": float(np.percentile(walls, 99)),
            "straggler_ratio": float(np.percentile(walls, 99)
                                     / max(np.median(walls), 1e-9)),
        }
