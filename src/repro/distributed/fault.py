"""Fault tolerance & straggler mitigation for the training loop.

At 1000+ nodes the failure modes this handles (paper-informed):
  * hard node loss      -> checkpoint/restart, elastically resharded onto
                           the surviving mesh (CheckpointManager.restore)
  * numerics blow-up    -> NaN/inf step detection, rollback + LR cut
  * stragglers          -> per-step wall-time EWMA; persistent outliers
                           trigger the scheduler's frequency-floor plan
                           (the paper's flat-774 profile) or pod drop

The hardware-failure *statistics* live here too:
:class:`WeibullFailureModel` is the per-node MTBF/repair renewal model
the discrete-event cluster simulator (:mod:`repro.cluster.sim`) draws
node outages from, shared with the training-loop planners above so both
layers agree on what a node-hour of risk means.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class WeibullFailureModel:
    """Per-node hardware-failure renewal process.

    Uptimes are Weibull-distributed — ``shape < 1`` captures infant
    mortality, ``shape > 1`` wear-out; HPC node-failure traces typically
    fit 0.7–1.8 — with the scale chosen so the *mean* uptime equals
    ``mtbf_s`` (MTBF = scale × Γ(1 + 1/shape)).  Repairs take a fixed
    ``repair_s`` (reboot + health check), after which the next uptime is
    drawn afresh (a renewal process, so no horizon needs to be fixed up
    front — the simulator draws lazily on each repair)."""

    mtbf_s: float = 500.0 * 3600.0     # per-node mean time between failures
    shape: float = 1.3
    repair_s: float = 1800.0

    def __post_init__(self):
        if self.mtbf_s <= 0 or self.shape <= 0 or self.repair_s < 0:
            raise ValueError("mtbf_s and shape must be positive, "
                             "repair_s non-negative")

    @property
    def scale_s(self) -> float:
        """Weibull scale λ with E[uptime] = ``mtbf_s``."""
        return self.mtbf_s / math.gamma(1.0 + 1.0 / self.shape)

    def draw_uptime_s(self, rng: np.random.Generator) -> float:
        """One uptime sample [s] (time from in-service to failure)."""
        return float(self.scale_s * rng.weibull(self.shape))

    def node_streams(self, seed: int,
                     n_nodes: int) -> List[np.random.Generator]:
        """Independent per-node RNG streams (``SeedSequence``-spawned).

        Node ``i``'s uptime sequence depends only on ``(seed, i)`` —
        never on how draws for other nodes interleave — so the
        simulator's lazy per-repair draws and the eager
        :meth:`node_outages` iterator produce *identical* ``(node,
        t_down, t_up)`` sequences from the same seed (pinned in
        ``tests/test_resilience.py``)."""
        ss = np.random.SeedSequence(seed)
        return [np.random.default_rng(child)
                for child in ss.spawn(n_nodes)]

    def node_outages(self, seed, n_nodes: int,
                     horizon_s: float) -> Iterator[Tuple[int, float, float]]:
        """All ``(node, t_down, t_up)`` outages before ``horizon_s`` —
        the eager counterpart of the simulator's lazy per-repair draws
        (planning/analysis use).  ``seed`` is an int (per-node
        :meth:`node_streams`, matching the simulator draw-for-draw) or
        a single shared ``np.random.Generator`` (sequential draws, for
        quick statistics)."""
        if isinstance(seed, np.random.Generator):
            streams = [seed] * n_nodes
        else:
            streams = self.node_streams(int(seed), n_nodes)
        for node in range(n_nodes):
            rng = streams[node]
            t = self.draw_uptime_s(rng)
            while t < horizon_s:
                yield node, t, t + self.repair_s
                t += self.repair_s + self.draw_uptime_s(rng)


@dataclass
class StepHealth:
    step: int
    wall_s: float
    loss: float
    ok: bool
    reason: str = ""


@dataclass
class FaultPolicy:
    max_retries: int = 2
    nan_lr_cut: float = 0.5
    straggler_ewma: float = 0.9
    straggler_threshold: float = 1.25   # x median step time
    checkpoint_every: int = 100


class FaultTolerantLoop:
    """Wraps a step callable with detection/rollback bookkeeping.

    The step fn is pure (params, opt, batch) -> (params, opt, metrics); the
    loop owns the last-good snapshot reference (a checkpoint step id).
    """

    def __init__(self, policy: FaultPolicy = FaultPolicy()):
        self.policy = policy
        self.ewma_wall: Optional[float] = None
        self.history: List[StepHealth] = []
        self.rollbacks = 0

    def observe(self, step: int, wall_s: float, loss: float) -> StepHealth:
        ok = math.isfinite(loss)
        reason = "" if ok else "non-finite loss"
        if self.ewma_wall is None:
            self.ewma_wall = wall_s
        else:
            a = self.policy.straggler_ewma
            self.ewma_wall = a * self.ewma_wall + (1 - a) * wall_s
        h = StepHealth(step, wall_s, loss, ok, reason)
        self.history.append(h)
        return h

    def is_straggling(self, wall_s: float) -> bool:
        return (self.ewma_wall is not None
                and wall_s > self.policy.straggler_threshold * self.ewma_wall)

    def should_rollback(self, h: StepHealth) -> bool:
        if h.ok:
            return False
        self.rollbacks += 1
        return self.rollbacks <= self.policy.max_retries

    def straggler_report(self) -> Dict[str, float]:
        walls = np.asarray([h.wall_s for h in self.history] or [0.0])
        return {
            "median_step_s": float(np.median(walls)),
            "p99_step_s": float(np.percentile(walls, 99)),
            "straggler_ratio": float(np.percentile(walls, 99)
                                     / max(np.median(walls), 1e-9)),
        }
