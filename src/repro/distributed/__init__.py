"""Distributed substrate: sharding rules, collectives, fault tolerance."""
