"""Searchers: exhaustive grid and coordinate descent over a :class:`Space`.

Both maximize MFLOPS/W subject to the paper's *perf-floor* constraint
("efficiency mode"): a point is feasible only if its performance is at
least ``(1 - max_perf_loss)`` of the best performance the model can
reach anywhere in the space.  The returned best point always satisfies
the floor — the floor is anchored at the searcher's own observed peak,
so the peak-performance point itself is always feasible.

A cost model is any callable ``evaluate(point) -> (perf_gflops,
power_w)``.  Returning ``perf <= 0`` (or non-finite values) marks the
point infeasible (e.g. a tile that does not fit VMEM) and it is skipped.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.autotune.space import Space

Evaluate = Callable[[Dict[str, Any]], Tuple[float, float]]


@dataclass(frozen=True)
class Candidate:
    point: Dict[str, Any]
    perf_gflops: float
    power_w: float

    @property
    def mflops_per_w(self) -> float:
        if self.power_w <= 0:
            return 0.0
        return self.perf_gflops / self.power_w * 1000.0

    @property
    def feasible(self) -> bool:
        return (self.perf_gflops > 0 and self.power_w > 0
                and self.perf_gflops == self.perf_gflops)   # NaN guard


@dataclass
class TuneResult:
    best: Candidate
    peak_perf_gflops: float        # best performance seen anywhere
    perf_floor_gflops: float       # (1 - max_perf_loss) * peak
    max_perf_loss: float
    evaluations: int
    trace: List[Candidate] = field(default_factory=list)

    @property
    def perf_loss(self) -> float:
        """Performance given up vs the peak point (the paper's ~13%)."""
        if self.peak_perf_gflops <= 0:
            return 0.0
        return 1.0 - self.best.perf_gflops / self.peak_perf_gflops

    def as_config(self) -> Dict[str, Any]:
        return dict(self.best.point)


def _evaluate(evaluate: Evaluate, point: Dict[str, Any]) -> Candidate:
    perf, power = evaluate(point)
    return Candidate(dict(point), float(perf), float(power))


def _pick(cands: List[Candidate], floor: float) -> Candidate:
    """Most efficient feasible candidate; ties resolve to the earlier
    (deterministic iteration order)."""
    ok = [c for c in cands if c.feasible and c.perf_gflops >= floor]
    if not ok:       # floor anchored at observed peak -> peak is feasible
        ok = [c for c in cands if c.feasible]
    if not ok:
        raise ValueError("no feasible point in the search space")
    return max(ok, key=lambda c: c.mflops_per_w)


def grid_search(space: Space, evaluate: Evaluate, *,
                max_perf_loss: float = 0.15,
                keep_trace: bool = True) -> TuneResult:
    """Exhaustive sweep — the paper's offline 'heuristic search in the
    parameter space', generalized to any :class:`Space`."""
    cands = [_evaluate(evaluate, p) for p in space.points()]
    feasible = [c for c in cands if c.feasible]
    if not feasible:
        raise ValueError("no feasible point in the search space")
    peak = max(c.perf_gflops for c in feasible)
    floor = (1.0 - max_perf_loss) * peak
    best = _pick(cands, floor)
    return TuneResult(best, peak, floor, max_perf_loss, len(cands),
                      trace=cands if keep_trace else [])


def coordinate_descent(space: Space, evaluate: Evaluate, *,
                       max_perf_loss: float = 0.15,
                       start: Optional[Dict[str, Any]] = None,
                       max_rounds: int = 8) -> TuneResult:
    """Axis-at-a-time search: O(rounds * sum(len(axis))) evaluations
    instead of the grid's product.

    Phase 1 coordinate-*ascends* raw performance to anchor the perf
    floor (the grid search gets this for free from full enumeration);
    phase 2 descends on MFLOPS/W, never accepting a move below the
    floor.  The floor uses the phase-1 peak, so the result can only be
    pessimistic about feasibility, never violate it.
    """
    trace: List[Candidate] = []
    evals = 0

    def counted(p: Dict[str, Any]) -> Candidate:
        nonlocal evals
        c = _evaluate(evaluate, p)
        evals += 1
        trace.append(c)
        return c

    def sweep_axis(point: Dict[str, Any], axis: str,
                   key: Callable[[Candidate], float],
                   floor: float) -> Candidate:
        cands = []
        for p in space.neighbors(point, axis):
            c = counted(p)
            if c.feasible and c.perf_gflops >= floor:
                cands.append(c)
        if not cands:
            return counted(point)
        return max(cands, key=key)

    def descend(start_pt: Dict[str, Any],
                key: Callable[[Candidate], float],
                floor: float) -> Candidate:
        cur = counted(start_pt)
        for _ in range(max_rounds):
            moved = False
            for axis in space.names:
                nxt = sweep_axis(cur.point, axis, key, floor)
                if key(nxt) > key(cur) + 1e-12:
                    cur, moved = nxt, True
            if not moved:
                break
        return cur

    start = dict(start or space.first())
    # Phase 1: find the performance peak (anchors the floor).
    peak_cand = descend(start, lambda c: c.perf_gflops, floor=0.0)
    peak = peak_cand.perf_gflops
    floor = (1.0 - max_perf_loss) * peak
    # Phase 2: maximize efficiency subject to the floor, starting from
    # the peak point (which satisfies the floor by construction).
    best = descend(peak_cand.point, lambda c: c.mflops_per_w, floor=floor)
    if best.perf_gflops < floor:          # defensive: never violate
        best = peak_cand
    return TuneResult(best, peak, floor, max_perf_loss, evals, trace=trace)
