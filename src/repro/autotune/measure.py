"""Pluggable cost models for the autotuner.

A cost model is any callable ``evaluate(point) -> (perf_gflops,
power_w)``.  Two families ship here:

* **Analytic** — queries the unified power engine
  (:mod:`repro.power.engine`) at the point's operating settings.  Fast,
  deterministic, CI-safe; this is how the paper's published operating
  point (774 MHz, 40% fan, efficiency-mode blocking) is *rediscovered*
  rather than hard-coded.
* **Measured** — timed execution of the real code path (``linpack_run``
  or the Pallas kernels in interpret mode on CPU).  Wall-clock is
  measured; power still comes from the engine (CI hosts have no power
  meter) — the ranking between candidates is what matters.

This module carries **no power model of its own**: the calibrated
fan→temperature, blocking→utilization and node-power curves it once
duplicated now live in :mod:`repro.power.model` /
:mod:`repro.power.layers`, and the node cost model is a thin wrapper
over :func:`repro.power.evaluate_operating_point` (the dedup test in
``tests/test_power_dedup.py`` keeps it that way).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.power.engine import evaluate_operating_point
from repro.power.layers import NodeModel
from repro.power.model import (OperatingPoint, temp_from_fan,  # noqa: F401
                               tpu_chip_power, uniform_vids)
from repro.roofline import hw

Point = Dict[str, Any]

INFEASIBLE: Tuple[float, float] = (0.0, float("inf"))


# ---------------------------------------------------------------------------
# Analytic node model (the paper's GPU cluster) — a view over the engine
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AnalyticNodeHPLModel:
    """Node Linpack (perf, power) at an operating point, queried from the
    power engine's layered node model.  Points are dicts with keys
    ``f_mhz, vid, fan, nb, lookahead`` (see ``space.operating_space``).
    """

    n_gpus: int = 4

    def __call__(self, point: Point) -> Tuple[float, float]:
        return self.evaluate(point)

    def evaluate(self, point: Point) -> Tuple[float, float]:
        op = OperatingPoint.from_point(point)
        node = NodeModel.from_vids(uniform_vids(self.n_gpus, op.vid))
        return evaluate_operating_point(op, node)


# Process-level cache for the scheduler's placement-time consult: the
# coordinate-descent search over the analytic node model is deterministic
# (it rediscovers the paper's 774 MHz / VID-floor / 40%-fan Green500
# point), so one search amortizes over every schedule() call.
_RECOMMENDED_OP: Optional[OperatingPoint] = None


def recommended_operating_point() -> OperatingPoint:
    """The autotuner cost model's operating-point pick, as an
    :class:`~repro.power.model.OperatingPoint`.

    This is what :meth:`repro.cluster.scheduler.Scheduler.schedule`
    consults at placement time for jobs that carry no ``preferred_op``:
    a coordinate-descent search of :class:`AnalyticNodeHPLModel` under
    the published perf floor — the same search
    ``benchmarks/paper_tables.py::autotune_operating_point`` gates, so
    the recommendation *is* the Green500 record point rather than a
    hard-coded constant.  Cached per process (the search is ~0.3 s)."""
    global _RECOMMENDED_OP
    if _RECOMMENDED_OP is None:
        from repro.autotune import tune_operating_point
        res = tune_operating_point(method="coordinate")
        _RECOMMENDED_OP = OperatingPoint.from_point(res.best.point)
    return _RECOMMENDED_OP


@dataclass(frozen=True)
class AnalyticHPLBlockingModel:
    """Blocking/lookahead tuning for an actual ``linpack_run`` problem
    size ``n``, at a fixed electrical operating point.

    CPU-scale blocks are mapped onto the paper-scale NB axis by the
    block *fraction* of the matrix (``block · 2048 / n``), so a 1024²
    problem with block 256 sits where NB 512 sits for the paper's run —
    the same knee, floor and utilization trade apply at every scale, and
    ``HPLConfig.efficiency()``'s halved block falls out as the winner.
    """

    n: int
    f_mhz: float = 774.0
    vid: float = 1.1425
    fan: float = 0.40
    node: AnalyticNodeHPLModel = AnalyticNodeHPLModel()

    def __call__(self, point: Point) -> Tuple[float, float]:
        return self.evaluate(point)

    def evaluate(self, point: Point) -> Tuple[float, float]:
        block = int(point["block"])
        if block < 1 or self.n % block:
            return INFEASIBLE
        nb_equiv = float(np.clip(block * 2048.0 / self.n, 64.0, 4096.0))
        return self.node.evaluate({
            "f_mhz": self.f_mhz, "vid": self.vid, "fan": self.fan,
            "nb": nb_equiv, "lookahead": int(point.get("lookahead", 1))})


# ---------------------------------------------------------------------------
# Analytic Pallas-kernel tile models (TPU roofline + TPU power model)
# ---------------------------------------------------------------------------

# Fixed cost per grid step (DMA issue + pipeline refill); pushes the
# tuner toward bigger tiles until VMEM pushes back.
GRID_STEP_OVERHEAD_S = 1.0e-6
# Inputs are double-buffered (see the Pallas guide's pipelining pattern),
# and the budget leaves headroom for the compiler's own allocations.
VMEM_BUDGET = 0.8 * hw.VMEM_PER_CORE


@dataclass(frozen=True)
class AnalyticDgemmModel:
    """(perf, power) of the tiled-matmul kernel for tile point
    ``{bm, bn, bk}`` on an (m, k) @ (k, n) problem."""

    m: int
    k: int
    n: int
    itemsize: int = 4              # float32 operands

    def __call__(self, point: Point) -> Tuple[float, float]:
        return self.evaluate(point)

    def evaluate(self, point: Point) -> Tuple[float, float]:
        bm, bn, bk = int(point["bm"]), int(point["bn"]), int(point["bk"])
        if self.m % bm or self.n % bn or self.k % bk:
            return INFEASIBLE
        vmem = (2 * (bm * bk + bk * bn) * self.itemsize   # double-buffered in
                + bm * bn * 4                             # f32 accumulator
                + bm * bn * self.itemsize)                # out tile
        if vmem > VMEM_BUDGET:
            return INFEASIBLE
        flops = 2.0 * self.m * self.n * self.k
        # each k-strip of x re-streams once per N-tile (and y per M-tile)
        hbm = (self.m * self.k * (self.n // bn)
               + self.k * self.n * (self.m // bm)
               + self.m * self.n) * self.itemsize
        # MXU is 128x128: sub-128 tiles underfill the systolic array
        mxu_eff = min(bm, 128) * min(bn, 128) / (128.0 * 128.0)
        compute_s = flops / (hw.PEAK_BF16_FLOPS * mxu_eff)
        memory_s = hbm / hw.HBM_BW
        steps = (self.m // bm) * (self.n // bn) * (self.k // bk)
        t = max(compute_s, memory_s) + steps * GRID_STEP_OVERHEAD_S
        power = tpu_chip_power(1.0, compute_s / t, memory_s / t)
        return flops / t / 1e9, power


@dataclass(frozen=True)
class AnalyticDslashModel:
    """(perf, power) of the T-blocked D-slash kernel for ``{t_block}``.

    Memory-bound (the paper's thesis): time is streaming traffic over
    HBM bandwidth plus per-grid-step overhead; VMEM must hold the spinor
    + gauge block for ``t_block`` time slices (plus the two halo
    slices)."""

    lat: Tuple[int, int, int, int]
    real_bytes: int = 4            # float32 split re/im on TPU

    def __call__(self, point: Point) -> Tuple[float, float]:
        return self.evaluate(point)

    def evaluate(self, point: Point) -> Tuple[float, float]:
        from repro.lqcd.dirac import (dslash_bytes_per_site,
                                      dslash_flops_per_site)
        tb = int(point["t_block"])
        X, Y, Z, T = self.lat
        if T % tb:
            return INFEASIBLE
        vol = X * Y * Z * T
        site_bytes = (4 * 18 + 24) * self.real_bytes   # links + spinor
        vmem = X * Y * Z * (tb + 2) * site_bytes * 2   # in + out blocks
        if vmem > VMEM_BUDGET:
            return INFEASIBLE
        flops = vol * dslash_flops_per_site()
        hbm = vol * dslash_bytes_per_site(self.real_bytes,
                                          compressed_links=False)
        # T-halo slices are re-fetched once per grid step
        hbm += (T // tb) * 2 * X * Y * Z * site_bytes
        memory_s = hbm / hw.HBM_BW
        compute_s = flops / hw.PEAK_BF16_FLOPS
        t = max(memory_s, compute_s) + (T // tb) * GRID_STEP_OVERHEAD_S
        power = tpu_chip_power(1.0, compute_s / t, memory_s / t)
        return flops / t / 1e9, power


# ---------------------------------------------------------------------------
# Measured cost models (timed execution of the real code paths)
# ---------------------------------------------------------------------------

def _timeit(fn, reps: int = 2) -> float:
    fn()                           # compile / warm
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


@dataclass
class MeasuredDgemmModel:
    """Times the actual Pallas ``dgemm`` (interpret mode off-TPU); power
    from the TPU chip model at the analytic utilization split."""

    m: int
    k: int
    n: int
    reps: int = 2
    _xy: Optional[tuple] = field(default=None, repr=False)

    def _operands(self):
        if self._xy is None:
            import jax
            kx, ky = jax.random.split(jax.random.PRNGKey(0))
            import jax.numpy as jnp
            self._xy = (jax.random.normal(kx, (self.m, self.k), jnp.float32),
                        jax.random.normal(ky, (self.k, self.n), jnp.float32))
        return self._xy

    def __call__(self, point: Point) -> Tuple[float, float]:
        return self.evaluate(point)

    def evaluate(self, point: Point) -> Tuple[float, float]:
        analytic = AnalyticDgemmModel(self.m, self.k, self.n)
        model = analytic.evaluate(point)     # feasibility + power, once
        if model == INFEASIBLE:
            return INFEASIBLE
        import jax
        from repro.kernels.dgemm.ops import dgemm
        x, y = self._operands()
        bm, bn, bk = int(point["bm"]), int(point["bn"]), int(point["bk"])
        t = _timeit(lambda: jax.block_until_ready(
            dgemm(x, y, bm=bm, bn=bn, bk=bk)), self.reps)
        flops = 2.0 * self.m * self.n * self.k
        return flops / t / 1e9, model[1]


@dataclass
class MeasuredHPLModel:
    """Times ``linpack_run`` at the point's blocking; node power from the
    engine at the point's electrical settings (defaults: the paper's
    efficiency clock/fan).  Power uses the same block → NB-axis mapping
    as :class:`AnalyticHPLBlockingModel`, so bigger blocks cost watts
    here too — otherwise the efficiency trade could never pick a
    smaller block."""

    n: int = 192
    f_mhz: float = 774.0
    vid: float = 1.1425
    fan: float = 0.40

    def __call__(self, point: Point) -> Tuple[float, float]:
        return self.evaluate(point)

    def evaluate(self, point: Point) -> Tuple[float, float]:
        from repro.configs.hpl import HPLConfig
        from repro.hpl.linpack import linpack_run
        block = int(point["block"])
        la = int(point.get("lookahead", 1))
        if block < 1 or self.n % block:
            return INFEASIBLE
        cfg = HPLConfig(n=self.n, block=block, lookahead=la)
        res = linpack_run(cfg)
        if not res.passed:
            return INFEASIBLE
        nb_equiv = float(np.clip(block * 2048.0 / self.n, 64.0, 4096.0))
        node = AnalyticNodeHPLModel()
        _, power = node.evaluate({"f_mhz": self.f_mhz, "vid": self.vid,
                                  "fan": self.fan, "nb": nb_equiv,
                                  "lookahead": la})
        return res.gflops, power
