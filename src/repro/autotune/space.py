"""Search spaces for the power-aware operating-point autotuner.

The paper's record came from an *offline search* over GPU clock, voltage
ID, fan duty and HPL blocking (§2–4); this module makes that parameter
space a first-class object.  A :class:`Space` is an ordered mapping of
axis name → discrete candidate values; searchers enumerate it (grid) or
walk it one axis at a time (coordinate descent).

Three concrete spaces ship with the repo:

  * :func:`operating_space` — the node-level space the paper swept:
    frequency (the S9150's DPM states), voltage ID, fan duty, HPL block
    size and lookahead depth;
  * :func:`dgemm_tile_space` — Pallas ``dgemm`` tile shapes (bm, bn, bk);
  * :func:`dslash_tile_space` — Pallas D-slash ``t_block`` choices.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, Sequence, Tuple

from repro.power.model import (NB_EFFICIENCY, NB_PERFORMANCE,  # noqa: F401
                               V_MAX, V_MIN)

# The S9150 (Hawaii) exposes a small set of firmware DPM clock states;
# 774 MHz is the one the paper locked for the Green500 run.  The grid is
# the *supported* states, not a continuum — exactly like the real sweep.
S9150_DPM_STATES_MHZ: Tuple[float, ...] = (300.0, 457.0, 562.0, 662.0,
                                           774.0, 851.0, 900.0)


@dataclass(frozen=True)
class Space:
    """An ordered, finite, discrete search space.

    ``axes`` maps axis name → tuple of candidate values.  Iteration order
    is deterministic (itertools.product over the axes in insertion
    order), which makes every searcher reproducible.
    """

    axes: Dict[str, Tuple[Any, ...]] = field(default_factory=dict)

    def __post_init__(self):
        for name, values in self.axes.items():
            if not values:
                raise ValueError(f"axis {name!r} has no candidate values")

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(self.axes)

    @property
    def size(self) -> int:
        n = 1
        for values in self.axes.values():
            n *= len(values)
        return n

    def points(self) -> Iterator[Dict[str, Any]]:
        names = self.names
        for combo in itertools.product(*(self.axes[n] for n in names)):
            yield dict(zip(names, combo))

    def first(self) -> Dict[str, Any]:
        return {n: v[0] for n, v in self.axes.items()}

    def with_axis(self, name: str, values: Sequence[Any]) -> "Space":
        axes = dict(self.axes)
        axes[name] = tuple(values)
        return Space(axes)

    def neighbors(self, point: Dict[str, Any], axis: str
                  ) -> Iterator[Dict[str, Any]]:
        """All points differing from ``point`` only along ``axis``."""
        for v in self.axes[axis]:
            yield {**point, axis: v}


def operating_space(*,
                    freqs_mhz: Sequence[float] = S9150_DPM_STATES_MHZ,
                    vids: Sequence[float] = (V_MIN, 1.16, 1.175, V_MAX),
                    fans: Sequence[float] = tuple(
                        round(0.20 + 0.05 * i, 2) for i in range(17)),
                    hpl_blocks: Sequence[int] = (NB_EFFICIENCY,
                                                 NB_PERFORMANCE),
                    lookaheads: Sequence[int] = (1, 2)) -> Space:
    """The paper's node operating-point space (§2–4).

    Fan duty runs 20%…100% in 5% steps (below ~20% the cards overheat
    immediately — the paper never ran there), voltage IDs span the
    published manufacturing range, and blocking is HPL-GPU's
    efficiency/performance NB pair.
    """
    return Space({
        "f_mhz": tuple(float(f) for f in freqs_mhz),
        "vid": tuple(float(v) for v in vids),
        "fan": tuple(float(s) for s in fans),
        "nb": tuple(int(b) for b in hpl_blocks),
        "lookahead": tuple(int(d) for d in lookaheads),
    })


def _tile_candidates(dim: int, choices: Sequence[int]) -> Tuple[int, ...]:
    """Tile sizes from ``choices`` that divide ``dim`` (plus ``dim`` itself
    when it is small enough to be its own tile)."""
    ok = [c for c in choices if c <= dim and dim % c == 0]
    if not ok:
        ok = [dim]
    return tuple(sorted(set(ok)))


def dgemm_tile_space(m: int, k: int, n: int,
                     choices: Sequence[int] = (128, 256, 512)) -> Space:
    """MXU-aligned (bm, bn, bk) candidates that tile an (m, k) @ (k, n)
    matmul exactly (the kernel asserts divisibility)."""
    return Space({
        "bm": _tile_candidates(m, choices),
        "bn": _tile_candidates(n, choices),
        "bk": _tile_candidates(k, choices),
    })


def dslash_tile_space(lat: Tuple[int, int, int, int],
                      choices: Sequence[int] = (1, 2, 4, 8)) -> Space:
    """T-axis block candidates for the D-slash kernels (grid runs over
    T / t_block; t_block must divide T).  Blocks are capped at T/2 so
    the ±1 halo slices always come from *neighboring* grid blocks — the
    kernel's overlapping index maps are validated in that regime."""
    T = lat[3]
    capped = [c for c in choices if c <= max(T // 2, 1)]
    return Space({"t_block": _tile_candidates(T, capped or [1])})
