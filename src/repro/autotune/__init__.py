"""Power-aware operating-point autotuner (the paper's offline search as
a first-class subsystem).

The Green500 record was found, not configured: the paper swept GPU
clock, voltage ID, fan duty and HPL blocking and took the MFLOPS/W
optimum subject to an acceptable Linpack loss (§2–4).  This package
reproduces that search and generalizes it to the repo's Pallas kernels:

  * :mod:`repro.autotune.space`   — discrete search spaces
  * :mod:`repro.autotune.search`  — grid + coordinate-descent searchers
  * :mod:`repro.autotune.measure` — analytic (CI-safe) and measured
    cost models
  * :mod:`repro.autotune.cache`   — JSON cache of winning configs keyed
    by (kernel, shape, device); the ``tuned=True`` paths in
    ``hpl/linpack.py`` and the kernel ops consult it

Quick use::

    from repro.autotune import tune_operating_point
    res = tune_operating_point()          # analytic, < 1 s
    res.best.point   # {'f_mhz': 774.0, 'vid': 1.1425, 'fan': 0.4,
                     #  'nb': 512, 'lookahead': 1}
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

from repro.autotune.cache import (CacheEntry, TuneCache, cache_key,
                                  default_cache, set_default_cache)
from repro.autotune.measure import (AnalyticDgemmModel, AnalyticDslashModel,
                                    AnalyticHPLBlockingModel,
                                    AnalyticNodeHPLModel, MeasuredDgemmModel,
                                    MeasuredHPLModel, temp_from_fan)
from repro.autotune.search import (Candidate, TuneResult,
                                   coordinate_descent, grid_search)
from repro.autotune.space import (NB_EFFICIENCY, NB_PERFORMANCE,
                                  S9150_DPM_STATES_MHZ, Space,
                                  dgemm_tile_space, dslash_tile_space,
                                  operating_space)

__all__ = [
    "AnalyticDgemmModel", "AnalyticDslashModel", "AnalyticHPLBlockingModel",
    "AnalyticNodeHPLModel", "CacheEntry", "Candidate", "EFFICIENCY_PERF_LOSS",
    "MeasuredDgemmModel", "MeasuredHPLModel", "NB_EFFICIENCY",
    "NB_PERFORMANCE", "S9150_DPM_STATES_MHZ", "Space", "TuneCache",
    "TuneResult", "cache_key", "coordinate_descent", "default_cache",
    "dgemm_tile_space", "dslash_tile_space", "grid_search",
    "operating_space", "set_default_cache", "temp_from_fan",
    "tune_dgemm_tiles", "tune_dslash_tblock", "tune_hpl_blocking",
    "tune_operating_point", "tuned_config",
]

# The paper traded ~13–15% Linpack for the efficiency record (301.5
# TFLOPS at 774 MHz vs the ~6.25 GFLOPS/node performance mode at 900);
# "efficiency mode" accepts up to this much loss.
EFFICIENCY_PERF_LOSS = 0.16


def _search(space: Space, model, *, method: str,
            max_perf_loss: float) -> TuneResult:
    if method == "grid":
        return grid_search(space, model, max_perf_loss=max_perf_loss)
    if method == "coordinate":
        return coordinate_descent(space, model, max_perf_loss=max_perf_loss)
    raise ValueError(f"unknown search method {method!r} "
                     "(expected 'grid' or 'coordinate')")


def tune_operating_point(*, space: Optional[Space] = None,
                         model=None, method: str = "grid",
                         max_perf_loss: float = EFFICIENCY_PERF_LOSS,
                         ) -> TuneResult:
    """Sweep the node operating-point space (clock, voltage ID, fan,
    HPL blocking, lookahead) for the MFLOPS/W optimum under the perf
    floor — the paper's record-setting search, analytic by default."""
    space = space or operating_space()
    model = model or AnalyticNodeHPLModel()
    return _search(space, model, method=method, max_perf_loss=max_perf_loss)


def tune_dgemm_tiles(m: int, k: int, n: int, *, measured: bool = False,
                     method: str = "grid", max_perf_loss: float = 0.10,
                     choices: Sequence[int] = (128, 256, 512)) -> TuneResult:
    """Tile-shape search for the ``dgemm`` Pallas kernel."""
    space = dgemm_tile_space(m, k, n, choices)
    model = MeasuredDgemmModel(m, k, n) if measured \
        else AnalyticDgemmModel(m, k, n)
    return _search(space, model, method=method, max_perf_loss=max_perf_loss)


def tune_dslash_tblock(lat: Tuple[int, int, int, int], *,
                       method: str = "grid",
                       max_perf_loss: float = 0.10) -> TuneResult:
    """T-block search for the D-slash Pallas kernels."""
    space = dslash_tile_space(lat)
    model = AnalyticDslashModel(lat)
    return _search(space, model, method=method, max_perf_loss=max_perf_loss)


def tune_hpl_blocking(n: int, *, measured: bool = False,
                      method: str = "grid",
                      max_perf_loss: float = EFFICIENCY_PERF_LOSS,
                      ) -> TuneResult:
    """Block-size/lookahead search for an ``n`` × ``n`` ``linpack_run``.

    Candidate blocks are the power-of-two fractions of ``n`` (down to
    32); the analytic model maps them onto the paper's NB axis, the
    measured model times real factorizations."""
    blocks = []
    b = n // 2
    while b >= 32:
        if n % b == 0:
            blocks.append(b)
        b //= 2
    if not blocks:
        blocks = [n]
    space = Space({"block": tuple(blocks), "lookahead": (1, 0, 2)})
    model = MeasuredHPLModel(n) if measured else AnalyticHPLBlockingModel(n)
    return _search(space, model, method=method, max_perf_loss=max_perf_loss)


# ---------------------------------------------------------------------------
# The tuned=True consult path
# ---------------------------------------------------------------------------

def _device_name() -> str:
    import jax
    return jax.default_backend()


def tuned_config(kernel: str, shape: Sequence[int], *,
                 device: Optional[str] = None,
                 cache: Optional[TuneCache] = None,
                 measured: bool = False) -> Dict[str, Any]:
    """Winning config for (kernel, shape, device) — cache hit, or run
    the tuner once and memoize.

    ``kernel`` is one of ``dgemm`` (shape (m, k, n) → {bm, bn, bk}),
    ``dslash`` (shape (X, Y, Z, T) → {t_block}), ``hpl`` (shape (n,) →
    {block, lookahead}) or ``operating_point`` (shape () → the full
    node point)."""
    device = device or _device_name()
    if cache is None:                # empty TuneCache is falsy (__len__)
        cache = default_cache()
    shape = tuple(int(d) for d in shape)
    hit = cache.get(kernel, shape, device)
    if hit is not None:
        return dict(hit.config)

    if kernel == "dgemm":
        m, k, n = shape
        res = tune_dgemm_tiles(m, k, n, measured=measured)
    elif kernel == "dslash":
        res = tune_dslash_tblock(shape)  # type: ignore[arg-type]
    elif kernel == "hpl":
        (n,) = shape
        res = tune_hpl_blocking(n, measured=measured)
    elif kernel == "operating_point":
        res = tune_operating_point()
    else:
        raise KeyError(f"unknown tunable kernel {kernel!r}")

    entry = CacheEntry(config=res.as_config(),
                       perf_gflops=res.best.perf_gflops,
                       power_w=res.best.power_w,
                       mflops_per_w=res.best.mflops_per_w,
                       model="measured" if measured else "analytic",
                       perf_loss=res.perf_loss)
    cache.put(kernel, shape, device, entry)
    return dict(entry.config)
