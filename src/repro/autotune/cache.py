"""JSON cache of winning configurations, keyed by (kernel, shape, device).

The ``tuned=True`` paths in ``hpl/linpack.py`` and the Pallas kernel ops
consult this cache instead of hard-coded constants; on a miss the
analytic tuner runs once and the winner is memoized (and, when a cache
file is configured, persisted).

File format (version 1)::

    {"version": 1,
     "entries": {
        "dgemm|1024x1024x1024|cpu": {
            "config": {"bm": 512, "bn": 512, "bk": 256},
            "perf_gflops": ..., "power_w": ..., "mflops_per_w": ...,
            "model": "analytic", "perf_loss": ...},
        ...}}

The cache path resolves from, in order: an explicit ``path`` argument,
the ``REPRO_AUTOTUNE_CACHE`` environment variable, or in-memory only
(no file I/O) — CI and tests stay hermetic by default.
"""
from __future__ import annotations

import json
import os
import threading
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import Any, Dict, Optional, Sequence, Tuple, Union

ENV_CACHE_PATH = "REPRO_AUTOTUNE_CACHE"
CACHE_VERSION = 1


@dataclass(frozen=True)
class CacheEntry:
    config: Dict[str, Any]
    perf_gflops: float = 0.0
    power_w: float = 0.0
    mflops_per_w: float = 0.0
    model: str = "analytic"        # analytic | measured
    perf_loss: float = 0.0         # vs the searcher's peak-perf point


def cache_key(kernel: str, shape: Sequence[int], device: str) -> str:
    dims = "x".join(str(int(d)) for d in shape)
    return f"{kernel}|{dims}|{device}"


class TuneCache:
    """Thread-safe (kernel, shape, device) → :class:`CacheEntry` store
    with JSON round-tripping."""

    def __init__(self, path: Union[str, Path, None] = None):
        self.path = Path(path) if path is not None else None
        # reentrant: put() holds the lock across its save()
        self._lock = threading.RLock()
        self._entries: Dict[str, CacheEntry] = {}
        if self.path is not None and self.path.exists():
            self.load(self.path)

    # -- access -------------------------------------------------------------
    def get(self, kernel: str, shape: Sequence[int],
            device: str) -> Optional[CacheEntry]:
        with self._lock:
            return self._entries.get(cache_key(kernel, shape, device))

    def put(self, kernel: str, shape: Sequence[int], device: str,
            entry: CacheEntry, *, persist: bool = True) -> None:
        with self._lock:
            self._entries[cache_key(kernel, shape, device)] = entry
            if persist and self.path is not None:
                self.save(self.path)

    def __len__(self) -> int:
        return len(self._entries)

    def keys(self) -> Tuple[str, ...]:
        with self._lock:
            return tuple(sorted(self._entries))

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    # -- persistence --------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        with self._lock:
            return {"version": CACHE_VERSION,
                    "entries": {k: asdict(v)
                                for k, v in sorted(self._entries.items())}}

    def save(self, path: Union[str, Path, None] = None) -> Path:
        path = Path(path) if path is not None else self.path
        if path is None:
            raise ValueError("no cache path configured")
        with self._lock:            # snapshot + write serialized together
            path.parent.mkdir(parents=True, exist_ok=True)
            # pid-unique tmp: concurrent processes never share a scratch
            # file; the final rename is atomic on POSIX either way
            tmp = path.with_suffix(path.suffix + f".{os.getpid()}.tmp")
            tmp.write_text(json.dumps(self.to_dict(), indent=1,
                                      sort_keys=True))
            tmp.replace(path)
        return path

    def load(self, path: Union[str, Path]) -> "TuneCache":
        raw = json.loads(Path(path).read_text())
        if raw.get("version") != CACHE_VERSION:
            raise ValueError(f"unsupported cache version "
                             f"{raw.get('version')!r} in {path}")
        entries = {k: CacheEntry(**v) for k, v in raw["entries"].items()}
        with self._lock:
            self._entries.update(entries)
        return self


# ---------------------------------------------------------------------------
# Process-wide default cache (what tuned=True consults)
# ---------------------------------------------------------------------------

_default: Optional[TuneCache] = None
_default_lock = threading.Lock()


def default_cache() -> TuneCache:
    """The singleton cache behind the ``tuned=True`` paths.  File-backed
    iff ``REPRO_AUTOTUNE_CACHE`` names a path; in-memory otherwise."""
    global _default
    with _default_lock:
        if _default is None:
            _default = TuneCache(os.environ.get(ENV_CACHE_PATH) or None)
        return _default


def set_default_cache(cache: Optional[TuneCache]) -> None:
    """Swap the singleton (tests; None re-resolves from the env)."""
    global _default
    with _default_lock:
        _default = cache
