"""Training & serving step builders + loops with energy accounting."""
from repro.runtime.steps import (  # noqa: F401
    make_train_step,
    make_prefill_step,
    make_decode_step,
)
