"""Step functions: train (fwd+bwd+AdamW), prefill, decode.

Builders return plain Python callables ready for ``jax.jit``; the launch
layer attaches in/out shardings and (for the dry-run) lowers them against
``ShapeDtypeStruct`` inputs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import MeshConfig, ModelConfig, TrainConfig
from repro.models import (forward_decode, forward_prefill,
                          forward_train_loss)
from repro.optim import adamw_update, lr_schedule


def make_train_step(cfg: ModelConfig, tc: TrainConfig,
                    mesh=None, mesh_cfg: Optional[MeshConfig] = None,
                    block_skip: bool = False):
    data_axes = mesh_cfg.data_axes if mesh_cfg is not None else ("data",)
    remat = tc.remat != "none"
    gdt = jnp.dtype(tc.grad_accum_dtype)

    def loss_fn(p, b):
        loss, metrics = forward_train_loss(
            cfg, p, b, mesh=mesh, data_axes=data_axes, remat=remat,
            block_skip=block_skip, remat_policy=tc.remat)
        return loss, metrics

    def train_step(params, opt_state, batch):
        M = tc.microbatches
        if M == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]),
                batch)

            def body(carry, b):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, b)
                gsum = jax.tree.map(
                    lambda a, x: a + x.astype(gdt), gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, gdt), params)
            (gsum, lsum), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda x: x / M, gsum)
            loss = lsum / M
            metrics = {"lm_loss": loss,
                       "aux_loss": jnp.zeros((), jnp.float32)}
        lr = lr_schedule(opt_state["step"], tc)
        params, opt_state, gnorm = adamw_update(grads, opt_state, params,
                                                lr, tc)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh=None,
                      mesh_cfg: Optional[MeshConfig] = None,
                      block_skip: bool = False, moe_fsdp: bool = True,
                      quantize_kv_cache: bool = False):
    data_axes = mesh_cfg.data_axes if mesh_cfg is not None else ("data",)

    def prefill_step(params, batch):
        return forward_prefill(cfg, params, batch, mesh=mesh,
                               data_axes=data_axes, block_skip=block_skip,
                               moe_fsdp=moe_fsdp,
                               quantize_kv_cache=quantize_kv_cache)

    return prefill_step


def grow_decode_cache(cfg: ModelConfig, cache: dict, batch_size: int,
                      total_len: int, *, dtype=None,
                      quantize_kv_cache: bool = False) -> dict:
    """Grow a prefill-sized decode cache to ``total_len`` positions.

    Allocates a fresh full-length cache via ``init_decode_cache`` and
    copies the prefilled entries into its leading slice (``pos`` moves
    verbatim; same-shape entries — e.g. SSM states, whose shape doesn't
    depend on sequence length — move without slicing).  Shared by the
    ``launch.serve`` driver and the replay engine's executed admission
    path (:class:`repro.serve.executed.ExecutedGroupRuntime`)."""
    from repro.models import init_decode_cache
    full = init_decode_cache(cfg, batch_size, total_len, dtype=dtype,
                             quantize_kv_cache=quantize_kv_cache)
    for k in cache:
        if k == "pos":
            full["pos"] = cache["pos"]
        elif full[k].shape == cache[k].shape:
            full[k] = cache[k]
        else:
            sl = tuple(slice(0, s) for s in cache[k].shape)
            full[k] = full[k].at[sl].set(cache[k])
    return full


def make_decode_step(cfg: ModelConfig, mesh=None,
                     mesh_cfg: Optional[MeshConfig] = None,
                     moe_fsdp: bool = True, moe_ep_data: bool = False):
    data_axes = mesh_cfg.data_axes if mesh_cfg is not None else ("data",)

    def decode_step(params, tokens, cache):
        return forward_decode(cfg, params, tokens, cache, mesh=mesh,
                              data_axes=data_axes, moe_fsdp=moe_fsdp,
                              moe_ep_data=moe_ep_data)

    return decode_step
