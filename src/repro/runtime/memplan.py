"""Automatic per-cell training memory plan.

Chooses (microbatches, moment dtype, grad-accum dtype, remat policy) so the
step fits the 16 GiB/chip HBM budget — the same decisions a production
launcher makes.  Verified post-hoc by ``compiled.memory_analysis()``.
"""
from __future__ import annotations

from dataclasses import replace

from repro.config import MeshConfig, ModelConfig, ShapeConfig, TrainConfig

HBM_BUDGET = 12 * 2**30            # leave headroom below the 16 GiB chip


def _block_size(n_layers: int) -> int:
    import math
    best = 1
    for b in range(1, int(math.isqrt(n_layers)) + 1):
        if n_layers % b == 0:
            best = b
    return best


def estimate_train_bytes(cfg: ModelConfig, shape: ShapeConfig,
                         mesh_cfg: MeshConfig, tc: TrainConfig) -> int:
    chips = mesh_cfg.n_devices
    dp, tp = mesh_cfg.data_size, mesh_cfg.model_size
    N = cfg.param_count()
    mdt = 2 if tc.moment_dtype == "bfloat16" else 4
    gdt = 2 if tc.grad_accum_dtype == "bfloat16" else 4
    static = N * (2 + 2 * mdt) // chips           # params + m + v
    # grad accumulator double-buffers as a scan carry
    grads = N * gdt * 2 // chips if tc.microbatches > 1 else N * 4 // chips

    B, S = shape.global_batch, shape.seq_len
    T_loc = B * S // dp // tc.microbatches
    res = T_loc * cfg.d_model * 2                 # one residual, bf16
    L = cfg.n_layers
    if tc.remat == "block":
        bs = _block_size(L)
        stored = (L // bs + bs) * res
    else:
        stored = L * res
    # per-layer transients live across the remat recompute window (inner
    # block): multiple activation-sized fp32/bf16 buffers coexist
    trans = 10 * res * 2
    if cfg.family != "ssm" and not cfg.mla.enabled:
        # blockwise attention: fp32 scores/accumulator blocks + stacked o
        Hl = cfg.n_heads / (tp if cfg.n_heads % tp == 0 else
                            (tp if True else 1))
        if cfg.n_heads % tp != 0:
            Hl = cfg.n_heads / tp      # seq-sharded path: S/tp rows, all H
        o_bytes = T_loc * cfg.n_heads * cfg.d_head * 4 / tp
        sc_bytes = (T_loc / max(S // 512, 1)) * cfg.n_heads / tp * 512 * 4
        trans += 3 * o_bytes + 4 * sc_bytes
    if cfg.mla.enabled:
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        trans += 4 * T_loc * cfg.n_heads * qk * 2 / tp
    if cfg.ssm.enabled:
        d_inner = cfg.ssm.expand * cfg.d_model
        in_dim = 2 * d_inner + 2 * cfg.ssm.n_groups * cfg.ssm.d_state \
            + d_inner // cfg.ssm.head_dim
        trans += 4 * T_loc * in_dim * 2           # proj/conv bf16 copies
        trans += 3 * T_loc * d_inner * 4          # gated-norm fp32 path
        Q = cfg.ssm.chunk_size
        Bl = max(T_loc // S, 1)
        trans += 2 * Bl * Q * Q * (d_inner // cfg.ssm.head_dim) * 4
    if cfg.moe.enabled:
        e = cfg.moe
        n_local = (e.n_experts // tp if e.n_experts % tp == 0
                   else e.n_experts)
        C = int(T_loc * e.top_k / e.n_experts * e.capacity_factor) + 1
        trans += 4 * (n_local + 1) * max(C, e.top_k) * cfg.d_model * 2
        trans += 2 * T_loc * e.top_k * cfg.d_model * 2
        trans += T_loc * cfg.d_model * 4          # fp32 combine
    # loss: fp32 logits chunk + lse buffers
    trans += 3 * (B // dp // tc.microbatches) * 1024 * cfg.vocab_padded \
        * 4 // tp
    if cfg.n_encoder_layers:
        enc_T = T_loc // cfg.encoder_ratio
        trans += cfg.n_encoder_layers * enc_T * cfg.d_model * 2
    # gathered layer weights (double buffered)
    from repro.roofline.analytic import layer_param_bytes
    trans += 2 * int(layer_param_bytes(cfg)) // tp
    fudge = 2.2 if cfg.ssm.enabled else 1.4
    return int(static + grads + stored + int(fudge * trans))


def auto_train_plan(cfg: ModelConfig, shape: ShapeConfig,
                    mesh_cfg: MeshConfig,
                    base: TrainConfig = TrainConfig()) -> TrainConfig:
    dp = mesh_cfg.data_size
    B = shape.global_batch
    valid_m = [m for m in (1, 2, 4, 8, 16, 32, 64) if B % (m * dp) == 0]
    if not valid_m:
        valid_m = [1]
    for moment in ("float32", "bfloat16"):
        for ga in ("float32", "bfloat16"):
            for m in valid_m:
                tc = replace(base, microbatches=m, moment_dtype=moment,
                             grad_accum_dtype=ga, remat="block")
                if estimate_train_bytes(cfg, shape, mesh_cfg, tc) <= HBM_BUDGET:
                    return tc
    return replace(base, microbatches=valid_m[-1], moment_dtype="bfloat16",
                   grad_accum_dtype="bfloat16", remat="block")
