"""Three-term roofline from a compiled dry-run artifact.

  compute term    = HLO_FLOPs / peak_FLOP/s            (per chip)
  memory term     = HLO_bytes / HBM_bw                 (per chip)
  collective term = wire_bytes / link_bw               (per chip)

``cost_analysis()`` supplies per-chip FLOPs and bytes (the SPMD module is
per-device).  Collective bytes are *not* in cost_analysis: we parse the
post-optimization HLO (``compiled.as_text()``) and convert every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
into wire bytes via the standard ring formulas.  Collectives whose replica
group crosses the pod boundary are charged at DCN bandwidth.

Caveat recorded in EXPERIMENTS.md: XLA:CPU's `bytes accessed` counts
operand+output bytes per (fused) op — an upper bound on true HBM traffic;
relative comparisons between iterations remain meaningful.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "u1": 1, "s1": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\]"
                             r"(?:T\(([\d,]+)\))?")


def shape_bytes(shape_str: str) -> int:
    """Bytes of 'bf16[2,3,4]' or a '(t1, t2)' tuple string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_group(line: str, n_devices: int) -> List[int]:
    """Device ids of the first replica group on the line."""
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return [int(x) for x in m.group(1).split(",") if x.strip()]
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        base = np.arange(g * s)
        reshape_dims = [int(x) for x in m.group(3).split(",")]
        arr = base.reshape(reshape_dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            arr = arr.transpose(perm)
        return list(arr.reshape(g, s)[0])
    return list(range(n_devices))


@dataclass
class CollectiveStats:
    kind: str
    count: int = 0
    out_bytes: int = 0
    wire_bytes: float = 0.0          # per-chip, ring-model
    cross_pod: bool = False


def collective_bytes_from_hlo(hlo_text: str, n_devices: int,
                              pod_size: int = 0,
                              ) -> Tuple[float, float, Dict[str, dict]]:
    """Returns (ici_wire_bytes, dcn_wire_bytes, per-kind stats) per chip."""
    stats: Dict[str, CollectiveStats] = {}
    ici, dcn = 0.0, 0.0
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if m is None:
            continue
        shape_str, kind = m.group(1), m.group(2)
        nbytes = shape_bytes(shape_str)
        group = _first_group(line, n_devices)
        g = max(len(group), 1)
        if g <= 1:
            continue
        if kind == "all-gather":
            wire = nbytes * (g - 1) / g
        elif kind == "all-reduce":
            wire = 2.0 * nbytes * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = nbytes * (g - 1)          # out is the scattered piece
        elif kind == "all-to-all":
            wire = nbytes * (g - 1) / g
        else:                                 # collective-permute
            wire = float(nbytes)
        cross = pod_size > 0 and len({d // pod_size for d in group}) > 1
        key = kind + ("/dcn" if cross else "")
        st = stats.setdefault(key, CollectiveStats(kind=key))
        st.count += 1
        st.out_bytes += nbytes
        st.wire_bytes += wire
        st.cross_pod = cross
        if cross:
            dcn += wire
        else:
            ici += wire
    return ici, dcn, {k: asdict(v) for k, v in stats.items()}


# ---------------------------------------------------------------------------

def model_flops(param_count: int, active_param_count: int, tokens: int,
                kind: str) -> float:
    """MODEL_FLOPS: 6·N·D train (fwd+bwd), 2·N·D forward-only."""
    n = active_param_count
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float                 # per chip
    hlo_bytes: float                 # per chip
    ici_bytes: float                 # per chip
    dcn_bytes: float                 # per chip
    model_flops_total: float
    useful_ratio: float              # MODEL_FLOPS / (HLO_FLOPs × chips)
    dominant: str = ""
    collectives: Dict[str, dict] = field(default_factory=dict)

    def __post_init__(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.dominant = max(terms, key=terms.get)

    @property
    def step_time_lower_bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs utilization if the step ran at its roofline bound."""
        t = self.step_time_lower_bound_s
        if t <= 0:
            return 0.0
        per_chip_useful = self.model_flops_total / max(
            1, self._chips) / t
        return per_chip_useful / hw.PEAK_BF16_FLOPS

    _chips: int = 1


def analyze(flops_per_chip: float, bytes_per_chip: float,
            ici_bytes: float, dcn_bytes: float, chips: int,
            model_flops_total: float,
            collectives: Optional[Dict[str, dict]] = None) -> RooflineTerms:
    compute_s = flops_per_chip / hw.PEAK_BF16_FLOPS
    memory_s = bytes_per_chip / hw.HBM_BW
    collective_s = ici_bytes / hw.ICI_LINK_BW + dcn_bytes / hw.DCN_POD_BW
    useful = model_flops_total / max(flops_per_chip * chips, 1.0)
    t = RooflineTerms(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        hlo_flops=flops_per_chip, hlo_bytes=bytes_per_chip,
        ici_bytes=ici_bytes, dcn_bytes=dcn_bytes,
        model_flops_total=model_flops_total, useful_ratio=useful,
        collectives=collectives or {})
    t._chips = chips
    return t


def analyze_compiled(compiled, n_devices: int, model_flops_total: float,
                     pod_size: int = 0) -> RooflineTerms:
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    ici, dcn, stats = collective_bytes_from_hlo(hlo, n_devices, pod_size)
    return analyze(flops, nbytes, ici, dcn, n_devices, model_flops_total,
                   stats)
