"""First-principles per-step cost model (FLOPs / HBM bytes / wire bytes).

Why this exists: XLA:CPU's ``HloCostAnalysis`` counts ``while``-loop bodies
ONCE, so any scanned program (layers, microbatches, attention chunks) is
undercounted by the trip count.  The dry-run keeps the HLO-parsed numbers for
verification, but the roofline terms come from this model — the same napkin
math the §Perf methodology demands, parameterized by the exact sharding and
remat/microbatch plan the step was compiled with.

All outputs are PER CHIP PER STEP.  Conventions:
  T   total tokens in the global batch (B*S; decode: B)
  dp  data-parallel world (pod*data axes), tp model axis
  matmul FLOPs = 2*m*n*k; backward = 2x forward; remat adds recompute.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.config import MeshConfig, ModelConfig, ShapeConfig, TrainConfig
from repro.models.frontend import enc_len_for
from repro.roofline import hw


@dataclass
class AnalyticCost:
    flops: float                   # per chip
    hbm_bytes: float               # per chip
    ici_bytes: float               # per chip (wire)
    dcn_bytes: float               # per chip (wire)
    detail: Dict[str, float]

    @property
    def compute_s(self) -> float:
        return self.flops / hw.PEAK_BF16_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / hw.HBM_BW

    @property
    def collective_s(self) -> float:
        return (self.ici_bytes / hw.ICI_LINK_BW
                + self.dcn_bytes / hw.DCN_POD_BW)


def _attn_dims(cfg: ModelConfig):
    if cfg.mla.enabled:
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        return qk, m.v_head_dim
    return cfg.d_head, cfg.d_head


def layer_param_bytes(cfg: ModelConfig) -> float:
    """Per-layer parameter bytes (bf16)."""
    body = (cfg.param_count()
            - cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2))
    layers = cfg.n_layers + cfg.n_encoder_layers
    return 2.0 * body / max(layers, 1)


def _attn_flops_fwd(cfg: ModelConfig, T: float, S_kv: float,
                    causal_factor: float) -> float:
    """Projections + scores/AV for T query tokens against S_kv keys."""
    d = cfg.d_model
    qk, vd = _attn_dims(cfg)
    H, KVH = cfg.n_heads, cfg.n_kv_heads
    if cfg.mla.enabled:
        m = cfg.mla
        f = 0.0
        f += 2 * T * d * m.q_lora_rank                      # q down
        f += 2 * T * m.q_lora_rank * H * qk                 # q up
        f += 2 * T * d * (m.kv_lora_rank + m.qk_rope_head_dim)
        f += 2 * T * m.kv_lora_rank * H * (m.qk_nope_head_dim + vd)
        f += 2 * T * H * vd * d                             # out
    else:
        f = 2 * T * d * (H + 2 * KVH) * cfg.d_head          # qkv proj
        f += 2 * T * H * cfg.d_head * d                     # out proj
    win = cfg.sliding_window
    eff_kv = min(S_kv, win) if win else S_kv
    f += 2 * 2 * T * eff_kv * H * qk * causal_factor        # scores + AV
    return f


def _ssm_flops_fwd(cfg: ModelConfig, T: float) -> float:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    H = di // s.head_dim
    P, N, Q = s.head_dim, s.d_state, s.chunk_size
    in_dim = 2 * di + 2 * s.n_groups * N + H
    f = 2 * T * d * in_dim + 2 * T * di * d                 # in/out proj
    f += 2 * T * s.d_conv * (di + 2 * s.n_groups * N)       # conv
    # SSD: intra-chunk (CB^T: Q*N per pair; weighted AV: Q*P) + states
    f += 2 * T * Q * s.n_groups * N                         # C·B within chunk
    f += 2 * T * Q * H * P * 0.5                            # masked AV
    f += 2 * 2 * T * H * P * N                              # state in/out
    return f


def _mlp_flops_fwd(cfg: ModelConfig, T: float) -> float:
    if cfg.moe.enabled:
        e = cfg.moe
        f = 2 * T * cfg.d_model * e.n_experts               # router
        slots = e.top_k * e.capacity_factor                 # per token
        nmat = 3 if cfg.mlp_variant in ("swiglu", "geglu") else 2
        f += nmat * 2 * T * slots * cfg.d_model * e.expert_d_ff
        f += (e.n_shared_experts * nmat * 2 * T * cfg.d_model
              * e.expert_d_ff)
        return f
    if cfg.d_ff == 0:
        return 0.0
    nmat = 3 if cfg.mlp_variant in ("swiglu", "geglu") else 2
    return nmat * 2 * T * cfg.d_model * cfg.d_ff


def forward_flops(cfg: ModelConfig, B: int, S: int, S_kv: float,
                  causal_factor: float) -> float:
    """Total forward FLOPs across the cluster for B sequences of S tokens
    attending to S_kv history."""
    T = float(B) * S
    per_layer = 0.0
    if cfg.family != "ssm":
        per_layer += _attn_flops_fwd(cfg, T, S_kv, causal_factor)
    if cfg.family in ("ssm", "hybrid"):
        per_layer += _ssm_flops_fwd(cfg, T)
    per_layer += _mlp_flops_fwd(cfg, T)
    total = cfg.n_layers * per_layer
    if cfg.family == "encdec":
        T_enc = float(B) * enc_len_for(cfg, S)
        enc_layer = (_attn_flops_fwd(
            cfg, T_enc, enc_len_for(cfg, S), 1.0)
            + _mlp_flops_fwd(cfg, T_enc))
        total += cfg.n_encoder_layers * enc_layer
        # decoder cross-attention: q/out for T, kv for T_enc, scores T x enc
        d, dh, H, KVH = cfg.d_model, cfg.d_head, cfg.n_heads, cfg.n_kv_heads
        total += cfg.n_layers * (
            2 * T * d * (H + 0) * dh + 2 * T * H * dh * d
            + 2 * T_enc * d * 2 * KVH * dh
            + 2 * 2 * T * enc_len_for(cfg, S) * H * dh)
    total += 2 * T * cfg.d_model * cfg.vocab_padded          # lm head
    return total


REMAT_EXTRA = {"none": 0.0, "layer": 1.0, "block": 2.0}


def train_cost(cfg: ModelConfig, shape: ShapeConfig, mesh_cfg: MeshConfig,
               tc: TrainConfig, *, block_skip: bool = False) -> AnalyticCost:
    B, S = shape.global_batch, shape.seq_len
    chips = mesh_cfg.n_devices
    dp = mesh_cfg.data_size
    tp = mesh_cfg.model_size
    M = tc.microbatches
    causal = 0.55 if block_skip else 1.0      # triangular scan ~ (nq+1)/2nq

    fwd = forward_flops(cfg, B, S, S, causal)
    extra = REMAT_EXTRA.get(tc.remat, 1.0)
    flops_total = fwd * (3.0 + extra)
    flops_chip = flops_total / chips

    # ---- HBM traffic per chip --------------------------------------------
    pbytes = 2.0 * cfg.param_count()          # bf16, cluster-total
    pbytes_tp = pbytes / tp                   # per chip after FSDP gather
    n_passes = (2.0 + extra) * M              # fwd + bwd + recompute, per mb
    w_traffic = pbytes_tp * n_passes          # gathered weights read
    mdt = 2.0 if tc.moment_dtype == "bfloat16" else 4.0
    opt_traffic = (cfg.param_count() / chips) * (2 * mdt * 2 + 4 + 2 + 2)
    # m,v read+write; grad read fp32; param read+write bf16
    T_loc = float(B) * S / dp / M
    act = T_loc * cfg.d_model * 2.0           # one residual, bf16
    act_traffic_layer = 8.0 * act             # in/out + norms + proj I/O
    if cfg.family != "ssm":
        # blockwise attention re-reads K/V once per q-chunk pass
        qk, _ = _attn_dims(cfg)
        win = cfg.sliding_window or S
        kv_bytes = T_loc * cfg.n_kv_heads * cfg.d_head * 2 * 2
        n_q_passes = max(min(S, win) // 512, 1)
        act_traffic_layer += kv_bytes / tp * n_q_passes * 0.25
    act_traffic = (act_traffic_layer * cfg.n_layers * M * (2.0 + extra)
                   / max(tp, 1) ** 0)         # activations not TP-sharded
    hbm = w_traffic + opt_traffic + act_traffic

    # ---- Collectives ------------------------------------------------------
    lw = layer_param_bytes(cfg) / tp          # per-chip gathered layer bytes
    L = cfg.n_layers + cfg.n_encoder_layers
    gathers = (1.0 + extra) * M + 1.0         # fwd(+recompute) AG + bwd AG
    ag = L * lw * (dp - 1) / dp * gathers
    rs = L * (lw * 2) * (dp - 1) / dp * M     # fp32 grad reduce-scatter
    act_bytes = T_loc * cfg.d_model * 2.0
    ar_per_layer = 2.0 * (2.0 * act_bytes * (tp - 1) / tp)  # 2 ARs (attn+mlp)
    tp_ar = L * ar_per_layer * M * (2.0 + extra)
    if cfg.moe.enabled:
        tp_ar += cfg.n_layers * 2.0 * (T_loc * cfg.d_model * 4.0) \
            * (tp - 1) / tp * M * (2.0 + extra)
    wire = ag + rs + tp_ar
    ici, dcn = wire, 0.0
    if mesh_cfg.multi_pod:
        pod = mesh_cfg.shape[0]
        frac = (pod - 1) / pod / (dp - 1) * dp  # share of dp hops crossing pods
        dcn = (ag + rs) * min(frac, 1.0) * 0.5
        ici = wire - dcn
    return AnalyticCost(flops_chip, hbm, ici, dcn, {
        "fwd_flops_total": fwd, "weight_traffic": w_traffic,
        "opt_traffic": opt_traffic, "act_traffic": act_traffic,
        "fsdp_ag": ag, "grad_rs": rs, "tp_ar": tp_ar})


def prefill_cost(cfg: ModelConfig, shape: ShapeConfig, mesh_cfg: MeshConfig,
                 *, block_skip: bool = False,
                 serve_tp_only: bool = True) -> AnalyticCost:
    B, S = shape.global_batch, shape.seq_len
    chips = mesh_cfg.n_devices
    dp, tp = mesh_cfg.data_size, mesh_cfg.model_size
    causal = 0.55 if block_skip else 1.0
    fwd = forward_flops(cfg, B, S, S, causal)
    flops_chip = fwd / chips

    pbytes_tp = 2.0 * cfg.param_count() / tp
    T_loc = float(B) * S / dp
    act_traffic = 8.0 * T_loc * cfg.d_model * 2.0 * cfg.n_layers
    cache_write = _cache_bytes(cfg, B, S) / chips
    hbm = pbytes_tp + act_traffic + cache_write

    L = cfg.n_layers + cfg.n_encoder_layers
    act_bytes = T_loc * cfg.d_model * 2.0
    wire = L * 2.0 * (2.0 * act_bytes * (tp - 1) / tp)
    if not serve_tp_only:
        wire += L * (layer_param_bytes(cfg) / tp) * (dp - 1) / dp
    ici, dcn = wire, 0.0
    if mesh_cfg.multi_pod:
        dcn = wire * 0.1
        ici = wire - dcn
    return AnalyticCost(flops_chip, hbm, ici, dcn,
                        {"fwd_flops_total": fwd,
                         "cache_write": cache_write})


def _cache_bytes(cfg: ModelConfig, B: int, S: int,
                 kv_int8: bool = False) -> float:
    total = 0.0
    L = cfg.n_layers
    W = cfg.sliding_window
    S_eff = min(S, W) if W else S
    if cfg.family != "ssm":
        if cfg.mla.enabled:
            m = cfg.mla
            total += L * B * S * (m.kv_lora_rank + m.qk_rope_head_dim) * 2
        else:
            per_elem = 1 if kv_int8 else 2
            total += 2 * L * B * S_eff * cfg.n_kv_heads * cfg.d_head \
                * per_elem
            if kv_int8:
                total += 2 * L * B * S_eff * cfg.n_kv_heads * 4  # scales
    if cfg.family in ("ssm", "hybrid"):
        di = cfg.ssm.expand * cfg.d_model
        H = di // cfg.ssm.head_dim
        total += L * B * H * cfg.ssm.head_dim * cfg.ssm.d_state * 4
        total += L * B * (cfg.ssm.d_conv - 1) * (
            di + 2 * cfg.ssm.n_groups * cfg.ssm.d_state) * 2
    if cfg.family == "encdec":
        total += 2 * L * B * enc_len_for(cfg, S) * cfg.n_kv_heads \
            * cfg.d_head * 2
    return total


def decode_cost(cfg: ModelConfig, shape: ShapeConfig, mesh_cfg: MeshConfig,
                *, serve_tp_only: bool = True,
                kv_int8: bool = False, moe_ep: bool = False,
                replicas: int = 1) -> AnalyticCost:
    """``moe_ep``: experts resident over the data axes (no weight gathers);
    ``replicas > 1``: replica-parallel serving — the mesh runs ``replicas``
    independent copies of the model, each on chips/replicas devices (the
    right-sizing fix for tiny-batch long-context streams)."""
    B, S = shape.global_batch, shape.seq_len
    chips = mesh_cfg.n_devices // replicas
    dp = max(mesh_cfg.data_size // replicas, 1)
    tp = mesh_cfg.model_size if replicas == 1 else max(
        mesh_cfg.n_devices // replicas // dp, 1)
    fwd = forward_flops(cfg, B, 1, S, 1.0)
    flops_chip = fwd / chips

    active_b = 2.0 * cfg.active_param_count()
    if moe_ep:
        # fully resident: dense part over tp, experts over all chips
        dense_b = 2.0 * (cfg.active_param_count()
                         - cfg.n_layers * cfg.moe.n_experts * 0)
        weight_read = 2.0 * cfg.param_count() / chips \
            + (active_b - 2.0 * cfg.param_count() / chips * 0) * 0
        weight_read = 2.0 * cfg.param_count() / chips
    else:
        weight_read = active_b / tp
    # cache read once; write is only the new token's K/V (tiny)
    cache_rw = _cache_bytes(cfg, B, S, kv_int8) / chips * 1.02
    hbm = weight_read + cache_rw + 4.0 * float(B) / dp * cfg.d_model * 2 \
        * cfg.n_layers

    L = cfg.n_layers
    act_bytes = float(B) / dp * cfg.d_model * 2.0
    wire = L * 2.0 * (2.0 * act_bytes * (tp - 1) / tp)
    # softmax reductions over the seq-sharded cache: ~3 scalars/head/token
    wire += L * 3.0 * float(B) / dp * cfg.n_heads * 4.0 * 2 * (tp - 1) / tp
    if moe_ep:
        # token AG over data + output RS over data + psum over model
        tok = float(B) * cfg.d_model * 2.0
        wire += L * (2.0 * tok * (dp - 1) / dp
                     + 2.0 * tok * (tp - 1) / tp)
    elif not serve_tp_only:
        wire += L * (layer_param_bytes(cfg) / tp) * (dp - 1) / dp
    ici, dcn = wire, 0.0
    if mesh_cfg.multi_pod and replicas == 1:
        dcn = wire * 0.1
        ici = wire - dcn
    return AnalyticCost(flops_chip, hbm, ici, dcn,
                        {"fwd_flops_total": fwd, "weight_read": weight_read,
                         "cache_rw": cache_rw, "replicas": replicas})


def cost_for(cfg: ModelConfig, shape: ShapeConfig, mesh_cfg: MeshConfig,
             tc: Optional[TrainConfig] = None, *, block_skip: bool = False,
             serve_tp_only: bool = True,
             kv_int8: bool = False, moe_ep: bool = False,
             replicas: int = 1) -> AnalyticCost:
    if shape.kind == "train":
        return train_cost(cfg, shape, mesh_cfg, tc or TrainConfig(),
                          block_skip=block_skip)
    if shape.kind == "prefill":
        return prefill_cost(cfg, shape, mesh_cfg, block_skip=block_skip,
                            serve_tp_only=serve_tp_only)
    return decode_cost(cfg, shape, mesh_cfg, serve_tp_only=serve_tp_only,
                       kv_int8=kv_int8, moe_ep=moe_ep, replicas=replicas)
