"""Roofline analysis from compiled dry-run artifacts."""
from repro.roofline.analysis import (  # noqa: F401
    RooflineTerms,
    analyze_compiled,
    collective_bytes_from_hlo,
    model_flops,
)
