"""Render the dry-run sweep into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional


def load_cells(dryrun_dir: Path, mesh: str = "pod1",
               variant: str = "baseline") -> List[dict]:
    cells = []
    for f in sorted(dryrun_dir.glob(f"*--{mesh}--{variant}.json")):
        cells.append(json.loads(f.read_text()))
    return cells


def fmt_s(x: Optional[float]) -> str:
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def markdown_table(cells: List[dict]) -> str:
    hdr = ("| arch | shape | status | compute | memory | collective | "
           "dominant | useful | frac | HBM GiB | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for c in cells:
        if c["status"] == "skip":
            lines.append(f"| {c['arch']} | {c['shape']} | SKIP | - | - | - "
                         f"| - | - | - | - | - |")
            continue
        if c["status"] != "ok":
            lines.append(f"| {c['arch']} | {c['shape']} | ERROR | | | | | "
                         f"| | | |")
            continue
        r = c["roofline"]
        hbm = c["memory"].get("total_hbm_bytes", 0) / 2**30
        frac = r.get("bw_useful_ratio") or r.get("roofline_fraction")
        lines.append(
            f"| {c['arch']} | {c['shape']} | ok | {fmt_s(r['compute_s'])} "
            f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
            f"| {r['dominant']} | {r['useful_ratio']:.2f} "
            f"| {frac:.3f} | {hbm:.1f} | "
            f"{'Y' if c.get('fits_hbm') else 'N'} |")
    return "\n".join(lines)


def pick_hillclimb_cells(cells: List[dict]) -> Dict[str, dict]:
    ok = [c for c in cells if c["status"] == "ok"]
    worst = min(ok, key=lambda c: (c["roofline"].get("bw_useful_ratio")
                                   or c["roofline"]["roofline_fraction"]))
    coll = max(ok, key=lambda c: c["roofline"]["collective_s"]
               / max(c["roofline"]["step_lower_bound_s"], 1e-12))
    decode = [c for c in ok if c["shape"] in ("decode_32k", "long_500k")]
    paper_rep = max(decode, key=lambda c: c["roofline"]["memory_s"])
    return {"worst_fraction": worst, "most_collective": coll,
            "paper_representative": paper_rep}


if __name__ == "__main__":
    import sys
    d = Path(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
    cells = load_cells(d)
    print(markdown_table(cells))
    picks = pick_hillclimb_cells(cells)
    print()
    for k, c in picks.items():
        print(f"{k}: {c['arch']} x {c['shape']}")
