"""TPU v5e hardware constants (the TARGET platform; the container is CPU)."""

PEAK_BF16_FLOPS = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_LINK_BW = 50e9             # bytes/s per link (~ICI); prompt-provided
DCN_POD_BW = 25e9              # bytes/s cross-pod (assumed half ICI)
HBM_PER_CHIP = 16 * 2**30      # 16 GiB
VMEM_PER_CORE = 128 * 2**20    # ~128 MiB VMEM

# L-CSC reference constants, for the paper-reproduction benchmarks
S9150_PEAK_FP64 = 2.53e12
S9150_HBM_BW = 320e9
