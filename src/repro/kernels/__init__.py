"""Pallas TPU kernels for the compute hot spots.

Each kernel package ships three files:
  kernel.py — ``pl.pallas_call`` with explicit BlockSpec VMEM tiling
  ops.py    — jit'd public wrapper (interpret-mode switch for CPU)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels:
  dslash   — Wilson D-slash stencil (the paper's memory-bound hotspot, C1)
  dgemm    — tiled matmul (HPL trailing update, C2)
  rmsnorm  — fused RMSNorm (LM substrate hot spot)
"""
