"""Pure-jnp oracle: the complex reference D-slash from repro.lqcd."""
import jax.numpy as jnp

from repro.lqcd.dirac import dslash


def to_split(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.stack([jnp.real(x), jnp.imag(x)], axis=-1).astype(jnp.float32)


def from_split(x: jnp.ndarray) -> jnp.ndarray:
    return (x[..., 0] + 1j * x[..., 1]).astype(jnp.complex64)


def dslash_ref(U: jnp.ndarray, psi: jnp.ndarray) -> jnp.ndarray:
    """Complex-field reference."""
    return dslash(U, psi)


def dslash_ref_split(U_s: jnp.ndarray, psi_s: jnp.ndarray) -> jnp.ndarray:
    """Split-field reference (same I/O convention as the kernel)."""
    return to_split(dslash(from_split(U_s), from_split(psi_s)))
