from repro.kernels.dslash.ops import dslash_pallas  # noqa: F401
from repro.kernels.dslash.ref import dslash_ref  # noqa: F401
