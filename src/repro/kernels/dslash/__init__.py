from repro.kernels.dslash.ops import (  # noqa: F401
    dslash_half_pallas,
    dslash_pallas,
)
from repro.kernels.dslash.ref import dslash_ref  # noqa: F401
