"""Wilson D-slash Pallas kernel — the paper's memory-bound hotspot (C1),
re-tiled for the TPU memory hierarchy.

GPU original (CL2QCD): one thread per site, LDS-staged links.  TPU version:
the lattice is blocked along T; each grid step keeps a (X, Y, Z, Tb) block
of spinors+links in VMEM.  Spatial (x/y/z) neighbors are in-block ``roll``s
(vector permutes); T-boundary halos arrive as single-slice blocks through
overlapping BlockSpec index maps ((i·Tb ± 1) mod T) — no host gathers.

Complex arithmetic is explicit re/im (TPU has no complex dtype): fields are
float32 arrays with a trailing length-2 axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# gamma matrices (Dirac basis), split re/im; order x, y, z, t
_g = np.zeros((4, 4, 4), np.complex64)
_g[0] = [[0, 0, 0, -1j], [0, 0, -1j, 0], [0, 1j, 0, 0], [1j, 0, 0, 0]]
_g[1] = [[0, 0, 0, -1], [0, 0, 1, 0], [0, 1, 0, 0], [-1, 0, 0, 0]]
_g[2] = [[0, 0, -1j, 0], [0, 0, 0, 1j], [1j, 0, 0, 0], [0, -1j, 0, 0]]
_g[3] = [[1, 0, 0, 0], [0, 1, 0, 0], [0, 0, -1, 0], [0, 0, 0, -1]]
_eye = np.eye(4, dtype=np.complex64)
PROJ_M = np.stack([_eye - _g[mu] for mu in range(4)])   # (1 - gamma_mu)
PROJ_P = np.stack([_eye + _g[mu] for mu in range(4)])   # (1 + gamma_mu)
PM_RE, PM_IM = np.real(PROJ_M), np.imag(PROJ_M)
PP_RE, PP_IM = np.real(PROJ_P), np.imag(PROJ_P)


def _su3_mv(u, psi, conj_transpose: bool):
    """(..., 3, 3, 2) x (..., 4, 3, 2) -> (..., 4, 3, 2) complex matvec."""
    u_re, u_im = u[..., 0], u[..., 1]
    p_re, p_im = psi[..., 0], psi[..., 1]
    if conj_transpose:
        # (U†)_{ab} = conj(U_{ba})
        re = (jnp.einsum("...ba,...sb->...sa", u_re, p_re)
              + jnp.einsum("...ba,...sb->...sa", u_im, p_im))
        im = (jnp.einsum("...ba,...sb->...sa", u_re, p_im)
              - jnp.einsum("...ba,...sb->...sa", u_im, p_re))
    else:
        re = (jnp.einsum("...ab,...sb->...sa", u_re, p_re)
              - jnp.einsum("...ab,...sb->...sa", u_im, p_im))
        im = (jnp.einsum("...ab,...sb->...sa", u_re, p_im)
              + jnp.einsum("...ab,...sb->...sa", u_im, p_re))
    return jnp.stack([re, im], axis=-1)


def _apply_proj(proj_re, proj_im, hop):
    """Spin projection, unrolled with scalar literals.

    Projector entries are only {0, ±1, ±2, ±i} — unrolling avoids both the
    constant-capture restriction of pallas kernels and 75% of the 4x4
    multiply work (most entries are zero)."""
    h_re, h_im = hop[..., 0], hop[..., 1]
    out_re, out_im = [], []
    for s_ in range(4):
        acc_re = jnp.zeros_like(h_re[..., 0, :])
        acc_im = jnp.zeros_like(acc_re)
        for t_ in range(4):
            cr = float(proj_re[s_, t_])
            ci = float(proj_im[s_, t_])
            if cr != 0.0:
                acc_re = acc_re + cr * h_re[..., t_, :]
                acc_im = acc_im + cr * h_im[..., t_, :]
            if ci != 0.0:
                acc_re = acc_re - ci * h_im[..., t_, :]
                acc_im = acc_im + ci * h_re[..., t_, :]
        out_re.append(acc_re)
        out_im.append(acc_im)
    re = jnp.stack(out_re, axis=-2)
    im = jnp.stack(out_im, axis=-2)
    return jnp.stack([re, im], axis=-1)


def _dslash_kernel(psi_ref, psi_next_ref, psi_prev_ref, u_ref, u_prev_ref,
                   o_ref):
    psi = psi_ref[...]                      # (X, Y, Z, Tb, 4, 3, 2)
    u = u_ref[...]                          # (4, X, Y, Z, Tb, 3, 3, 2)
    out = jnp.zeros_like(psi)
    T_AX = 3

    for mu in range(3):                     # x, y, z — in-VMEM rolls
        # numpy constants inline as literals (jax Arrays would need to be
        # kernel inputs)
        pm_re, pm_im = PM_RE[mu], PM_IM[mu]
        pp_re, pp_im = PP_RE[mu], PP_IM[mu]
        psi_f = jnp.roll(psi, -1, axis=mu)
        out = out + _apply_proj(pm_re, pm_im, _su3_mv(u[mu], psi_f, False))
        u_b = jnp.roll(u[mu], 1, axis=mu)
        psi_b = jnp.roll(psi, 1, axis=mu)
        out = out + _apply_proj(pp_re, pp_im, _su3_mv(u_b, psi_b, True))

    # t direction — halo blocks from the neighbor T-slices
    mu = 3
    psi_f = jnp.concatenate(
        [jax.lax.slice_in_dim(psi, 1, psi.shape[T_AX], axis=T_AX),
         psi_next_ref[...]], axis=T_AX)
    out = out + _apply_proj(PM_RE[mu], PM_IM[mu],
                            _su3_mv(u[mu], psi_f, False))
    psi_b = jnp.concatenate(
        [psi_prev_ref[...],
         jax.lax.slice_in_dim(psi, 0, psi.shape[T_AX] - 1, axis=T_AX)],
        axis=T_AX)
    u_b = jnp.concatenate(
        [u_prev_ref[...][mu],
         jax.lax.slice_in_dim(u[mu], 0, u[mu].shape[T_AX] - 1, axis=T_AX)],
        axis=T_AX)
    out = out + _apply_proj(PP_RE[mu], PP_IM[mu],
                            _su3_mv(u_b, psi_b, True))
    o_ref[...] = out


def _dslash_eo_kernel(out_parity, psi_ref, psi_next_ref, psi_prev_ref,
                      uout_ref, usrc_ref, usrc_prev_ref, o_ref):
    """One parity block of D-slash on the compact (checkerboard) layout.

    Input spinors live on the opposite parity of the output; both are
    half-lattices (X//2 leading axis), so each grid step streams only
    same-parity blocks through VMEM — half the spinor traffic of the full
    kernel per output site, which is the CL2QCD bandwidth trick.

    Compact-layout hop rules (derivation in ``repro.lqcd.eo``):
      y/z hops: in-block rolls;  t hops: rolls with halo slices;
      x hops:  roll applied only where s = (y+z+t+parity) % 2 == 1.
    """
    psi = psi_ref[...]                      # (Xh, Y, Z, Tb, 4, 3, 2)
    u_out = uout_ref[...]                   # (4, Xh, Y, Z, Tb, 3, 3, 2)
    u_src = usrc_ref[...]
    T_AX = 3
    _, Y, Z, Tb = psi.shape[:4]

    # s_out(y, z, t_global): x offset of the first output-parity site
    iy = jax.lax.broadcasted_iota(jnp.int32, (Y, Z, Tb), 0)
    iz = jax.lax.broadcasted_iota(jnp.int32, (Y, Z, Tb), 1)
    it = jax.lax.broadcasted_iota(jnp.int32, (Y, Z, Tb), 2) \
        + pl.program_id(0) * Tb
    s_out = ((iy + iz + it + out_parity) % 2)[..., None, None, None] == 1

    # x hops: output site x = 2i + s_out -> +x neighbour at compact i+s_out,
    # -x neighbour (and its link) at compact i + s_out - 1
    psi_f = jnp.where(s_out, jnp.roll(psi, -1, axis=0), psi)
    psi_b = jnp.where(s_out, psi, jnp.roll(psi, 1, axis=0))
    u_b = jnp.where(s_out, u_src[0], jnp.roll(u_src[0], 1, axis=0))
    out = _apply_proj(PM_RE[0], PM_IM[0], _su3_mv(u_out[0], psi_f, False))
    out = out + _apply_proj(PP_RE[0], PP_IM[0], _su3_mv(u_b, psi_b, True))

    for mu in (1, 2):                       # y, z — in-VMEM rolls
        psi_f = jnp.roll(psi, -1, axis=mu)
        psi_b = jnp.roll(psi, 1, axis=mu)
        u_b = jnp.roll(u_src[mu], 1, axis=mu)
        out = out + _apply_proj(PM_RE[mu], PM_IM[mu],
                                _su3_mv(u_out[mu], psi_f, False))
        out = out + _apply_proj(PP_RE[mu], PP_IM[mu],
                                _su3_mv(u_b, psi_b, True))

    # t direction — halo blocks from the neighbour T-slices
    mu = 3
    psi_f = jnp.concatenate(
        [jax.lax.slice_in_dim(psi, 1, psi.shape[T_AX], axis=T_AX),
         psi_next_ref[...]], axis=T_AX)
    out = out + _apply_proj(PM_RE[mu], PM_IM[mu],
                            _su3_mv(u_out[mu], psi_f, False))
    psi_b = jnp.concatenate(
        [psi_prev_ref[...],
         jax.lax.slice_in_dim(psi, 0, psi.shape[T_AX] - 1, axis=T_AX)],
        axis=T_AX)
    u_b = jnp.concatenate(
        [usrc_prev_ref[...][mu],
         jax.lax.slice_in_dim(u_src[mu], 0, u_src[mu].shape[T_AX] - 1,
                              axis=T_AX)], axis=T_AX)
    out = out + _apply_proj(PP_RE[mu], PP_IM[mu], _su3_mv(u_b, psi_b, True))
    o_ref[...] = out


def dslash_eo_split(U_out_s: jnp.ndarray, U_src_s: jnp.ndarray,
                    psi_s: jnp.ndarray, src_parity: int, *,
                    t_block: int = 4, interpret: bool = False) -> jnp.ndarray:
    """Half-lattice D-slash hop on re/im-split compact fields.

    U_out_s/U_src_s: (4, X//2, Y, Z, T, 3, 3, 2) f32 packed at the
    output/source parity; psi_s: (X//2, Y, Z, T, 4, 3, 2) f32 on
    ``src_parity`` sites.  Returns the opposite-parity half-field.
    """
    Xh, Y, Z, T = psi_s.shape[:4]
    tb = min(t_block, T)
    assert T % tb == 0
    n_t = T // tb

    psi_spec = pl.BlockSpec((Xh, Y, Z, tb, 4, 3, 2),
                            lambda i: (0, 0, 0, i, 0, 0, 0))
    halo_next = pl.BlockSpec(
        (Xh, Y, Z, 1, 4, 3, 2),
        lambda i: (0, 0, 0, (i * tb + tb) % T, 0, 0, 0))
    halo_prev = pl.BlockSpec(
        (Xh, Y, Z, 1, 4, 3, 2),
        lambda i: (0, 0, 0, (i * tb - 1) % T, 0, 0, 0))
    u_spec = pl.BlockSpec((4, Xh, Y, Z, tb, 3, 3, 2),
                          lambda i: (0, 0, 0, 0, i, 0, 0, 0))
    u_prev = pl.BlockSpec((4, Xh, Y, Z, 1, 3, 3, 2),
                          lambda i: (0, 0, 0, 0, (i * tb - 1) % T, 0, 0, 0))

    return pl.pallas_call(
        functools.partial(_dslash_eo_kernel, 1 - src_parity),
        grid=(n_t,),
        in_specs=[psi_spec, halo_next, halo_prev, u_spec, u_spec, u_prev],
        out_specs=psi_spec,
        out_shape=jax.ShapeDtypeStruct(psi_s.shape, psi_s.dtype),
        interpret=interpret,
    )(psi_s, psi_s, psi_s, U_out_s, U_src_s, U_src_s)


def dslash_split(U_s: jnp.ndarray, psi_s: jnp.ndarray, *, t_block: int = 4,
                 interpret: bool = False) -> jnp.ndarray:
    """D-slash on re/im-split fields.

    U_s: (4, X, Y, Z, T, 3, 3, 2) f32; psi_s: (X, Y, Z, T, 4, 3, 2) f32.
    """
    X, Y, Z, T = psi_s.shape[:4]
    tb = min(t_block, T)
    assert T % tb == 0
    n_t = T // tb

    psi_spec = pl.BlockSpec((X, Y, Z, tb, 4, 3, 2),
                            lambda i: (0, 0, 0, i, 0, 0, 0))
    halo_next = pl.BlockSpec(
        (X, Y, Z, 1, 4, 3, 2),
        lambda i: (0, 0, 0, (i * tb + tb) % T, 0, 0, 0))
    halo_prev = pl.BlockSpec(
        (X, Y, Z, 1, 4, 3, 2),
        lambda i: (0, 0, 0, (i * tb - 1) % T, 0, 0, 0))
    u_spec = pl.BlockSpec((4, X, Y, Z, tb, 3, 3, 2),
                          lambda i: (0, 0, 0, 0, i, 0, 0, 0))
    u_prev = pl.BlockSpec((4, X, Y, Z, 1, 3, 3, 2),
                          lambda i: (0, 0, 0, 0, (i * tb - 1) % T, 0, 0, 0))

    return pl.pallas_call(
        _dslash_kernel,
        grid=(n_t,),
        in_specs=[psi_spec, halo_next, halo_prev, u_spec, u_prev],
        out_specs=psi_spec,
        out_shape=jax.ShapeDtypeStruct(psi_s.shape, psi_s.dtype),
        interpret=interpret,
    )(psi_s, psi_s, psi_s, U_s, U_s)
