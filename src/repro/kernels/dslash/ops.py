"""Public jit'd wrappers: complex-field D-slash backed by the Pallas
kernels.

``tuned=True`` resolves ``t_block`` from the autotune cache for this
lattice and backend (``repro.autotune``; analytic roofline tuner on a
cache miss) instead of the static default of 4.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.dslash.kernel import dslash_eo_split, dslash_split
from repro.kernels.dslash.ref import from_split, to_split

DEFAULT_T_BLOCK = 4


def _resolve_t_block(t_block: int | None, tuned: bool,
                     lat: tuple) -> int:
    if t_block is not None:
        return t_block
    if tuned:
        from repro.autotune import tuned_config
        return int(tuned_config("dslash", lat)["t_block"])
    return DEFAULT_T_BLOCK


def sharded_t_block(local_lat: tuple) -> int:
    """T-block for a T-sharded local volume, resolved through the
    autotune cache so sharded local volumes (including their ±1 halo
    pad) get their own entries — the multi-chip even-odd path
    (``repro.lqcd.multichip_eo``) calls this once per gauge binding."""
    from repro.autotune import tuned_config
    lat = tuple(int(d) for d in local_lat)
    return int(tuned_config("dslash", lat)["t_block"])


@partial(jax.jit, static_argnames=("t_block", "interpret"))
def _dslash_call(U: jnp.ndarray, psi: jnp.ndarray, *, t_block: int,
                 interpret: bool) -> jnp.ndarray:
    out_s = dslash_split(to_split(U), to_split(psi), t_block=t_block,
                         interpret=interpret)
    return from_split(out_s)


def dslash_pallas(U: jnp.ndarray, psi: jnp.ndarray, *,
                  t_block: int | None = None, tuned: bool = False,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Complex-in/complex-out D-slash via the split-field Pallas kernel."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    # gauge layout is (4, X, Y, Z, T, 3, 3): direction axis leads
    t_block = _resolve_t_block(t_block, tuned, tuple(U.shape[1:5]))
    return _dslash_call(U, psi, t_block=t_block, interpret=interpret)


@partial(jax.jit, static_argnames=("src_parity", "t_block", "interpret"))
def _dslash_half_call(U_e: jnp.ndarray, U_o: jnp.ndarray, psi: jnp.ndarray,
                      src_parity: int, *, t_block: int,
                      interpret: bool) -> jnp.ndarray:
    U_out, U_src = (U_o, U_e) if src_parity == 0 else (U_e, U_o)
    out_s = dslash_eo_split(to_split(U_out), to_split(U_src), to_split(psi),
                            src_parity, t_block=t_block, interpret=interpret)
    return from_split(out_s)


def dslash_half_pallas(U_e: jnp.ndarray, U_o: jnp.ndarray, psi: jnp.ndarray,
                       src_parity: int, *, t_block: int | None = None,
                       tuned: bool = False,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Even-odd hop on complex compact half-fields via the Pallas kernel.

    Same contract as ``repro.lqcd.eo.dslash_half``: ``psi`` lives on
    ``src_parity`` sites (compact layout), the result on the opposite
    parity.  ``U_e``/``U_o`` are the packed gauge halves from
    ``repro.lqcd.eo.pack_gauge``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    # the packed half-lattice keeps the full T extent (X is halved)
    t_block = _resolve_t_block(t_block, tuned, tuple(U_e.shape[1:5]))
    return _dslash_half_call(U_e, U_o, psi, src_parity, t_block=t_block,
                             interpret=interpret)
