"""Public jit'd wrapper: complex-field D-slash backed by the Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.dslash.kernel import dslash_split
from repro.kernels.dslash.ref import from_split, to_split


@partial(jax.jit, static_argnames=("t_block", "interpret"))
def dslash_pallas(U: jnp.ndarray, psi: jnp.ndarray, *, t_block: int = 4,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Complex-in/complex-out D-slash via the split-field Pallas kernel."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    out_s = dslash_split(to_split(U), to_split(psi), t_block=t_block,
                         interpret=interpret)
    return from_split(out_s)
