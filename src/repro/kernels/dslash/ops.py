"""Public jit'd wrapper: complex-field D-slash backed by the Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.dslash.kernel import dslash_eo_split, dslash_split
from repro.kernels.dslash.ref import from_split, to_split


@partial(jax.jit, static_argnames=("t_block", "interpret"))
def dslash_pallas(U: jnp.ndarray, psi: jnp.ndarray, *, t_block: int = 4,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Complex-in/complex-out D-slash via the split-field Pallas kernel."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    out_s = dslash_split(to_split(U), to_split(psi), t_block=t_block,
                         interpret=interpret)
    return from_split(out_s)


@partial(jax.jit, static_argnames=("src_parity", "t_block", "interpret"))
def dslash_half_pallas(U_e: jnp.ndarray, U_o: jnp.ndarray, psi: jnp.ndarray,
                       src_parity: int, *, t_block: int = 4,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Even-odd hop on complex compact half-fields via the Pallas kernel.

    Same contract as ``repro.lqcd.eo.dslash_half``: ``psi`` lives on
    ``src_parity`` sites (compact layout), the result on the opposite
    parity.  ``U_e``/``U_o`` are the packed gauge halves from
    ``repro.lqcd.eo.pack_gauge``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    U_out, U_src = (U_o, U_e) if src_parity == 0 else (U_e, U_o)
    out_s = dslash_eo_split(to_split(U_out), to_split(U_src), to_split(psi),
                            src_parity, t_block=t_block, interpret=interpret)
    return from_split(out_s)
