"""Tiled matmul Pallas kernel (the HPL trailing-update hot spot).

Grid (M/bm, N/bn, K/bk); each (i, j) tile owns an fp32 VMEM accumulator
that integrates over the k-steps; MXU-aligned block shapes (multiples of
128 on the matmul dims).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(x: jnp.ndarray, y: jnp.ndarray, *, bm: int = 256,
                  bn: int = 256, bk: int = 256, out_dtype=None,
                  interpret: bool = False) -> jnp.ndarray:
    """x: (M, K) @ y: (K, N) -> (M, N); fp32 accumulation in VMEM."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"dims ({m},{n},{k}) must tile by ({bm},{bn},{bk})")
    out_dtype = out_dtype or x.dtype
    k_steps = k // bk
    kernel = functools.partial(_matmul_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)
