from repro.kernels.dgemm.ops import dgemm  # noqa: F401
from repro.kernels.dgemm.ref import dgemm_ref  # noqa: F401
