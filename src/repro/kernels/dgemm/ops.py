"""Public jit'd wrapper for the dgemm Pallas kernel.

``interpret=None`` auto-selects: compiled on TPU, interpret mode on CPU
(the container validates kernels in interpret mode; TPU is the target).

``tuned=True`` replaces the hard-coded 256³ tile default with the
autotuner's winner for this (m, k, n) and backend, resolved through the
JSON cache (``repro.autotune``) — a cache miss runs the analytic
roofline+power tuner once and memoizes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.dgemm.kernel import matmul_pallas

DEFAULT_TILE = 256


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _dgemm_call(x: jnp.ndarray, y: jnp.ndarray, *, bm: int, bn: int,
                bk: int, interpret: bool) -> jnp.ndarray:
    return matmul_pallas(x, y, bm=bm, bn=bn, bk=bk, interpret=interpret)


def dgemm(x: jnp.ndarray, y: jnp.ndarray, *, bm: int | None = None,
          bn: int | None = None, bk: int | None = None,
          tuned: bool = False,
          interpret: bool | None = None) -> jnp.ndarray:
    """Tiled matmul.  Tile resolution order: explicit ``bm/bn/bk``
    arguments, then (``tuned=True``) the autotune cache, then the
    static default."""
    if interpret is None:
        interpret = not _on_tpu()
    if tuned and (bm is None or bn is None or bk is None):
        from repro.autotune import tuned_config
        cfg = tuned_config("dgemm", (x.shape[0], x.shape[1], y.shape[1]))
        bm = bm if bm is not None else cfg["bm"]
        bn = bn if bn is not None else cfg["bn"]
        bk = bk if bk is not None else cfg["bk"]
    bm = DEFAULT_TILE if bm is None else bm
    bn = DEFAULT_TILE if bn is None else bn
    bk = DEFAULT_TILE if bk is None else bk
    return _dgemm_call(x, y, bm=bm, bn=bn, bk=bk, interpret=interpret)
