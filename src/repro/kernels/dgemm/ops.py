"""Public jit'd wrapper for the dgemm Pallas kernel.

``interpret=None`` auto-selects: compiled on TPU, interpret mode on CPU
(the container validates kernels in interpret mode; TPU is the target).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.dgemm.kernel import matmul_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def dgemm(x: jnp.ndarray, y: jnp.ndarray, *, bm: int = 256, bn: int = 256,
          bk: int = 256, interpret: bool | None = None) -> jnp.ndarray:
    if interpret is None:
        interpret = not _on_tpu()
    return matmul_pallas(x, y, bm=bm, bn=bn, bk=bk, interpret=interpret)
