"""Pure-jnp oracle for the dgemm kernel."""
import jax.numpy as jnp


def dgemm_ref(x: jnp.ndarray, y: jnp.ndarray, out_dtype=None) -> jnp.ndarray:
    out_dtype = out_dtype or x.dtype
    return jnp.dot(x.astype(jnp.float32),
                   y.astype(jnp.float32)).astype(out_dtype)
