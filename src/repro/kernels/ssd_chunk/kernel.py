"""Mamba-2 SSD within-chunk kernel (the hot inner block of the chunked
scan): given one chunk's x, dt, B, C and the incoming state h, produce the
chunk's outputs and the outgoing state — all in VMEM.

Grid = (batch, n_chunks is handled by the outer lax.scan; here we grid over
batch x heads) so each program instance owns a (Q, P) x (Q, N) working set:
the (Q, Q) decay matrix, the C·Bᵀ scores, and the state update — the exact
arithmetic of `repro.models.ssm.ssd_chunked`'s chunk_step, fused.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h_ref, y_ref,
                h_out_ref):
    x = x_ref[...].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[...].astype(jnp.float32)      # (Q,)
    a = a_ref[0]                              # scalar per head
    bm = b_ref[...].astype(jnp.float32)       # (Q, N)
    cm = c_ref[...].astype(jnp.float32)       # (Q, N)
    h = h_ref[...].astype(jnp.float32)        # (P, N)

    q = x.shape[0]
    la = dt * a                               # (Q,) log decay
    cs = jnp.cumsum(la)
    diff = cs[:, None] - cs[None, :]          # (Q, Q)
    iota = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    iotb = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    lm = jnp.exp(jnp.where(iota >= iotb, diff, -1e30))
    scores = (cm @ bm.T) * lm * dt[None, :]   # (Q, Q)
    y = scores @ x                            # intra-chunk
    y = y + (cm * jnp.exp(cs)[:, None]) @ h.T   # inter-chunk
    decay_end = jnp.exp(cs[-1] - cs) * dt     # (Q,)
    h_new = h * jnp.exp(cs[-1]) + x.T @ (bm * decay_end[:, None])
    y_ref[...] = y.astype(y_ref.dtype)
    h_out_ref[...] = h_new.astype(h_out_ref.dtype)


def ssd_chunk_pallas(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                     B_mat: jnp.ndarray, C_mat: jnp.ndarray,
                     h: jnp.ndarray, *, interpret: bool = False):
    """One chunk for all batches/heads.

    x: (B, Q, H, P); dt: (B, Q, H); A: (H,); B_mat/C_mat: (B, Q, N)
    (group-broadcast done by the caller); h: (B, H, P, N).
    Returns (y (B, Q, H, P), h_new (B, H, P, N)).
    """
    Bb, Q, H, P = x.shape
    N = B_mat.shape[-1]
    grid = (Bb, H)
    y, h_new = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, Q, None, P), lambda b, h_: (b, 0, h_, 0)),
            pl.BlockSpec((None, Q, None), lambda b, h_: (b, 0, h_)),
            pl.BlockSpec((1,), lambda b, h_: (h_,)),
            pl.BlockSpec((None, Q, N), lambda b, h_: (b, 0, 0)),
            pl.BlockSpec((None, Q, N), lambda b, h_: (b, 0, 0)),
            pl.BlockSpec((None, None, P, N), lambda b, h_: (b, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, Q, None, P), lambda b, h_: (b, 0, h_, 0)),
            pl.BlockSpec((None, None, P, N), lambda b, h_: (b, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, Q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((Bb, H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, A, B_mat, C_mat, h)
    return y, h_new
