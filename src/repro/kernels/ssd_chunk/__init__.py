from repro.kernels.ssd_chunk.ops import ssd_chunk  # noqa: F401
from repro.kernels.ssd_chunk.ref import ssd_chunk_ref  # noqa: F401
