"""Public jit'd wrapper for the SSD chunk kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ssd_chunk.kernel import ssd_chunk_pallas


@partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(x, dt, A, B_mat, C_mat, h, *, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return ssd_chunk_pallas(x, dt, A, B_mat, C_mat, h, interpret=interpret)
