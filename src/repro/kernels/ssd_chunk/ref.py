"""Pure-jnp oracle: one SSD chunk via the model's chunked implementation."""
import jax.numpy as jnp

from repro.models.ssm import ssd_chunked


def ssd_chunk_ref(x, dt, A, B_mat, C_mat, h):
    """Same I/O as the kernel; B_mat/C_mat: (B, Q, N) single-group."""
    y, h_new = ssd_chunked(x, dt, A, B_mat[:, :, None, :],
                           C_mat[:, :, None, :], chunk=x.shape[1], h0=h)
    return y, h_new
