"""Fused RMSNorm Pallas kernel.

One pass over the rows: mean-of-squares reduction and scale in VMEM, fp32
math, bf16 I/O.  Row-block tiling keeps the (rows_block, d) tile + scale
vector resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_pallas(x: jnp.ndarray, w: jnp.ndarray, *, eps: float = 1e-6,
                   block_rows: int = 256,
                   interpret: bool = False) -> jnp.ndarray:
    """x: (rows, d); w: (d,) -> (rows, d)."""
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, w)
