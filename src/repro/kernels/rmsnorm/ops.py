"""Public jit'd wrapper for the rmsnorm Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_pallas


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, *, eps: float = 1e-6,
            block_rows: int = 256,
            interpret: bool | None = None) -> jnp.ndarray:
    """Fused RMSNorm over the last dim; accepts (..., d)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    rows = x2.shape[0]
    br = block_rows
    while rows % br:
        br //= 2
    y = rmsnorm_pallas(x2, w, eps=eps, block_rows=max(br, 1),
                       interpret=interpret)
    return y.reshape(shape)
