"""The paper's primary contribution: energy-efficiency machinery (C1-C5).

Subpackages:
  energy/  power models, TDP throttle simulation, DVFS planning,
           Green500 L1/L2/L3 measurement, variability, scheduling
The LQCD application (C1) lives in ``repro.lqcd``; the HPL benchmark (C2)
in ``repro.hpl``; both consume the models here.
"""
