"""Energy core — legacy façade over the unified power engine.

Power models, throttle simulation, DVFS planning, Green500 measurement
methodology, chip variability and cluster scheduling.  The power/energy
implementation now lives in :mod:`repro.power`; this package keeps the
pre-refactor import surface working (plus the DVFS planner and the
scheduler, which remain here)."""
from repro.core.energy.power_model import (  # noqa: F401
    NodePowerModel,
    S9150,
    fan_power,
    gpu_power,
    node_power,
    voltage_at,
)
from repro.core.energy.throttle import (  # noqa: F401
    dgemm_perf_gflops,
    hpl_node_perf,
    sustained_frequency,
)
from repro.core.energy.dvfs import FreqPlan, plan_frequency  # noqa: F401
from repro.core.energy.green500 import (  # noqa: F401
    LinpackTrace,
    PowerTrace,
    level1_exploit,
    linpack_power_trace,
    measure_efficiency,
)
from repro.core.energy.solver_energy import (  # noqa: F401
    S9150_HW,
    SolverEnergyReport,
    SolverHW,
    solver_energy,
)
