"""Energy core — legacy façade over the unified power engine.

Power models, throttle simulation, DVFS planning, Green500 measurement
methodology and chip variability.  The power/energy implementation lives
in :mod:`repro.power`, the scheduler in :mod:`repro.cluster`; this
package keeps the pre-refactor import surface working (plus the DVFS
planner and the throttle perf curves, which remain here).

The re-exports below pull from the real homes directly so that importing
this package — or its still-native submodules ``dvfs``/``throttle``/
``solver_energy`` — does not trip the :class:`DeprecationWarning` that
the ``power_model``/``green500``/``scheduler`` shim modules emit."""
from repro.power.model import (  # noqa: F401
    S9150,
    fan_power,
    gpu_power,
    voltage_at,
)
from repro.power.layers import NodePowerModel, node_power  # noqa: F401
from repro.core.energy.throttle import (  # noqa: F401
    dgemm_perf_gflops,
    hpl_node_perf,
    sustained_frequency,
)
from repro.core.energy.dvfs import FreqPlan, plan_frequency  # noqa: F401
from repro.power.green500 import (  # noqa: F401
    LinpackTrace,
    level1_exploit,
    linpack_power_trace,
    measure_efficiency,
)
from repro.power.trace import PowerTrace  # noqa: F401
from repro.core.energy.solver_energy import (  # noqa: F401
    S9150_HW,
    SolverEnergyReport,
    SolverHW,
    solver_energy,
)
