"""Legacy import path for the Green500 measurement methodology.

The implementation lives in :mod:`repro.power.green500` and operates on
the unified :class:`repro.power.PowerTrace` telemetry type (the old
``LinpackTrace`` dataclass is now a constructor shim producing one).
This module re-exports the pre-refactor names so existing imports keep
working.
"""
import warnings

warnings.warn(
    "repro.core.energy.green500 is deprecated; import from "
    "repro.power.green500 (the unified power-telemetry engine) instead",
    DeprecationWarning, stacklevel=2)

from repro.power.green500 import (  # noqa: E402,F401
    LEVEL_MIN_FRACTION,
    LinpackTrace,
    MeasurementResult,
    extrapolation_error,
    hpl_load_profile,
    level1_exploit,
    linpack_power_trace,
    measure_efficiency,
    node_efficiencies,
    select_median_nodes,
)
from repro.power.trace import PowerTrace  # noqa: E402,F401
