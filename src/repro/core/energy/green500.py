"""Green500 power-measurement methodology (paper §3, EEHPC v1.2).

Implements the three measurement levels over a simulated Linpack power
trace, the node-variability estimate, the median-node selection the authors
used, and the Level-1 exploit they demonstrated (+30% overestimate).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.energy.dvfs import fan_curve
from repro.core.energy.power_model import fan_power


@dataclass
class LinpackTrace:
    """Time series of one Linpack run: cluster power and cumulative FLOPs."""

    t: np.ndarray                # seconds
    power_w: np.ndarray          # instantaneous cluster power
    flops_rate: np.ndarray       # instantaneous GFLOPS
    network_w: float = 0.0       # switches (measured separately at L3)

    @property
    def duration(self) -> float:
        return float(self.t[-1] - self.t[0])

    def total_flops(self) -> float:
        return float(np.trapezoid(self.flops_rate, self.t))

    def avg_power(self, t0: Optional[float] = None,
                  t1: Optional[float] = None,
                  include_network: bool = True) -> float:
        t0 = self.t[0] if t0 is None else t0
        t1 = self.t[-1] if t1 is None else t1
        m = (self.t >= t0) & (self.t <= t1)
        p = float(np.trapezoid(self.power_w[m], self.t[m]) / (t1 - t0))
        return p + (self.network_w if include_network else 0.0)


def linpack_power_trace(n_nodes: int, node_peak_w: float,
                        node_gflops: float, *, duration_s: float = 3600.0,
                        network_w: float = 257.0,
                        adaptive_fan: bool = True,
                        dt: float = 5.0) -> LinpackTrace:
    """Synthetic HPL run: full power during factorization, decaying load in
    the final ~25% as the trailing matrix shrinks (the shape that makes
    Level-1 window-picking exploitable)."""
    t = np.arange(0.0, duration_s + dt, dt)
    x = t / duration_s
    # load factor: ~1 until 75%, then N^3-ish tail down to ~35%
    load = np.where(x < 0.75, 1.0, 0.35 + 0.65 * ((1 - x) / 0.25) ** 1.5)
    dyn_frac = 0.75                    # dynamic fraction of node power
    power = n_nodes * node_peak_w * (1 - dyn_frac + dyn_frac * load)
    if adaptive_fan:
        # end-of-run fan derating (paper §2 last para of the fan discussion)
        fan_delta = np.array([fan_power(0.40) - fan_power(fan_curve(l))
                              for l in load])
        power = power - n_nodes * fan_delta
    flops = n_nodes * node_gflops * load
    return LinpackTrace(t, power, flops, network_w=network_w)


# ---------------------------------------------------------------------------
# Measurement levels (EEHPC methodology v1.2 — paper Table 2)
# ---------------------------------------------------------------------------

@dataclass
class MeasurementResult:
    level: int
    measured_fraction: float
    window: Tuple[float, float]
    avg_power_w: float
    perf_gflops: float
    mflops_per_w: float
    notes: str = ""


def measure_efficiency(trace: LinpackTrace, level: int, *,
                       measured_fraction: float = 1.0,
                       window: Optional[Tuple[float, float]] = None,
                       ) -> MeasurementResult:
    """Apply one of the three measurement levels to a run trace.

    L1: >=1/64 of the system, >=20% of the middle 80% of the run,
        compute nodes only (network excluded).
    L2: >=1/8, full runtime, network estimated (we add it).
    L3: full system, full runtime, network measured.
    """
    perf = trace.total_flops() / trace.duration      # sustained GFLOPS
    if level == 1:
        lo = trace.t[0] + 0.1 * trace.duration
        hi = trace.t[-1] - 0.1 * trace.duration
        if window is None:
            window = (lo, lo + 0.2 * (hi - lo))
        p = trace.avg_power(window[0], window[1], include_network=False)
        notes = "compute nodes only; window inside middle 80%"
    elif level == 2:
        window = (float(trace.t[0]), float(trace.t[-1]))
        p = trace.avg_power(include_network=True)
        notes = "full runtime; network estimated"
    else:
        window = (float(trace.t[0]), float(trace.t[-1]))
        p = trace.avg_power(include_network=True)
        notes = "full runtime; network measured"
    frac = max(measured_fraction, {1: 1 / 64, 2: 1 / 8, 3: 1.0}[level])
    return MeasurementResult(level, frac, window, p, perf,
                             perf / p * 1000.0, notes)


def level1_exploit(trace: LinpackTrace) -> MeasurementResult:
    """Best (highest) efficiency obtainable within the letter of L1: slide
    the minimum 20%-of-middle-80% window to the lowest-power region.

    The paper showed this overestimates L-CSC's true efficiency by up to
    ~30% — and that several top-ranked systems measured this way."""
    lo = trace.t[0] + 0.1 * trace.duration
    hi = trace.t[-1] - 0.1 * trace.duration
    win = 0.2 * (hi - lo)
    best = None
    for start in np.linspace(lo, hi - win, 200):
        r = measure_efficiency(trace, 1, window=(start, start + win))
        if best is None or r.mflops_per_w > best.mflops_per_w:
            best = r
    best.notes = "L1 exploit: lowest-power window"
    return best


# ---------------------------------------------------------------------------
# Node variability & median-node selection (paper §3)
# ---------------------------------------------------------------------------

def node_efficiencies(rng: np.random.Generator, n_nodes: int,
                      base_mflops_w: float = 5215.0,
                      sigma_frac: float = 0.008) -> np.ndarray:
    """Single-node Linpack efficiencies across the population."""
    return rng.normal(base_mflops_w, base_mflops_w * sigma_frac, n_nodes)


def select_median_nodes(effs: Sequence[float], k: int = 2) -> List[int]:
    """Paper: 'we used nodes with middle power consumption among the nodes
    we had measured individually' — pick the k median nodes."""
    order = np.argsort(effs)
    mid = len(order) // 2
    lo = max(0, mid - k // 2)
    return list(order[lo:lo + k])


def extrapolation_error(effs: Sequence[float], k: int = 2) -> float:
    """|median-node estimate − population mean| / mean — the paper argues
    this is <1% given the ±1.2% spread."""
    effs = np.asarray(effs)
    sel = select_median_nodes(effs, k)
    est = float(np.mean(effs[sel]))
    return abs(est - float(np.mean(effs))) / float(np.mean(effs))
