"""TDP throttle *performance* curves (paper §2, Fig. 1a).

The electrical side of throttling (``sustained_frequency``,
``gpu_power_throttled``) lives in :mod:`repro.power.model` with the rest
of the calibration constants; this module keeps the performance story
built on top of it and re-exports the power-side names for the
pre-refactor import path.

The paper's key observations, reproduced by this model:
  * chips with higher voltage ID hit the TDP limit and throttle; the
    throttled clock oscillates, which is LESS efficient than constant
    operation at the highest non-throttling frequency;
  * at 774 MHz no chip throttles → flat performance profile across nodes;
  * at 900 MHz DGEMM spans 1250 (V=1.1425) down to 950–1100 (V=1.2).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

from repro.power.model import (  # noqa: F401  (re-exported power side)
    HPL_GPU_UTIL,
    S9150,
    gpu_power_throttled,
    sustained_frequency,
)

# Oscillating between P-states loses pipeline efficiency vs constant clock
OSC_PENALTY = 0.08
DGEMM_EFF = 0.493           # CL2QCD-era DGEMM efficiency vs fp64 peak
# HPL-GPU pipelines CPU DGEMM + lookahead: node HPL exceeds 4x standalone
# DGEMM (published: 6175-6280 node vs 4x950-1250 standalone).  The scale
# bundles the CPU DGEMM share and lookahead overlap; HPL's burstier GPU
# duty cycle (util < 1) throttles less than the continuous DGEMM loop.
HPL_NODE_SCALE = 1.256


def effective_frequency(f_set_mhz: float, vid_900: float, *,
                        temp_c: float = 55.0, util: float = 1.0) -> float:
    """Average effective clock including the oscillation penalty."""
    f_sus, throttled = sustained_frequency(f_set_mhz, vid_900,
                                           temp_c=temp_c, util=util)
    return f_sus * (1.0 - OSC_PENALTY) if throttled else f_sus


def dgemm_perf_gflops(f_set_mhz: float, vid_900: float, *,
                      temp_c: float = 55.0) -> float:
    """Single-GPU sustained DGEMM (fp64) — reproduces Fig. 1a left."""
    f_eff = effective_frequency(f_set_mhz, vid_900, temp_c=temp_c)
    return S9150.peak_fp64_gflops(f_eff / 1000.0) * DGEMM_EFF


def hpl_node_perf(f_set_mhz: float, vids: Sequence[float], *,
                  temp_c: float = 55.0,
                  util: float = HPL_GPU_UTIL) -> float:
    """Node HPL GFLOPS.  Multi-node HPL is gated by the slowest node, so
    cluster perf = n_nodes * min(node perf) (paper §2).

    ``util`` is the sustained GPU duty cycle (blocking-dependent — the
    autotuner's analytic model varies it with HPL's NB; the default is
    the calibrated Green500-run value).

    No oscillation penalty: HPL's phase structure (panel factorization /
    update bursts) absorbs the P-state dithering that hurts the
    continuous DGEMM loop."""
    gpu = 0.0
    for v in vids:
        f_sus, _ = sustained_frequency(f_set_mhz, v, temp_c=temp_c,
                                       util=util)
        gpu += S9150.peak_fp64_gflops(f_sus / 1000.0) * DGEMM_EFF
    return gpu * HPL_NODE_SCALE


def cluster_hpl_perf(f_set_mhz: float, node_vids: Sequence[Sequence[float]],
                     *, temp_c: float = 55.0) -> float:
    """Slowest node dictates (synchronous distribution of HPL panels)."""
    per_node = [hpl_node_perf(f_set_mhz, vids, temp_c=temp_c)
                for vids in node_vids]
    return len(per_node) * min(per_node)


# ---------------------------------------------------------------------------
# TPU-side throttle (framework target)
# ---------------------------------------------------------------------------

def tpu_sustained_scale(freq_scale: float, compute_util: float,
                        mem_util: float, *, chip_eff: float = 1.0,
                        tdp_w: float = 200.0) -> Tuple[float, bool]:
    """TPU analogue: chip_eff < 1 models a worse-binned chip (higher draw).

    Returns (sustained freq scale, throttled)."""
    from repro.power.model import (TPU_DYN_COMPUTE_W, TPU_DYN_MEM_W,
                                   TPU_IDLE_W)
    p = (TPU_IDLE_W + TPU_DYN_COMPUTE_W * freq_scale ** 2 * compute_util
         / chip_eff + TPU_DYN_MEM_W * mem_util)
    if p <= tdp_w:
        return freq_scale, False
    f2 = (tdp_w - TPU_IDLE_W - TPU_DYN_MEM_W * mem_util) * chip_eff \
        / max(TPU_DYN_COMPUTE_W * compute_util, 1e-9)
    return float(np.sqrt(max(f2, 0.09))), True
