"""Legacy import path for the node/GPU power model.

The calibrated models now live in :mod:`repro.power` (the unified
power-telemetry engine): device-level constants and curves in
``repro.power.model``, the node/rack/cluster composition (host + GPUs +
fans + PSU-efficiency curve) in ``repro.power.layers``.  This module
re-exports the pre-refactor names so existing imports keep working —
no constant is defined here.
"""
import warnings

warnings.warn(
    "repro.core.energy.power_model is deprecated; import from repro.power "
    "(repro.power.model / repro.power.layers) instead",
    DeprecationWarning, stacklevel=2)

from repro.power.model import (  # noqa: E402,F401
    EFFICIENT_MHZ,
    FAN_BASE_W,
    FAN_CUBIC_W,
    K_DYN,
    P_GPU_STATIC_40C,
    STOCK_MHZ,
    S9150,
    S10000_CHIP,
    TEMP_SLOPE_W_PER_C,
    TPU_DYN_COMPUTE_W,
    TPU_DYN_MEM_W,
    TPU_IDLE_W,
    TPU_TDP_W,
    V_F_SLOPE,
    V_MAX,
    V_MIN,
    GPUSpec,
    fan_power,
    gpu_dynamic_power,
    gpu_power,
    gpu_static_power,
    sample_vids,
    tpu_chip_power,
    voltage_at,
)
from repro.power.layers import (  # noqa: E402,F401
    P_HOST_DC_W,
    NodeModel,
    NodePowerModel,
    PSUCurve,
    node_power,
)
