"""Node / GPU power model calibrated to the paper's Fig. 1b and §3–4.

Calibration targets (all published):
  * S9150 TDP 275 W; stock 900 MHz, efficiency clock 774 MHz
  * voltage IDs span 1.1425 V … 1.2 V at 900 MHz (Fig. 1a)
  * optimum fan duty 40%, power slope steeper above 40% (Fig. 1b)
  * Green500 run: 56 nodes, 57.2 kW → 1021 W/node at 774 MHz
  * node Linpack 6175–6280 GFLOPS @900 MHz, ≈5384 GFLOPS @774 MHz
    (301.5 TFLOPS / 56), efficiency 5271.8 MFLOPS/W

Model:  P_gpu = P_static(V, T) + K_DYN · f · V² · util     (f in GHz)
        P_node = P_host + Σ P_gpu + P_fan(s)
The derivation of the constants is in DESIGN.md §6 / benchmarks; the
benchmarks assert the reproduction against the published numbers.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Device specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GPUSpec:
    name: str
    stream_processors: int
    fp64_flops_per_sp_per_cycle: float
    tdp_w: float
    mem_bw_gbs: float
    mem_gb: int

    def peak_fp64_gflops(self, f_ghz: float) -> float:
        return (self.stream_processors * self.fp64_flops_per_sp_per_cycle
                * f_ghz)


S9150 = GPUSpec("FirePro S9150", 2816, 1.0, 275.0, 320.0, 16)
S10000_CHIP = GPUSpec("FirePro S10000 (per chip)", 1792, 0.5, 187.5, 240.0, 6)

# Published clocks / voltages
STOCK_MHZ = 900
EFFICIENT_MHZ = 774
V_MIN = 1.1425           # best chips' voltage ID at 900 MHz
V_MAX = 1.2              # worst chips'

# Calibrated constants
P_GPU_STATIC_40C = 35.0  # W at 40 °C, V_MIN
TEMP_SLOPE_W_PER_C = 0.30
K_DYN = 200.0            # W / (GHz · V²): V_MIN chips just avoid throttle at 900
P_HOST_W = 200.0         # 2x10-core CPUs + 256 GB DIMMs + chipset + IB HCA
FAN_BASE_W = 12.0
FAN_CUBIC_W = 160.0      # node fans at 100% ≈ 172 W
V_F_SLOPE = 0.0006       # V per MHz of downclock


def voltage_at(f_mhz: float, vid_900: float) -> float:
    """Operating voltage at frequency f for a chip with voltage-ID vid_900."""
    return max(0.8, vid_900 - V_F_SLOPE * (STOCK_MHZ - f_mhz))


def gpu_static_power(vid_900: float, temp_c: float = 55.0) -> float:
    scale = (vid_900 / V_MIN) ** 2
    return (P_GPU_STATIC_40C + TEMP_SLOPE_W_PER_C * max(temp_c - 40.0, 0.0)) \
        * scale


def gpu_dynamic_power(f_ghz: float, v: float, util: float = 1.0) -> float:
    return K_DYN * f_ghz * v * v * util


def gpu_power(f_mhz: float, vid_900: float, *, temp_c: float = 55.0,
              util: float = 1.0, spec: GPUSpec = S9150) -> float:
    """Un-throttled electrical power draw (may exceed TDP — the throttle
    module clamps by reducing frequency, not by magic)."""
    v = voltage_at(f_mhz, vid_900)
    return gpu_static_power(vid_900, temp_c) + gpu_dynamic_power(
        f_mhz / 1000.0, v, util)


def fan_power(speed: float) -> float:
    """Node fan power vs duty cycle in [0, 1] (cubic — Fig. 1b shape)."""
    s = float(np.clip(speed, 0.0, 1.0))
    return FAN_BASE_W + FAN_CUBIC_W * s ** 3


def node_power(f_mhz: float, vids: Sequence[float], *, fan: float = 0.40,
               temp_c: float = 55.0, util: float = 1.0,
               gpu_clamped_w: Sequence[float] | None = None) -> float:
    """Total node power.  If ``gpu_clamped_w`` is given (post-throttle), use
    it; otherwise evaluate the unconstrained model."""
    if gpu_clamped_w is not None:
        gpus = float(np.sum(gpu_clamped_w))
    else:
        gpus = float(sum(gpu_power(f_mhz, v, temp_c=temp_c, util=util)
                         for v in vids))
    return P_HOST_W + gpus + fan_power(fan)


@dataclass
class NodePowerModel:
    """Convenience wrapper binding a node's chip population."""

    vids: Sequence[float]
    fan: float = 0.40
    temp_c: float = 55.0
    spec: GPUSpec = S9150

    def power(self, f_mhz: float, util: float = 1.0,
              gpu_clamped_w: Sequence[float] | None = None) -> float:
        return node_power(f_mhz, self.vids, fan=self.fan, temp_c=self.temp_c,
                          util=util, gpu_clamped_w=gpu_clamped_w)

    def with_fan(self, fan: float) -> "NodePowerModel":
        return dataclasses.replace(self, fan=fan)


def sample_vids(rng: np.random.Generator, n: int) -> np.ndarray:
    """Manufacturing voltage-ID spread (paper: every ASIC differs)."""
    # triangular-ish spread within the published [V_MIN, V_MAX]
    return np.clip(rng.normal((V_MIN + V_MAX) / 2, 0.015, n), V_MIN, V_MAX)


# ---------------------------------------------------------------------------
# TPU-side power model (the framework target; assumed constants, documented)
# ---------------------------------------------------------------------------

TPU_IDLE_W = 60.0
TPU_DYN_COMPUTE_W = 110.0    # MXU-bound at full clock
TPU_DYN_MEM_W = 30.0         # HBM-bound component
TPU_TDP_W = 200.0            # per-chip budget (v5e-class, assumed)


def tpu_chip_power(freq_scale: float, compute_util: float,
                   mem_util: float) -> float:
    """P(f) for a TPU chip: dynamic compute power scales ~ f·V(f)² ≈ f²."""
    f = float(np.clip(freq_scale, 0.3, 1.0))
    return (TPU_IDLE_W + TPU_DYN_COMPUTE_W * f * f * compute_util
            + TPU_DYN_MEM_W * mem_util)
