"""Energy-to-solution model for the CG/D-slash workload (paper §1, §4).

The paper's efficiency story is solver-level: D-slash is memory-bound, so
time-to-solution is (bytes moved) / (effective bandwidth), and
energy-to-solution is that time times device power.  Even-odd
preconditioning and reduced precision both enter through the byte count:

  * one normal-op application (M†M, or the Schur A†A) streams two
    D-slash-equivalents of traffic regardless of preconditioning — the
    even-odd win per op is in the *CG vector algebra*, whose vectors are
    half as long — and preconditioning cuts the number of ops;
  * reduced inner precision scales every byte of the inner iterations.

``solver_energy`` turns measured iteration counts into the paper-style
figure of merit (GFLOPS/W).  Device constants come from the unified
power engine (:mod:`repro.power`) — the S9150 spec and the published
bandwidth fraction are referenced, not re-declared — and each report
carries the :class:`repro.power.PowerTrace` its energy was integrated
from, so solver runs land on the same telemetry bus as everything else.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.configs.lcsc_lqcd import DSLASH_BW_FRACTION
from repro.lqcd.dirac import dslash_bytes_per_site, dslash_flops_per_site
from repro.power.model import S9150
from repro.power.trace import PowerTrace, TraceRecorder

# CG linear algebra per normal-op iteration: x/r/p updates and the two
# reductions touch ~10 spinor-vector streams (24 reals per site each).
CG_VECTOR_STREAMS = 10
REALS_PER_SPINOR = 24


@dataclass(frozen=True)
class SolverHW:
    """Device constants for the bandwidth/power model (default: FirePro
    S9150, the paper's GPU — taken from the ``repro.power`` spec)."""

    name: str = S9150.name
    bandwidth_gbs: float = S9150.mem_bw_gbs
    bw_fraction: float = DSLASH_BW_FRACTION    # CL2QCD reaches ~80% of peak
    power_w: float = S9150.tdp_w               # board TDP


S9150_HW = SolverHW()


@dataclass(frozen=True)
class SolverEnergyReport:
    name: str
    normal_ops: int                    # total normal-op applications
    bytes_total: float
    time_s: float
    energy_j: float
    gflops: float                      # sustained, over the whole solve
    gflops_per_w: float
    trace: Optional[PowerTrace] = field(default=None, repr=False,
                                        compare=False)


def normal_op_bytes(volume: int, real_bytes: int, *, even_odd: bool,
                    compressed_links: bool = True) -> float:
    """Traffic of one normal-op application plus its CG vector algebra."""
    # M†M: two full-lattice hops; A†A: four half-lattice hops — same hop
    # traffic either way (2 x volume sites streamed per application)
    hop = 2 * volume * dslash_bytes_per_site(real_bytes, compressed_links)
    sites = volume // 2 if even_odd else volume
    vecs = CG_VECTOR_STREAMS * sites * REALS_PER_SPINOR * real_bytes
    return float(hop + vecs)


def solver_energy(name: str, volume: int, inner_ops: int, *,
                  outer_ops: int = 0, inner_real_bytes: int = 4,
                  outer_real_bytes: int = 4, even_odd: bool = False,
                  compressed_links: bool = True,
                  hw: SolverHW = S9150_HW,
                  recorder: Optional[TraceRecorder] = None,
                  ) -> SolverEnergyReport:
    """Energy-to-solution estimate from iteration counts.

    ``inner_ops`` are normal-op applications at ``inner_real_bytes``
    precision; ``outer_ops`` are full-precision defect-correction steps
    (residual recomputation ≈ one Schur application ≈ half a normal op,
    counted as a full one to stay conservative).

    The solve is emitted into a :class:`TraceRecorder` as a constant
    memory-bound device-power phase; energy is integrated from the
    resulting trace (``trace.energy_j()``), not from a private
    watts×seconds product.  A shared ``recorder`` may carry earlier
    phases — this solve is appended after its latest sample, so
    sequential solves stack on one bus instead of overlapping at t=0.
    """
    b = (inner_ops * normal_op_bytes(volume, inner_real_bytes,
                                     even_odd=even_odd,
                                     compressed_links=compressed_links)
         + outer_ops * normal_op_bytes(volume, outer_real_bytes,
                                       even_odd=even_odd,
                                       compressed_links=compressed_links))
    eff_bw = hw.bandwidth_gbs * 1e9 * hw.bw_fraction
    time_s = b / eff_bw
    flops = (inner_ops + outer_ops) * 2 * volume * dslash_flops_per_site()
    gflops = flops / time_s / 1e9

    # explicit None check (an empty recorder is falsy but still the
    # caller's bus); stack this phase after anything already recorded
    rec = recorder if recorder is not None \
        else TraceRecorder(source=f"solver:{name}")
    t0 = rec.t_last
    # memory-bound solve: flat device power over the run (two samples
    # bound the phase; recorder grids finer if dt_s is set)
    for t in (t0, t0 + time_s):
        rec.emit(t, {"gpu": hw.power_w}, flops_rate=gflops, util=1.0)
    trace = rec.trace()
    energy_j = trace.energy_j(t0=t0, t1=t0 + time_s)
    return SolverEnergyReport(name, inner_ops + outer_ops, b, time_s,
                              energy_j, gflops, gflops / hw.power_w,
                              trace=trace)
