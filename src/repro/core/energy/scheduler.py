"""Deprecated import path for the cluster scheduler.

The job model and scheduler now live in :mod:`repro.cluster.scheduler`,
co-designed with the unified Workload API (``repro.cluster``): the same
``Job``/``Chip``/``Placement`` types, topology-aware policies, power-cap
enforcement and the straggler models.  This module re-exports the
pre-refactor names so existing imports keep working.
"""
import warnings

warnings.warn(
    "repro.core.energy.scheduler is deprecated; import from "
    "repro.cluster.scheduler (the power-aware cluster scheduler behind "
    "the unified Workload API) instead",
    DeprecationWarning, stacklevel=2)

from repro.cluster.scheduler import (  # noqa: E402,F401
    Chip,
    Job,
    Placement,
    drop_slowest_pod,
    expected_slowdown,
    frequency_floor_mitigation,
    makespan,
    schedule_throughput,
    straggler_step_time,
)
