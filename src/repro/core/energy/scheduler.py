"""Cluster scheduling & straggler model (paper §1–2).

Two paper observations become framework features:
  * "run most lattices on a single GPU; use all four GPUs of a node for
    independent lattices" — a throughput scheduler that prefers chip-local
    jobs and only shards a job when it exceeds single-chip memory
    (charging the published ~20% multi-GPU penalty);
  * "multi-node HPL distributes work evenly, so the slowest node dictates
    performance" — a synchronous-step straggler model with mitigation by
    frequency flooring (the flat-774 result) or dropping the slow pod.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.lcsc_lqcd import MULTI_GPU_SLOWDOWN


@dataclass(frozen=True)
class Job:
    name: str
    mem_gb: float
    work_units: float            # relative wall-clock on one reference chip


@dataclass
class Chip:
    chip_id: int
    mem_gb: float
    perf_scale: float = 1.0      # chip-to-chip variation
    busy_until: float = 0.0


@dataclass
class Placement:
    job: Job
    chips: List[int]
    start: float
    end: float
    sharded: bool


def schedule_throughput(jobs: Sequence[Job], chips: List[Chip],
                        *, multi_gpu_penalty: float = MULTI_GPU_SLOWDOWN,
                        ) -> List[Placement]:
    """Greedy list scheduler: single-chip placement unless the job's memory
    demands sharding; sharded jobs take ceil(mem/chip_mem) chips and run at
    (1 - penalty) efficiency (paper: ~20% for >1 GPU lattices)."""
    placements: List[Placement] = []
    for job in sorted(jobs, key=lambda j: -j.work_units):
        need = max(1, math.ceil(job.mem_gb / chips[0].mem_gb))
        pool = sorted(chips, key=lambda c: c.busy_until)[:need]
        start = max(c.busy_until for c in pool)
        if need == 1:
            dur = job.work_units / pool[0].perf_scale
        else:
            agg = sum(c.perf_scale for c in pool) * (1 - multi_gpu_penalty)
            dur = job.work_units / agg
        for c in pool:
            c.busy_until = start + dur
        placements.append(Placement(job, [c.chip_id for c in pool], start,
                                    start + dur, need > 1))
    return placements


def makespan(placements: Sequence[Placement]) -> float:
    return max(p.end for p in placements) if placements else 0.0


# ---------------------------------------------------------------------------
# Synchronous-step straggler model
# ---------------------------------------------------------------------------

def straggler_step_time(base_step_s: float, perf_scales: Sequence[float],
                        ) -> float:
    """Synchronous SPMD: the slowest participant gates every step."""
    return base_step_s / min(perf_scales)


def expected_slowdown(n_chips: int, sigma: float,
                      rng: Optional[np.random.Generator] = None,
                      trials: int = 256) -> float:
    """E[min perf] over a population with relative spread sigma — how much
    a 1000+ chip job loses to manufacturing spread without mitigation."""
    rng = rng or np.random.default_rng(0)
    mins = rng.normal(1.0, sigma, size=(trials, n_chips)).min(axis=1)
    return float(1.0 / np.clip(mins, 1e-3, None).mean())


def frequency_floor_mitigation(perf_scales: Sequence[float],
                               ) -> Tuple[float, float]:
    """The paper's fix: clock every chip at the slowest chip's sustainable
    rate → no oscillation, flat profile.  Returns (uniform scale, gain vs
    unmitigated oscillating population)."""
    floor = min(perf_scales)
    # oscillating chips lose an extra 8% (throttle.OSC_PENALTY)
    unmitigated = min(p * (1 - 0.08 * (p < 1.0)) for p in perf_scales)
    return floor, floor / unmitigated - 1.0


def drop_slowest_pod(pod_perf: Dict[str, float], threshold: float = 0.93,
                     ) -> Tuple[List[str], float]:
    """Elastic mitigation: drop a pod whose perf is below threshold x median
    if the remaining aggregate throughput improves (synchronous scaling:
    throughput = n_pods x min(perf))."""
    names = list(pod_perf)
    perfs = np.array([pod_perf[n] for n in names])
    full = len(perfs) * perfs.min()
    best_names, best = names, full
    med = float(np.median(perfs))
    for i, n in enumerate(names):
        if perfs[i] < threshold * med:
            rest = np.delete(perfs, i)
            alt = len(rest) * rest.min()
            if alt > best:
                best, best_names = alt, [m for j, m in enumerate(names)
                                         if j != i]
    return best_names, best / full - 1.0
