"""Roofline-coupled DVFS planning — the paper's C5 as framework machinery.

The paper's insight, generalized: a step's time is max(compute, memory,
collective); only the compute term scales with clock.  For memory-/
collective-bound phases (the paper's D̸; our decode cells) the clock can be
dropped with near-zero perf loss (<1.5% in the paper).  For compute-bound
phases the best clock is the highest NON-THROTTLING one (774-vs-900 MHz).

``plan_frequency`` makes that decision from the roofline terms of a compiled
step; ``heuristic_search`` reproduces the paper's parameter-space search
(frequency x fan) on the calibrated node model.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Sequence, Tuple

from repro.config import EnergyConfig
from repro.core.energy.throttle import tpu_sustained_scale
from repro.power.model import fan_curve, tpu_chip_power  # noqa: F401
# (fan_curve moved to repro.power.model; re-exported here for the
# pre-refactor import path)


@dataclass(frozen=True)
class FreqPlan:
    freq_scale: float            # chosen clock (fraction of peak)
    step_time_s: float
    power_w: float               # per chip
    energy_per_step_j: float
    perf_loss: float             # vs best achievable step time
    throttled: bool
    efficiency_flops_per_w: float
    dominant: str


def _step_time(freq: float, compute_s: float, memory_s: float,
               collective_s: float) -> float:
    return max(compute_s / max(freq, 1e-6), memory_s, collective_s)


def plan_frequency(compute_s: float, memory_s: float, collective_s: float,
                   *, flops_per_step: float = 0.0,
                   cfg: EnergyConfig = EnergyConfig(),
                   chip_eff: float = 1.0) -> FreqPlan:
    """Pick the per-step clock from the roofline decomposition."""
    total = max(compute_s + memory_s + collective_s, 1e-12)

    def evaluate(f: float) -> FreqPlan:
        cu = compute_s / total
        mu = memory_s / total
        f_sus, throttled = tpu_sustained_scale(f, cu, mu, chip_eff=chip_eff)
        t = _step_time(f_sus, compute_s, memory_s, collective_s)
        if throttled:
            t *= 1.05                         # oscillation penalty
        p = tpu_chip_power(f_sus, cu * (compute_s / max(f_sus, 1e-6)) / t,
                           mu * memory_s / t)
        e = p * t
        eff = flops_per_step / e if e > 0 else 0.0
        return FreqPlan(f, t, p, e, 0.0, throttled, eff,
                        dominant=max((("compute", compute_s),
                                      ("memory", memory_s),
                                      ("collective", collective_s)),
                                     key=lambda kv: kv[1])[0])

    plans = [evaluate(f) for f in cfg.freq_grid]
    best_t = min(p.step_time_s for p in plans)
    plans = [FreqPlan(p.freq_scale, p.step_time_s, p.power_w,
                      p.energy_per_step_j,
                      p.step_time_s / best_t - 1.0, p.throttled,
                      p.efficiency_flops_per_w, p.dominant) for p in plans]
    if cfg.mode == "performance":
        # highest clock that does not throttle (the 774-vs-900 result);
        # fall back to min step time
        ok = [p for p in plans if not p.throttled]
        pool = ok or plans
        return min(pool, key=lambda p: (p.step_time_s, p.power_w))
    # efficiency mode: min energy subject to bounded perf loss
    ok = [p for p in plans if p.perf_loss <= cfg.max_perf_loss]
    pool = ok or plans
    return min(pool, key=lambda p: p.energy_per_step_j)


# ---------------------------------------------------------------------------
# The paper's heuristic parameter search (node model, GPU cluster)
# ---------------------------------------------------------------------------


def heuristic_search(objective: Callable[[float, float], Tuple[float, float]],
                     freqs_mhz: Sequence[float],
                     fans: Sequence[float]) -> Dict:
    """Grid search over (frequency, fan duty) maximizing perf/power.

    ``objective(f_mhz, fan)`` returns (perf_gflops, power_w).  Mirrors the
    paper's 'heuristic search in the parameter space of GPU voltage, GPU and
    CPU frequencies, fan speed settings'."""
    best = None
    trace = []
    for f in freqs_mhz:
        for s in fans:
            perf, power = objective(f, s)
            eff = perf / max(power, 1e-9)
            trace.append({"f_mhz": f, "fan": s, "perf_gflops": perf,
                          "power_w": power, "mflops_per_w": eff * 1000.0})
            if best is None or eff > best["mflops_per_w"] / 1000.0:
                best = trace[-1]
    return {"best": best, "trace": trace}
