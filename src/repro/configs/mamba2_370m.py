"""mamba2-370m [ssm] — 48L d_model=1024, attention-free, vocab=50280.

SSD (state-space duality), ssm_state=128. [arXiv:2405.21060; unverified]
"""
from repro.config import ModelConfig, SSMConfig, register_arch

ARCH_ID = "mamba2-370m"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        n_layers=48,
        d_model=1024,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      chunk_size=256),
        norm_variant="rmsnorm",
        tie_embeddings=True,
        source="arXiv:2405.21060",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=256,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      chunk_size=32),
        norm_variant="rmsnorm",
        tie_embeddings=True,
        source="smoke",
    )


register_arch(ARCH_ID, full, smoke)
