"""llama3-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.

GQA with 128k vocab. [arXiv:2407.21783; unverified]
"""
from repro.config import ModelConfig, register_arch

ARCH_ID = "llama3-8b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=128256,
        rope_theta=500_000.0,
        mlp_variant="swiglu",
        norm_variant="rmsnorm",
        source="arXiv:2407.21783",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=256,
        rope_theta=500_000.0,
        mlp_variant="swiglu",
        norm_variant="rmsnorm",
        source="smoke",
    )


register_arch(ARCH_ID, full, smoke)
