"""minitron-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.

Pruned Nemotron: squared-ReLU MLP (non-gated), huge vocab.
[arXiv:2407.14679; hf]
"""
from repro.config import ModelConfig, register_arch

ARCH_ID = "minitron-8b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=16384,
        vocab_size=256000,
        mlp_variant="relu2",
        norm_variant="layernorm",
        source="arXiv:2407.14679",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=192,
        vocab_size=512,
        mlp_variant="relu2",
        norm_variant="layernorm",
        source="smoke",
    )


register_arch(ARCH_ID, full, smoke)
