"""Architecture configs. Importing this package registers every assigned arch.

Each module defines ``full()`` (the exact published configuration) and
``smoke()`` (a reduced same-family configuration for CPU tests) and calls
``repro.config.register_arch``.
"""
from repro.configs import (  # noqa: F401
    whisper_small,
    grok1_314b,
    deepseek_v2_236b,
    qwen15_32b,
    minitron_8b,
    olmo_1b,
    llama3_8b,
    mamba2_370m,
    llava_next_mistral_7b,
    hymba_1_5b,
    lcsc_lqcd,
    hpl,
)
