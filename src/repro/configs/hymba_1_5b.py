"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001.

Parallel attention + mamba heads in every layer; ssm_state=16; sliding-window
attention makes 500k decode sub-quadratic. [arXiv:2411.13676; hf]
"""
from repro.config import ModelConfig, SSMConfig, register_arch

ARCH_ID = "hymba-1.5b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        d_head=64,
        d_ff=5504,
        vocab_size=32001,
        sliding_window=2048,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64,
                      chunk_size=256),
        mlp_variant="swiglu",
        norm_variant="rmsnorm",
        source="arXiv:2411.13676",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="hybrid",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=160,
        vocab_size=256,
        sliding_window=32,
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=16,
                      chunk_size=16),
        mlp_variant="swiglu",
        norm_variant="rmsnorm",
        source="smoke",
    )


register_arch(ARCH_ID, full, smoke)
