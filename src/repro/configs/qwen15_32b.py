"""qwen1.5-32b [dense] — 64L d_model=5120 40H (GQA kv=40) d_ff=27392 vocab=152064.

QKV bias. [hf:Qwen/Qwen1.5-0.5B family config scaled; hf]
"""
from repro.config import ModelConfig, register_arch

ARCH_ID = "qwen1.5-32b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=40,
        d_ff=27392,
        vocab_size=152064,
        qkv_bias=True,
        mlp_variant="swiglu",
        norm_variant="rmsnorm",
        source="hf:Qwen/Qwen1.5-32B",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=2,
        d_model=80,
        n_heads=5,
        n_kv_heads=5,
        d_ff=208,
        vocab_size=256,
        qkv_bias=True,
        mlp_variant="swiglu",
        norm_variant="rmsnorm",
        source="smoke",
    )


register_arch(ARCH_ID, full, smoke)
