"""HPL (Linpack) benchmark configuration — the paper's §2 workload.

Mirrors HPL-GPU's two operating modes: ``performance`` and ``efficiency``
(the efficiency mode sacrifices a small fraction of performance for lower
power — paper §2 last paragraph).
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class HPLConfig:
    n: int = 1024                 # matrix size (CPU-scale default)
    block: int = 128              # panel/update block size NB
    lookahead: int = 1            # lookahead depth (HPL-GPU style)
    mode: str = "performance"     # performance | efficiency
    dtype: str = "float32"
    seed: int = 7

    def efficiency(self) -> "HPLConfig":
        # Efficiency mode: smaller update tiles keep the chip below the
        # throttle point; paired with the DVFS plan's derated clock.
        return HPLConfig(n=self.n, block=max(32, self.block // 2),
                         lookahead=self.lookahead, mode="efficiency",
                         dtype=self.dtype, seed=self.seed)

    def tuned(self) -> "HPLConfig":
        """Blocking/lookahead from the autotune cache for this problem
        size (``repro.autotune``; the analytic searcher runs once on a
        cache miss) — replaces the hard-coded block constants."""
        from repro.autotune import tuned_config
        best = tuned_config("hpl", (self.n,))
        return HPLConfig(n=self.n, block=int(best["block"]),
                         lookahead=int(best["lookahead"]),
                         mode=self.mode, dtype=self.dtype,
                         seed=self.seed)


SMOKE_HPL = HPLConfig(n=192, block=32)
DEFAULT_HPL = HPLConfig()
