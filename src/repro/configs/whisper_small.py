"""whisper-small [audio] — 12L d_model=768 12H (GQA kv=12) d_ff=3072 vocab=51865.

Encoder-decoder; conv audio frontend is a STUB (input_specs() provides
precomputed frame embeddings, enc_len = dec_len / encoder_ratio).
[arXiv:2212.04356; unverified]
"""
from repro.config import ModelConfig, register_arch

ARCH_ID = "whisper-small"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="encdec",
        n_layers=12,              # decoder layers
        n_encoder_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab_size=51865,
        mlp_variant="gelu",
        norm_variant="layernorm",
        frontend="audio",
        encoder_ratio=4,
        tie_embeddings=True,
        source="arXiv:2212.04356",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="encdec",
        n_layers=2,
        n_encoder_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        mlp_variant="gelu",
        norm_variant="layernorm",
        frontend="audio",
        encoder_ratio=4,
        tie_embeddings=True,
        source="smoke",
    )


register_arch(ARCH_ID, full, smoke)
