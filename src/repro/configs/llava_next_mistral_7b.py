"""llava-next-mistral-7b [vlm] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000.

Mistral-7B backbone; anyres vision tiling is a STUB (input_specs() provides
n_patches precomputed patch embeddings prepended to the text sequence).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from repro.config import ModelConfig, register_arch

ARCH_ID = "llava-next-mistral-7b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        rope_theta=1_000_000.0,
        mlp_variant="swiglu",
        norm_variant="rmsnorm",
        frontend="vlm",
        n_patches=576,
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=256,
        mlp_variant="swiglu",
        norm_variant="rmsnorm",
        frontend="vlm",
        n_patches=16,
        source="smoke",
    )


register_arch(ARCH_ID, full, smoke)
