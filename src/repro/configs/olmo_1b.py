"""olmo-1b [dense] — 16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304.

Non-parametric LayerNorm (no learnable scale/bias), tied embeddings.
[arXiv:2402.00838; hf]
"""
from repro.config import ModelConfig, register_arch

ARCH_ID = "olmo-1b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=8192,
        vocab_size=50304,
        mlp_variant="swiglu",
        norm_variant="nonparametric_ln",
        tie_embeddings=True,
        source="arXiv:2402.00838",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab_size=256,
        mlp_variant="swiglu",
        norm_variant="nonparametric_ln",
        tie_embeddings=True,
        source="smoke",
    )


register_arch(ARCH_ID, full, smoke)
