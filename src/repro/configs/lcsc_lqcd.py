"""The paper's own workload: LQCD on the L-CSC cluster.

Describes the Wilson D-slash / CG configuration and the published cluster
constants used by the calibrated models and benchmarks.  Not an LM arch —
not part of ARCH_IDS — but selectable by the LQCD example/benchmarks.
"""
from dataclasses import dataclass, field
from typing import Tuple

from repro.config import SolverConfig


@dataclass(frozen=True)
class LatticeConfig:
    """4D lattice for Wilson-Dirac D-slash."""

    shape: Tuple[int, int, int, int] = (32, 32, 32, 8)  # (x, y, z, t) thermal
    kappa: float = 0.137
    dtype: str = "float32"
    even_odd: bool = True
    solver: SolverConfig = field(default_factory=SolverConfig)

    @property
    def volume(self) -> int:
        v = 1
        for s in self.shape:
            v *= s
        return v

    @property
    def mem_gb(self) -> float:
        """Solver working-set estimate for the Workload/Job spec: gauge
        field (4 links × 18 reals/site) plus ~16 spinor-field streams
        (x, r, p, Ap, even/odd halves, defect vectors) at 24 reals/site.
        Thermal lattices fit on one GPU; cold (large-T) lattices are what
        force multi-GPU sharding (paper §1)."""
        real_bytes = 4 if self.dtype == "float32" else 8
        reals_per_site = 4 * 18 + 16 * 24
        return self.volume * reals_per_site * real_bytes / 1e9


# Solver presets: the seed's plain full-lattice CGNE, and the paper's
# CL2QCD strategy (even-odd + reduced-precision inner CG).
PLAIN_SOLVER = SolverConfig(preconditioner="none", inner_dtype="none")
EO_SOLVER = SolverConfig(preconditioner="even_odd", inner_dtype="none")
EO_MIXED_SOLVER = SolverConfig(preconditioner="even_odd",
                               inner_dtype="bfloat16")

# A thermal (T > 0) lattice: time extent anti-proportional to temperature.
THERMAL_LATTICE = LatticeConfig(shape=(32, 32, 32, 8))
# A T ~ 0 lattice (needs much more memory — paper §1).
COLD_LATTICE = LatticeConfig(shape=(32, 32, 32, 64))
# Smoke lattice for CPU tests.
SMOKE_LATTICE = LatticeConfig(shape=(4, 4, 4, 4))


@dataclass(frozen=True)
class LCSCNode:
    """Published per-node constants (paper Table 1 + §1)."""

    name: str
    cpu_cores: int
    gpus: int
    system_memory_gb: int
    gpu_stream_processors: int
    gpu_memory_gb: int
    gpu_peak_bandwidth_gbs: float     # aggregate per node
    peak_fp64_gflops: float           # aggregate per node


LOEWE_CSC = LCSCNode("LOEWE-CSC", 24, 1, 64, 1600, 1, 153.6, 745.6)
SANAM = LCSCNode("Sanam", 32, 4, 128, 7168, 12, 960.0, 3661.0)
L_CSC = LCSCNode("L-CSC", 40, 4, 256, 11264, 64, 1280.0, 10618.0)

# Per-GPU constants (paper §1)
S9150_BW_GBS = 320.0
S9150_MEM_GB = 16
S9150_TDP_W = 275.0
S10000_BW_GBS_PER_CHIP = 240.0
S10000_MEM_GB_PER_CHIP = 6

# Published application numbers (paper §1, §4)
DSLASH_GFLOPS_PER_S9150 = 135.0       # CL2QCD D-slash per S9150
DSLASH_BW_FRACTION = 0.80             # ~80% of peak memory bandwidth
CLUSTER_DSLASH_TFLOPS = 89.5
CLUSTER_PEAK_PFLOPS = 1.7
MULTI_GPU_SLOWDOWN = 0.20             # ~20% when a lattice spans >1 GPU

# Green500 run (paper §3–4)
GREEN500_NODES = 56
GREEN500_LINPACK_TFLOPS = 301.5
GREEN500_AVG_POWER_KW = 57.2
GREEN500_EFFICIENCY_MFLOPS_W = 5271.8
GREEN500_SWITCH_POWER_W = 257.0
SINGLE_NODE_EFFICIENCIES_MFLOPS_W = (
    5154.1, 5260.1, 5248.4, 5245.5, 5125.1, 5301.2, 5169.3)
NODE_VARIABILITY = 0.012              # ±1.2%
LEVEL1_OVERESTIMATE = 0.30            # up to 30% (paper §3)

# DVFS (paper §2, Fig. 1)
STOCK_CLOCK_MHZ = 900
EFFICIENT_CLOCK_MHZ = 774
BEST_CONSTANT_CLOCK_MHZ = 820
VOLTAGE_MIN = 1.1425
VOLTAGE_MAX = 1.2
DGEMM_GFLOPS_BEST_900 = 1250.0        # lowest-voltage GPUs @900 MHz
DGEMM_GFLOPS_WORST_900 = (950.0, 1100.0)
HPL_NODE_GFLOPS_900 = (6175.0, 6280.0)
OPTIMAL_FAN_SPEED = 0.40
DSLASH_EFF_PERF_LOSS = 0.015          # <1.5% at efficiency clocks
