"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072.

MoE: 8 experts, top-2 routing. [hf:xai-org/grok-1; unverified]
"""
from repro.config import MoEConfig, ModelConfig, register_arch

ARCH_ID = "grok-1-314b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=32768,
        vocab_size=131072,
        moe=MoEConfig(n_experts=8, top_k=2, expert_d_ff=32768),
        mlp_variant="geglu",
        norm_variant="rmsnorm",
        source="hf:xai-org/grok-1",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=128),
        mlp_variant="geglu",
        norm_variant="rmsnorm",
        source="smoke",
    )


register_arch(ARCH_ID, full, smoke)
