"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff=1536 vocab=102400.

MLA kv_lora=512; MoE: 2 shared + 160 routed experts, top-6.
[arXiv:2405.04434; hf]
"""
from repro.config import MLAConfig, MoEConfig, ModelConfig, register_arch

ARCH_ID = "deepseek-v2-236b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        d_ff=12288,            # dense-MLP d_ff of the first (non-MoE) layer class
        vocab_size=102400,
        moe=MoEConfig(n_experts=160, top_k=6, n_shared_experts=2,
                      expert_d_ff=1536),
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        mlp_variant="swiglu",
        norm_variant="rmsnorm",
        source="arXiv:2405.04434",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        moe=MoEConfig(n_experts=8, top_k=2, n_shared_experts=1,
                      expert_d_ff=48),
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16),
        mlp_variant="swiglu",
        norm_variant="rmsnorm",
        source="smoke",
    )


register_arch(ARCH_ID, full, smoke)
