"""Serve-side statistics: per-request latency percentiles, SLO
compliance, and joules-per-token — all derived from the replay engine's
request records plus the :class:`repro.power.PowerTrace` it emitted.

Glossary (all times in seconds, all energies in joules):

  * **wait**        admit − arrival (queueing delay before prefill)
  * **TTFT**        first_token − arrival (time to first token: queue +
                    prefill)
  * **latency**     done − arrival (full request turnaround)
  * **J/request**   window energy (busy + idle + host share) / completed
                    requests — idle watts are *charged*, which is the
                    whole autoscaling story
  * **J/token**     window energy / (prompt + generated tokens
                    processed); ``j_per_gen_token`` divides by generated
                    tokens only (the figure the old driver printed,
                    now with an honest denominator)
  * **compliance**  fraction of completed requests with latency ≤ the
                    p99 SLO target (1.0 when no SLO is set)

The engine emits *step* telemetry — doubled samples at each interval
boundary, so the series is piecewise-constant and the trapezoid rule
integrates it exactly.  :func:`step_window_integral` integrates such a
series over an arbitrary window (per-request energy windows land
exactly on interval boundaries, where linear edge interpolation would
split the step); :meth:`PowerTrace.energy_j` with ``(t0, t1)`` remains
the right tool for the smooth dt-gridded cluster traces.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.power.trace import PowerTrace


def step_window_integral(t: np.ndarray, y: np.ndarray,
                         t0: float, t1: float) -> float:
    """∫y dt over [t0, t1] treating ``(t, y)`` as a piecewise-constant
    series: segment ``[t[i], t[i+1])`` carries value ``y[i]`` (its left
    sample).  Exact for the serve engine's doubled-boundary emission,
    including windows whose edges land on boundaries."""
    t = np.asarray(t, dtype=float)
    y = np.asarray(y, dtype=float)
    if t.shape[0] < 2 or t1 <= t0:
        return 0.0
    lo = np.clip(t[:-1], t0, t1)
    hi = np.clip(t[1:], t0, t1)
    return float(np.sum(y[:-1] * np.maximum(hi - lo, 0.0)))


def request_energy_j(trace: PowerTrace, t0: float, t1: float) -> float:
    """This request's share of bus energy over its in-flight window
    [t0, t1]: at every instant it is charged ``power / batch`` where
    ``batch`` is the engine's emitted in-flight count (the ``batch``
    aux series) — computed from the bus, not a side accumulator."""
    b = trace.aux.get("batch")
    if b is None:
        raise ValueError("trace has no 'batch' aux series — not a serve "
                         "replay trace")
    share = trace.power_w / np.maximum(b, 1.0)
    return step_window_integral(trace.t, share, t0, t1)


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


@dataclass(frozen=True)
class ServeStats:
    """One replay's aggregate report (see module glossary)."""

    n_requests: int
    completed: int
    span_s: float
    p50_latency_s: float
    p95_latency_s: float
    p99_latency_s: float
    p50_ttft_s: float
    p99_ttft_s: float
    mean_wait_s: float
    tokens_prompt: int
    tokens_gen: int
    energy_j: float
    peak_power_w: float
    slo_s: Optional[float] = None
    slo_compliance: float = 1.0
    #: replica-failure resilience surface (all 0 without fault injection)
    retries: int = 0                 # failure-driven resubmissions
    gave_up: int = 0                 # requests that exhausted the budget
    replica_failures: int = 0        # live-replica kills during the run

    @property
    def j_per_request(self) -> float:
        return self.energy_j / max(self.completed, 1)

    @property
    def j_per_token(self) -> float:
        return self.energy_j / max(self.tokens_prompt + self.tokens_gen, 1)

    @property
    def j_per_gen_token(self) -> float:
        return self.energy_j / max(self.tokens_gen, 1)

    def summary(self) -> str:
        slo = "" if self.slo_s is None else \
            f" slo<={self.slo_s:.3g}s compliance={self.slo_compliance:.3f}"
        if self.replica_failures or self.retries or self.gave_up:
            slo += (f" | {self.replica_failures} replica failures, "
                    f"{self.retries} retries, {self.gave_up} gave up")
        return (f"{self.completed}/{self.n_requests} req in "
                f"{self.span_s:.3g}s | p50/p99 latency "
                f"{self.p50_latency_s:.3g}/{self.p99_latency_s:.3g}s "
                f"p99 ttft {self.p99_ttft_s:.3g}s{slo} | "
                f"{self.energy_j:.4g} J, {self.j_per_request:.3g} J/req, "
                f"{self.j_per_token:.3g} J/token "
                f"(peak {self.peak_power_w:.0f} W)")


def compute_serve_stats(records, trace: Optional[PowerTrace], *,
                        t0: float = 0.0, span: Optional[float] = None,
                        slo_s: Optional[float] = None,
                        replica_failures: int = 0) -> ServeStats:
    """Fold per-request records + the emitted trace window into one
    :class:`ServeStats`.  ``t0``/``span`` bound the energy integral to
    this replay's own bus emissions (a shared recorder carries earlier
    phases too).

    Under fault injection the compliance denominator *degrades
    honestly*: a request that exhausted its retry budget counts as an
    SLO miss (``ok / (completed + gave_up)``) — identical to the plain
    ratio when nothing was dropped."""
    done = [r for r in records if r.done_s is not None]
    lat = [r.done_s - r.arrival_s for r in done]
    ttft = [r.first_token_s - r.arrival_s for r in done
            if r.first_token_s is not None]
    wait = [r.admit_s - r.arrival_s for r in done if r.admit_s is not None]
    gave_up = sum(1 for r in records if getattr(r, "gave_up", False))
    retries = int(sum(getattr(r, "retries", 0) for r in records))
    energy = 0.0
    peak = 0.0
    if trace is not None:
        t1 = float(trace.t[-1]) if span is None else t0 + span
        energy = trace.energy_j(t0, t1)
        m = (trace.t >= t0) & (trace.t <= t1)
        if np.any(m):
            peak = float(np.max(trace.power_w[m]))
    compliance = 1.0
    if slo_s is not None and (lat or gave_up):
        ok = int(np.sum(np.asarray(lat) <= slo_s)) if lat else 0
        compliance = ok / max(len(lat) + gave_up, 1)
    return ServeStats(
        n_requests=len(records), completed=len(done),
        span_s=(max((r.done_s for r in done), default=0.0)
                - min((r.arrival_s for r in records), default=0.0)),
        p50_latency_s=_pct(lat, 50), p95_latency_s=_pct(lat, 95),
        p99_latency_s=_pct(lat, 99),
        p50_ttft_s=_pct(ttft, 50), p99_ttft_s=_pct(ttft, 99),
        mean_wait_s=float(np.mean(wait)) if wait else 0.0,
        tokens_prompt=int(sum(r.prompt_len for r in done)),
        tokens_gen=int(sum(r.gen_len for r in done)),
        energy_j=energy, peak_power_w=peak,
        slo_s=slo_s, slo_compliance=compliance,
        retries=retries, gave_up=gave_up,
        replica_failures=replica_failures)
