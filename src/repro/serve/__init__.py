"""Serve-traffic replay: recorded-request traces, continuous batching,
per-request J/token accounting, and SLO-aware autoscaling.

See ``docs/serving.md`` for the model and the stats glossary.
"""
from repro.serve.autoscale import (HOST_SHARE_W, AutoscalePolicy,
                                   FleetResult, RetryPolicy, flat_out,
                                   run_fleet)
from repro.serve.engine import (ContinuousBatchingEngine, Replica,
                                RequestRecord, ServeCostModel, ServeResult,
                                emit_step_intervals)
from repro.serve.executed import ExecutedGroupRuntime
from repro.serve.replay import ReplayServeWorkload, replay_shards
from repro.serve.stats import (ServeStats, compute_serve_stats,
                               request_energy_j, step_window_integral)
from repro.serve.trace import (RequestTrace, constant_trace, diurnal_trace,
                               poisson_trace)

__all__ = [
    "AutoscalePolicy", "ContinuousBatchingEngine", "ExecutedGroupRuntime",
    "FleetResult",
    "HOST_SHARE_W", "Replica", "ReplayServeWorkload", "RequestRecord",
    "RequestTrace", "RetryPolicy", "ServeCostModel", "ServeResult",
    "ServeStats",
    "compute_serve_stats", "constant_trace", "diurnal_trace",
    "emit_step_intervals", "flat_out", "poisson_trace",
    "replay_shards", "request_energy_j", "run_fleet",
    "step_window_integral",
]
