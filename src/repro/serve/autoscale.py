"""SLO-aware autoscaling + operating-point policy over a replica fleet.

The headline question (ROADMAP: "what operating point + autoscaling
policy minimizes energy per request under a p99 latency SLO and a wall
power cap?") becomes a closed-loop simulation:

  * a fleet of up to ``n_max`` :class:`~repro.serve.engine.Replica`
    chips, each running the same serve model at the policy's DVFS
    operating point (per-replica ``OperatingPoint``, PR-7 style);
  * a **router** that assigns each arriving request to the
    least-loaded live replica (LB tie-break: lowest id, so high-id
    replicas drain naturally and can be parked);
  * a **controller** ticking every ``dt_ctrl_s``: scale **up** when
    total backlog exceeds ``up_backlog ×`` the live slot capacity for
    ``hold_up`` consecutive ticks, scale **down** when in-flight
    utilization stays under ``down_util`` for ``hold_down`` ticks —
    classic queue-depth hysteresis.  Parked replicas draw 0 W; a
    replica being woken draws idle power for ``startup_s`` before it
    accepts traffic (model load), which is what makes hysteresis
    matter;
  * a **wall power cap**: the live-replica count is bounded so that
    worst-case draw (busy chips + host share) never exceeds
    ``power_cap_w`` — the cap is enforced by construction and verified
    against the emitted trace's peak.

Each live replica is charged a host-power share
(``P_HOST_DC_W / 4`` — one L-CSC host board serves 4 accelerators), so
"static flat-out" pays idle chip + host watts all night while the
autoscaled fleet parks replicas through the diurnal trough: that gap,
at equal SLO compliance, is the benchmark gate
(``benchmarks/paper_tables.py::serve_replay``).
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from itertools import count
from typing import List, Optional, Tuple

import numpy as np

from repro.distributed.fault import WeibullFailureModel
from repro.power.layers import P_HOST_DC_W
from repro.power.model import OperatingPoint
from repro.power.trace import PowerTrace, TraceRecorder
from repro.serve.engine import (Replica, RequestRecord, ServeCostModel,
                                emit_step_intervals)
from repro.serve.stats import ServeStats, compute_serve_stats
from repro.serve.trace import RequestTrace

#: per-replica share of the node host board (4 accelerators per host)
HOST_SHARE_W = P_HOST_DC_W / 4.0


@dataclass(frozen=True)
class RetryPolicy:
    """How spilled requests are retried after a replica failure: capped
    exponential backoff (``backoff_s · 2^(attempt-1)``, clipped at
    ``backoff_cap_s``) onto the surviving replicas, against a per-request
    ``max_retries`` budget — exhausting it marks the request
    ``gave_up`` (an honest SLO miss in :class:`ServeStats`)."""

    max_retries: int = 3
    backoff_s: float = 0.5
    backoff_cap_s: float = 8.0

    def __post_init__(self):
        if self.max_retries < 0 or self.backoff_s <= 0.0 \
                or self.backoff_cap_s < self.backoff_s:
            raise ValueError("max_retries must be ≥ 0, backoff_s positive "
                             "and backoff_cap_s ≥ backoff_s")

    def delay_s(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (1-based)."""
        return min(self.backoff_s * 2.0 ** (max(attempt, 1) - 1),
                   self.backoff_cap_s)


@dataclass(frozen=True)
class AutoscalePolicy:
    """One point in the policy space the benchmark compares."""

    name: str = "autoscaled"
    n_max: int = 8
    n_min: int = 1
    op: Optional[OperatingPoint] = None   # per-replica DVFS point
    mode: str = "efficiency"              # DVFS plan mode
    autoscale: bool = True                # False: n_max live forever
    dt_ctrl_s: float = 10.0
    startup_s: float = 0.0                # wake latency (idle watts, no traffic)
    up_backlog: float = 1.25              # backlog / live slots to scale up
    down_util: float = 0.30               # in-flight util to scale down
    hold_up: int = 1                      # consecutive ticks (hysteresis)
    hold_down: int = 3
    power_cap_w: Optional[float] = None


def flat_out(n: int, *, name: str = "static_flat_out",
             power_cap_w: Optional[float] = None) -> AutoscalePolicy:
    """The baseline: every replica live for the whole day at the stock
    clock in performance mode — no DVFS derate, no parking."""
    return AutoscalePolicy(name=name, n_max=n, n_min=n,
                           op=OperatingPoint(f_mhz=900.0),
                           mode="performance", autoscale=False,
                           power_cap_w=power_cap_w)


@dataclass
class FleetResult:
    """One policy's day: per-request records, the merged fleet trace
    (chip + host components), aggregate stats, and the live-replica
    step series the controller produced."""

    policy: AutoscalePolicy
    records: List[RequestRecord]
    trace: PowerTrace
    stats: ServeStats
    live_t: np.ndarray          # live-count step series (times)
    live_n: np.ndarray
    t_off: float
    span_s: float
    busy_w_per_replica: float = 0.0
    replica_failures: int = 0
    # every (rid, t_down, t_up) injected during the run
    outages: List[Tuple[int, float, float]] = field(default_factory=list)

    @property
    def n_live_peak(self) -> int:
        return int(self.live_n.max()) if self.live_n.size else 0

    @property
    def n_live_min(self) -> int:
        return int(self.live_n.min()) if self.live_n.size else 0


def _merge_fleet(replicas: List[Replica], live_t: np.ndarray,
                 live_n: np.ndarray):
    """Sum the replicas' piecewise-constant intervals (plus the host
    share of the live count) onto the union of their boundaries."""
    edges = set()
    for r in replicas:
        for iv in r.intervals:
            edges.add(iv[0])
            edges.add(iv[1])
    edges.update(float(t) for t in live_t)
    edges = np.array(sorted(edges))
    mids = 0.5 * (edges[:-1] + edges[1:])
    chip = np.zeros(mids.shape)
    gflops = np.zeros(mids.shape)
    batch = np.zeros(mids.shape)
    for r in replicas:
        starts = np.array([iv[0] for iv in r.intervals])
        ends = np.array([iv[1] for iv in r.intervals])
        pos = np.searchsorted(starts, mids, side="right") - 1
        ok = pos >= 0
        p = np.clip(pos, 0, len(starts) - 1)
        ok &= mids < ends[p]
        chip[ok] += np.array([iv[2] for iv in r.intervals])[p[ok]]
        gflops[ok] += np.array([iv[3] for iv in r.intervals])[p[ok]]
        batch[ok] += np.array([float(iv[4]) for iv in r.intervals])[p[ok]]
    lp = np.clip(np.searchsorted(live_t, mids, side="right") - 1,
                 0, len(live_t) - 1)
    host = live_n[lp] * HOST_SHARE_W
    intervals = [(float(edges[i]), float(edges[i + 1]), float(chip[i]),
                  float(gflops[i]), int(batch[i]))
                 for i in range(len(mids))]
    return intervals, host


def run_fleet(cost: ServeCostModel, requests: RequestTrace,
              policy: AutoscalePolicy, *,
              slo_s: Optional[float] = None,
              recorder: Optional[TraceRecorder] = None,
              failures: Optional[WeibullFailureModel] = None,
              retry: Optional[RetryPolicy] = None,
              failure_seed: int = 0) -> FleetResult:
    """Replay ``requests`` through a fleet under ``policy`` and return
    the merged telemetry + stats (see module docstring).

    ``failures`` injects per-replica Weibull kills (seeded by
    ``failure_seed``, one RNG stream per slot): a dead replica spills
    its queued + in-flight requests, which are retried under ``retry``
    (default :class:`RetryPolicy`) with capped exponential backoff onto
    the survivors; the slot returns after ``repair_s``.  Without
    ``failures`` the original event loop runs unchanged (bit-identical
    baseline)."""
    if not len(requests):
        raise ValueError("empty request trace: nothing to serve")
    if failures is not None:
        return _run_fleet_failures(cost, requests, policy, slo_s=slo_s,
                                   recorder=recorder, failures=failures,
                                   retry=retry or RetryPolicy(),
                                   failure_seed=failure_seed)
    probe = Replica(cost, op=policy.op, mode=policy.mode)
    worst_w = probe.p_busy + HOST_SHARE_W
    n_eff = policy.n_max
    if policy.power_cap_w is not None:
        n_allowed = int(math.floor(policy.power_cap_w / worst_w + 1e-9))
        if n_allowed < policy.n_min:
            raise ValueError(
                f"power cap {policy.power_cap_w:.0f} W admits only "
                f"{n_allowed} replicas at {worst_w:.0f} W each < n_min="
                f"{policy.n_min}")
        n_eff = min(n_eff, n_allowed)

    replicas = [Replica(cost, op=policy.op, mode=policy.mode, rid=i,
                        live=False)
                for i in range(policy.n_max)]
    n_init = policy.n_min if policy.autoscale else n_eff
    available_at = [math.inf] * policy.n_max
    for i in range(n_init):
        replicas[i].live = True
        available_at[i] = 0.0
    live_events: List[Tuple[float, int]] = [(0.0, n_init)]

    records = [RequestRecord(i, float(requests.arrival_s[i]),
                             int(requests.prompt_len[i]),
                             int(requests.gen_len[i]))
               for i in range(len(requests))]

    def advance_all(t: float) -> None:
        for r in replicas:
            if r.t < t:
                r.advance(t)

    def route(rec: RequestRecord, t: float) -> None:
        live = [r for r in replicas if r.live]
        ready = [r for r in live if available_at[r.rid] <= t]
        pool = ready or live
        target = min(pool, key=lambda r: (r.load(), r.rid))
        target.submit(rec)

    up_count = down_count = 0

    def control(t: float) -> None:
        nonlocal up_count, down_count
        if not policy.autoscale:
            return
        live = [r for r in replicas if r.live]
        n_live = len(live)
        slots = n_live * replicas[0].max_batch
        backlog = sum(r.load() for r in live)
        util = sum(len(r.inflight) for r in live) / max(slots, 1)
        if backlog > policy.up_backlog * slots:
            up_count += 1
            down_count = 0
        elif util < policy.down_util:
            down_count += 1
            up_count = 0
        else:
            up_count = down_count = 0
        if up_count >= policy.hold_up and n_live < n_eff:
            r_on = next(r for r in replicas if not r.live)
            r_on.live = True
            available_at[r_on.rid] = t + policy.startup_s
            live_events.append((t, n_live + 1))
            up_count = 0
        elif down_count >= policy.hold_down and n_live > policy.n_min:
            idle = [r for r in live if r.load() == 0
                    and available_at[r.rid] <= t]
            if idle:
                r_off = max(idle, key=lambda r: r.rid)
                r_off.live = False
                available_at[r_off.rid] = math.inf
                live_events.append((t, n_live - 1))
                down_count = 0

    i = 0
    n = len(records)
    t_tick = policy.dt_ctrl_s
    while i < n:
        t_arr = records[i].arrival_s
        if t_arr <= t_tick:
            advance_all(t_arr)
            route(records[i], t_arr)
            i += 1
        else:
            advance_all(t_tick)
            control(t_tick)
            t_tick += policy.dt_ctrl_s

    # traffic over: drain in place (no further control), then bring every
    # replica to the common horizon — the last work completion — so both
    # policies are billed over the same kind of span, with no idle tail
    # quantized to the control tick
    for r in replicas:
        r.drain()
    horizon = max(r.t for r in replicas)
    for r in replicas:
        if r.t < horizon:
            r.advance(horizon)

    live_t = np.array([e[0] for e in live_events])
    live_n = np.array([float(e[1]) for e in live_events])
    intervals, host = _merge_fleet(replicas, live_t, live_n)
    bus = recorder if recorder is not None \
        else TraceRecorder(source=f"serve.fleet.{policy.name}")
    t_off = bus.t_last
    emit_step_intervals(bus, intervals, t_off=t_off,
                        components={"host": host},
                        aux={"n_live": live_n[np.clip(
                            np.searchsorted(live_t, np.array(
                                [0.5 * (iv[0] + iv[1])
                                 for iv in intervals]), side="right") - 1,
                            0, len(live_t) - 1)]})
    trace = bus.trace()
    span = intervals[-1][1]
    stats = compute_serve_stats(records, trace, t0=t_off, span=span,
                                slo_s=slo_s)
    if policy.power_cap_w is not None \
            and stats.peak_power_w > policy.power_cap_w + 1e-6:
        raise AssertionError(
            f"policy {policy.name!r} exceeded its own power cap: "
            f"{stats.peak_power_w:.1f} W > {policy.power_cap_w:.1f} W")
    return FleetResult(policy, records, trace, stats, live_t, live_n,
                       t_off, span, busy_w_per_replica=probe.p_busy)


# event priorities at equal timestamps: repairs land before the failure
# clock restarts, retries/arrivals see post-repair capacity, controller
# ticks observe the settled state (arrival-before-tick matches the
# no-failure loop's ``t_arr <= t_tick`` ordering)
_PRIO = {"repair": 0, "fail": 1, "retry": 2, "arrive": 3, "tick": 4}


def _run_fleet_failures(cost: ServeCostModel, requests: RequestTrace,
                        policy: AutoscalePolicy, *,
                        slo_s: Optional[float],
                        recorder: Optional[TraceRecorder],
                        failures: WeibullFailureModel,
                        retry: RetryPolicy,
                        failure_seed: int) -> FleetResult:
    """The fault-injected twin of :func:`run_fleet`'s event loop:
    arrivals, controller ticks, per-slot Weibull kills, repairs and
    retry wake-ups merged on one event heap."""
    probe = Replica(cost, op=policy.op, mode=policy.mode)
    worst_w = probe.p_busy + HOST_SHARE_W
    n_eff = policy.n_max
    if policy.power_cap_w is not None:
        n_allowed = int(math.floor(policy.power_cap_w / worst_w + 1e-9))
        if n_allowed < policy.n_min:
            raise ValueError(
                f"power cap {policy.power_cap_w:.0f} W admits only "
                f"{n_allowed} replicas at {worst_w:.0f} W each < n_min="
                f"{policy.n_min}")
        n_eff = min(n_eff, n_allowed)

    replicas = [Replica(cost, op=policy.op, mode=policy.mode, rid=i,
                        live=False)
                for i in range(policy.n_max)]
    n_init = policy.n_min if policy.autoscale else n_eff
    available_at = [math.inf] * policy.n_max
    for i in range(n_init):
        replicas[i].live = True
        available_at[i] = 0.0
    live_events: List[Tuple[float, int]] = [(0.0, n_init)]

    records = [RequestRecord(i, float(requests.arrival_s[i]),
                             int(requests.prompt_len[i]),
                             int(requests.gen_len[i]))
               for i in range(len(requests))]

    rngs = failures.node_streams(failure_seed, policy.n_max)
    down_until = [0.0] * policy.n_max
    revive = [False] * policy.n_max   # was live when killed → relive
    outages: List[Tuple[int, float, float]] = []
    replica_failures = 0
    arrivals_left = len(records)
    retries_pending = 0

    heap: List[tuple] = []
    seq = count()

    def push(t: float, kind: str, payload=None) -> None:
        heapq.heappush(heap, (t, _PRIO[kind], next(seq), kind, payload))

    for rec in records:
        push(rec.arrival_s, "arrive", rec)
    for rid in range(policy.n_max):
        push(failures.draw_uptime_s(rngs[rid]), "fail", rid)
    push(policy.dt_ctrl_s, "tick", None)

    def advance_all(t: float) -> None:
        for r in replicas:
            if r.t < t:
                r.advance(t)

    def n_live() -> int:
        return sum(1 for r in replicas if r.live)

    def route(rec: RequestRecord, t: float) -> bool:
        live = [r for r in replicas if r.live]
        if not live:
            return False
        ready = [r for r in live if available_at[r.rid] <= t]
        pool = ready or live
        target = min(pool, key=lambda r: (r.load(), r.rid))
        target.submit(rec)
        return True

    def wake_spare(t: float) -> None:
        """Emergency replacement: bring up the lowest-id parked,
        repaired slot (capacity lost to a kill comes back before the
        controller would react)."""
        if n_live() >= n_eff:
            return
        spare = [r for r in replicas
                 if not r.live and down_until[r.rid] <= t]
        if spare:
            r_on = min(spare, key=lambda r: r.rid)
            r_on.live = True
            available_at[r_on.rid] = t + policy.startup_s
            live_events.append((t, n_live()))

    def submit_or_park(rec: RequestRecord, t: float) -> None:
        """Route now, or — with every slot dead — park on the retry
        heap (no budget consumed: the outage is the fleet's fault)."""
        nonlocal retries_pending
        if not route(rec, t):
            wake_spare(t)
            if not route(rec, t):
                retries_pending += 1
                push(t + retry.backoff_s, "retry", rec)

    up_count = down_count = 0

    def control(t: float) -> None:
        nonlocal up_count, down_count
        if not policy.autoscale:
            return
        live = [r for r in replicas if r.live]
        n_now = len(live)
        slots = n_now * replicas[0].max_batch
        backlog = sum(r.load() for r in live)
        util = sum(len(r.inflight) for r in live) / max(slots, 1)
        if backlog > policy.up_backlog * slots:
            up_count += 1
            down_count = 0
        elif util < policy.down_util:
            down_count += 1
            up_count = 0
        else:
            up_count = down_count = 0
        if up_count >= policy.hold_up and n_now < n_eff:
            spare = [r for r in replicas
                     if not r.live and down_until[r.rid] <= t]
            if spare:
                r_on = min(spare, key=lambda r: r.rid)
                r_on.live = True
                available_at[r_on.rid] = t + policy.startup_s
                live_events.append((t, n_now + 1))
                up_count = 0
        elif down_count >= policy.hold_down and n_now > policy.n_min:
            idle = [r for r in live if r.load() == 0
                    and available_at[r.rid] <= t]
            if idle:
                r_off = max(idle, key=lambda r: r.rid)
                r_off.live = False
                available_at[r_off.rid] = math.inf
                live_events.append((t, n_now - 1))
                down_count = 0

    while heap:
        t, _, _, kind, payload = heapq.heappop(heap)
        if kind == "repair":
            rid = payload
            if revive[rid] and n_live() < n_eff:
                r_on = replicas[rid]
                r_on.live = True
                available_at[rid] = t + policy.startup_s
                live_events.append((t, n_live()))
            revive[rid] = False
            # the slot's failure clock restarts when it is back in
            # service — a renewal process per slot, like the cluster sim
            push(t + failures.draw_uptime_s(rngs[rid]), "fail", rid)
        elif kind == "fail":
            rid = payload
            advance_all(t)
            down_until[rid] = t + failures.repair_s
            outages.append((rid, t, down_until[rid]))
            push(down_until[rid], "repair", rid)
            r = replicas[rid]
            if r.live:
                replica_failures += 1
                lost = r.fail()
                revive[rid] = True
                available_at[rid] = math.inf
                live_events.append((t, n_live()))
                if n_live() < policy.n_min:
                    wake_spare(t)
                for rec in lost:
                    rec.retries += 1
                    if rec.retries > retry.max_retries:
                        rec.gave_up = True
                    else:
                        retries_pending += 1
                        push(t + retry.delay_s(rec.retries), "retry", rec)
        elif kind == "retry":
            retries_pending -= 1
            advance_all(t)
            submit_or_park(payload, t)
        elif kind == "arrive":
            arrivals_left -= 1
            advance_all(t)
            submit_or_park(payload, t)
        else:                                        # tick
            advance_all(t)
            control(t)
            if (arrivals_left or retries_pending
                    or any(r.load() for r in replicas)):
                push(t + policy.dt_ctrl_s, "tick", None)
        if (not arrivals_left and not retries_pending
                and not any(r.load() for r in replicas)):
            break

    for r in replicas:
        r.drain()
    horizon = max(r.t for r in replicas)
    for r in replicas:
        if r.t < horizon:
            r.advance(horizon)

    live_t = np.array([e[0] for e in live_events])
    live_n = np.array([float(e[1]) for e in live_events])
    intervals, host = _merge_fleet(replicas, live_t, live_n)
    bus = recorder if recorder is not None \
        else TraceRecorder(source=f"serve.fleet.{policy.name}")
    t_off = bus.t_last
    emit_step_intervals(bus, intervals, t_off=t_off,
                        components={"host": host},
                        aux={"n_live": live_n[np.clip(
                            np.searchsorted(live_t, np.array(
                                [0.5 * (iv[0] + iv[1])
                                 for iv in intervals]), side="right") - 1,
                            0, len(live_t) - 1)]})
    trace = bus.trace()
    span = intervals[-1][1]
    stats = compute_serve_stats(records, trace, t0=t_off, span=span,
                                slo_s=slo_s,
                                replica_failures=replica_failures)
    if policy.power_cap_w is not None \
            and stats.peak_power_w > policy.power_cap_w + 1e-6:
        raise AssertionError(
            f"policy {policy.name!r} exceeded its own power cap: "
            f"{stats.peak_power_w:.1f} W > {policy.power_cap_w:.1f} W")
    return FleetResult(policy, records, trace, stats, live_t, live_n,
                       t_off, span, busy_w_per_replica=probe.p_busy,
                       replica_failures=replica_failures, outages=outages)
