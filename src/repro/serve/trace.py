"""Recorded-request trace format + seeded synthetic traffic generators.

RAPS-style telemetry snapshots (ExaDigiT: ``raps/telemetry.py`` saves
job arrival/shape arrays as npz), applied to *serving*: a request trace
is three parallel arrays —

  * ``arrival_s``    absolute submit time [s]
  * ``prompt_len``   prompt tokens to prefill
  * ``gen_len``      tokens to decode

— saved/loaded as one ``.npz`` with a JSON ``meta`` sidecar key, so a
recorded production stream and a synthetic generator are
interchangeable inputs to the continuous-batching replay engine
(:mod:`repro.serve.engine`).

The generators are seeded and deterministic (the replay benchmarks gate
on exact numbers):

  * :func:`constant_trace` — fixed-rate (or all-at-t0 burst: the
    analytic-oracle case);
  * :func:`poisson_trace` — exponential inter-arrival gaps, the open
    queue model (mirrors :class:`repro.cluster.events.PoissonArrivals`);
  * :func:`diurnal_trace` — a *non-homogeneous* Poisson process whose
    rate follows a sinusoidal day curve (night trough → midday peak),
    drawn by thinning: the millions-of-users stand-in the autoscaling
    benchmark replays.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Sequence

import numpy as np

_KEYS = ("arrival_s", "prompt_len", "gen_len")


@dataclass
class RequestTrace:
    """One recorded (or synthesized) request stream, sorted by arrival."""

    arrival_s: np.ndarray
    prompt_len: np.ndarray
    gen_len: np.ndarray
    meta: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        a = np.asarray(self.arrival_s, dtype=float)
        p = np.asarray(self.prompt_len)
        g = np.asarray(self.gen_len)
        if not (a.ndim == p.ndim == g.ndim == 1):
            raise ValueError("trace arrays must be 1-D")
        if not (a.shape == p.shape == g.shape):
            raise ValueError(f"trace arrays must share a length, got "
                             f"{a.shape[0]}/{p.shape[0]}/{g.shape[0]}")
        if a.size and (not np.all(np.isfinite(a)) or np.any(a < 0.0)):
            raise ValueError("arrival times must be finite and >= 0")
        for name, arr in (("prompt_len", p), ("gen_len", g)):
            if arr.size and (np.any(arr != np.floor(arr)) or np.any(arr < 1)):
                raise ValueError(f"{name} must be positive integers")
        order = np.argsort(a, kind="stable")
        self.arrival_s = a[order]
        self.prompt_len = p[order].astype(np.int64)
        self.gen_len = g[order].astype(np.int64)

    def __len__(self) -> int:
        return int(self.arrival_s.shape[0])

    @property
    def n_requests(self) -> int:
        return len(self)

    @property
    def duration_s(self) -> float:
        """Arrival span (0 for an empty or single-burst trace)."""
        return float(self.arrival_s[-1] - self.arrival_s[0]) if len(self) \
            else 0.0

    @property
    def total_prompt_tokens(self) -> int:
        return int(self.prompt_len.sum())

    @property
    def total_gen_tokens(self) -> int:
        return int(self.gen_len.sum())

    # -- persistence (RAPS npz snapshot format) ------------------------------

    def save(self, path) -> None:
        np.savez(path, arrival_s=self.arrival_s,
                 prompt_len=self.prompt_len, gen_len=self.gen_len,
                 meta=np.array(json.dumps(self.meta)))

    @classmethod
    def load(cls, path) -> "RequestTrace":
        with np.load(path, allow_pickle=False) as z:
            missing = [k for k in _KEYS if k not in z.files]
            if missing:
                raise ValueError(f"malformed request trace {path!r}: "
                                 f"missing {missing} (has {z.files})")
            meta = {}
            if "meta" in z.files:
                try:
                    meta = json.loads(str(z["meta"]))
                except (json.JSONDecodeError, UnicodeDecodeError) as e:
                    raise ValueError(
                        f"malformed request trace {path!r}: bad meta "
                        f"({e})") from None
            return cls(z["arrival_s"], z["prompt_len"], z["gen_len"],
                       meta=meta)

    # -- sharding ------------------------------------------------------------

    def shard(self, n: int) -> List["RequestTrace"]:
        """Round-robin split into ``n`` shards: each keeps ~1/n of the
        rate with the same arrival-time envelope, so a shard is a
        placeable unit of a cluster-wide stream
        (:class:`repro.serve.replay.ReplayServeWorkload` per shard)."""
        if n < 1:
            raise ValueError("need at least one shard")
        return [RequestTrace(self.arrival_s[i::n], self.prompt_len[i::n],
                             self.gen_len[i::n],
                             meta={**self.meta, "shard": i, "of": n})
                for i in range(n)]


# ---------------------------------------------------------------------------
# Seeded generators
# ---------------------------------------------------------------------------


def _lengths(rng: np.random.Generator, n: int, prompt_lens: Sequence[int],
             gen_lens: Sequence[int]):
    p = rng.choice(np.asarray(prompt_lens, dtype=np.int64), size=n)
    g = rng.choice(np.asarray(gen_lens, dtype=np.int64), size=n)
    return p, g


def constant_trace(n: int, *, prompt_len: int = 64, gen_len: int = 32,
                   rate_per_s: float = 0.0, t0: float = 0.0) -> RequestTrace:
    """``n`` identical requests: all at ``t0`` when ``rate_per_s`` is 0
    (the closed-batch burst the analytic oracle replays), else evenly
    spaced at the given rate."""
    if rate_per_s > 0.0:
        arrival = t0 + np.arange(n) / rate_per_s
    else:
        arrival = np.full(n, float(t0))
    return RequestTrace(arrival, np.full(n, prompt_len),
                        np.full(n, gen_len),
                        meta={"generator": "constant",
                              "rate_per_s": rate_per_s})


def poisson_trace(n: int, rate_per_s: float, *,
                  prompt_lens: Sequence[int] = (64,),
                  gen_lens: Sequence[int] = (32,),
                  seed: int = 0, t0: float = 0.0) -> RequestTrace:
    """Open-queue stream: seeded exponential inter-arrival gaps at
    ``rate_per_s``, prompt/gen lengths drawn from the given discrete
    mixes (discrete buckets keep the engine's prefill-cost cache
    small)."""
    if rate_per_s <= 0.0:
        raise ValueError("rate_per_s must be positive")
    rng = np.random.default_rng(seed)
    arrival = t0 + np.cumsum(rng.exponential(1.0 / rate_per_s, size=n))
    p, g = _lengths(rng, n, prompt_lens, gen_lens)
    return RequestTrace(arrival, p, g,
                        meta={"generator": "poisson", "seed": seed,
                              "rate_per_s": rate_per_s})


def diurnal_trace(duration_s: float, *, rate_peak_per_s: float,
                  rate_floor_per_s: float = 0.0,
                  prompt_lens: Sequence[int] = (64,),
                  gen_lens: Sequence[int] = (32,),
                  seed: int = 0) -> RequestTrace:
    """One synthetic "day" of traffic: a non-homogeneous Poisson
    process whose rate follows a sinusoid — trough ``rate_floor_per_s``
    at t=0 and t=duration, peak ``rate_peak_per_s`` mid-day:

        rate(t) = floor + (peak − floor) · ½(1 − cos 2πt/duration)

    Drawn by thinning a homogeneous process at the peak rate (accept
    with probability rate(t)/peak), so it stays exactly Poisson and
    exactly seeded."""
    if duration_s <= 0.0:
        raise ValueError("duration_s must be positive")
    if rate_peak_per_s <= 0.0 or rate_floor_per_s < 0.0 \
            or rate_floor_per_s > rate_peak_per_s:
        raise ValueError("need 0 <= rate_floor_per_s <= rate_peak_per_s, "
                         "rate_peak_per_s > 0")
    rng = np.random.default_rng(seed)
    arrivals = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / rate_peak_per_s)
        if t >= duration_s:
            break
        rate = rate_floor_per_s + (rate_peak_per_s - rate_floor_per_s) \
            * 0.5 * (1.0 - np.cos(2.0 * np.pi * t / duration_s))
        if rng.uniform() < rate / rate_peak_per_s:
            arrivals.append(t)
    n = len(arrivals)
    p, g = _lengths(rng, n, prompt_lens, gen_lens)
    return RequestTrace(np.asarray(arrivals), p, g,
                        meta={"generator": "diurnal", "seed": seed,
                              "duration_s": duration_s,
                              "rate_peak_per_s": rate_peak_per_s,
                              "rate_floor_per_s": rate_floor_per_s})
