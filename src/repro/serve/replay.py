"""`ReplayServeWorkload`: a replay shard as a first-class cluster
workload.

Wraps one :class:`repro.serve.trace.RequestTrace` shard plus a
:class:`repro.serve.engine.ContinuousBatchingEngine` behind the PR-4
``Workload`` protocol, so the PR-6 online simulator can *place* it
(``job()`` — memory from the serve roofline, work units from the
shard's reference-point replay makespan), *fail and requeue* it like
any batch job, and optionally *execute* it at the placement's resolved
PR-7 operating point (``simulate(..., execute=True)``) to get
per-request latency/energy details.

``serve_replay`` is registered as a memory-bound kind
(``repro.cluster.scheduler.MEMORY_BOUND_KINDS``): decode is
bandwidth-bound, so a clock derate leaves the placement duration at
rate 1.0 — the paper's thesis, wired into the scheduler's rate model.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional

from repro.cluster.scheduler import Job
from repro.cluster.workload import (WorkloadResult, _result,
                                    register_workload)
from repro.power.model import OperatingPoint
from repro.power.trace import TraceRecorder
from repro.serve.engine import ContinuousBatchingEngine, ServeCostModel
from repro.serve.trace import RequestTrace, poisson_trace


@register_workload("serve_replay")
@dataclass
class ReplayServeWorkload:
    """One request-trace shard served by one continuously-batched chip.

    ``trace=None`` synthesizes a small seeded Poisson shard at half the
    replica's steady-state capacity (a usable default for scheduler
    tests and demos)."""

    name: str = "serve_replay"
    trace: Optional[RequestTrace] = None
    arch: str = "llama3-8b"
    max_batch: int = 8
    prompt_len: int = 64               # cost-model reference shape
    gen: int = 32
    smoke: bool = True
    kv_int8: bool = False
    kv_budget_tokens: Optional[int] = None
    slo_s: Optional[float] = None
    mode: str = "efficiency"
    seed: int = 0
    preferred_op: Optional[OperatingPoint] = None
    _cost_cache: Optional[ServeCostModel] = field(
        default=None, init=False, repr=False, compare=False)
    _ref_cache: Optional[Any] = field(
        default=None, init=False, repr=False, compare=False)

    def _cost(self) -> ServeCostModel:
        if self._cost_cache is None:
            self._cost_cache = ServeCostModel(
                self.arch, max_batch=self.max_batch,
                prompt_len=self.prompt_len, gen=self.gen,
                smoke=self.smoke, kv_int8=self.kv_int8)
        return self._cost_cache

    def engine(self) -> ContinuousBatchingEngine:
        return ContinuousBatchingEngine(
            self._cost(), kv_budget_tokens=self.kv_budget_tokens,
            mode=self.mode)

    def __post_init__(self):
        if self.trace is None:
            cost = self._cost()
            plan, _, _ = cost.plan(self.preferred_op, self.mode)
            t_pre, _ = cost.prefill_cost(self.prompt_len, self.max_batch)
            service_s = t_pre + self.gen * plan.step_time_s
            rate = 0.5 * self.max_batch / max(service_s, 1e-12)
            self.trace = poisson_trace(
                4 * self.max_batch, rate,
                prompt_lens=(self.prompt_len,), gen_lens=(self.gen,),
                seed=self.seed)

    def _reference(self):
        """The shard replayed once at its preferred point — its
        makespan calibrates ``Job.work_units`` (reference-chip
        seconds)."""
        if self._ref_cache is None:
            op = self.preferred_op or OperatingPoint.green500()
            self._ref_cache = self.engine().replay(self.trace, op=op,
                                                   slo_s=self.slo_s)
        return self._ref_cache

    def job(self) -> Job:
        pre, dec = self._cost().workload._costs()
        mem_gb = max((pre.hbm_bytes + dec.hbm_bytes) / 1e9, 0.1)
        return Job(self.name, mem_gb,
                   work_units=self._reference().span_s,
                   shardable=False, preferred_op=self.preferred_op,
                   kind=self.kind, state_bytes=self.state_bytes())

    def state_bytes(self) -> float:
        # serving is stateless: dropped requests are retried, not
        # restored — checkpointing never triggers for replay shards
        return 0.0

    def execute(self, op: OperatingPoint, *,
                recorder: Optional[TraceRecorder] = None) -> WorkloadResult:
        res = self.engine().replay(self.trace, op=op, recorder=recorder,
                                   slo_s=self.slo_s)
        st = res.stats
        perf = res.trace.total_flops(res.t_off, res.t_off + res.span_s) \
            / max(res.span_s, 1e-12)
        details = dict(requests=st.n_requests, completed=st.completed,
                       p50_latency_s=st.p50_latency_s,
                       p99_latency_s=st.p99_latency_s,
                       p99_ttft_s=st.p99_ttft_s,
                       j_per_request=st.j_per_request,
                       j_per_token=st.j_per_token,
                       j_per_gen_token=st.j_per_gen_token,
                       slo_compliance=st.slo_compliance,
                       freq_scale=res.plan.freq_scale)
        return _result(self, op, res.trace, perf, res.span_s,
                       window=(res.t_off, res.t_off + res.span_s),
                       **details)


def replay_shards(trace: RequestTrace, n_shards: int,
                  **kwargs) -> List[ReplayServeWorkload]:
    """Split a cluster-wide request stream round-robin into ``n_shards``
    placeable workloads (each keeps ~1/n of the rate)."""
    return [ReplayServeWorkload(name=f"serve_replay/{i}", trace=shard,
                                **kwargs)
            for i, shard in enumerate(trace.shard(n_shards))]
