"""Continuous-batching replay engine over analytic per-step serve costs.

The model (vLLM-style continuous batching, reduced to what the paper's
energy question needs):

  * one replica = one chip running the serve model; it holds an
    **in-flight decode batch** of at most ``max_batch`` requests plus a
    FCFS admission queue;
  * admission happens at step boundaries: queued requests join while a
    batch slot and KV-cache budget (``kv_budget_tokens``, reserved as
    ``prompt+gen`` per request, vLLM-reservation style) are free;
  * an admitted group is **prefilled as a batch** (same-prompt-length
    runs grouped); prefill interrupts decode for the whole replica — no
    chunked prefill;
  * decode advances the whole in-flight batch one token per step; steps
    are atomic, and the engine walks step *chunks* cut at the next
    completion or external boundary, so the loop is event-scale, not
    token-scale.

All times and watts come from ``ServeWorkload.energy_plan()``'s
analytic roofline costs (:class:`ServeCostModel`), so a replay is fast,
deterministic and machine-independent: decode steps take the DVFS
plan's ``step_time_s`` and burn ``power_w``; prefill takes the
prefill-shape roofline time; an idle live replica draws the chip idle
floor.  Because decode is memory-bound, a deep clock derate barely
moves ``step_time_s`` but cuts watts — the paper's C5 thesis, measured
here per request.

Telemetry goes onto the PR-3 :class:`TraceRecorder` bus as *doubled
boundary samples* (piecewise-constant, trapezoid-exact), with the
in-flight count as a ``batch`` aux series — per-request latency and
joules-per-token then fall out of the trace
(:func:`repro.serve.stats.request_energy_j`).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.power.model import OperatingPoint, tpu_chip_power
from repro.power.trace import PowerTrace, TraceRecorder
from repro.serve.stats import ServeStats, compute_serve_stats
from repro.serve.trace import RequestTrace

_EPS = 1e-12


class ServeCostModel:
    """Analytic per-step costs for one serve shape, shared by every
    replica: the decode DVFS plan (per operating point) and a prefill
    roofline cache keyed by (prompt_len, group_size).

    Built around :class:`repro.cluster.workload.ServeWorkload` so the
    replay engine, the ``launch.serve`` driver and the cluster
    scheduler price a step identically — the constant-rate oracle in
    ``benchmarks/paper_tables.py::serve_replay`` pins that equality."""

    def __init__(self, arch: str = "llama3-8b", *, max_batch: int = 8,
                 prompt_len: int = 64, gen: int = 32, smoke: bool = True,
                 kv_int8: bool = False):
        from repro.cluster.workload import ServeWorkload
        self.workload = ServeWorkload(arch=arch, batch=max_batch,
                                      prompt_len=prompt_len, gen=gen,
                                      smoke=smoke, kv_int8=kv_int8)
        self.arch = arch
        self.max_batch = max_batch
        self.prompt_len = prompt_len
        self.gen = gen
        self.smoke = smoke
        self.kv_int8 = kv_int8
        self._plans: Dict[Tuple[str, Optional[OperatingPoint]], tuple] = {}
        self._prefill: Dict[Tuple[int, int], Tuple[float, float]] = {}

    def plan(self, op: Optional[OperatingPoint] = None,
             mode: str = "efficiency"):
        """(FreqPlan, prefill cost, decode cost) at ``op`` — cached."""
        key = (mode, op)
        if key not in self._plans:
            self._plans[key] = self.workload.energy_plan(mode, op)
        return self._plans[key]

    def prefill_cost(self, prompt_len: int, group: int) \
            -> Tuple[float, float]:
        """(seconds, flops) to prefill a group of ``group`` prompts of
        ``prompt_len`` tokens — the roofline time is clock-independent
        here, exactly as ``ServeWorkload.execute`` bills it."""
        key = (int(prompt_len), int(group))
        hit = self._prefill.get(key)
        if hit is None:
            from repro.config import ShapeConfig, SINGLE_POD_MESH, get_arch
            from repro.roofline.analytic import cost_for
            entry = get_arch(self.arch)
            cfg = entry.smoke() if self.smoke else entry.full()
            pre = cost_for(cfg, ShapeConfig("serve_prefill", int(prompt_len),
                                            int(group), "prefill"),
                           SINGLE_POD_MESH, kv_int8=self.kv_int8)
            t = max(pre.compute_s, pre.memory_s) + pre.collective_s
            hit = self._prefill[key] = (t, pre.flops)
        return hit


@dataclass
class RequestRecord:
    """One request's lifecycle timestamps (engine-relative seconds)."""

    idx: int
    arrival_s: float
    prompt_len: int
    gen_len: int
    admit_s: Optional[float] = None        # prefill start (ends queueing)
    first_token_s: Optional[float] = None  # prefill end
    done_s: Optional[float] = None         # last decode step
    replica: int = 0
    tokens: Optional[np.ndarray] = None    # real tokens (executed runtime)
    retries: int = 0                       # replica-failure resubmissions
    gave_up: bool = False                  # retry budget exhausted (dropped)

    @property
    def wait_s(self) -> Optional[float]:
        return None if self.admit_s is None else self.admit_s - self.arrival_s

    @property
    def ttft_s(self) -> Optional[float]:
        return None if self.first_token_s is None \
            else self.first_token_s - self.arrival_s

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.done_s is None else self.done_s - self.arrival_s


class Replica:
    """One chip's continuous-batching state machine, advanced between
    external boundaries (arrivals, controller ticks).  Used directly by
    :class:`ContinuousBatchingEngine` (one replica) and by the
    autoscaling fleet (:mod:`repro.serve.autoscale`, N replicas).

    ``live=False`` replicas draw 0 W (powered off); live-but-idle
    replicas draw the chip idle floor."""

    def __init__(self, cost: ServeCostModel, *,
                 op: Optional[OperatingPoint] = None,
                 mode: str = "efficiency",
                 max_batch: Optional[int] = None,
                 kv_budget_tokens: Optional[int] = None,
                 runtime: Optional[Any] = None,
                 rid: int = 0, live: bool = True):
        plan, _pre, dec = cost.plan(op, mode)
        self.cost = cost
        self.plan = plan
        self.t_step = plan.step_time_s
        self.p_busy = plan.power_w
        self.p_idle = tpu_chip_power(plan.freq_scale, 0.0, 0.0)
        self.seq_flops = dec.flops / cost.max_batch   # per sequence per step
        self.max_batch = cost.max_batch if max_batch is None else max_batch
        self.kv_budget_tokens = kv_budget_tokens
        self.runtime = runtime
        self.rid = rid
        self.live = live
        self.t = 0.0
        self.queue: List[RequestRecord] = []
        self.inflight: List[List] = []     # [record, tokens_remaining]
        self.kv_used = 0
        # (t_start, t_end, watts, gflops, batch) — contiguous coverage
        self.intervals: List[Tuple[float, float, float, float, int]] = []

    # -- load signals (the autoscaler's observables) -------------------------

    def load(self) -> int:
        return len(self.queue) + len(self.inflight)

    def util(self) -> float:
        return len(self.inflight) / self.max_batch

    # -- submission ----------------------------------------------------------

    def submit(self, rec: RequestRecord) -> None:
        need = rec.prompt_len + rec.gen_len
        if self.kv_budget_tokens is not None and need > self.kv_budget_tokens:
            raise ValueError(
                f"request {rec.idx} needs {need} KV tokens > budget "
                f"{self.kv_budget_tokens} — it could never be admitted")
        rec.replica = self.rid
        self.queue.append(rec)

    # -- internals -----------------------------------------------------------

    def _emit(self, t_end: float, watts: float, gflops: float,
              batch: int) -> None:
        if t_end > self.t + _EPS:
            self.intervals.append((self.t, t_end, watts, gflops, batch))
            self.t = t_end

    def _admit(self) -> List[RequestRecord]:
        admitted: List[RequestRecord] = []
        while self.queue and len(self.inflight) + len(admitted) \
                < self.max_batch:
            rec = self.queue[0]
            need = rec.prompt_len + rec.gen_len
            if self.kv_budget_tokens is not None \
                    and self.kv_used + need > self.kv_budget_tokens:
                break                      # FCFS: no skipping the head
            self.kv_used += need
            admitted.append(self.queue.pop(0))
        return admitted

    def _prefill(self, admitted: List[RequestRecord]) -> None:
        # batch same-prompt-length runs into one prefill each
        i = 0
        while i < len(admitted):
            s = admitted[i].prompt_len
            j = i
            while j < len(admitted) and admitted[j].prompt_len == s:
                j += 1
            group = admitted[i:j]
            t_pre, flops = self.cost.prefill_cost(s, len(group))
            start = self.t
            batch = len(self.inflight) + len(group)
            self._emit(start + t_pre, self.p_busy,
                       flops / max(t_pre, _EPS) / 1e9, batch)
            if self.runtime is not None:
                gen_max = max(r.gen_len for r in group)
                toks = self.runtime.run_group(s, gen_max, len(group))
                for r, row in zip(group, toks):
                    r.tokens = np.asarray(row[:r.gen_len])
            for r in group:
                r.admit_s = start
                r.first_token_s = self.t
                self.inflight.append([r, r.gen_len])
            i = j

    def _decode_chunk(self, t_end: float) -> None:
        rem_min = min(entry[1] for entry in self.inflight)
        k = rem_min
        if t_end != math.inf:
            # cut at the boundary so admissions/control happen on time;
            # steps stay atomic (ceil, at least one)
            k = min(k, max(1, math.ceil((t_end - self.t) / self.t_step
                                        - _EPS)))
        batch = len(self.inflight)
        self._emit(self.t + k * self.t_step, self.p_busy,
                   batch * self.seq_flops / max(self.t_step, _EPS) / 1e9,
                   batch)
        keep: List[List] = []
        for entry in self.inflight:
            entry[1] -= k
            if entry[1] <= 0:
                entry[0].done_s = self.t
                self.kv_used -= entry[0].prompt_len + entry[0].gen_len
            else:
                keep.append(entry)
        self.inflight = keep

    # -- the clock -----------------------------------------------------------

    def advance(self, t_end: float) -> None:
        """Process work until the replica's clock reaches ``t_end``
        (the last busy chunk may overshoot — steps are atomic).  With
        ``t_end=inf``, drain everything submitted and stop."""
        while self.t < t_end - _EPS:
            admitted = self._admit()
            if admitted:
                self._prefill(admitted)
            elif self.inflight:
                self._decode_chunk(t_end)
            elif t_end == math.inf:
                break
            else:
                self._emit(t_end, self.p_idle if self.live else 0.0,
                           0.0, 0)

    def drain(self) -> None:
        self.advance(math.inf)

    # -- failure injection ---------------------------------------------------

    def fail(self) -> List[RequestRecord]:
        """Kill the replica at its current clock: power off and spill
        every queued + in-flight request for the caller to retry
        elsewhere (:mod:`repro.serve.autoscale`).  Generation has no
        durable state, so a spilled request restarts from its prompt —
        admit/first-token stamps are cleared and re-set on the retry
        prefill (the power its dead work burned stays on the trace)."""
        lost = [e[0] for e in self.inflight] + list(self.queue)
        for e in self.inflight:
            e[0].admit_s = None
            e[0].first_token_s = None
        self.inflight = []
        self.queue = []
        self.kv_used = 0
        self.live = False
        return lost


def emit_step_intervals(recorder: TraceRecorder, intervals, *,
                        t_off: float = 0.0,
                        component: str = "chip",
                        components: Optional[Dict[str, np.ndarray]] = None,
                        aux: Optional[Dict[str, np.ndarray]] = None) -> None:
    """Emit contiguous ``(start, end, watts, gflops, batch)`` intervals
    as doubled boundary samples: the series is piecewise-constant and
    the trapezoid integral over any span of whole intervals is exact
    (``emit_intervals``'s dt-grid resampling would smear boundaries).
    ``components`` adds per-interval power series (e.g. host watts);
    ``aux`` adds per-interval aux series (e.g. freq_scale)."""
    if not intervals:
        raise ValueError("no intervals to emit")
    n = len(intervals)
    starts = np.array([iv[0] for iv in intervals]) + t_off
    ends = np.array([iv[1] for iv in intervals]) + t_off
    watts = np.array([iv[2] for iv in intervals])
    gflops = np.array([iv[3] for iv in intervals])
    batch = np.array([float(iv[4]) for iv in intervals])
    if np.any(np.abs(starts[1:] - ends[:-1]) > 1e-9):
        raise ValueError("intervals must be contiguous")
    idx = np.repeat(np.arange(n), 2)
    ts = np.stack([starts, ends], axis=1).reshape(-1)
    comps = {component: watts[idx]}
    if components:
        comps.update({k: np.asarray(v, dtype=float)[idx]
                      for k, v in components.items()})
    extra_aux = {k: np.asarray(v, dtype=float)[idx]
                 for k, v in (aux or {}).items()}
    recorder.emit_series(ts, comps, flops_rate=gflops[idx],
                         batch=batch[idx], **extra_aux)


@dataclass
class ServeResult:
    """One replay: per-request records, the emitted trace, aggregate
    stats, and where on the (possibly shared) bus this replay lives
    (``t_off`` .. ``t_off + span_s``)."""

    records: List[RequestRecord]
    trace: PowerTrace
    stats: ServeStats
    t_off: float
    span_s: float
    plan: Any = field(repr=False, default=None)

    @property
    def energy_j(self) -> float:
        return self.stats.energy_j

    def request_energy_j(self, idx: int) -> float:
        """Request ``idx``'s joules, integrated from the bus over its
        in-flight window at a 1/batch share."""
        from repro.serve.stats import request_energy_j
        r = self.records[idx]
        if r.admit_s is None or r.done_s is None:
            return 0.0
        return request_energy_j(self.trace, self.t_off + r.admit_s,
                                self.t_off + r.done_s)


class ContinuousBatchingEngine:
    """Single-replica replay: feed a :class:`RequestTrace` through one
    continuously-batched chip at an operating point, emitting onto
    ``recorder`` (or a private bus)."""

    def __init__(self, cost: ServeCostModel, *,
                 max_batch: Optional[int] = None,
                 kv_budget_tokens: Optional[int] = None,
                 mode: str = "efficiency",
                 runtime: Optional[Any] = None):
        self.cost = cost
        self.max_batch = max_batch
        self.kv_budget_tokens = kv_budget_tokens
        self.mode = mode
        self.runtime = runtime

    def replay(self, trace: RequestTrace, *,
               op: Optional[OperatingPoint] = None,
               recorder: Optional[TraceRecorder] = None,
               slo_s: Optional[float] = None) -> ServeResult:
        if not len(trace):
            raise ValueError("empty request trace: nothing to replay")
        rep = Replica(self.cost, op=op, mode=self.mode,
                      max_batch=self.max_batch,
                      kv_budget_tokens=self.kv_budget_tokens,
                      runtime=self.runtime)
        records = [RequestRecord(i, float(trace.arrival_s[i]),
                                 int(trace.prompt_len[i]),
                                 int(trace.gen_len[i]))
                   for i in range(len(trace))]
        for rec in records:
            rep.advance(rec.arrival_s)
            rep.submit(rec)
        rep.drain()

        bus = recorder if recorder is not None \
            else TraceRecorder(source="serve.replay")
        t_off = bus.t_last
        emit_step_intervals(bus, rep.intervals, t_off=t_off,
                            aux={"freq_scale": np.full(
                                len(rep.intervals), rep.plan.freq_scale)})
        out = bus.trace()
        span = rep.intervals[-1][1]
        stats = compute_serve_stats(records, out, t0=t_off, span=span,
                                    slo_s=slo_s)
        return ServeResult(records, out, stats, t_off, span, plan=rep.plan)
