"""Executed-group runtime: real jitted prefill/decode behind the
replay engine's admission path.

The analytic engine prices time and power; this hook makes the *model*
real: when attached (``ContinuousBatchingEngine(runtime=...)``), every
admitted prefill group runs the actual jitted prefill, grows the KV
cache to the full generation length via
:func:`repro.runtime.steps.grow_decode_cache` (the same helper the
``launch.serve`` driver uses — the satellite extraction, reused here),
and greedy-decodes the group, storing each request's generated tokens
on its :class:`~repro.serve.engine.RequestRecord`.  Timing and energy
stay analytic (deterministic, machine-independent); only the token
content is executed.

Smoke-scale, token-only model families (prompts are synthesized
uniformly at random per group, seeded).
"""
from __future__ import annotations

from typing import Optional

import numpy as np


class ExecutedGroupRuntime:
    """Real prefill + cache-grow + decode for one admitted group."""

    def __init__(self, arch: str = "llama3-8b", *, smoke: bool = True,
                 kv_int8: bool = False, seed: int = 0,
                 params: Optional[dict] = None):
        import jax
        from repro.config import get_arch
        from repro.models import init_params
        from repro.runtime.steps import make_decode_step, make_prefill_step
        entry = get_arch(arch)
        self.cfg = entry.smoke() if smoke else entry.full()
        if self.cfg.family in ("vlm", "encdec"):
            raise ValueError(
                f"ExecutedGroupRuntime supports token-only families; "
                f"{arch!r} is {self.cfg.family!r}")
        self.kv_int8 = kv_int8
        self.params = params if params is not None \
            else init_params(self.cfg, jax.random.PRNGKey(seed))
        self._prefill = jax.jit(make_prefill_step(
            self.cfg, quantize_kv_cache=kv_int8))
        self._decode = jax.jit(make_decode_step(self.cfg))
        self._rng = np.random.default_rng(seed)

    def run_group(self, prompt_len: int, gen_len: int,
                  n: int) -> np.ndarray:
        """Prefill ``n`` random prompts of ``prompt_len`` tokens, grow
        the cache to ``prompt_len + gen_len``, greedy-decode
        ``gen_len`` tokens.  Returns an ``(n, gen_len)`` int array."""
        import jax
        import jax.numpy as jnp
        from repro.runtime.steps import grow_decode_cache
        cfg = self.cfg
        batch = {"tokens": jnp.asarray(
            self._rng.integers(0, cfg.vocab_size, (n, prompt_len)),
            jnp.int32)}
        logits, cache = self._prefill(self.params, batch)
        cache = grow_decode_cache(cfg, cache, n, prompt_len + gen_len,
                                  quantize_kv_cache=self.kv_int8)
        out = []
        tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)[:, None]
        for _ in range(gen_len):
            out.append(np.asarray(tok))
            logits, cache = self._decode(self.params,
                                         tok.astype(jnp.int32), cache)
            tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)[:, None]
        jax.block_until_ready(logits)
        return np.concatenate(out, axis=1)
