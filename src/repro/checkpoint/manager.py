"""Checkpoint/restart with async writes and elastic restore.

Design for 1000+ nodes, scaled down honestly for this container:
  * every leaf is written as its own ``.npy`` under a step directory with a
    JSON manifest (tree paths, shapes, dtypes, step) — content-addressed
    enough to verify integrity on restore;
  * writes happen on a background thread (training never blocks on disk);
  * ``restore`` reshards onto ANY mesh: leaves are loaded host-side and
    ``jax.device_put`` against the new NamedSharding — this is what makes
    elastic scaling (Nx pods -> (N-1)x pods) possible after a pod loss;
  * ``keep`` bounds disk usage; a half-written step directory is detected
    via the manifest-last protocol and ignored on restore (crash safety);
  * a checkpoint that *looks* complete but is corrupt (truncated leaf
    file, shape mismatch against its own manifest, unreadable JSON)
    raises :class:`CheckpointError` from ``restore`` —
    ``restore_latest`` instead walks back to the newest retained step
    that loads cleanly (with a warning), so a mid-restart disk hiccup
    costs one checkpoint interval, not the run;
  * background-write failures (disk full, permissions) are captured and
    re-raised from the next ``wait()``/``save()`` instead of dying
    silently on the writer thread.
"""
from __future__ import annotations

import json
import shutil
import threading
import warnings
from pathlib import Path
from typing import Any, List, Optional, Tuple

import jax
import numpy as np


class CheckpointError(RuntimeError):
    """A checkpoint directory is unreadable or fails validation."""


def _flatten(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._write_error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        """Snapshot to host then write asynchronously.

        Non-numpy-native dtypes (bfloat16) are stored widened to fp32; the
        manifest keeps the original dtype and restore() casts back."""
        host = []
        for name, leaf in _flatten(tree):
            arr = np.asarray(leaf)
            orig = str(arr.dtype)
            if arr.dtype.kind == "V":      # ml_dtypes (bfloat16, fp8, ...)
                arr = arr.astype(np.float32)
            host.append((name, arr, orig))
        self.wait()

        def write():
            try:
                d = self.dir / f"step_{step:08d}"
                tmp = self.dir / f".tmp_step_{step:08d}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir()
                manifest = {"step": step, "leaves": {}}
                for i, (name, arr, orig) in enumerate(host):
                    fn = f"leaf_{i:05d}.npy"
                    np.save(tmp / fn, arr)
                    manifest["leaves"][name] = {
                        "file": fn, "shape": list(arr.shape), "dtype": orig}
                # manifest last: its presence marks the checkpoint complete
                (tmp / "manifest.json").write_text(json.dumps(manifest))
                if d.exists():
                    shutil.rmtree(d)
                tmp.rename(d)
                self._gc()
            except BaseException as e:     # surfaced by the next wait()
                self._write_error = e

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        """Join the in-flight write; re-raise any failure it hit (an
        async ``save`` must not be lost in the thread)."""
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
        if self._write_error is not None:
            err, self._write_error = self._write_error, None
            raise CheckpointError(
                f"background checkpoint write failed: {err}") from err

    def _gc(self) -> None:
        steps = sorted(self.dir.glob("step_*"))
        for old in steps[:-self.keep]:
            shutil.rmtree(old, ignore_errors=True)

    # -- restore -------------------------------------------------------------
    def steps(self) -> List[int]:
        """All retained manifest-complete steps, oldest first."""
        out = []
        for d in self.dir.glob("step_*"):
            if (d / "manifest.json").exists():     # complete checkpoints only
                out.append(int(d.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Load into the structure of ``like``; optionally reshard onto a
        (possibly different) mesh via ``shardings`` (same pytree shape).

        Raises :class:`CheckpointError` when the step directory is
        corrupt: unreadable manifest, a missing leaf, a truncated
        ``.npy``, or a leaf whose shape disagrees with the manifest."""
        d = self.dir / f"step_{step:08d}"
        try:
            manifest = json.loads((d / "manifest.json").read_text())
        except (OSError, ValueError) as e:
            raise CheckpointError(
                f"step {step}: unreadable manifest in {d}: {e}") from e
        flat_names = [name for name, _ in _flatten(like)]
        leaves = []
        for name in flat_names:
            try:
                meta = manifest["leaves"][name]
                arr = np.load(d / meta["file"])
            except KeyError as e:
                raise CheckpointError(
                    f"step {step}: leaf {name!r} missing from manifest"
                    ) from e
            except (OSError, ValueError, EOFError) as e:
                raise CheckpointError(
                    f"step {step}: leaf {name!r} unreadable "
                    f"(truncated/corrupt file): {e}") from e
            if list(arr.shape) != list(meta.get("shape", arr.shape)):
                raise CheckpointError(
                    f"step {step}: leaf {name!r} shape {list(arr.shape)} != "
                    f"manifest {meta['shape']} (truncated write?)")
            leaves.append(arr)
        tdef = jax.tree_util.tree_structure(like)
        tree = jax.tree_util.tree_unflatten(tdef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, l, s: jax.device_put(
                    jax.numpy.asarray(a).astype(l.dtype), s),
                tree, like, shardings)
        else:
            tree = jax.tree.map(
                lambda a, l: jax.numpy.asarray(a).astype(l.dtype),
                tree, like)
        return tree

    def restore_latest(self, like: Any, shardings: Any = None,
                       ) -> Tuple[Optional[int], Any]:
        """``(step, tree)`` from the newest retained checkpoint that
        loads cleanly.  A corrupt latest step (truncated mid-crash) is
        skipped with a warning and the previous retained step is tried —
        a restart loses one checkpoint interval instead of raising
        mid-restore.  ``(None, None)`` when nothing restorable exists."""
        for step in reversed(self.steps()):
            try:
                return step, self.restore(step, like, shardings)
            except CheckpointError as e:
                warnings.warn(
                    f"checkpoint step {step} is corrupt, falling back to "
                    f"the previous retained step: {e}",
                    RuntimeWarning, stacklevel=2)
        return None, None
