"""Version bridges for jax/numpy APIs that moved between releases.

``jax.shard_map`` only exists as a top-level API in newer jax; older
releases (e.g. the 0.4.x line in CI images) ship it as
``jax.experimental.shard_map.shard_map`` with ``check_rep`` instead of
``check_vma`` and ``auto`` (the complement) instead of ``axis_names``.
All repo code goes through this wrapper so the multi-device paths run
on either line.

``trapezoid`` bridges numpy's rename: ``np.trapezoid`` is numpy>=2.0
only, ``np.trapz`` is deprecated there but the only spelling on the
1.x line.  The supported numpy range is declared in pyproject.toml.
"""
from __future__ import annotations

from typing import Optional, Set

import jax
import numpy as np

_np_trapezoid = getattr(np, "trapezoid", None) or np.trapz


def trapezoid(y, x=None, dx: float = 1.0, axis: int = -1):
    """Trapezoidal integration on either numpy line (1.22+ and 2.x)."""
    return _np_trapezoid(y, x=x, dx=dx, axis=axis)


def axis_size(axis_name: str) -> int:
    """Concrete size of a mapped axis inside a ``shard_map`` body.

    ``jax.lax.axis_size`` is new-API; on the 0.4.x line the axis
    environment tracks sizes as plain ints (``jax.core.axis_frame``)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    frame = jax.core.axis_frame(axis_name)
    return int(frame) if isinstance(frame, int) else int(frame.size)


def shard_map(f, *, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None,
              axis_names: Optional[Set[str]] = None):
    """Top-level ``jax.shard_map`` when available, else the experimental
    one with the old keyword spellings."""
    if hasattr(jax, "shard_map"):
        kw = {}
        if check_vma is not None:
            kw["check_vma"] = check_vma
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {}
    if check_vma is not None:
        kw["check_rep"] = check_vma
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)
