"""The cluster driver: ``run(jobs, policy) → ClusterRunResult``.

Composition, not new physics: the scheduler places the batch on the
topology, each tick asks the PR-3 power layers for per-node component
watts given which chips are busy, and everything lands on one
:class:`TraceRecorder` — so the merged cluster-level
:class:`repro.power.PowerTrace` feeds the Green500 L1/L2/L3 methodology
and the paper-table benchmarks exactly like a single-workload trace
does.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.cluster.scheduler import (ClusterTopology, Job, Schedule,
                                     Scheduler)
from repro.cluster.workload import Workload, WorkloadResult
from repro.power.model import OperatingPoint
from repro.power.trace import PowerTrace, TraceRecorder


@dataclass
class ClusterRunResult:
    """One scheduled batch: placements, per-workload results, and the
    merged cluster-level power trace."""

    schedule: Schedule
    trace: PowerTrace
    results: List[WorkloadResult] = field(default_factory=list)

    @property
    def op(self) -> OperatingPoint:
        return self.schedule.op

    @property
    def makespan(self) -> float:
        return self.schedule.makespan

    def efficiency(self, level: int = 3):
        """Green500 measurement of the merged trace."""
        from repro.power.green500 import measure_efficiency
        return measure_efficiency(self.trace, level)


def _merged_trace(schedule: Schedule, *, dt_s: float,
                  network_w: float) -> PowerTrace:
    """Tick the schedule through the layered node model: busy chips draw
    dynamic power and produce FLOPS at their placement's effective rate,
    idle chips draw static power, and hosts/fans/PSU losses are charged
    whether or not a node is busy (the cluster is powered on)."""
    from repro.power.engine import node_hpl_gflops
    from repro.power.layers import NodeModel

    top = schedule.topology
    op = schedule.op
    node = NodeModel()
    g = top.gpus_per_node
    # per-chip watts at this op, busy vs idle (load scales GPU duty)
    gpu = node.gpus[0]
    w_busy = gpu.power(op, load=1.0)
    w_idle = gpu.power(op, load=0.0)
    chip_peak_gflops = node_hpl_gflops(op, node) / g

    # a zero-work batch still gets a one-interval idle trace; a short
    # batch ends at its makespan, never padded out to dt_s
    span = schedule.makespan or dt_s
    rec = TraceRecorder(source="cluster.run")
    # grid over [0, makespan], ending exactly at the makespan (the final
    # sample reports the busy state just before it — the left limit — so
    # the trapezoid energy covers the full last interval and nothing
    # after the batch is billed)
    ts = np.arange(0.0, span, dt_s)
    if not ts.size or ts[-1] < span:
        ts = np.append(ts, span)
    for t in ts:
        active = schedule.active_chips(min(t, span - 1e-9))
        watts: Dict[str, float] = {"gpu": 0.0, "host": 0.0, "fan": 0.0,
                                   "psu_loss": 0.0, "network": network_w}
        flops = 0.0
        busy = 0
        for n in range(top.n_nodes):
            overrides = []
            for c in range(n * g, (n + 1) * g):
                p = active.get(c)
                overrides.append(w_busy if p is not None else w_idle)
                if p is not None:
                    flops += chip_peak_gflops * p.rate_per_chip
                    busy += 1
            for name, w in node.component_watts(
                    op, gpu_w_override=overrides).items():
                watts[name] += w
        rec.emit(t, watts, flops_rate=flops,
                 util=busy / top.n_chips, f_mhz=op.f_mhz, fan=op.fan)
    trace = rec.trace()
    trace.meta.update(
        n_nodes=top.n_nodes, policy=schedule.meta.get("policy", ""),
        operating_point={"f_mhz": op.f_mhz, "vid": op.vid, "fan": op.fan,
                         "nb": op.nb, "lookahead": op.lookahead})
    return trace


def run(workloads: Sequence[Union[Workload, Job]], *,
        policy: str = "packed",
        topology: Optional[ClusterTopology] = None,
        op: Optional[OperatingPoint] = None,
        power_cap_w: Optional[float] = None,
        network_w: Optional[float] = None,
        dt_s: float = 5.0,
        execute: bool = True) -> ClusterRunResult:
    """Schedule a mixed batch and merge its telemetry.

    ``workloads`` may mix :class:`Workload` adapters (their ``job()``
    spec is placed; with ``execute=True`` their real code path also runs
    and contributes a :class:`WorkloadResult`) and bare :class:`Job`
    specs (placed and power-modeled only — the cluster-scale path).

    ``op`` defaults to the first job's ``preferred_op`` (falling back to
    the Green500 point); a ``power_cap_w`` may derate it down the DPM
    ladder.  The merged cluster trace carries component watts for every
    node — busy or idle — plus the separately-metered switches.
    """
    if not workloads:
        raise ValueError("empty workload batch: nothing to run "
                         "(Scheduler.schedule accepts an empty job list "
                         "if you only need a placement)")
    jobs: List[Job] = []
    adapters: List[Workload] = []
    for w in workloads:
        if isinstance(w, Job):
            jobs.append(w)
        else:
            jobs.append(w.job())
            adapters.append(w)
    if op is None:
        op = next((j.preferred_op for j in jobs
                   if j.preferred_op is not None), None)

    sched = Scheduler(topology, policy=policy, power_cap_w=power_cap_w)
    schedule = sched.schedule(jobs, op=op)
    schedule.meta["policy"] = policy

    if network_w is None:
        network_w = schedule.topology.network_w

    trace = _merged_trace(schedule, dt_s=dt_s, network_w=float(network_w))

    results: List[WorkloadResult] = []
    if execute:
        for wl in adapters:
            results.append(wl.execute(schedule.op))
    return ClusterRunResult(schedule, trace, results)
