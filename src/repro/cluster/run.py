"""The cluster driver: ``run(jobs, policy) → ClusterRunResult``.

Composition, not new physics: the scheduler places the batch on the
topology, the power layers report per-node component watts given which
chips are busy, and everything lands on one :class:`TraceRecorder` — so
the merged cluster-level :class:`repro.power.PowerTrace` feeds the
Green500 L1/L2/L3 methodology and the paper-table benchmarks exactly
like a single-workload trace does.

The hot path is *interval-driven and vectorized* (ExaDigiT/RAPS style):
placement start/end events decompose the schedule into piecewise-
constant occupancy intervals, each interval is evaluated once through
the batched layer API, and the result is broadcast onto the ``dt_s``
sample grid — no per-tick × per-node × per-chip Python loops, which is
what makes the full 160-node / 640-GPU L-CSC topology with 1000+ jobs
tractable.  The original per-tick loop survives as
:func:`_merged_trace_reference`, the equivalence-test oracle.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.cluster.scheduler import (ClusterTopology, Job, Schedule,
                                     Scheduler)
from repro.cluster.workload import Workload, WorkloadResult
from repro.power.model import OperatingPoint
from repro.power.trace import PowerTrace, TraceRecorder


@dataclass
class ClusterRunResult:
    """One scheduled batch: placements, per-workload results, and the
    merged cluster-level power trace."""

    schedule: Schedule
    trace: PowerTrace
    results: List[WorkloadResult] = field(default_factory=list)

    @property
    def op(self) -> OperatingPoint:
        return self.schedule.op

    @property
    def makespan(self) -> float:
        return self.schedule.makespan

    def efficiency(self, level: int = 3):
        """Green500 measurement of the merged trace."""
        from repro.power.green500 import measure_efficiency
        return measure_efficiency(self.trace, level)


def _op_table(schedule: Schedule):
    """Per-chip power/rate scaffolding shared by the vectorized engine
    and the loop oracle: ``(node, w_busy, w_idle, chip_peak_gflops)``
    where ``w_busy`` maps every distinct placement operating point (plus
    the schedule reference) to its busy-chip watts — the per-bin lookup
    table the heterogeneous trace indexes into.

    ``chip_peak_gflops`` is anchored at the *fixed* Green500 reference
    point, not ``schedule.op``: ``Placement.rate_per_chip`` already
    carries each job's clock-for-perf scaling (``op_rate_scale``), so a
    compute-bound placement at 900 MHz produces exactly the engine's
    900 MHz peak, while the delivered FLOPS of a memory-bound job is
    invariant to the clock it happens to run at — the paper's thesis."""
    from repro.power.engine import node_hpl_gflops
    from repro.power.layers import NodeModel

    node = NodeModel()
    gpu = node.gpus[0]
    ref = schedule.op
    ops = {ref} | {p.op for p in schedule.placements if p.op is not None}
    w_busy = {o: gpu.power(o, load=1.0) for o in ops}
    return (node, w_busy, gpu.power(ref, load=0.0),
            node_hpl_gflops(OperatingPoint.green500(), node)
            / schedule.topology.gpus_per_node)


def _sample_grid(span: float, dt_s: float) -> np.ndarray:
    """Grid over [0, span], ending exactly at the span (the final sample
    reports the busy state just before it — the left limit — so the
    trapezoid energy covers the full last interval and nothing after the
    batch is billed)."""
    ts = np.arange(0.0, span, dt_s)
    if not ts.size or ts[-1] < span:
        ts = np.append(ts, span)
    return ts


def _stamp_cluster_meta(trace: PowerTrace, schedule: Schedule) -> None:
    op = schedule.op
    clocks = sorted({(p.op or op).f_mhz for p in schedule.placements}
                    | {op.f_mhz})
    trace.meta.update(
        n_nodes=schedule.topology.n_nodes,
        policy=schedule.meta.get("policy", ""),
        operating_point={"f_mhz": op.f_mhz, "vid": op.vid, "fan": op.fan,
                         "nb": op.nb, "lookahead": op.lookahead},
        placement_clocks_mhz=clocks,
        heterogeneous=len(clocks) > 1)


def _merged_trace(schedule: Schedule, *, dt_s: float,
                  network_w: float) -> PowerTrace:
    """Vectorized interval-driven merge: busy chips draw dynamic power
    and produce FLOPS at their placement's effective rate, idle chips
    draw static power, and hosts/fans/PSU losses are charged whether or
    not a node is busy (the cluster is powered on).

    The trace is piecewise-constant between placement start/end events,
    so each distinct occupancy interval is evaluated **once** through
    the batched layer API and then broadcast onto the ``dt_s`` grid —
    sample-for-sample (bit-level) identical to the per-tick loop oracle
    :func:`_merged_trace_reference`.

    Heterogeneous batches: each placement stamps its own operating
    point's busy watts (from the shared per-op lookup table) onto its
    chips, so one interval matrix carries e.g. 900 MHz HPL nodes next
    to 774 MHz LQCD nodes; idle chips and the node's host/fan/PSU
    composition stay at the schedule reference point."""
    top = schedule.topology
    op = schedule.op
    node, w_busy, w_idle, chip_peak_gflops = _op_table(schedule)
    g = top.gpus_per_node
    n_chips = top.n_chips

    # a zero-work batch still gets a one-interval idle trace; a short
    # batch ends at its makespan, never padded out to dt_s
    span = schedule.makespan or dt_s

    # -- event decomposition: occupancy is constant between placement
    #    start/end events, so those times bound the evaluation intervals
    events = {0.0}
    live = [p for p in schedule.placements if p.end > p.start]
    for p in live:
        events.add(p.start)
        events.add(p.end)
    starts = np.array(sorted(e for e in events if 0.0 <= e < span))
    n_int = starts.shape[0]

    # -- per-chip piecewise-constant occupancy / watts / flops-rate
    #    matrices.  Later placements overwrite earlier ones on a shared
    #    chip, matching Schedule.active_chips' last-wins dict semantics;
    #    each placement writes its own op's busy watts.
    active = np.zeros((n_int, n_chips), dtype=bool)
    rate = np.zeros((n_int, n_chips))
    chip_w = np.full((n_int, n_chips), w_idle)
    for p in live:
        s = int(np.searchsorted(starts, p.start, side="left"))
        e = int(np.searchsorted(starts, p.end, side="left"))
        active[s:e, p.chips] = True
        rate[s:e, p.chips] = chip_peak_gflops * p.rate_per_chip
        chip_w[s:e, p.chips] = w_busy[p.op or op]

    # -- one batched layer evaluation per interval: per-node GPU DC draw
    #    (summed over the chip axis exactly like the scalar API sums its
    #    per-chip overrides), then the node composition elementwise
    gpu_dc = np.sum(chip_w.reshape(n_int, top.n_nodes, g), axis=2)
    per_node = node.component_watts_series(op, gpu_dc=gpu_dc)
    watts_int = {name: np.sum(w, axis=1) for name, w in per_node.items()}
    flops_int = np.sum(rate, axis=1)
    util_int = np.sum(active, axis=1) / n_chips

    # -- broadcast onto the dt_s grid: each sample reads the interval it
    #    falls in (the final sample at t == span reads the left limit);
    #    the piecewise-constant ingestion lives on the recorder so the
    #    online simulator's event boundaries ride the same path
    watts_int["network"] = np.full(n_int, float(network_w))
    rec = TraceRecorder(source="cluster.run")
    rec.emit_intervals(starts, watts_int, span=span, dt_s=dt_s,
                       flops_rate=flops_int, util=util_int,
                       f_mhz=op.f_mhz, fan=op.fan)
    trace = rec.trace()
    _stamp_cluster_meta(trace, schedule)
    return trace


def _merged_trace_reference(schedule: Schedule, *, dt_s: float,
                            network_w: float) -> PowerTrace:
    """The legacy per-tick ``ticks × nodes × chips`` Python loop over the
    *scalar* layer API — kept as the equivalence-test oracle for the
    vectorized engine (and as the baseline the measured speedup in
    ``benchmarks/paper_tables.py::cluster_scale`` is taken against).

    Per-tick values are accumulated into per-node/per-chip arrays and
    reduced with ``np.sum`` so the float association matches the
    vectorized engine's axis reductions bit-for-bit.  Per-placement
    operating points read the same busy-watts lookup table the
    vectorized engine indexes, chip by chip."""
    top = schedule.topology
    op = schedule.op
    node, w_busy, w_idle, chip_peak_gflops = _op_table(schedule)
    g = top.gpus_per_node

    span = schedule.makespan or dt_s
    rec = TraceRecorder(source="cluster.run")
    for t in _sample_grid(span, dt_s):
        active = schedule.active_chips(min(t, span - 1e-9))
        per_node: Dict[str, np.ndarray] = {
            name: np.zeros(top.n_nodes)
            for name in ("gpu", "host", "fan", "psu_loss")}
        f_chip = np.zeros(top.n_chips)
        busy = 0
        for n in range(top.n_nodes):
            overrides = []
            for c in range(n * g, (n + 1) * g):
                p = active.get(c)
                overrides.append(w_busy[p.op or op] if p is not None
                                 else w_idle)
                if p is not None:
                    f_chip[c] = chip_peak_gflops * p.rate_per_chip
                    busy += 1
            for name, w in node.component_watts(
                    op, gpu_w_override=overrides).items():
                per_node[name][n] = w
        watts = {name: float(np.sum(col)) for name, col in per_node.items()}
        watts["network"] = network_w
        rec.emit(t, watts, flops_rate=float(np.sum(f_chip)),
                 util=busy / top.n_chips, f_mhz=op.f_mhz, fan=op.fan)
    trace = rec.trace()
    _stamp_cluster_meta(trace, schedule)
    return trace


def run(workloads: Sequence[Union[Workload, Job]], *,
        policy: str = "packed",
        topology: Optional[ClusterTopology] = None,
        op: Optional[OperatingPoint] = None,
        power_cap_w: Optional[float] = None,
        network_w: Optional[float] = None,
        dt_s: float = 5.0,
        execute: bool = True) -> ClusterRunResult:
    """Schedule a mixed batch and merge its telemetry.

    ``workloads`` may mix :class:`Workload` adapters (their ``job()``
    spec is placed; with ``execute=True`` their real code path also runs
    and contributes a :class:`WorkloadResult`) and bare :class:`Job`
    specs (placed and power-modeled only — the cluster-scale path).

    Each job's operating point is resolved individually (explicit ``op``
    override → the job's ``preferred_op`` → the autotuner cost model's
    recommendation); a ``power_cap_w`` derates each point down the DPM
    ladder.  The merged cluster trace carries component watts for every
    node — busy or idle — plus the separately-metered switches, pricing
    each placement at its own point.
    """
    if not workloads:
        raise ValueError("empty workload batch: nothing to run "
                         "(Scheduler.schedule accepts an empty job list "
                         "if you only need a placement)")
    jobs: List[Job] = []
    adapters: List[tuple] = []            # (workload, its job spec)
    for w in workloads:
        if isinstance(w, Job):
            jobs.append(w)
        else:
            job = w.job()
            jobs.append(job)
            adapters.append((w, job))

    sched = Scheduler(topology, policy=policy, power_cap_w=power_cap_w)
    schedule = sched.schedule(jobs, op=op)
    schedule.meta["policy"] = policy

    if network_w is None:
        network_w = schedule.topology.network_w

    trace = _merged_trace(schedule, dt_s=dt_s, network_w=float(network_w))

    results: List[WorkloadResult] = []
    if execute:
        # each adapter runs at the point its placement resolved to —
        # the same object identity the scheduler placed
        op_by_job = {id(p.job): p.op for p in schedule.placements}
        for wl, job in adapters:
            results.append(wl.execute(op_by_job.get(id(job))
                                      or schedule.op))
    return ClusterRunResult(schedule, trace, results)
