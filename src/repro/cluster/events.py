"""Event model for the online cluster simulator.

RAPS-style discrete-event operation (ExaDigiT): the simulator's clock
jumps between *events* — job arrivals, job completions, node failures,
node repairs — and between events nothing changes, so the schedule stays
piecewise-constant and the PR-5 interval engine evaluates the power
layers once per event boundary instead of once per tick.

This module owns the event vocabulary and the arrival sources:

  * :class:`Arrival` / :func:`batch_arrivals` — explicit ``(t, Job)``
    submissions (all-at-t=0 is the batch-oracle case);
  * :class:`TraceArrivals` — a recorded submission trace, RAPS
    telemetry-replay style;
  * :class:`PoissonArrivals` — seeded exponential inter-arrival times
    over a job list (the open-queue workload model).

Event ordering at one timestamp is fixed by priority: completions free
chips before failures are assessed, failures take nodes down before
repairs bring others back, and arrivals queue last — then the dispatcher
runs once over the drained batch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.cluster.scheduler import Job

# heap priority at equal timestamps: a job finishing exactly when its
# node fails has completed; a repair lands before a same-instant arrival
# so the arrival sees the node up
FINISH, FAIL, REPAIR, ARRIVE = range(4)


@dataclass(frozen=True)
class Arrival:
    """One submission: the job and its absolute submit time [s].

    ``workload`` (optional) is the PR-4 ``Workload`` adapter the job
    spec came from — the simulator places/fails/requeues the *job*, and
    can execute the workload at the placement's resolved operating
    point afterwards (``simulate(..., execute=True)``)."""

    t: float
    job: Job
    workload: Optional[Any] = None


def _one(t: float, x) -> Arrival:
    if isinstance(x, Job):
        return Arrival(t, x)
    if hasattr(x, "job") and hasattr(x, "execute"):   # Workload protocol
        return Arrival(t, x.job(), workload=x)
    raise TypeError(f"cannot submit {type(x).__name__!r}: expected a Job "
                    f"or a Workload (has job()/execute())")


def _normalize(items: Iterable) -> List[Arrival]:
    out: List[Arrival] = []
    for it in items:
        if isinstance(it, Arrival):
            out.append(it)
        elif isinstance(it, (Job,)) or (hasattr(it, "job")
                                        and hasattr(it, "execute")):
            out.append(_one(0.0, it))
        else:
            try:
                t, x = it
            except TypeError:
                raise TypeError(
                    f"cannot submit {type(it).__name__!r}: expected an "
                    f"Arrival, a Job, a Workload (has job()/execute()) or "
                    f"a (t, job-or-workload) pair") from None
            out.append(_one(float(t), x))
    if any(a.t < 0.0 for a in out):
        raise ValueError("arrival times must be non-negative")
    # stable: simultaneous submissions keep their submission order
    return sorted(out, key=lambda a: a.t)


def batch_arrivals(jobs: Sequence[Job], t: float = 0.0) -> List[Arrival]:
    """Every job submitted at the same instant — the closed-batch case
    the oracle test compares against ``cluster.run()``."""
    return [Arrival(float(t), j) for j in jobs]


class TraceArrivals:
    """A recorded submission trace: ``(t_submit, Job)`` pairs (or
    :class:`Arrival` objects), replayed verbatim."""

    def __init__(self, items: Iterable):
        self._arrivals = _normalize(items)

    def arrivals(self) -> List[Arrival]:
        return list(self._arrivals)


class PoissonArrivals:
    """Open-queue submissions: the given jobs arrive in order with
    seeded exponential inter-arrival gaps (rate ``rate_per_s``), i.e. a
    Poisson process thinned onto a finite job list.  Deterministic for a
    fixed seed — the property/determinism tests rely on it."""

    def __init__(self, jobs: Sequence[Job], rate_per_s: float, *,
                 seed: int = 0, t0: float = 0.0):
        if rate_per_s <= 0.0:
            raise ValueError("rate_per_s must be positive")
        rng = np.random.default_rng(seed)
        gaps = rng.exponential(1.0 / rate_per_s, size=len(jobs))
        times = t0 + np.cumsum(gaps)
        self._arrivals = [Arrival(float(t), j) for t, j in zip(times, jobs)]

    def arrivals(self) -> List[Arrival]:
        return list(self._arrivals)


ArrivalsLike = Union[Sequence[Job], Sequence[Arrival], Sequence[Tuple],
                     TraceArrivals, PoissonArrivals]


def as_arrivals(arrivals: ArrivalsLike) -> List[Arrival]:
    """Normalize any supported arrival source to a sorted list: a job
    list (all at t=0), ``(t, job)`` pairs, :class:`Arrival` objects, or
    an arrival-process object with an ``arrivals()`` method."""
    if hasattr(arrivals, "arrivals"):
        return _normalize(arrivals.arrivals())
    return _normalize(arrivals)
