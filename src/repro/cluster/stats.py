"""End-of-run statistics for the online cluster simulator.

RAPS reports a scheduling run as one summary block — utilization, wait
times, energy, cost at a $/kWh tariff — next to the power telemetry.
:class:`SimStats` is that block for :func:`repro.cluster.sim.simulate`:
everything is derived from the per-job records, the committed
placements, and the merged :class:`repro.power.PowerTrace`, so the
numbers and the trace can never disagree.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.cluster.scheduler import ClusterTopology, Job, Placement
from repro.power.trace import PowerTrace

#: default electricity tariff [$ / kWh] — European industrial rate of
#: the paper's era (GSI's power bill is the stated motivation, §1)
DEFAULT_USD_PER_KWH = 0.25

COMPLETED = "completed"
DROPPED = "dropped"       # exceeded the requeue budget after failures


@dataclass
class JobRecord:
    """One submitted job's life: submit → (wait) → start → end, plus any
    failure-driven requeues along the way."""

    uid: int
    job: Job
    submit_s: float
    start_s: Optional[float] = None     # first dispatch (wait = start-submit)
    end_s: Optional[float] = None       # terminal completion time
    requeues: int = 0
    state: str = "queued"               # queued|running|completed|dropped
    #: work fraction durably preserved by the last completed checkpoint
    #: (the progress surface): a killed attempt restarts from here, not
    #: from zero — 0.0 without a CheckpointPolicy, 1.0 on completion
    completed_fraction: float = 0.0
    checkpoints: int = 0                # completed checkpoint writes

    @property
    def wait_s(self) -> Optional[float]:
        return None if self.start_s is None else self.start_s - self.submit_s

    @property
    def progress(self) -> float:
        return 1.0 if self.state == COMPLETED else self.completed_fraction


@dataclass(frozen=True)
class SimStats:
    """The RAPS-style end-of-run report."""

    jobs_submitted: int
    jobs_completed: int
    jobs_dropped: int
    requeues: int
    node_failures: int
    node_downtime_s: float              # node-seconds out of service
    makespan_s: float
    utilization: float                  # busy chip-seconds / capacity
    wait_mean_s: float
    wait_p95_s: float
    queue_peak: int
    energy_j: float
    avg_power_w: float
    cost_usd: float
    usd_per_kwh: float = DEFAULT_USD_PER_KWH
    #: chip-seconds of compute redone after failure kills (work executed
    #: since the last completed checkpoint — the whole attempt without a
    #: CheckpointPolicy), and the busy-watt joules that compute burned.
    #: Both are exactly 0 in the no-failure oracle case.
    wasted_chip_s: float = 0.0
    wasted_node_s: float = 0.0          # same waste in node-seconds
    wasted_energy_j: float = 0.0
    checkpoints: int = 0                # completed checkpoint writes
    checkpoint_overhead_s: float = 0.0  # wall seconds paused for writes
    checkpoint_energy_j: float = 0.0    # storage-component write joules
    extras: Dict[str, float] = field(default_factory=dict)

    @property
    def energy_kwh(self) -> float:
        return self.energy_j / 3.6e6

    @property
    def goodput(self) -> float:
        """Fraction of committed busy chip-seconds that was *useful*
        first-time compute: 1 − (redone work + checkpoint pauses) /
        busy.  The resilience benchmark's second gate (next to
        energy-to-completion)."""
        total = self._busy_chip_s
        if total <= 0.0:
            return 1.0
        lost = self.wasted_chip_s + self.checkpoint_overhead_chip_s
        return max(1.0 - lost / total, 0.0)

    @property
    def checkpoint_overhead_chip_s(self) -> float:
        return self.extras.get("ckpt_overhead_chip_s", 0.0)

    @property
    def _busy_chip_s(self) -> float:
        return self.extras.get("busy_chip_s", 0.0)

    def summary(self) -> str:
        """One human-readable block (RAPS prints the same shape)."""
        return (
            f"jobs      {self.jobs_completed}/{self.jobs_submitted} completed"
            f" ({self.requeues} requeues, {self.jobs_dropped} dropped)\n"
            f"failures  {self.node_failures} node failures, "
            f"{self.node_downtime_s / 3600.0:.1f} node-hours down\n"
            f"waste     {self.wasted_node_s / 3600.0:.2f} node-hours "
            f"redone ({self.wasted_energy_j / 3.6e6:.2f} kWh)   "
            f"goodput {self.goodput:.1%}\n"
            f"ckpt      {self.checkpoints} writes, "
            f"{self.checkpoint_overhead_s:.0f} s paused, "
            f"{self.checkpoint_energy_j / 3.6e6:.3f} kWh to storage\n"
            f"makespan  {self.makespan_s / 3600.0:.2f} h   "
            f"utilization {self.utilization:.1%}   "
            f"peak queue {self.queue_peak}\n"
            f"wait      mean {self.wait_mean_s:.0f} s, "
            f"p95 {self.wait_p95_s:.0f} s\n"
            f"energy    {self.energy_kwh:.1f} kWh "
            f"(avg {self.avg_power_w / 1e3:.2f} kW)   "
            f"cost ${self.cost_usd:.2f} @ ${self.usd_per_kwh:.2f}/kWh")


def compute_stats(records: Sequence[JobRecord],
                  placements: Sequence[Placement],
                  trace: PowerTrace,
                  topology: ClusterTopology, *,
                  node_failures: int = 0,
                  node_downtime_s: float = 0.0,
                  queue_peak: int = 0,
                  usd_per_kwh: float = DEFAULT_USD_PER_KWH,
                  wasted_chip_s: float = 0.0,
                  wasted_node_s: float = 0.0,
                  wasted_energy_j: float = 0.0,
                  checkpoints: int = 0,
                  checkpoint_overhead_s: float = 0.0,
                  checkpoint_overhead_chip_s: float = 0.0,
                  checkpoint_energy_j: float = 0.0) -> SimStats:
    """Fold the simulator's records into one :class:`SimStats` block.

    Utilization counts *committed* chip-seconds (including work lost to
    a node failure — those chips did draw busy power) against
    ``n_chips × makespan``; waits are first-dispatch latencies over the
    jobs that started.  The wasted/checkpoint figures come from the
    simulator's per-attempt accounting
    (:mod:`repro.cluster.resilience`)."""
    makespan = max((p.end for p in placements), default=0.0)
    busy = sum((p.end - p.start) * len(p.chips) for p in placements)
    cap = topology.n_chips * makespan
    waits = np.asarray([r.wait_s for r in records if r.wait_s is not None],
                       dtype=float)
    energy = trace.energy_j()
    duration = max(trace.duration, 1e-12)
    return SimStats(
        jobs_submitted=len(records),
        jobs_completed=sum(r.state == COMPLETED for r in records),
        jobs_dropped=sum(r.state == DROPPED for r in records),
        requeues=sum(r.requeues for r in records),
        node_failures=node_failures,
        node_downtime_s=node_downtime_s,
        makespan_s=makespan,
        utilization=busy / cap if cap > 0.0 else 0.0,
        wait_mean_s=float(np.mean(waits)) if waits.size else 0.0,
        wait_p95_s=float(np.percentile(waits, 95)) if waits.size else 0.0,
        queue_peak=queue_peak,
        energy_j=energy,
        avg_power_w=energy / duration,
        cost_usd=energy / 3.6e6 * usd_per_kwh,
        usd_per_kwh=usd_per_kwh,
        wasted_chip_s=wasted_chip_s,
        wasted_node_s=wasted_node_s,
        wasted_energy_j=wasted_energy_j,
        checkpoints=checkpoints,
        checkpoint_overhead_s=checkpoint_overhead_s,
        checkpoint_energy_j=checkpoint_energy_j,
        extras={"busy_chip_s": busy,
                "ckpt_overhead_chip_s": checkpoint_overhead_chip_s})
