"""The unified Workload API: one protocol over every workload entry
point in the repo.

Before this layer, each workload exposed an incompatible ad-hoc surface:
``linpack_run(cfg)``, ``solve_wilson_eo(U, b, kappa, ...)``, the
``launch.train``/``launch.serve`` CLI drivers, and the power engine's
synthetic load shapes.  A :class:`Workload` normalizes all of them into

  * ``job()``      → a :class:`repro.cluster.scheduler.Job` spec
                     (memory, work units, shardability, preferred
                     operating point) the scheduler can place, and
  * ``execute()``  → a :class:`WorkloadResult` (perf, energy-to-solution)
                     carrying the :class:`repro.power.PowerTrace` the run
                     emitted into the PR-3 telemetry bus.

Adapters register themselves in ``WORKLOAD_REGISTRY`` so drivers and
benchmarks can build batches by name (``make_workload("hpl")``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Protocol, Type,
                    runtime_checkable)

import numpy as np

from repro.cluster.scheduler import Job
from repro.power.model import OperatingPoint
from repro.power.trace import PowerTrace, TraceRecorder


@dataclass(frozen=True)
class WorkloadResult:
    """What every workload returns: performance, energy-to-solution and
    the telemetry it was integrated from."""

    name: str
    kind: str
    perf_gflops: float
    wall_s: float
    energy_j: float
    power_trace: PowerTrace = field(repr=False)
    job: Job
    details: Dict[str, Any] = field(default_factory=dict)

    @property
    def gflops_per_w(self) -> float:
        return self.perf_gflops * self.wall_s / max(self.energy_j, 1e-12)


@runtime_checkable
class Workload(Protocol):
    """Anything the cluster can schedule and run.

    ``job()`` is the placement spec; ``execute(op)`` runs the workload's
    real (smoke-scale) or analytic code path at the given operating
    point, emits telemetry into ``recorder`` (or a private bus), and
    returns a :class:`WorkloadResult`.

    ``state_bytes()`` is the resilience surface: how many bytes a
    checkpoint of this workload streams to storage
    (:class:`repro.cluster.resilience.CheckpointPolicy` prices the
    Daly interval from it).  ``0.0`` means *stateless* — nothing worth
    checkpointing (e.g. serving, whose KV cache is reconstructible) —
    and disables checkpoint scheduling for the job entirely."""

    name: str

    def job(self) -> Job:
        ...

    def state_bytes(self) -> float:
        ...

    def execute(self, op: OperatingPoint, *,
                recorder: Optional[TraceRecorder] = None) -> WorkloadResult:
        ...


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

WORKLOAD_REGISTRY: Dict[str, Type] = {}


def register_workload(kind: str) -> Callable[[Type], Type]:
    def deco(cls: Type) -> Type:
        if kind in WORKLOAD_REGISTRY:
            raise ValueError(f"workload kind {kind!r} already registered")
        WORKLOAD_REGISTRY[kind] = cls
        cls.kind = kind
        return cls
    return deco


def list_workloads() -> List[str]:
    return sorted(WORKLOAD_REGISTRY)


# kinds whose adapter lives outside this module and registers on import
_LAZY_KINDS = {"serve_replay": "repro.serve.replay"}


def make_workload(kind: str, **kwargs) -> Workload:
    if kind not in WORKLOAD_REGISTRY and kind in _LAZY_KINDS:
        import importlib
        importlib.import_module(_LAZY_KINDS[kind])
    try:
        cls = WORKLOAD_REGISTRY[kind]
    except KeyError:
        raise KeyError(f"unknown workload kind {kind!r}; registered: "
                       f"{list_workloads()} (+lazy: {sorted(_LAZY_KINDS)})"
                       ) from None
    return cls(**kwargs)


def _result(wl, op: OperatingPoint, trace: PowerTrace, perf_gflops: float,
            wall_s: float, window: Optional[tuple] = None,
            **details) -> WorkloadResult:
    """``window`` bounds the energy integral to this workload's own
    emission span — on a shared bus the trace carries earlier phases
    too, and those must not be billed to this result."""
    energy = trace.energy_j() if window is None \
        else trace.energy_j(t0=window[0], t1=window[1])
    return WorkloadResult(
        name=wl.name, kind=wl.kind, perf_gflops=perf_gflops, wall_s=wall_s,
        energy_j=energy, power_trace=trace, job=wl.job(),
        details={"op_f_mhz": op.f_mhz, **details})


def _plan_at(ac, mode: str, op: Optional[OperatingPoint]):
    """DVFS plan for a roofline cost, with the clock grid capped at the
    operating point's frequency (relative to the stock clock) — how a
    scheduler-chosen derate (e.g. a power cap) reaches the TPU-side
    frequency planner."""
    from repro.config import EnergyConfig
    from repro.core.energy.dvfs import plan_frequency
    cfg = EnergyConfig(mode=mode)
    if op is not None:
        from repro.power.model import STOCK_MHZ
        cap = op.f_mhz / STOCK_MHZ
        # below the grid's floor, run AT the cap (clamped to the TPU
        # model's 0.3 validity floor) — never above it
        grid = tuple(f for f in cfg.freq_grid if f <= cap + 1e-9) \
            or (float(np.clip(cap, 0.3, 1.0)),)
        cfg = EnergyConfig(mode=mode, freq_grid=grid)
    return plan_frequency(ac.compute_s, ac.memory_s, ac.collective_s,
                          flops_per_step=ac.flops, cfg=cfg)


# ---------------------------------------------------------------------------
# Adapters
# ---------------------------------------------------------------------------


@register_workload("hpl")
@dataclass
class HPLWorkload:
    """``repro.hpl.linpack_run`` behind the Workload API.

    The smoke-scale LU actually runs; the Job spec describes the
    paper-scale footprint (HPL fills GPU memory and shards node-wide, so
    it asks for a whole node and prefers the mode's operating point)."""

    name: str = "hpl"
    cfg: Optional[Any] = None          # HPLConfig; default SMOKE_HPL
    mem_gb: float = 52.0               # paper-scale: ~13 GB on each of 4 GPUs
    work_units: float = 1800.0
    tuned: bool = False

    def __post_init__(self):
        if self.cfg is None:
            from repro.configs.hpl import SMOKE_HPL
            self.cfg = SMOKE_HPL

    def job(self) -> Job:
        op = OperatingPoint.green500() if self.cfg.mode == "efficiency" \
            else OperatingPoint(f_mhz=900.0)
        return Job(self.name, self.mem_gb, self.work_units,
                   shardable=True, preferred_op=op, kind=self.kind,
                   state_bytes=self.state_bytes())

    def state_bytes(self) -> float:
        # the in-place factored matrix IS the restart state
        return self.mem_gb * 1e9

    def execute(self, op: OperatingPoint, *,
                recorder: Optional[TraceRecorder] = None) -> WorkloadResult:
        from repro.config import EnergyConfig
        from repro.hpl.linpack import linpack_run
        mode = "efficiency" if op.f_mhz < 900.0 else "performance"
        res = linpack_run(self.cfg, energy=EnergyConfig(mode=mode),
                          tuned=self.tuned, recorder=recorder)
        t_end = float(res.power_trace.t[-1])
        return _result(self, op, res.power_trace, res.gflops, res.wall_s,
                       window=(t_end - res.wall_s, t_end),
                       residual=res.residual, n=res.n, block=res.block,
                       passed=res.passed)


@register_workload("lqcd")
@dataclass
class LQCDSolveWorkload:
    """``repro.lqcd.solve_dirac`` (plain / even-odd mixed CG) behind the
    Workload API — the paper's production workload: one lattice per GPU,
    sharded only when the lattice outgrows chip memory.

    ``calibration`` (an :class:`repro.lqcd.LQCDCalibration`, e.g. from
    ``measured_lqcd_calibration()``) replaces the analytic S9150 roofline
    with figures measured from the executed multi-chip normal op: the
    energy model then streams at the calibration's effective bandwidth and
    burns its busy watts.  Left ``None``, the default analytic path is
    byte-identical to before."""

    name: str = "lqcd"
    lattice: Optional[Any] = None      # LatticeConfig; default SMOKE_LATTICE
    seed: int = 0
    calibration: Optional[Any] = None  # LQCDCalibration; default analytic

    def __post_init__(self):
        if self.lattice is None:
            from repro.configs.lcsc_lqcd import SMOKE_LATTICE
            self.lattice = SMOKE_LATTICE

    def job(self) -> Job:
        # thermal lattices run one-per-GPU; work scales with volume
        return Job(self.name, self.lattice.mem_gb,
                   work_units=self.lattice.volume / 4096.0,
                   shardable=True, preferred_op=OperatingPoint.green500(),
                   kind=self.kind, state_bytes=self.state_bytes())

    def state_bytes(self) -> float:
        # gauge configuration + current solver iterate — the GPU-resident
        # lattice working set restarts the trajectory
        return self.lattice.mem_gb * 1e9

    def execute(self, op: OperatingPoint, *,
                recorder: Optional[TraceRecorder] = None) -> WorkloadResult:
        import jax
        import jax.numpy as jnp
        from repro.core.energy.solver_energy import SolverHW, solver_energy
        from repro.lqcd import random_su3_field, solve_dirac
        from repro.power.model import gpu_power_throttled

        lat = self.lattice.shape
        ku, kr, ki = jax.random.split(jax.random.PRNGKey(self.seed), 3)
        U = random_su3_field(ku, lat)
        b = (jax.random.normal(kr, lat + (4, 3))
             + 1j * jax.random.normal(ki, lat + (4, 3))
             ).astype(jnp.complex64)
        res = solve_dirac(U, b, self.lattice.kappa, self.lattice.solver)
        scfg = self.lattice.solver
        eo = scfg.preconditioner != "none"
        inner_bytes = 2 if (eo and scfg.mixed_precision) else 4
        cal = self.calibration
        if cal is not None:
            # measured multi-chip figures (repro.lqcd.multichip_eo): stream
            # at the executed effective bandwidth, burn the calibrated
            # aggregate busy watts
            hw = SolverHW(name=f"{cal.source}:{cal.n_devices}chip",
                          bandwidth_gbs=cal.eff_bw_gbs, bw_fraction=1.0,
                          power_w=cal.busy_w)
        else:
            # the operating point sets device power (undervolted/derated
            # chips draw less); the memory-bound solve time barely moves
            # with clock — the paper's <1.5% claim — so bandwidth stays at
            # the S9150 spec
            hw = SolverHW(power_w=gpu_power_throttled(
                op.f_mhz, op.vid, temp_c=op.temperature(), util=1.0))
        rep = solver_energy(
            f"cg/{self.name}", self.lattice.volume, int(res.iters),
            outer_ops=int(getattr(res, "outer_iters", 0)),
            inner_real_bytes=inner_bytes, even_odd=eo, hw=hw,
            recorder=recorder)
        t_end = float(rep.trace.t[-1])
        extra = {}
        if cal is not None:
            from repro.lqcd.multichip_eo import analytic_lqcd_calibration
            ana = analytic_lqcd_calibration(cal.lattice, cal.n_devices)
            extra = dict(calibration_source=cal.source,
                         cal_n_devices=cal.n_devices,
                         cal_gflops=cal.gflops,
                         cal_gflops_per_w=cal.gflops_per_w,
                         cal_vs_analytic=cal.gflops / max(ana.gflops, 1e-9))
        return _result(self, op, rep.trace, rep.gflops, rep.time_s,
                       window=(t_end - rep.time_s, t_end),
                       iters=int(res.iters),
                       rel_residual=float(res.rel_residual),
                       converged=bool(res.converged), **extra)


@register_workload("train")
@dataclass
class TrainWorkload:
    """The ``launch.train`` driver's energy/telemetry path behind the
    Workload API: roofline step cost + DVFS plan + per-step chip-power
    emission.  ``execute`` is analytic (no jitted steps) so schedulers
    and benchmarks can run it anywhere; the real training loop in
    :mod:`repro.launch.train` builds the same plan through this adapter."""

    name: str = "train"
    arch: str = "olmo-1b"
    steps: int = 8
    batch: int = 8
    seq: int = 128
    smoke: bool = True
    remat: str = "none"            # must match the compiled step (the
                                   # launch.train driver uses remat="none")
    preferred_op: Optional[OperatingPoint] = None
    _cost_cache: Optional[Any] = field(default=None, init=False,
                                       repr=False, compare=False)

    def _cost(self):
        if self._cost_cache is None:
            from repro.config import (ShapeConfig, SINGLE_POD_MESH,
                                      TrainConfig, get_arch)
            entry = get_arch(self.arch)
            cfg = entry.smoke() if self.smoke else entry.full()
            shape = ShapeConfig("custom", self.seq, self.batch, "train")
            from repro.roofline.analytic import cost_for
            self._cost_cache = cost_for(cfg, shape, SINGLE_POD_MESH,
                                        TrainConfig(remat=self.remat))
        return self._cost_cache

    def energy_plan(self, mode: str = "efficiency",
                    op: Optional[OperatingPoint] = None):
        """The DVFS plan for this step shape (shared with the driver).
        ``op`` caps the clock grid at the scheduler-chosen frequency."""
        ac = self._cost()
        return _plan_at(ac, mode, op), ac

    def job(self) -> Job:
        ac = self._cost()
        # model + optimizer working set, with roofline bytes as the proxy
        mem_gb = max(ac.hbm_bytes / 1e9, 0.1)
        return Job(self.name, mem_gb,
                   work_units=self.steps * ac.flops / 1e12,
                   shardable=True, preferred_op=self.preferred_op,
                   kind=self.kind, state_bytes=self.state_bytes())

    def state_bytes(self) -> float:
        # params + optimizer moments (activations are recomputed on
        # restart) — the roofline HBM footprint is the honest upper bound
        return float(max(self._cost().hbm_bytes, 1e8))

    def execute(self, op: OperatingPoint, *,
                recorder: Optional[TraceRecorder] = None) -> WorkloadResult:
        plan, ac = self.energy_plan(op=op)
        rec = recorder if recorder is not None \
            else TraceRecorder(source="workload.train")
        t0 = rec.t_last
        step_s = plan.step_time_s
        for i in range(self.steps + 1):
            rec.emit(t0 + i * step_s, {"chip": plan.power_w},
                     flops_rate=0.0 if i == 0 else ac.flops / step_s / 1e9,
                     freq_scale=plan.freq_scale)
        trace = rec.trace()
        wall = self.steps * step_s
        return _result(self, op, trace, ac.flops / step_s / 1e9, wall,
                       window=(t0, t0 + wall),
                       steps=self.steps, dominant=plan.dominant,
                       freq_scale=plan.freq_scale)


@register_workload("serve")
@dataclass
class ServeWorkload:
    """The ``launch.serve`` driver's energy/telemetry path behind the
    Workload API: prefill + decode roofline costs, decode-dominated DVFS
    plan, two-phase chip-power emission."""

    name: str = "serve"
    arch: str = "llama3-8b"
    batch: int = 4
    prompt_len: int = 64
    gen: int = 32
    smoke: bool = True
    kv_int8: bool = False
    preferred_op: Optional[OperatingPoint] = None
    _cost_cache: Optional[Any] = field(default=None, init=False,
                                       repr=False, compare=False)

    def _costs(self):
        if self._cost_cache is None:
            from repro.config import ShapeConfig, SINGLE_POD_MESH, get_arch
            from repro.roofline.analytic import cost_for
            entry = get_arch(self.arch)
            cfg = entry.smoke() if self.smoke else entry.full()
            total = self.prompt_len + self.gen
            dec = cost_for(cfg, ShapeConfig("serve", total, self.batch,
                                            "decode"),
                           SINGLE_POD_MESH, kv_int8=self.kv_int8)
            pre = cost_for(cfg, ShapeConfig("serve_prefill", self.prompt_len,
                                            self.batch, "prefill"),
                           SINGLE_POD_MESH, kv_int8=self.kv_int8)
            self._cost_cache = (pre, dec)
        return self._cost_cache

    def energy_plan(self, mode: str = "efficiency",
                    op: Optional[OperatingPoint] = None):
        """Decode-shape DVFS plan (shared with the driver).  ``op`` caps
        the clock grid at the scheduler-chosen frequency."""
        pre, dec = self._costs()
        return _plan_at(dec, mode, op), pre, dec

    def job(self) -> Job:
        pre, dec = self._costs()
        mem_gb = max((pre.hbm_bytes + dec.hbm_bytes) / 1e9, 0.1)
        work = (pre.flops + self.gen * dec.flops) / 1e12
        return Job(self.name, mem_gb, work_units=work, shardable=True,
                   preferred_op=self.preferred_op, kind=self.kind,
                   state_bytes=self.state_bytes())

    def state_bytes(self) -> float:
        # serving is stateless (weights are re-loadable, the KV cache is
        # reconstructible): nothing to checkpoint, retries are the
        # resilience story (repro.serve.autoscale RetryPolicy)
        return 0.0

    def execute(self, op: OperatingPoint, *,
                recorder: Optional[TraceRecorder] = None) -> WorkloadResult:
        plan, pre, dec = self.energy_plan(op=op)
        rec = recorder if recorder is not None \
            else TraceRecorder(source="workload.serve")
        t0 = rec.t_last
        t_pre = max(pre.compute_s, pre.memory_s) + pre.collective_s
        t_dec = self.gen * plan.step_time_s
        rec.emit(t0, {"chip": plan.power_w}, flops_rate=0.0,
                 freq_scale=plan.freq_scale)
        rec.emit(t0 + t_pre, {"chip": plan.power_w},
                 flops_rate=pre.flops / max(t_pre, 1e-12) / 1e9,
                 freq_scale=plan.freq_scale)
        rec.emit(t0 + t_pre + t_dec, {"chip": plan.power_w},
                 flops_rate=dec.flops / plan.step_time_s / 1e9,
                 freq_scale=plan.freq_scale)
        trace = rec.trace()
        wall = t_pre + t_dec
        perf = (pre.flops + self.gen * dec.flops) / wall / 1e9
        return _result(self, op, trace, perf, wall,
                       window=(t0, t0 + wall), gen=self.gen,
                       batch=self.batch, dominant=plan.dominant)


@register_workload("synthetic")
@dataclass
class SyntheticWorkload:
    """``repro.power.simulate``'s synthetic load shapes behind the
    Workload API: a relative load profile driven through the layered
    cluster model (single node by default)."""

    name: str = "synthetic"
    profile: Optional[Any] = None      # engine load profile (SyntheticHPL…)
    n_nodes: int = 1
    mem_gb: float = 13.0
    work_units: float = 600.0
    preferred_op: Optional[OperatingPoint] = None

    def __post_init__(self):
        if self.profile is None:
            from repro.power.engine import ConstantLoad
            self.profile = ConstantLoad(duration_s=600.0)

    def job(self) -> Job:
        return Job(self.name, self.mem_gb, self.work_units,
                   shardable=True, preferred_op=self.preferred_op,
                   kind=self.kind, state_bytes=self.state_bytes())

    def state_bytes(self) -> float:
        return self.mem_gb * 1e9

    def execute(self, op: OperatingPoint, *,
                recorder: Optional[TraceRecorder] = None) -> WorkloadResult:
        from repro.power.engine import simulate
        from repro.power.layers import lcsc_cluster
        cluster = lcsc_cluster(self.n_nodes,
                               nodes_per_rack=min(self.n_nodes, 8))
        t0 = recorder.t_last if recorder is not None else 0.0
        trace = simulate(self.profile, op, cluster=cluster,
                         recorder=recorder)
        wall = float(self.profile.duration_s)
        # sustained GFLOPS over this profile's own window (a shared bus
        # carries other phases' flops too)
        perf = trace.total_flops(t0, t0 + wall) / max(wall, 1e-12)
        return _result(self, op, trace, perf, wall,
                       window=(t0, t0 + wall),
                       n_nodes=self.n_nodes,
                       profile=type(self.profile).__name__)
