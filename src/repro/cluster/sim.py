"""Online discrete-event cluster simulator: arrival queues, FCFS +
conservative backfill, Weibull node failures with requeue.

The paper's Green500 story is a snapshot of a *live* machine — L-CSC ran
as an operated cluster where jobs arrive, nodes fail and power varies
over time, not as one closed batch.  This module turns
``cluster.run(jobs, policy)`` into that RAPS-style online operation:

  * an **arrival queue** (trace- or Poisson-driven submit times,
    :mod:`repro.cluster.events`) feeds a wait queue;
  * the **dispatcher** places FCFS, optionally with conservative
    (EASY-style) backfill: a blocked queue head gets a chip reservation
    at its earliest projected start, and later jobs may jump ahead only
    onto chips outside that reservation or if they finish before it —
    so backfill never delays the head;
  * **node failures** are drawn from the shared
    :class:`repro.distributed.fault.WeibullFailureModel` renewal
    process; a failure kills the placements on that node mid-flight
    (the power they burned stays on the trace), requeues the jobs at
    their original queue position, and returns the node after its
    repair time;
  * the event loop only produces **interval boundaries** — placements
    are piecewise-constant between events — so the merged cluster power
    rides the PR-5 vectorized interval engine
    (:func:`repro.cluster.run._merged_trace`) unchanged, and 160 nodes
    × weeks of simulated time stays interactive.

Determinism: everything stochastic (arrival gaps, failure draws) comes
from seeded generators, so a ``(arrivals, seed)`` pair replays exactly.

Oracle property (pinned in ``tests/test_cluster_sim.py``): with every
arrival at t=0, no failures, and placement choices that share the batch
scheduler's tie-breaks (:class:`repro.cluster.scheduler.ChipPool`), the
simulator's merged ``PowerTrace`` is bit-identical to the closed-batch
``cluster.run()`` trace.
"""
from __future__ import annotations

import heapq
from bisect import insort
from dataclasses import dataclass, field
from itertools import count
from typing import Dict, List, Optional, Tuple

from repro.cluster.events import (ARRIVE, FAIL, FINISH, REPAIR, Arrival,
                                  ArrivalsLike, as_arrivals)
from repro.cluster.run import _merged_trace
from repro.cluster.scheduler import (ChipPool, ClusterTopology,
                                     GREEN500_TOPOLOGY, MULTI_GPU_SLOWDOWN,
                                     Placement, Schedule, Scheduler,
                                     _commit_placement, _reference_op,
                                     op_rate_scale, synchronous_rate)
from repro.cluster.stats import (COMPLETED, DEFAULT_USD_PER_KWH, DROPPED,
                                 JobRecord, SimStats, compute_stats)
from repro.distributed.fault import WeibullFailureModel
from repro.power.model import OperatingPoint
from repro.power.trace import PowerTrace


@dataclass
class SimResult:
    """One simulated run: the as-executed schedule (every placement,
    including failure-truncated attempts), the merged cluster power
    trace, the RAPS-style stats block, and the per-job records."""

    schedule: Schedule
    trace: PowerTrace
    stats: SimStats
    records: List[JobRecord] = field(default_factory=list)
    # uid → WorkloadResult for completed Workload-backed arrivals, when
    # simulate(..., execute=True) ran them at their placement's op
    results: Dict[int, object] = field(default_factory=dict)

    @property
    def op(self) -> OperatingPoint:
        return self.schedule.op

    @property
    def makespan(self) -> float:
        return self.schedule.makespan

    def efficiency(self, level: int = 3):
        """Green500 measurement of the merged trace."""
        from repro.power.green500 import measure_efficiency
        return measure_efficiency(self.trace, level)


class _Sim:
    """The event loop's mutable state (one run, then discarded)."""

    def __init__(self, arrivals: List[Arrival], *,
                 topology: ClusterTopology, policy: str, backfill: bool,
                 op: Optional[OperatingPoint], power_cap_w: Optional[float],
                 failure_model: Optional[WeibullFailureModel], seed: int,
                 max_requeues: int, penalty: float):
        self.topology = topology
        self.backfill = backfill
        self.failure_model = failure_model
        self.max_requeues = max_requeues
        self.penalty = penalty

        sched = Scheduler(topology, policy=policy,
                          power_cap_w=power_cap_w,
                          multi_gpu_penalty=penalty)
        jobs = [a.job for a in arrivals]
        # per-job operating points, resolved up front exactly like the
        # batch scheduler (explicit op → preferred_op → autotuner pick,
        # each derated under the cap); self.op is the batch reference
        self.op, self.derated = sched.resolve_operating_point(op)
        self.job_ops: List[OperatingPoint] = []
        for j in jobs:
            job_op, job_derated = sched.resolve_operating_point(op, job=j)
            self.job_ops.append(job_op)
            self.derated = self.derated or job_derated
        # chip widths validated up front: an unplaceable job fails the
        # submit, exactly like the batch scheduler
        self.need = [sched._chips_needed(j) for j in jobs]

        self.pool = ChipPool(topology, policy=policy)
        self.records = [JobRecord(uid, a.job, a.t)
                        for uid, a in enumerate(arrivals)]
        self.queue: List[JobRecord] = []        # (submit_s, uid)-sorted
        self.running: Dict[int, Tuple[Placement, JobRecord, int]] = {}
        self.placements: List[Placement] = []
        self.heap: List[tuple] = []
        self._seq = count()
        self.pending_arrivals = len(arrivals)
        self.queue_peak = 0
        self.n_failures = 0
        self.downtime_s = 0.0

        for a, rec in zip(arrivals, self.records):
            self._push(a.t, ARRIVE, ("arrive", rec.uid))
        if failure_model is not None:
            import numpy as np
            self.rng = np.random.default_rng(seed)
            for node in range(topology.n_nodes):
                self._push(failure_model.draw_uptime_s(self.rng), FAIL,
                           ("fail", node))

    # -- plumbing ------------------------------------------------------------

    def _push(self, t: float, prio: int, payload: tuple) -> None:
        heapq.heappush(self.heap, (t, prio, next(self._seq), payload))

    def _enqueue(self, rec: JobRecord) -> None:
        rec.state = "queued"
        # requeued jobs keep their original queue position (submit time)
        insort(self.queue, rec, key=lambda r: (r.submit_s, r.uid))
        self.queue_peak = max(self.queue_peak, len(self.queue))

    # -- event handlers ------------------------------------------------------

    def _start(self, rec: JobRecord, pool_chips, t: float) -> None:
        p = _commit_placement(rec.job, pool_chips, self.penalty, now=t,
                              op=self.job_ops[rec.uid])
        self.placements.append(p)
        if rec.start_s is None:
            rec.start_s = p.start
        rec.state = "running"
        self.running[rec.uid] = (p, rec, rec.requeues)
        self._push(p.end, FINISH, ("finish", rec.uid, rec.requeues))

    def _on_finish(self, uid: int, attempt: int, t: float) -> None:
        entry = self.running.get(uid)
        if entry is None or entry[2] != attempt:
            return                      # stale: this attempt was killed
        _, rec, _ = self.running.pop(uid)
        rec.state = COMPLETED
        rec.end_s = t

    def _on_fail(self, node: int, t: float) -> None:
        model = self.failure_model
        up_at = t + model.repair_s
        self.pool.fail_node(node, t, up_at)
        self._push(up_at, REPAIR, ("repair", node))
        self.n_failures += 1
        self.downtime_s += model.repair_s
        g = self.topology.gpus_per_node
        victims = [uid for uid, (p, _, _) in self.running.items()
                   if any(c // g == node for c in p.chips)]
        for uid in victims:
            p, rec, _ = self.running.pop(uid)
            p.end = t                   # power burned up to the kill stays
            self.pool.release(p.chips, t)
            rec.requeues += 1
            if rec.requeues > self.max_requeues:
                rec.state = DROPPED
                rec.end_s = t
            else:
                self._enqueue(rec)

    def _on_repair(self, node: int, t: float) -> None:
        self.pool.repair_node(node, t)
        self._push(t + self.failure_model.draw_uptime_s(self.rng), FAIL,
                   ("fail", node))

    # -- dispatcher ----------------------------------------------------------

    def _dispatch(self, t: float) -> None:
        # FCFS: start queue heads while they fit right now
        while self.queue:
            rec = self.queue[0]
            cand = self.pool.pick_now(self.need[rec.uid], t)
            if cand is None:
                break
            self.queue.pop(0)
            self._start(rec, cand, t)
        if not (self.backfill and self.queue):
            return
        # conservative (EASY-style) backfill: reserve the blocked head's
        # earliest projected pool; later jobs may start now only on
        # chips outside the reservation, or on reserved chips if they
        # provably finish before the head's start
        head = self.queue[0]
        res_pool, t_res = self.pool.earliest_pool(self.need[head.uid])
        reserved = frozenset(c.chip_id for c in res_pool or ())
        i = 1
        while i < len(self.queue):
            rec = self.queue[i]
            need = self.need[rec.uid]
            cand = self.pool.pick_now(need, t, exclude=reserved)
            if cand is None:
                cand = self.pool.pick_now(need, t)
                if cand is not None:
                    rate = (synchronous_rate(
                        [c.perf_scale for c in cand], self.penalty)
                        * op_rate_scale(rec.job, self.job_ops[rec.uid]))
                    if t + rec.job.work_units / rate > t_res:
                        cand = None
            if cand is None:
                i += 1
            else:
                self.queue.pop(i)
                self._start(rec, cand, t)

    # -- the loop ------------------------------------------------------------

    def run(self) -> None:
        heap = self.heap
        while heap:
            if not (self.queue or self.running or self.pending_arrivals):
                break                   # only failure churn left
            t = heap[0][0]
            batch = []
            while heap and heap[0][0] == t:
                batch.append(heapq.heappop(heap))
            for _, _, _, payload in batch:      # (t, prio, seq)-ordered
                kind = payload[0]
                if kind == "finish":
                    self._on_finish(payload[1], payload[2], t)
                elif kind == "fail":
                    self._on_fail(payload[1], t)
                elif kind == "repair":
                    self._on_repair(payload[1], t)
                else:                            # arrive
                    self.pending_arrivals -= 1
                    self._enqueue(self.records[payload[1]])
            self._dispatch(t)
        bad = [r for r in self.records
               if r.state not in (COMPLETED, DROPPED)]
        if bad:
            raise RuntimeError(
                f"simulation ended with {len(bad)} non-terminal jobs "
                f"(first: {bad[0].job.name!r} in state {bad[0].state!r}) — "
                f"event-loop invariant broken")


def simulate(arrivals: ArrivalsLike, *,
             topology: Optional[ClusterTopology] = None,
             policy: str = "packed",
             backfill: bool = True,
             op: Optional[OperatingPoint] = None,
             power_cap_w: Optional[float] = None,
             failure_model: Optional[WeibullFailureModel] = None,
             seed: int = 0,
             max_requeues: int = 3,
             multi_gpu_penalty: float = MULTI_GPU_SLOWDOWN,
             dt_s: float = 5.0,
             network_w: Optional[float] = None,
             usd_per_kwh: float = DEFAULT_USD_PER_KWH,
             execute: bool = False) -> SimResult:
    """Run the online simulator and return schedule + trace + stats.

    ``arrivals`` is anything :func:`repro.cluster.events.as_arrivals`
    accepts: a plain job list (all submitted at t=0 — the batch-oracle
    case), ``(t, job)`` pairs, or an arrival process
    (:class:`PoissonArrivals`, :class:`TraceArrivals`).

    ``backfill=False`` is plain FCFS with head-of-line blocking;
    ``backfill=True`` adds conservative (EASY-style) backfill under the
    head's reservation.  ``failure_model`` turns on Weibull node
    failures with requeue (``seed`` drives the draws); jobs are dropped
    after ``max_requeues`` failure kills.  ``power_cap_w`` derates the
    operating point down the DPM ladder exactly like the batch
    scheduler, and the merged trace feeds Green500 L1/L2/L3 unchanged.

    Arrivals may also be PR-4 ``Workload`` adapters (or ``(t,
    workload)`` pairs) — their ``job()`` spec is what gets placed,
    failed and requeued; with ``execute=True`` every *completed*
    workload is additionally executed at its final placement's resolved
    operating point and the results land in ``SimResult.results``
    (uid-keyed) — e.g. per-request serve stats from a
    :class:`repro.serve.replay.ReplayServeWorkload` shard.
    """
    arr = as_arrivals(arrivals)
    if not arr:
        raise ValueError("empty arrival stream: nothing to simulate")
    topology = topology or GREEN500_TOPOLOGY
    sim = _Sim(arr, topology=topology, policy=policy, backfill=backfill,
               op=op, power_cap_w=power_cap_w, failure_model=failure_model,
               seed=seed, max_requeues=max_requeues, penalty=multi_gpu_penalty)
    sim.run()

    schedule = Schedule(sim.placements, _reference_op(sim.placements, sim.op),
                        topology, derated=sim.derated)
    schedule.meta["policy"] = policy
    if network_w is None:
        network_w = topology.network_w
    trace = _merged_trace(schedule, dt_s=dt_s, network_w=float(network_w))
    trace.meta.update(online=True, backfill=backfill,
                      failures=sim.n_failures)
    stats = compute_stats(sim.records, sim.placements, trace, topology,
                          node_failures=sim.n_failures,
                          node_downtime_s=sim.downtime_s,
                          queue_peak=sim.queue_peak,
                          usd_per_kwh=usd_per_kwh)
    results: Dict[int, object] = {}
    if execute:
        # last placement wins for requeued jobs — that attempt completed
        op_by_job = {id(p.job): (p.op or sim.op) for p in sim.placements}
        for a, rec in zip(arr, sim.records):
            if a.workload is None or rec.state != COMPLETED:
                continue
            results[rec.uid] = a.workload.execute(
                op_by_job.get(id(a.job), sim.op))
    return SimResult(schedule, trace, stats, sim.records, results)
