"""Online discrete-event cluster simulator: arrival queues, FCFS +
conservative backfill, Weibull node failures with requeue.

The paper's Green500 story is a snapshot of a *live* machine — L-CSC ran
as an operated cluster where jobs arrive, nodes fail and power varies
over time, not as one closed batch.  This module turns
``cluster.run(jobs, policy)`` into that RAPS-style online operation:

  * an **arrival queue** (trace- or Poisson-driven submit times,
    :mod:`repro.cluster.events`) feeds a wait queue;
  * the **dispatcher** places FCFS, optionally with conservative
    (EASY-style) backfill: a blocked queue head gets a chip reservation
    at its earliest projected start, and later jobs may jump ahead only
    onto chips outside that reservation or if they finish before it —
    so backfill never delays the head;
  * **node failures** are drawn from the shared
    :class:`repro.distributed.fault.WeibullFailureModel` renewal
    process; a failure kills the placements on that node mid-flight
    (the power they burned stays on the trace), requeues the jobs at
    their original queue position, and returns the node after its
    repair time;
  * the event loop only produces **interval boundaries** — placements
    are piecewise-constant between events — so the merged cluster power
    rides the PR-5 vectorized interval engine
    (:func:`repro.cluster.run._merged_trace`) unchanged, and 160 nodes
    × weeks of simulated time stays interactive.

Determinism: everything stochastic (arrival gaps, failure draws) comes
from seeded generators, so a ``(arrivals, seed)`` pair replays exactly.

Oracle property (pinned in ``tests/test_cluster_sim.py``): with every
arrival at t=0, no failures, and placement choices that share the batch
scheduler's tie-breaks (:class:`repro.cluster.scheduler.ChipPool`), the
simulator's merged ``PowerTrace`` is bit-identical to the closed-batch
``cluster.run()`` trace.
"""
from __future__ import annotations

import heapq
import math
from bisect import insort
from dataclasses import dataclass, field
from itertools import count
from typing import Dict, List, Optional, Tuple

from repro.cluster.events import (ARRIVE, FAIL, FINISH, REPAIR, Arrival,
                                  ArrivalsLike, as_arrivals)
from repro.cluster.resilience import AttemptPlan, CheckpointPolicy
from repro.cluster.run import _merged_trace
from repro.cluster.scheduler import (ChipPool, ClusterTopology,
                                     GREEN500_TOPOLOGY, MULTI_GPU_SLOWDOWN,
                                     Placement, Schedule, Scheduler,
                                     _commit_placement, _reference_op,
                                     op_rate_scale, synchronous_rate)
from repro.cluster.stats import (COMPLETED, DEFAULT_USD_PER_KWH, DROPPED,
                                 JobRecord, SimStats, compute_stats)
from repro.distributed.fault import WeibullFailureModel
from repro.power.model import OperatingPoint
from repro.power.trace import PowerTrace


@dataclass
class SimResult:
    """One simulated run: the as-executed schedule (every placement,
    including failure-truncated attempts), the merged cluster power
    trace, the RAPS-style stats block, and the per-job records."""

    schedule: Schedule
    trace: PowerTrace
    stats: SimStats
    records: List[JobRecord] = field(default_factory=list)
    # uid → WorkloadResult for completed Workload-backed arrivals, when
    # simulate(..., execute=True) ran them at their placement's op
    results: Dict[int, object] = field(default_factory=dict)
    # every (node, t_down, t_up) drawn during the run — matches the
    # eager WeibullFailureModel.node_outages(seed, ...) draw-for-draw
    outages: List[Tuple[int, float, float]] = field(default_factory=list)

    @property
    def op(self) -> OperatingPoint:
        return self.schedule.op

    @property
    def makespan(self) -> float:
        return self.schedule.makespan

    def efficiency(self, level: int = 3):
        """Green500 measurement of the merged trace."""
        from repro.power.green500 import measure_efficiency
        return measure_efficiency(self.trace, level)


@dataclass
class _Attempt:
    """One running placement attempt: the committed placement, its job
    record, the attempt ordinal (stale-FINISH guard), and — with a
    :class:`CheckpointPolicy` — its checkpoint schedule."""

    placement: Placement
    rec: JobRecord
    attempt: int
    plan: Optional[AttemptPlan] = None


class _Sim:
    """The event loop's mutable state (one run, then discarded)."""

    def __init__(self, arrivals: List[Arrival], *,
                 topology: ClusterTopology, policy: str, backfill: bool,
                 op: Optional[OperatingPoint], power_cap_w: Optional[float],
                 failure_model: Optional[WeibullFailureModel], seed: int,
                 max_requeues: int, penalty: float,
                 checkpoint: Optional[CheckpointPolicy] = None,
                 elastic: bool = False):
        self.topology = topology
        self.backfill = backfill
        self.failure_model = failure_model
        self.max_requeues = max_requeues
        self.penalty = penalty
        self.checkpoint = checkpoint
        self.elastic = elastic

        sched = Scheduler(topology, policy=policy,
                          power_cap_w=power_cap_w,
                          multi_gpu_penalty=penalty)
        self.sched = sched              # elastic restarts re-resolve here
        self.op_arg = op
        jobs = [a.job for a in arrivals]
        # per-job operating points, resolved up front exactly like the
        # batch scheduler (explicit op → preferred_op → autotuner pick,
        # each derated under the cap); self.op is the batch reference
        self.op, self.derated = sched.resolve_operating_point(op)
        self.job_ops: List[OperatingPoint] = []
        for j in jobs:
            job_op, job_derated = sched.resolve_operating_point(op, job=j)
            self.job_ops.append(job_op)
            self.derated = self.derated or job_derated
        # chip widths validated up front: an unplaceable job fails the
        # submit, exactly like the batch scheduler
        self.need = [sched._chips_needed(j) for j in jobs]
        # the memory floor — elastic restarts may shrink a requeued
        # attempt down to this width when the full pool isn't available
        self.min_need = [max(1, math.ceil(j.mem_gb / topology.gpu_mem_gb))
                         for j in jobs]

        self.pool = ChipPool(topology, policy=policy)
        self.records = [JobRecord(uid, a.job, a.t)
                        for uid, a in enumerate(arrivals)]
        self.queue: List[JobRecord] = []        # (submit_s, uid)-sorted
        self.running: Dict[int, _Attempt] = {}
        self.placements: List[Placement] = []
        self.heap: List[tuple] = []
        self._seq = count()
        self.pending_arrivals = len(arrivals)
        self.queue_peak = 0
        self.n_failures = 0
        self.downtime_s = 0.0
        self.outages: List[Tuple[int, float, float]] = []

        # resilience accounting (all stay 0 without failures)
        self.wasted_chip_s = 0.0
        self.wasted_node_s = 0.0
        self.wasted_energy_j = 0.0
        self.ckpt_count = 0
        self.ckpt_overhead_s = 0.0
        self.ckpt_overhead_chip_s = 0.0
        self.ckpt_energy_j = 0.0
        # absolute (t0, t1, watts) storage-write windows for the trace
        self.ckpt_windows: List[Tuple[float, float, float]] = []
        self._busy_w: Dict[OperatingPoint, float] = {}

        for a, rec in zip(arrivals, self.records):
            self._push(a.t, ARRIVE, ("arrive", rec.uid))
        if failure_model is not None:
            # one SeedSequence-spawned stream per node: node i's uptime
            # sequence depends only on (seed, i), so the eager
            # node_outages(seed, ...) iterator replays these draws
            self.node_rng = failure_model.node_streams(seed,
                                                       topology.n_nodes)
            for node in range(topology.n_nodes):
                self._push(failure_model.draw_uptime_s(self.node_rng[node]),
                           FAIL, ("fail", node))

    # -- plumbing ------------------------------------------------------------

    def _push(self, t: float, prio: int, payload: tuple) -> None:
        heapq.heappush(self.heap, (t, prio, next(self._seq), payload))

    def _enqueue(self, rec: JobRecord) -> None:
        rec.state = "queued"
        # requeued jobs keep their original queue position (submit time)
        insort(self.queue, rec, key=lambda r: (r.submit_s, r.uid))
        self.queue_peak = max(self.queue_peak, len(self.queue))

    # -- resilience helpers --------------------------------------------------

    def _chip_busy_w(self, op: Optional[OperatingPoint]) -> float:
        """Busy watts per chip at ``op`` — the same GPU model figure the
        trace engine prices placements at (:func:`run._op_table`)."""
        op = op or self.op
        w = self._busy_w.get(op)
        if w is None:
            from repro.power.layers import NodeModel
            w = NodeModel().gpus[0].power(op, load=1.0)
            self._busy_w[op] = w
        return w

    def _plan_for(self, rec: JobRecord, pool_chips,
                  op: OperatingPoint, rate: float) -> Optional[AttemptPlan]:
        """This attempt's checkpoint schedule (None without a policy).
        The interval comes from the Daly formula at the placement's node
        span; the remaining-work seconds match ``_commit_placement``'s
        arithmetic exactly so the plan and the placement agree."""
        if self.checkpoint is None:
            return None
        job = rec.job
        scale = 1.0 - rec.completed_fraction
        work = job.work_units if scale == 1.0 else job.work_units * scale
        mtbf = (self.failure_model.mtbf_s
                if self.failure_model is not None else math.inf)
        n_nodes = len({c.node_id for c in pool_chips})
        tau = self.checkpoint.interval_for(job, n_nodes=n_nodes,
                                           mtbf_node_s=mtbf)
        return AttemptPlan(work / rate, tau,
                           self.checkpoint.write_time_s(job))

    def _book_checkpoints(self, p: Placement, plan: AttemptPlan,
                          until_s: Optional[float] = None) -> int:
        """Bill ``plan``'s write windows (clipped at a kill) onto the
        storage accounting and return how many *completed* — only those
        preserve progress, but a truncated write still burned power."""
        wins = plan.checkpoint_windows(until_s)
        if not wins:
            return 0
        g = self.topology.gpus_per_node
        n_nodes = len({c // g for c in p.chips})
        w_node = self.checkpoint.write_w * n_nodes
        full = 0
        for w0, w1 in wins:
            dur = w1 - w0
            if dur >= plan.delta_s - 1e-9:
                full += 1
            self.ckpt_overhead_s += dur
            self.ckpt_overhead_chip_s += dur * len(p.chips)
            self.ckpt_energy_j += dur * w_node
            self.ckpt_windows.append((p.start + w0, p.start + w1, w_node))
        self.ckpt_count += full
        return full

    # -- event handlers ------------------------------------------------------

    def _start(self, rec: JobRecord, pool_chips, t: float) -> None:
        op = self.job_ops[rec.uid]
        if len(pool_chips) != self.need[rec.uid]:
            # elastic restart on a narrower surviving pool: re-resolve
            # the operating point for the attempt's actual width
            op, d = self.sched.resolve_operating_point(self.op_arg,
                                                       job=rec.job)
            self.derated = self.derated or d
        plan = None
        extra = 0.0
        scale = 1.0 - rec.completed_fraction
        if self.checkpoint is not None:
            rate = (synchronous_rate([c.perf_scale for c in pool_chips],
                                     self.penalty)
                    * op_rate_scale(rec.job, op))
            plan = self._plan_for(rec, pool_chips, op, rate)
            extra = plan.overhead_s
        p = _commit_placement(rec.job, pool_chips, self.penalty, now=t,
                              op=op, work_scale=scale, extra_s=extra)
        self.placements.append(p)
        if rec.start_s is None:
            rec.start_s = p.start
        rec.state = "running"
        self.running[rec.uid] = _Attempt(p, rec, rec.requeues, plan)
        self._push(p.end, FINISH, ("finish", rec.uid, rec.requeues))

    def _on_finish(self, uid: int, attempt: int, t: float) -> None:
        a = self.running.get(uid)
        if a is None or a.attempt != attempt:
            return                      # stale: this attempt was killed
        del self.running[uid]
        rec = a.rec
        rec.state = COMPLETED
        rec.end_s = t
        rec.completed_fraction = 1.0
        if a.plan is not None:
            rec.checkpoints += self._book_checkpoints(a.placement, a.plan)

    def _on_fail(self, node: int, t: float) -> None:
        model = self.failure_model
        up_at = t + model.repair_s
        self.pool.fail_node(node, t, up_at)
        self._push(up_at, REPAIR, ("repair", node))
        self.n_failures += 1
        self.downtime_s += model.repair_s
        self.outages.append((node, t, up_at))
        g = self.topology.gpus_per_node
        victims = [uid for uid, a in self.running.items()
                   if any(c // g == node for c in a.placement.chips)]
        for uid in victims:
            a = self.running.pop(uid)
            p, rec = a.placement, a.rec
            elapsed = t - p.start
            frac0 = rec.completed_fraction
            if a.plan is not None:
                preserved_s, wasted_s = a.plan.progress_at(elapsed)
                if a.plan.work_s > 0.0 and preserved_s > 0.0:
                    # this attempt owed (1 - frac0) of the job; rounded
                    # *down* to the last completed checkpoint
                    rec.completed_fraction = min(
                        frac0 + preserved_s / a.plan.work_s * (1.0 - frac0),
                        1.0)
                rec.checkpoints += self._book_checkpoints(p, a.plan,
                                                          until_s=elapsed)
            else:
                wasted_s = min(max(elapsed, 0.0), p.end - p.start)
            self.wasted_chip_s += wasted_s * len(p.chips)
            self.wasted_node_s += wasted_s * len({c // g for c in p.chips})
            self.wasted_energy_j += (wasted_s * len(p.chips)
                                     * self._chip_busy_w(p.op))
            p.end = t                   # power burned up to the kill stays
            self.pool.release(p.chips, t)
            rec.requeues += 1
            if rec.requeues > self.max_requeues:
                rec.state = DROPPED
                rec.end_s = t
            else:
                self._enqueue(rec)

    def _on_repair(self, node: int, t: float) -> None:
        self.pool.repair_node(node, t)
        self._push(t + self.failure_model.draw_uptime_s(self.node_rng[node]),
                   FAIL, ("fail", node))

    # -- dispatcher ----------------------------------------------------------

    def _pick(self, rec: JobRecord, t: float,
              exclude: frozenset = frozenset()):
        """A free pool for ``rec`` — full width first; a requeued job
        may elastically shrink to its memory floor when enabled."""
        cand = self.pool.pick_now(self.need[rec.uid], t, exclude=exclude)
        if (cand is None and self.elastic and rec.requeues > 0
                and self.min_need[rec.uid] < self.need[rec.uid]):
            cand = self.pool.pick_now(self.min_need[rec.uid], t,
                                      exclude=exclude)
        return cand

    def _est_duration_s(self, rec: JobRecord, cand) -> float:
        """Projected attempt duration on ``cand`` (backfill's finish
        estimate) — identical arithmetic to what :meth:`_start` would
        commit, including remaining-fraction and checkpoint overhead."""
        op = self.job_ops[rec.uid]
        rate = (synchronous_rate([c.perf_scale for c in cand], self.penalty)
                * op_rate_scale(rec.job, op))
        plan = self._plan_for(rec, cand, op, rate)
        if plan is not None:
            return plan.duration_s
        return rec.job.work_units / rate

    def _dispatch(self, t: float) -> None:
        # FCFS: start queue heads while they fit right now
        while self.queue:
            rec = self.queue[0]
            cand = self._pick(rec, t)
            if cand is None:
                break
            self.queue.pop(0)
            self._start(rec, cand, t)
        if not (self.backfill and self.queue):
            return
        # conservative (EASY-style) backfill: reserve the blocked head's
        # earliest projected pool; later jobs may start now only on
        # chips outside the reservation, or on reserved chips if they
        # provably finish before the head's start
        head = self.queue[0]
        res_pool, t_res = self.pool.earliest_pool(self.need[head.uid])
        reserved = frozenset(c.chip_id for c in res_pool or ())
        i = 1
        while i < len(self.queue):
            rec = self.queue[i]
            cand = self._pick(rec, t, exclude=reserved)
            if cand is None:
                cand = self._pick(rec, t)
                if cand is not None:
                    if t + self._est_duration_s(rec, cand) > t_res:
                        cand = None
            if cand is None:
                i += 1
            else:
                self.queue.pop(i)
                self._start(rec, cand, t)

    # -- the loop ------------------------------------------------------------

    def run(self) -> None:
        heap = self.heap
        while heap:
            if not (self.queue or self.running or self.pending_arrivals):
                break                   # only failure churn left
            t = heap[0][0]
            batch = []
            while heap and heap[0][0] == t:
                batch.append(heapq.heappop(heap))
            for _, _, _, payload in batch:      # (t, prio, seq)-ordered
                kind = payload[0]
                if kind == "finish":
                    self._on_finish(payload[1], payload[2], t)
                elif kind == "fail":
                    self._on_fail(payload[1], t)
                elif kind == "repair":
                    self._on_repair(payload[1], t)
                else:                            # arrive
                    self.pending_arrivals -= 1
                    self._enqueue(self.records[payload[1]])
            self._dispatch(t)
        bad = [r for r in self.records
               if r.state not in (COMPLETED, DROPPED)]
        if bad:
            raise RuntimeError(
                f"simulation ended with {len(bad)} non-terminal jobs "
                f"(first: {bad[0].job.name!r} in state {bad[0].state!r}) — "
                f"event-loop invariant broken")


def _inject_storage(trace: PowerTrace,
                    windows: List[Tuple[float, float, float]]) -> None:
    """Add the checkpoint-write ``storage`` component to the merged
    trace: a step function that is ``watts`` inside each half-open
    ``[t0, t1)`` write window (overlapping windows sum).  Samples use
    the interval engine's convention — sample ``i`` covers
    ``[t[i], t[i+1])``, and the final boundary reads its left limit —
    so Green500 L1/L2/L3 integrate checkpoint energy honestly."""
    import numpy as np
    span = float(trace.t[-1])
    ts = np.minimum(np.asarray(trace.t, dtype=float), span - 1e-9)
    t_ev = np.array([w[0] for w in windows] + [w[1] for w in windows])
    dw = np.array([w[2] for w in windows] + [-w[2] for w in windows])
    order = np.argsort(t_ev, kind="stable")
    t_ev = t_ev[order]
    level = np.cumsum(dw[order])
    idx = np.searchsorted(t_ev, ts, side="right") - 1
    series = np.where(idx >= 0, level[np.clip(idx, 0, None)], 0.0)
    trace.components["storage"] = series


def simulate(arrivals: ArrivalsLike, *,
             topology: Optional[ClusterTopology] = None,
             policy: str = "packed",
             backfill: bool = True,
             op: Optional[OperatingPoint] = None,
             power_cap_w: Optional[float] = None,
             failure_model: Optional[WeibullFailureModel] = None,
             seed: int = 0,
             max_requeues: int = 3,
             multi_gpu_penalty: float = MULTI_GPU_SLOWDOWN,
             dt_s: float = 5.0,
             network_w: Optional[float] = None,
             usd_per_kwh: float = DEFAULT_USD_PER_KWH,
             checkpoint: Optional[CheckpointPolicy] = None,
             elastic: bool = False,
             execute: bool = False) -> SimResult:
    """Run the online simulator and return schedule + trace + stats.

    ``arrivals`` is anything :func:`repro.cluster.events.as_arrivals`
    accepts: a plain job list (all submitted at t=0 — the batch-oracle
    case), ``(t, job)`` pairs, or an arrival process
    (:class:`PoissonArrivals`, :class:`TraceArrivals`).

    ``backfill=False`` is plain FCFS with head-of-line blocking;
    ``backfill=True`` adds conservative (EASY-style) backfill under the
    head's reservation.  ``failure_model`` turns on Weibull node
    failures with requeue (``seed`` drives the draws); jobs are dropped
    after ``max_requeues`` failure kills.  ``power_cap_w`` derates the
    operating point down the DPM ladder exactly like the batch
    scheduler, and the merged trace feeds Green500 L1/L2/L3 unchanged.

    Arrivals may also be PR-4 ``Workload`` adapters (or ``(t,
    workload)`` pairs) — their ``job()`` spec is what gets placed,
    failed and requeued; with ``execute=True`` every *completed*
    workload is additionally executed at its final placement's resolved
    operating point and the results land in ``SimResult.results``
    (uid-keyed) — e.g. per-request serve stats from a
    :class:`repro.serve.replay.ReplayServeWorkload` shard.

    ``checkpoint`` (a :class:`repro.cluster.resilience.CheckpointPolicy`)
    makes every attempt pause for Daly-interval (or fixed-interval)
    checkpoint writes: killed attempts requeue with
    ``completed_fraction`` rounded down to the last completed write
    instead of zero, write energy lands on the trace as a ``storage``
    component, and wasted/checkpoint totals surface in ``SimStats``.
    ``elastic=True`` lets a requeued job restart on a narrower surviving
    pool (down to its memory floor) at a re-resolved operating point
    rather than waiting for its full width.
    """
    arr = as_arrivals(arrivals)
    if not arr:
        raise ValueError("empty arrival stream: nothing to simulate")
    topology = topology or GREEN500_TOPOLOGY
    sim = _Sim(arr, topology=topology, policy=policy, backfill=backfill,
               op=op, power_cap_w=power_cap_w, failure_model=failure_model,
               seed=seed, max_requeues=max_requeues, penalty=multi_gpu_penalty,
               checkpoint=checkpoint, elastic=elastic)
    sim.run()

    schedule = Schedule(sim.placements, _reference_op(sim.placements, sim.op),
                        topology, derated=sim.derated)
    schedule.meta["policy"] = policy
    if network_w is None:
        network_w = topology.network_w
    trace = _merged_trace(schedule, dt_s=dt_s, network_w=float(network_w))
    trace.meta.update(online=True, backfill=backfill,
                      failures=sim.n_failures)
    if sim.ckpt_windows:
        # only when ≥1 write actually happened — the no-failure oracle
        # (MTBF=∞ ⇒ zero checkpoints) keeps the batch component set
        _inject_storage(trace, sim.ckpt_windows)
    stats = compute_stats(sim.records, sim.placements, trace, topology,
                          node_failures=sim.n_failures,
                          node_downtime_s=sim.downtime_s,
                          queue_peak=sim.queue_peak,
                          usd_per_kwh=usd_per_kwh,
                          wasted_chip_s=sim.wasted_chip_s,
                          wasted_node_s=sim.wasted_node_s,
                          wasted_energy_j=sim.wasted_energy_j,
                          checkpoints=sim.ckpt_count,
                          checkpoint_overhead_s=sim.ckpt_overhead_s,
                          checkpoint_overhead_chip_s=sim.ckpt_overhead_chip_s,
                          checkpoint_energy_j=sim.ckpt_energy_j)
    results: Dict[int, object] = {}
    if execute:
        # last placement wins for requeued jobs — that attempt completed
        op_by_job = {id(p.job): (p.op or sim.op) for p in sim.placements}
        for a, rec in zip(arr, sim.records):
            if a.workload is None or rec.state != COMPLETED:
                continue
            results[rec.uid] = a.workload.execute(
                op_by_job.get(id(a.job), sim.op))
    return SimResult(schedule, trace, stats, sim.records, results,
                     outages=sim.outages)
