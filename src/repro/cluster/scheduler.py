"""Power-aware cluster scheduler (paper §1–2), RAPS-style.

Absorbs the pre-power-bus job model that lived in
``repro.core.energy.scheduler`` (shimmed there now) and grows it into a
topology-aware scheduler the Workload API feeds:

  * "run most lattices on a single GPU; use all four GPUs of a node for
    independent lattices" — the ``packed`` policy prefers chip-local
    placement and only shards a job when it exceeds single-chip memory,
    keeping the shards on as few nodes as possible and charging the
    published ~20% multi-GPU penalty;
  * "multi-node HPL distributes work evenly, so the slowest node dictates
    performance" — sharded jobs advance at synchronous-step pace,
    ``n_chips × min(perf_scale)``, not the optimistic sum;
  * a cluster power cap is enforced by derating the operating point down
    the S9150's DPM ladder (the autotuner's discrete frequency states)
    until the full-load cluster draw fits — the paper's own mechanism
    for staying inside the facility budget.

The legacy straggler-mitigation helpers (frequency flooring, pod
dropping) ride along unchanged.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.lcsc_lqcd import (GREEN500_SWITCH_POWER_W,
                                     MULTI_GPU_SLOWDOWN)
from repro.power.model import OperatingPoint


class SchedulingError(ValueError):
    """A job batch cannot be placed on the topology at all."""


class PowerCapError(SchedulingError):
    """No supported operating point fits the requested power cap."""


@dataclass(frozen=True)
class Job:
    """One schedulable unit of work — the normalized spec every
    :class:`repro.cluster.workload.Workload` adapter emits.

    ``work_units`` is relative wall-clock on one reference chip at the
    reference operating point; ``preferred_op`` lets a workload ask for
    its own operating point (the scheduler may still derate it to meet a
    cluster power cap).  ``state_bytes`` is the checkpointable state a
    restart needs (``Workload.state_bytes()`` fills it in); ``None``
    falls back to the resident working set — see
    :func:`repro.cluster.resilience.job_state_bytes`."""

    name: str
    mem_gb: float
    work_units: float
    shardable: bool = True
    preferred_op: Optional[OperatingPoint] = None
    kind: str = "generic"
    state_bytes: Optional[float] = None


@dataclass
class Chip:
    chip_id: int
    mem_gb: float
    perf_scale: float = 1.0      # chip-to-chip variation
    busy_until: float = 0.0
    node_id: int = 0


@dataclass
class Placement:
    job: Job
    chips: List[int]
    start: float
    end: float
    sharded: bool
    nodes: Tuple[int, ...] = ()
    rate_per_chip: float = 1.0   # effective work rate per chip (ref = 1.0)
    op: Optional[OperatingPoint] = None   # per-job point; None = schedule ref


@dataclass(frozen=True)
class ClusterTopology:
    """The machine the scheduler places onto: L-CSC is 160 nodes of
    4×S9150 (16 GB each); the Green500 run used a 56-node subset.
    ``network_w`` is the separately-metered switch draw (paper §3:
    257 W), charged at the wall whatever the nodes do."""

    n_nodes: int = 160
    gpus_per_node: int = 4
    gpu_mem_gb: float = 16.0
    perf_scales: Optional[Tuple[float, ...]] = None   # per chip, else 1.0
    network_w: float = GREEN500_SWITCH_POWER_W

    @property
    def n_chips(self) -> int:
        return self.n_nodes * self.gpus_per_node

    @property
    def node_mem_gb(self) -> float:
        return self.gpus_per_node * self.gpu_mem_gb

    def chips(self) -> List[Chip]:
        scales = self.perf_scales or (1.0,) * self.n_chips
        if len(scales) != self.n_chips:
            raise ValueError(f"need {self.n_chips} perf scales, got "
                             f"{len(scales)}")
        return [Chip(i, self.gpu_mem_gb, float(scales[i]),
                     node_id=i // self.gpus_per_node)
                for i in range(self.n_chips)]


GREEN500_TOPOLOGY = ClusterTopology(n_nodes=56)
L_CSC_TOPOLOGY = ClusterTopology(n_nodes=160)


@dataclass
class Schedule:
    """The scheduler's output: placements plus the operating point the
    batch actually runs at (possibly derated to meet the power cap)."""

    placements: List[Placement]
    op: OperatingPoint
    topology: ClusterTopology
    derated: bool = False
    meta: Dict[str, float] = field(default_factory=dict)

    @property
    def makespan(self) -> float:
        return max((p.end for p in self.placements), default=0.0)

    def active_chips(self, t: float) -> Dict[int, Placement]:
        """chip_id → placement running on it at time ``t``."""
        out: Dict[int, Placement] = {}
        for p in self.placements:
            if p.start <= t < p.end:
                for c in p.chips:
                    out[c] = p
        return out


def synchronous_rate(perf_scales: Sequence[float],
                     penalty: float = MULTI_GPU_SLOWDOWN) -> float:
    """Aggregate work rate of a sharded job: every synchronous step is
    paced by the slowest shard, so the pool delivers
    ``n × min(perf) × (1 − penalty)`` — not the sum of its chips."""
    scales = list(perf_scales)
    if len(scales) == 1:
        return scales[0]
    return len(scales) * min(scales) * (1.0 - penalty)


# Workload kinds whose runtime the paper measures as clock-insensitive
# (LQCD: <1.5% across the DPM ladder — memory-bound); everything else
# (HPL, generic compute) scales with the engine's HPL perf curve.
# serve_replay: decode-dominated request replay (repro.serve) — same
# bandwidth-bound physics as serve.
MEMORY_BOUND_KINDS = frozenset({"lqcd", "serve", "serve_replay",
                                "synthetic"})

_RATE_SCALE_CACHE: Dict[OperatingPoint, float] = {}


def op_rate_scale(job: Job, op: Optional[OperatingPoint]) -> float:
    """Work-rate multiplier for running ``job`` at ``op`` instead of the
    Green500 reference point ``Job.work_units`` is calibrated against.

    Memory-bound kinds run at 1.0 regardless of clock (the paper's LQCD
    thesis); compute-bound kinds scale by the engine's node-HPL perf at
    ``op`` over the same figure at the reference — so a 900 MHz HPL
    placement finishes in the published clock-for-perf ratio.  Exactly
    1.0 at the reference point itself, keeping pre-heterogeneous
    schedules bit-identical."""
    ref = OperatingPoint.green500()
    if op is None or op == ref or job.kind in MEMORY_BOUND_KINDS:
        return 1.0
    scale = _RATE_SCALE_CACHE.get(op)
    if scale is None:
        from repro.power.engine import node_hpl_gflops
        scale = node_hpl_gflops(op) / node_hpl_gflops(ref)
        _RATE_SCALE_CACHE[op] = scale
    return scale


def _commit_placement(job: Job, pool: List[Chip],
                      penalty: float, *,
                      now: Optional[float] = None,
                      op: Optional[OperatingPoint] = None,
                      work_scale: float = 1.0,
                      extra_s: float = 0.0) -> Placement:
    """Book ``job`` onto ``pool``: earliest common start, synchronous-step
    pacing, busy_until advanced on every chip.  The one placement
    definition the Scheduler, the online simulator, and the legacy flat
    API all use.  ``now`` clamps the start to the current simulation
    time (an online dispatch can't start in the past); the batch path
    leaves it unset.  ``op`` is the job's resolved operating point: it
    both rides on the placement (the trace engine prices each interval
    at its placement's point) and paces the work via
    :func:`op_rate_scale`.

    The resilience layer books *partial* attempts: ``work_scale`` is
    the fraction of ``work_units`` still owed after checkpoint-restored
    progress, and ``extra_s`` appends checkpoint-write pause seconds to
    the duration.  ``rate_per_chip`` then reflects the *effective*
    delivered rate over the whole attempt (compute work / total wall),
    so the trace engine's FLOPS stay honest during write pauses.  The
    defaults leave the arithmetic bit-identical to the pre-resilience
    path."""
    start = max(c.busy_until for c in pool)
    if now is not None and now > start:
        start = now
    rate = (synchronous_rate([c.perf_scale for c in pool], penalty)
            * op_rate_scale(job, op))
    work = job.work_units if work_scale == 1.0 \
        else job.work_units * work_scale
    dur = work / rate
    rate_chip = rate / len(pool)
    if extra_s > 0.0:
        dur += extra_s
        rate_chip = (work / dur) / len(pool)
    for c in pool:
        c.busy_until = start + dur
    return Placement(job, [c.chip_id for c in pool], start, start + dur,
                     len(pool) > 1,
                     nodes=tuple(sorted({c.node_id for c in pool})),
                     rate_per_chip=rate_chip, op=op)


def _reference_op(placements: Sequence[Placement],
                  fallback: OperatingPoint) -> OperatingPoint:
    """A schedule's single reference point: the unique per-placement op
    when the batch is homogeneous (so ``Schedule.op`` stays exact for
    single-point batches), else ``fallback`` — heterogeneous batches
    keep their per-placement ops and the reference only anchors idle
    power, fan and metadata."""
    ops = {p.op for p in placements if p.op is not None}
    if len(ops) == 1:
        return next(iter(ops))
    return fallback


class Scheduler:
    """Greedy list scheduler over a :class:`ClusterTopology`.

    Policies:
      * ``packed`` — chip-local packing: single-chip placement unless the
        job's memory demands sharding; shards stay on the fewest nodes.
      * ``round_robin`` — the naive baseline: every shardable job is
        spread over one node's worth of GPUs, striped round-robin across
        nodes, always paying the multi-GPU penalty.
    """

    POLICIES = ("packed", "round_robin")

    def __init__(self, topology: Optional[ClusterTopology] = None, *,
                 policy: str = "packed",
                 multi_gpu_penalty: float = MULTI_GPU_SLOWDOWN,
                 power_cap_w: Optional[float] = None):
        if policy not in self.POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"choose from {self.POLICIES}")
        self.topology = topology or GREEN500_TOPOLOGY
        self.policy = policy
        self.penalty = multi_gpu_penalty
        self.power_cap_w = power_cap_w
        self._auto_op: Optional[OperatingPoint] = None
        self._derate_cache: Dict[OperatingPoint,
                                 Tuple[OperatingPoint, bool]] = {}

    # -- power cap ---------------------------------------------------------

    def resolve_operating_point(self, op: Optional[OperatingPoint] = None,
                                job: Optional[Job] = None,
                                ) -> Tuple[OperatingPoint, bool]:
        """Resolve the operating point one job (or the batch reference,
        when ``job`` is None) actually runs at.  Resolution order:
        explicit ``op`` override → the job's ``preferred_op`` → the
        autotuner cost model's recommendation (cached; falls back to the
        Green500 point if the autotuner is unavailable) — then derated
        down the S9150 DPM ladder until the full-load cluster draw fits
        the power cap.  Returns ``(op, derated)``.  Every job's
        preference is honored individually: nothing is coerced onto a
        batch-wide point any more."""
        if op is None and job is not None and job.preferred_op is not None:
            op = job.preferred_op
        if op is None:
            op = self._recommended_op()
        if self.power_cap_w is None:
            return op, False
        return self._derate(op)

    def _recommended_op(self) -> OperatingPoint:
        """The autotuner cost model's pick for jobs with no preference —
        the coordinate-descent search over the analytic node model
        (which rediscovers the paper's Green500 point)."""
        if self._auto_op is None:
            try:
                from repro.autotune.measure import recommended_operating_point
                self._auto_op = recommended_operating_point()
            except Exception:
                self._auto_op = OperatingPoint.green500()
        return self._auto_op

    def _derate(self, op: OperatingPoint) -> Tuple[OperatingPoint, bool]:
        """Walk ``op`` down the S9150 DPM ladder (the autotuner's
        discrete frequency states) until the full-load cluster draw fits
        the cap.  Conservative per-job check: the whole cluster at this
        job's point must fit, so any mix of admitted points also fits."""
        cached = self._derate_cache.get(op)
        if cached is not None:
            return cached
        from repro.autotune.space import S9150_DPM_STATES_MHZ
        # the requested clock itself, then every DPM state below it (an
        # op already under the lowest state has nowhere left to derate)
        ladder = sorted({op.f_mhz}
                        | {f for f in S9150_DPM_STATES_MHZ if f < op.f_mhz},
                        reverse=True)
        for f in ladder:
            cand = op.replace(f_mhz=float(f))
            if self._full_load_power(cand) <= self.power_cap_w:
                self._derate_cache[op] = (cand, f != op.f_mhz)
                return cand, f != op.f_mhz
        floor = self._full_load_power(op.replace(f_mhz=float(ladder[-1])))
        raise PowerCapError(
            f"power cap {self.power_cap_w:.0f} W infeasible: the lowest "
            f"reachable clock ({ladder[-1]:.0f} MHz) still draws "
            f"{floor:.0f} W at full load on {self.topology.n_nodes} nodes")

    def _full_load_power(self, op: OperatingPoint) -> float:
        """Worst-case wall draw the cap is checked against: every node at
        full load, plus the switches (they count at the wall too)."""
        from repro.power.layers import NodeModel
        return NodeModel().power(op) * self.topology.n_nodes \
            + self.topology.network_w

    # -- placement ---------------------------------------------------------

    def schedule(self, jobs: Sequence[Job], *,
                 op: Optional[OperatingPoint] = None) -> Schedule:
        """Place ``jobs`` (largest first), resolving each job's operating
        point individually (see :meth:`resolve_operating_point`).  An
        explicit ``op`` overrides every preference — the pre-existing
        "force the batch to one point" knob.  ``Schedule.op`` is the
        single point when the batch is homogeneous, else the resolved
        batch reference; per-placement points ride on
        ``Placement.op``."""
        ref, derated = self.resolve_operating_point(op)
        chips = self.topology.chips()
        placements: List[Placement] = []
        for job in sorted(jobs, key=lambda j: -j.work_units):
            job_op, job_derated = self.resolve_operating_point(op, job=job)
            derated = derated or job_derated
            placements.append(self._place(job, chips, op=job_op))
        return Schedule(placements, _reference_op(placements, ref),
                        self.topology, derated=derated)

    def _chips_needed(self, job: Job) -> int:
        need = max(1, math.ceil(job.mem_gb / self.topology.gpu_mem_gb))
        if need > 1 and not job.shardable:
            raise SchedulingError(
                f"job {job.name!r} needs {job.mem_gb:.1f} GB but is not "
                f"shardable (chip memory {self.topology.gpu_mem_gb:.0f} GB)")
        if need > self.topology.gpus_per_node:
            raise SchedulingError(
                f"job {job.name!r} needs {job.mem_gb:.1f} GB — more than a "
                f"node's total GPU memory "
                f"({self.topology.node_mem_gb:.0f} GB); cross-node lattice "
                f"sharding is not supported (paper: lattices stay within "
                f"one node)")
        if self.policy == "round_robin" and job.shardable:
            # the naive baseline shards everything node-wide
            need = self.topology.gpus_per_node
        return need

    def _pick_pool(self, need: int, chips: List[Chip]) -> List[Chip]:
        if need == 1:
            return [min(chips, key=lambda c: (c.busy_until, c.chip_id))]
        if self.policy == "packed":
            # chip-local: the node whose ``need`` earliest-free chips free
            # up soonest keeps the shards together
            best: Optional[List[Chip]] = None
            best_t = math.inf
            by_node: Dict[int, List[Chip]] = {}
            for c in chips:
                by_node.setdefault(c.node_id, []).append(c)
            for node_chips in by_node.values():
                if len(node_chips) < need:
                    continue
                pool = sorted(node_chips,
                              key=lambda c: (c.busy_until, c.chip_id))[:need]
                t = max(c.busy_until for c in pool)
                if t < best_t:
                    best, best_t = pool, t
            assert best is not None   # need ≤ gpus_per_node is pre-checked
            return best
        # round_robin: stripe across nodes by raw chip order, earliest-free
        return sorted(chips, key=lambda c: (c.busy_until, c.chip_id))[:need]

    def _place(self, job: Job, chips: List[Chip], *,
               op: Optional[OperatingPoint] = None) -> Placement:
        pool = self._pick_pool(self._chips_needed(job), chips)
        return _commit_placement(job, pool, self.penalty, op=op)


# ---------------------------------------------------------------------------
# Online chip pool (the discrete-event simulator's state)
# ---------------------------------------------------------------------------


class ChipPool:
    """Online chip-state tracker for the discrete-event simulator
    (:mod:`repro.cluster.sim`).

    The batch :class:`Scheduler` books a whole batch onto
    ``Chip.busy_until`` up front; this pool exposes the *same* chips —
    same selection keys, same tie-breaks as :meth:`Scheduler._pick_pool`
    — to an event loop that acquires chips at dispatch time and releases
    them again on finish/failure/repair events.  A chip's ``busy_until``
    doubles as its "free since" timestamp once idle, so
    earliest-freed-first selection orders by ``(busy_until, chip_id)``
    exactly like the batch scheduler: an all-arrivals-at-t=0, no-failure
    online run reproduces the batch booking bit-for-bit (the oracle
    property ``tests/test_cluster_sim.py`` pins down).
    """

    def __init__(self, topology: ClusterTopology, *, policy: str = "packed"):
        if policy not in Scheduler.POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"choose from {Scheduler.POLICIES}")
        self.topology = topology
        self.policy = policy
        self.chips = topology.chips()
        self._up = [True] * topology.n_nodes
        self._down_until = [0.0] * topology.n_nodes

    # -- queries -------------------------------------------------------------

    def is_up(self, node_id: int) -> bool:
        return self._up[node_id]

    def node_chips(self, node_id: int) -> List[Chip]:
        g = self.topology.gpus_per_node
        return self.chips[node_id * g:(node_id + 1) * g]

    def _select(self, need: int, chips: List[Chip],
                key) -> Optional[List[Chip]]:
        """The one pool-selection definition (mirrors the batch
        scheduler): single chip → global ``min(key)``; packed shards →
        the node whose ``need`` best chips minimize the max key time
        (nodes visited in id order, strict improvement — first wins
        ties); round_robin → the ``need`` globally-best chips."""
        if need == 1:
            if not chips:
                return None
            return [min(chips, key=key)]
        if self.policy == "packed":
            by_node: Dict[int, List[Chip]] = {}
            for c in chips:
                by_node.setdefault(c.node_id, []).append(c)
            best: Optional[List[Chip]] = None
            best_t = math.inf
            for node_id in sorted(by_node):
                node_chips = by_node[node_id]
                if len(node_chips) < need:
                    continue
                pool = sorted(node_chips, key=key)[:need]
                t = max(key(c)[0] for c in pool)
                if t < best_t:
                    best, best_t = pool, t
            return best
        # round_robin: stripe across nodes by global key order
        if len(chips) < need:
            return None
        return sorted(chips, key=key)[:need]

    def pick_now(self, need: int, t: float,
                 exclude: frozenset = frozenset()) -> Optional[List[Chip]]:
        """A pool of ``need`` chips that are free *right now* (idle, on
        an up node, not in ``exclude``), or None.  ``exclude`` carries a
        blocked queue head's reserved chips during backfill."""
        free = [c for c in self.chips
                if self._up[c.node_id] and c.busy_until <= t
                and c.chip_id not in exclude]
        return self._select(need, free,
                            key=lambda c: (c.busy_until, c.chip_id))

    def earliest_pool(self, need: int,
                      ) -> Tuple[Optional[List[Chip]], float]:
        """Projected reservation for a blocked queue head: the pool of
        ``need`` chips that frees up earliest given current bookings and
        node outages (a down node's chips come back at its repair time).
        Returns ``(chips, t_free)``."""
        def avail(c: Chip) -> float:
            t = c.busy_until
            if not self._up[c.node_id]:
                t = max(t, self._down_until[c.node_id])
            return t

        pool = self._select(need, self.chips,
                            key=lambda c: (avail(c), c.chip_id))
        if pool is None:
            return None, math.inf
        return pool, max(avail(c) for c in pool)

    # -- release hooks (the event loop's state transitions) ------------------

    def release(self, chip_ids: Sequence[int], t: float) -> None:
        """Roll a killed placement's bookings back to ``t`` (node
        failure): the chips become free-since-``t`` immediately."""
        for cid in chip_ids:
            self.chips[cid].busy_until = t

    def fail_node(self, node_id: int, t: float, up_at: float) -> None:
        """Take a node out of service until ``up_at``.  The caller kills
        and :meth:`release`\\ s any placement touching its chips."""
        self._up[node_id] = False
        self._down_until[node_id] = up_at

    def repair_node(self, node_id: int, t: float) -> None:
        """Return a node to service: its chips read as free-since-``t``
        (they could not have been booked while down)."""
        self._up[node_id] = True
        self._down_until[node_id] = 0.0
        for c in self.node_chips(node_id):
            if c.busy_until < t:
                c.busy_until = t


# ---------------------------------------------------------------------------
# Legacy flat API (the pre-Workload call sites; core/energy/scheduler.py
# re-exports these)
# ---------------------------------------------------------------------------


def schedule_throughput(jobs: Sequence[Job], chips: List[Chip],
                        *, multi_gpu_penalty: float = MULTI_GPU_SLOWDOWN,
                        ) -> List[Placement]:
    """Greedy list scheduler over an explicit chip list: single-chip
    placement unless the job's memory demands sharding; sharded jobs take
    ceil(mem/chip_mem) chips at synchronous-step pace with the published
    ~20% penalty."""
    placements: List[Placement] = []
    for job in sorted(jobs, key=lambda j: -j.work_units):
        need = max(1, math.ceil(job.mem_gb / chips[0].mem_gb))
        pool = sorted(chips, key=lambda c: (c.busy_until, c.chip_id))[:need]
        placements.append(_commit_placement(job, pool, multi_gpu_penalty))
    return placements


def makespan(placements: Sequence[Placement]) -> float:
    return max(p.end for p in placements) if placements else 0.0


# ---------------------------------------------------------------------------
# Synchronous-step straggler model
# ---------------------------------------------------------------------------

def straggler_step_time(base_step_s: float, perf_scales: Sequence[float],
                        ) -> float:
    """Synchronous SPMD: the slowest participant gates every step."""
    return base_step_s / min(perf_scales)


def expected_slowdown(n_chips: int, sigma: float,
                      rng: Optional[np.random.Generator] = None,
                      trials: int = 256) -> float:
    """E[min perf] over a population with relative spread sigma — how much
    a 1000+ chip job loses to manufacturing spread without mitigation."""
    rng = rng or np.random.default_rng(0)
    mins = rng.normal(1.0, sigma, size=(trials, n_chips)).min(axis=1)
    return float(1.0 / np.clip(mins, 1e-3, None).mean())


def frequency_floor_mitigation(perf_scales: Sequence[float],
                               ) -> Tuple[float, float]:
    """The paper's fix: clock every chip at the slowest chip's sustainable
    rate → no oscillation, flat profile.  Returns (uniform scale, gain vs
    unmitigated oscillating population)."""
    floor = min(perf_scales)
    # oscillating chips lose an extra 8% (throttle.OSC_PENALTY)
    unmitigated = min(p * (1 - 0.08 * (p < 1.0)) for p in perf_scales)
    return floor, floor / unmitigated - 1.0


def drop_slowest_pod(pod_perf: Dict[str, float], threshold: float = 0.93,
                     ) -> Tuple[List[str], float]:
    """Elastic mitigation: drop a pod whose perf is below threshold x median
    if the remaining aggregate throughput improves (synchronous scaling:
    throughput = n_pods x min(perf))."""
    names = list(pod_perf)
    perfs = np.array([pod_perf[n] for n in names])
    full = len(perfs) * perfs.min()
    best_names, best = names, full
    med = float(np.median(perfs))
    for i, n in enumerate(names):
        if perfs[i] < threshold * med:
            rest = np.delete(perfs, i)
            alt = len(rest) * rest.min()
            if alt > best:
                best, best_names = alt, [m for j, m in enumerate(names)
                                         if j != i]
    return best_names, best / full - 1.0


def with_perf_floor(topology: ClusterTopology) -> ClusterTopology:
    """Frequency-floor mitigation applied to a heterogeneous topology:
    every chip paced at the slowest chip's rate (flat-774-style)."""
    if topology.perf_scales is None:
        return topology
    floor = min(topology.perf_scales)
    return replace(topology, perf_scales=(floor,) * topology.n_chips)
