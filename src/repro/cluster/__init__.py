"""Unified Workload API + power-aware cluster scheduler.

The layer above the power engine: every workload entry point in the repo
(HPL, LQCD solves, train/serve drivers, synthetic loads) is normalized
behind one :class:`Workload` protocol, placed by a RAPS-style scheduler
onto the 160-node / 4-GPU L-CSC topology, and merged into a single
cluster-level :class:`repro.power.PowerTrace`:

  :mod:`repro.cluster.workload`   Workload protocol, registry, adapters
  :mod:`repro.cluster.scheduler`  Job/Chip/Placement, topologies,
                                  policies, power-cap enforcement,
                                  straggler models
  :mod:`repro.cluster.run`        ``run(jobs, policy) → ClusterRunResult``

Quick use::

    from repro.cluster import HPLWorkload, LQCDSolveWorkload, run
    res = run([HPLWorkload(), LQCDSolveWorkload()], policy="packed")
    res.trace.avg_power()      # merged cluster watts through the PR-3 bus
    res.efficiency(3)          # Green500 L3 over the merged trace

The pre-power-bus job model (``repro.core.energy.scheduler``) is a
deprecated shim over :mod:`repro.cluster.scheduler`.
"""
from repro.cluster.scheduler import (  # noqa: F401
    GREEN500_TOPOLOGY,
    L_CSC_TOPOLOGY,
    Chip,
    ClusterTopology,
    Job,
    Placement,
    PowerCapError,
    Schedule,
    Scheduler,
    SchedulingError,
    drop_slowest_pod,
    expected_slowdown,
    frequency_floor_mitigation,
    makespan,
    schedule_throughput,
    straggler_step_time,
    synchronous_rate,
    with_perf_floor,
)
from repro.cluster.workload import (  # noqa: F401
    WORKLOAD_REGISTRY,
    HPLWorkload,
    LQCDSolveWorkload,
    ServeWorkload,
    SyntheticWorkload,
    TrainWorkload,
    Workload,
    WorkloadResult,
    list_workloads,
    make_workload,
    register_workload,
)
from repro.cluster.run import ClusterRunResult, run  # noqa: F401
