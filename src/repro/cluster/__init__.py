"""Unified Workload API + power-aware cluster scheduler.

The layer above the power engine: every workload entry point in the repo
(HPL, LQCD solves, train/serve drivers, synthetic loads) is normalized
behind one :class:`Workload` protocol, placed by a RAPS-style scheduler
onto the 160-node / 4-GPU L-CSC topology, and merged into a single
cluster-level :class:`repro.power.PowerTrace`:

  :mod:`repro.cluster.workload`   Workload protocol, registry, adapters
  :mod:`repro.cluster.scheduler`  Job/Chip/Placement, topologies,
                                  policies, power-cap enforcement,
                                  straggler models, the online ChipPool
  :mod:`repro.cluster.run`        ``run(jobs, policy) → ClusterRunResult``
  :mod:`repro.cluster.sim`        online discrete-event simulator
                                  (arrival queues, backfill, failures)
  :mod:`repro.cluster.events`     arrival sources (Poisson / trace)
  :mod:`repro.cluster.resilience` Daly-interval CheckpointPolicy,
                                  per-attempt checkpoint schedules
  :mod:`repro.cluster.stats`      RAPS-style end-of-run report

Quick use::

    from repro.cluster import HPLWorkload, LQCDSolveWorkload, run
    res = run([HPLWorkload(), LQCDSolveWorkload()], policy="packed")
    res.trace.avg_power()      # merged cluster watts through the PR-3 bus
    res.efficiency(3)          # Green500 L3 over the merged trace

Online operation (open queue, failures)::

    from repro.cluster import Job, PoissonArrivals, simulate
    from repro.distributed.fault import WeibullFailureModel
    jobs = [Job(f"lat{i}", 13.0, 3600.0) for i in range(500)]
    res = simulate(PoissonArrivals(jobs, rate_per_s=0.05, seed=1),
                   failure_model=WeibullFailureModel(mtbf_s=3.6e6))
    print(res.stats.summary())  # utilization, waits, energy, $ cost

The pre-power-bus job model (``repro.core.energy.scheduler``) is a
deprecated shim over :mod:`repro.cluster.scheduler`.
"""
from repro.cluster.scheduler import (  # noqa: F401
    GREEN500_TOPOLOGY,
    L_CSC_TOPOLOGY,
    Chip,
    ChipPool,
    ClusterTopology,
    Job,
    Placement,
    PowerCapError,
    Schedule,
    Scheduler,
    SchedulingError,
    drop_slowest_pod,
    expected_slowdown,
    frequency_floor_mitigation,
    makespan,
    schedule_throughput,
    straggler_step_time,
    synchronous_rate,
    with_perf_floor,
)
from repro.cluster.workload import (  # noqa: F401
    WORKLOAD_REGISTRY,
    HPLWorkload,
    LQCDSolveWorkload,
    ServeWorkload,
    SyntheticWorkload,
    TrainWorkload,
    Workload,
    WorkloadResult,
    list_workloads,
    make_workload,
    register_workload,
)
from repro.cluster.run import ClusterRunResult, run  # noqa: F401
from repro.cluster.events import (  # noqa: F401
    Arrival,
    PoissonArrivals,
    TraceArrivals,
    as_arrivals,
    batch_arrivals,
)
from repro.cluster.resilience import (  # noqa: F401
    AttemptPlan,
    CheckpointPolicy,
    daly_interval_s,
    job_state_bytes,
)
from repro.cluster.stats import JobRecord, SimStats  # noqa: F401
from repro.cluster.sim import SimResult, simulate  # noqa: F401
