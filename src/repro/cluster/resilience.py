"""Checkpoint/restart resilience: Daly-interval scheduling + wasted-work
accounting for the online cluster simulator.

L-CSC is a commodity cluster, so node failure is an operating
assumption — and because the whole project optimizes *energy to
solution*, every joule burned on a killed attempt that restarts from
zero is a direct MFLOPS/W hit.  This module gives the discrete-event
simulator (:mod:`repro.cluster.sim`) the policy layer that bounds that
waste:

  * :class:`CheckpointPolicy` derives the Young/Daly first-order
    optimal checkpoint interval ``τ* = √(2·δ·MTBF)`` from the shared
    :class:`repro.distributed.fault.WeibullFailureModel` and a
    per-workload checkpoint cost model — state bytes from the
    ``Workload`` protocol's ``state_bytes()`` surface (or the job's
    resident working set), write time ``δ`` from a storage-bandwidth
    constant, write *energy* from a storage-subsystem power constant
    that the simulator emits onto the PR-3 telemetry bus as its own
    ``storage`` component, so checkpoint overhead shows up in the
    Green500 L1/L2/L3 numbers honestly;
  * :class:`AttemptPlan` is one placement attempt's checkpoint
    schedule: ``work_s`` seconds of compute with a ``δ``-second write
    pause after every ``τ`` seconds of work (never one at the very
    end).  It answers the three questions the event loop asks — how
    long does this attempt run (:attr:`duration_s`), how much progress
    survives a kill ``e`` seconds in (:meth:`progress_at`, rounded
    *down* to the last completed checkpoint), and which write windows
    actually burned storage power (:meth:`checkpoint_windows`).

With no failure model the MTBF is infinite, ``τ* = ∞`` and zero
checkpoints are scheduled — the no-failure oracle path stays
bit-identical to batch ``cluster.run()`` (pinned in
``tests/test_resilience.py`` and gated in
``benchmarks/paper_tables.py::cluster_resilience``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Tuple

#: node-local checkpoint storage write bandwidth [bytes/s] — the
#: paper-era commodity SATA-SSD/RAID figure (≈1 GB/s per node)
DEFAULT_STORAGE_BW_BS = 1.0e9

#: extra node power while a checkpoint streams to storage [W] — drives
#: + controller burst draw, billed as the trace's ``storage`` component
DEFAULT_WRITE_W = 25.0


def job_state_bytes(job) -> float:
    """Checkpointable state for a job spec: an explicit
    ``Job.state_bytes`` (set by a ``Workload.state_bytes()`` adapter)
    wins — including an explicit ``0.0``, which marks the workload
    *stateless* (serving: KV cache is reconstructible) and disables
    checkpointing for it.  Otherwise the resident working set
    (``mem_gb``) is the honest upper bound — HPL's factored matrix and
    an LQCD gauge+spinor set both live GPU-resident."""
    sb = getattr(job, "state_bytes", None)
    if sb is not None:
        return float(sb)
    return float(job.mem_gb) * 1e9


def daly_interval_s(delta_s: float, mtbf_s: float) -> float:
    """Young/Daly first-order optimal checkpoint interval
    ``√(2·δ·MTBF)`` — infinite (checkpointing off) when the MTBF is
    infinite or the write is free."""
    if not math.isfinite(mtbf_s) or mtbf_s <= 0.0 or delta_s <= 0.0:
        return math.inf
    return math.sqrt(2.0 * delta_s * mtbf_s)


@dataclass(frozen=True)
class CheckpointPolicy:
    """When and at what cost a running placement checkpoints.

    ``interval_s=None`` (the default) derives the per-attempt Daly
    interval from the failure model's MTBF at the placement's node
    span; a fixed override models naive operator-chosen intervals (the
    benchmark's sweep).  ``min_interval_s`` floors pathological
    always-checkpointing regimes."""

    storage_bw_bs: float = DEFAULT_STORAGE_BW_BS
    write_w: float = DEFAULT_WRITE_W
    interval_s: Optional[float] = None   # fixed override; None = Daly
    min_interval_s: float = 30.0

    def __post_init__(self):
        if self.storage_bw_bs <= 0.0 or self.write_w < 0.0:
            raise ValueError("storage_bw_bs must be positive, write_w "
                             "non-negative")
        if self.interval_s is not None and self.interval_s <= 0.0:
            raise ValueError("fixed interval_s must be positive")

    def write_time_s(self, job) -> float:
        """δ — seconds to stream the job's state to storage."""
        return job_state_bytes(job) / self.storage_bw_bs

    def interval_for(self, job, *, n_nodes: int = 1,
                     mtbf_node_s: float = math.inf) -> float:
        """The checkpoint interval for one attempt of ``job`` spanning
        ``n_nodes`` nodes.  A placement on ``n`` independent nodes
        fails at ``n×`` the per-node rate, so its effective MTBF is
        ``mtbf_node_s / n`` — wider shards checkpoint more often."""
        if self.interval_s is not None:
            return max(float(self.interval_s), self.min_interval_s)
        mtbf = mtbf_node_s / max(int(n_nodes), 1)
        tau = daly_interval_s(self.write_time_s(job), mtbf)
        return tau if not math.isfinite(tau) \
            else max(tau, self.min_interval_s)


@dataclass(frozen=True)
class AttemptPlan:
    """One placement attempt's checkpoint schedule.

    The attempt timeline alternates ``τ`` seconds of compute with a
    ``δ``-second write pause; checkpoint ``i`` *completes* at
    attempt-relative time ``i·(τ+δ)``.  No checkpoint is scheduled at
    the very end (finishing *is* the durable state), so an attempt with
    ``work_s ≤ τ`` runs checkpoint-free."""

    work_s: float                     # compute seconds this attempt owes
    tau_s: float                      # checkpoint interval (∞ = never)
    delta_s: float                    # per-checkpoint write time

    @property
    def n_checkpoints(self) -> int:
        if not math.isfinite(self.tau_s) or self.tau_s <= 0.0 \
                or self.work_s <= 0.0:
            return 0
        return max(int(math.ceil(self.work_s / self.tau_s - 1e-9)) - 1, 0)

    @property
    def overhead_s(self) -> float:
        """Wall seconds the attempt pauses for checkpoint writes."""
        return self.n_checkpoints * self.delta_s

    @property
    def duration_s(self) -> float:
        return self.work_s + self.overhead_s

    def checkpoint_windows(self, until_s: Optional[float] = None,
                           ) -> List[Tuple[float, float]]:
        """Attempt-relative ``(w_start, w_end)`` write windows.
        ``until_s`` (a kill time) clips the schedule: a write in
        progress at the kill is truncated — its energy was still burned
        and is still billed, but only *completed* writes preserve
        progress (:meth:`progress_at`)."""
        out: List[Tuple[float, float]] = []
        for i in range(1, self.n_checkpoints + 1):
            w0 = i * self.tau_s + (i - 1) * self.delta_s
            w1 = w0 + self.delta_s
            if until_s is not None:
                if w0 >= until_s:
                    break
                w1 = min(w1, until_s)
            if w1 > w0:
                out.append((w0, w1))
        return out

    def progress_at(self, elapsed_s: float) -> Tuple[float, float]:
        """``(preserved_s, wasted_s)`` when the attempt is killed
        ``elapsed_s`` in: compute seconds durably saved by the last
        *completed* checkpoint (rounded down — a write in progress
        saves nothing), and compute seconds executed since it (redone
        work, the waste :class:`repro.cluster.stats.SimStats` surfaces).
        """
        e = min(max(elapsed_s, 0.0), self.duration_s)
        if self.n_checkpoints == 0:
            return 0.0, min(e, self.work_s)
        cycle = self.tau_s + self.delta_s
        k = min(int(e // cycle), self.n_checkpoints)
        rem = max(e - k * cycle, 0.0)
        executed = min(k * self.tau_s + min(rem, self.tau_s), self.work_s)
        preserved = k * self.tau_s
        return preserved, max(executed - preserved, 0.0)
