"""int8 gradient compression with error feedback for cross-pod DP.

The pod axis rides DCN-class links (~4x slower than ICI); compressing the
cross-pod gradient all-reduce 4x (fp32 -> int8 + per-tensor scale) recovers
most of it.  Error feedback (Seide et al.) accumulates the quantization
residual locally so the compression bias vanishes over steps.

Used when TrainConfig.grad_compress=True and the mesh has a 'pod' axis:
parameters are then FSDP-sharded over 'data' only; this module performs the
explicit pod-axis mean.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum_leaf(g: jnp.ndarray, err: jnp.ndarray, axis: str,
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One leaf: quantize(g + err) -> psum(int32) -> dequantize; returns
    (reduced gradient, new error feedback)."""
    from repro.compat import axis_size
    n = axis_size(axis)
    g_fb = g.astype(jnp.float32) + err
    q, scale = quantize_int8(g_fb)
    # int8 sums can overflow int8; widen to int32 on the wire model —
    # real deployments sum scales separately; we psum q and mean scales
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis)
    scale_mean = jax.lax.pmean(scale, axis)
    # error feedback MUST measure against the dequantization the sum
    # actually used (the mean scale), otherwise the per-pod scale skew is a
    # bias the feedback never sees
    new_err = g_fb - dequantize_int8(q, scale_mean)
    g_red = q_sum.astype(jnp.float32) * scale_mean / n
    return g_red, new_err


def compressed_pod_mean(grads: Any, err_state: Any, mesh,
                        data_axes=("data",), pod_axis: str = "pod",
                        ) -> Tuple[Any, Any]:
    """Apply compressed mean over the pod axis to a gradient pytree.

    Gradients are FSDP-sharded over ``data_axes`` and replicated over the
    pod axis on entry (per-pod partial means); exit is the cross-pod mean.
    """
    def one(g, e):
        def body(g_l, e_l):
            return compressed_psum_leaf(g_l, e_l, pod_axis)

        spec = P()   # leaves arrive pod-replicated per-shard; shard_map over
        # pod only: treat other axes as replicated within this collective
        from repro.compat import shard_map
        return shard_map(
            body, mesh=mesh,
            in_specs=(P(), P()), out_specs=(P(), P()),
            axis_names={pod_axis}, check_vma=False)(g, e)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_e = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return new_g, new_e


def init_error_state(grads_like: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_like)
