"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp

from repro.config import TrainConfig


def lr_schedule(step: jnp.ndarray, tc: TrainConfig) -> jnp.ndarray:
    """Linear warmup + cosine decay to 10%."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - tc.warmup_steps)
                    / jnp.maximum(tc.total_steps - tc.warmup_steps, 1), 0, 1)
    cos = 0.1 + 0.45 * (1 + jnp.cos(jnp.pi * prog))
    return tc.learning_rate * warm * cos
