"""AdamW in plain JAX.

Moments are fp32 and sharded exactly like the parameters (ZeRO-style: with
FSDP specs the optimizer state is fully sharded across the mesh).  Params may
be bf16; the update math runs in fp32 and casts back.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


def adamw_init(params: Any, moment_dtype=jnp.float32) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads: Any, state: Dict[str, Any], params: Any,
                 lr: jnp.ndarray, tc: TrainConfig,
                 ) -> Tuple[Any, Dict[str, Any], jnp.ndarray]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, tc.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2 = tc.beta1, tc.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        mdt = m.dtype
        g = g.astype(jnp.float32) * clip
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + tc.eps) + tc.weight_decay * p.astype(
            jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new.astype(mdt), v_new.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    # serialize per-leaf updates: without the barrier, XLA may keep the
    # fp32 (g, m, v) temporaries of EVERY stacked leaf live at once —
    # several GiB/chip on 100B+ models
    out = []
    prev = None
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        if prev is not None:
            p, g, m, v, *prev = jax.lax.optimization_barrier(
                (p, g, m, v) + tuple(prev))
        res = upd(p, g, m, v)
        out.append(res)
        prev = list(res)
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(tdef, [o[1] for o in out]),
        "v": jax.tree.unflatten(tdef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, gnorm
