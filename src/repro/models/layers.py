"""Shared layers: norms, embeddings, RoPE, MLP variants.

All functions are pure; parameters are plain dict pytrees so they stack
cleanly on a leading layer axis for ``lax.scan``.
"""
from __future__ import annotations

from typing import Dict, Optional

import math

import jax
import jax.numpy as jnp

from repro.config import ModelConfig

Params = Dict[str, jnp.ndarray]


def param_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Normalisation
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, key) -> Params:
    if cfg.norm_variant == "nonparametric_ln":
        return {}
    d = cfg.d_model
    p = {"scale": jnp.ones((d,), param_dtype(cfg))}
    if cfg.norm_variant == "layernorm":
        p["bias"] = jnp.zeros((d,), param_dtype(cfg))
    return p


def apply_norm(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm_variant == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6)
        y = y * p["scale"].astype(jnp.float32)
    else:  # layernorm / nonparametric_ln
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + 1e-5)
        if cfg.norm_variant == "layernorm":
            y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------

def init_embedding(cfg: ModelConfig, key) -> Params:
    scale = 1.0 / math.sqrt(cfg.d_model)
    tok = jax.random.normal(key, (cfg.vocab_padded, cfg.d_model), jnp.float32)
    return {"tokens": (tok * scale).astype(param_dtype(cfg))}


def embed_tokens(p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["tokens"], tokens, axis=0)


def init_lm_head(cfg: ModelConfig, key) -> Params:
    if cfg.tie_embeddings:
        return {}
    scale = 1.0 / math.sqrt(cfg.d_model)
    w = jax.random.normal(key, (cfg.d_model, cfg.vocab_padded), jnp.float32)
    return {"w": (w * scale).astype(param_dtype(cfg))}


def lm_head_logits(cfg: ModelConfig, embed_p: Params, head_p: Params,
                   x: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        w = embed_p["tokens"].T
    else:
        w = head_p["w"]
    logits = jnp.einsum("...d,dv->...v", x, w)
    if cfg.vocab_padded != cfg.vocab_size:
        # mask the padded vocab tail so it carries no probability mass
        valid = jnp.arange(cfg.vocab_padded) < cfg.vocab_size
        logits = jnp.where(valid, logits, jnp.asarray(-1e30, logits.dtype))
    return logits


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, d_head); positions: (S,) or broadcastable to x[..., :, 0]."""
    d_head = x.shape[-1]
    inv = rope_freqs(d_head, theta)                       # (d_head/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

def _dense_init(key, shape, scale: float, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = param_dtype(cfg)
    s_in = 0.02
    s_out = 0.02 / math.sqrt(2.0 * cfg.n_layers)
    ks = jax.random.split(key, 3)
    if cfg.mlp_variant in ("swiglu", "geglu"):
        return {
            "w_gate": _dense_init(ks[0], (d, f), s_in, dt),
            "w_up": _dense_init(ks[1], (d, f), s_in, dt),
            "w_down": _dense_init(ks[2], (f, d), s_out, dt),
        }
    return {
        "w_up": _dense_init(ks[0], (d, f), s_in, dt),
        "w_down": _dense_init(ks[1], (f, d), s_out, dt),
    }


def apply_mlp(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.mlp_variant in ("swiglu", "geglu"):
        gate = jnp.einsum("...d,df->...f", x, p["w_gate"])
        up = jnp.einsum("...d,df->...f", x, p["w_up"])
        act = jax.nn.silu(gate) if cfg.mlp_variant == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    else:
        h = jnp.einsum("...d,df->...f", x, p["w_up"])
        if cfg.mlp_variant == "relu2":
            h = jnp.square(jax.nn.relu(h))
        else:  # gelu
            h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, p["w_down"])
