"""Mamba-2 SSD (state-space duality) block — chunked training/prefill path
and single-step recurrent decode.

The chunked algorithm follows arXiv:2405.21060 §6: within-chunk outputs via a
masked (C Bᵀ ∘ L) "attention-like" term, across-chunk state carried by a
``lax.scan`` recurrence.  All decay math is done in log space (segment sums)
for stability.  Pure JAX; the Pallas ``ssd_chunk`` kernel implements the
within-chunk term for TPU.
"""
from __future__ import annotations

from typing import Dict, Tuple

import math

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import param_dtype

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_ch


def init_ssm(cfg: ModelConfig, key) -> Params:
    s = cfg.ssm
    dt = param_dtype(cfg)
    d = cfg.d_model
    d_inner, n_heads, conv_ch = ssm_dims(cfg)
    ks = jax.random.split(key, 4)
    in_dim = 2 * d_inner + 2 * s.n_groups * s.d_state + n_heads

    def mk(k, shape, scl):
        return (jax.random.normal(k, shape, jnp.float32) * scl).astype(dt)

    return {
        "w_in": mk(ks[0], (d, in_dim), 0.02),
        "conv_w": mk(ks[1], (s.d_conv, conv_ch), 0.2),
        "conv_b": jnp.zeros((conv_ch,), dt),
        # A_log: A = -exp(A_log), initialized in [1, 16] as in mamba2
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dt),
        "w_out": mk(ks[2], (d_inner, d), 0.02 / math.sqrt(2.0 * cfg.n_layers)),
    }


# ---------------------------------------------------------------------------
# Projections shared by chunked and decode paths
# ---------------------------------------------------------------------------

def _split_proj(cfg: ModelConfig, proj: jnp.ndarray):
    s = cfg.ssm
    d_inner, n_heads, _ = ssm_dims(cfg)
    g = s.n_groups
    z, xBC_dt = jnp.split(proj, [d_inner], axis=-1)
    xBC, dt_raw = jnp.split(xBC_dt, [d_inner + 2 * g * s.d_state], axis=-1)
    return z, xBC, dt_raw


def _gated_norm(x: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray):
    """Mamba-2 gated RMSNorm: norm(x * silu(z)) * scale."""
    y = x * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    return (y * jax.lax.rsqrt(var + 1e-6) * scale.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Chunked SSD forward (train / prefill)
# ---------------------------------------------------------------------------

def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                B_mat: jnp.ndarray, C_mat: jnp.ndarray, chunk: int,
                h0: jnp.ndarray | None = None, out_dtype=None,
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked scan of  h_t = exp(A dt_t) h_{t-1} + dt_t B_t x_tᵀ ;
    y_t = C_t · h_t.

    x: (B, S, H, P); dt: (B, S, H); A: (H,) (negative);
    B_mat/C_mat: (B, S, G, N) group-shared.
    Returns y: (B, S, H, P) fp32 and final state (B, H, P, N).
    """
    Bsz, S, H, P = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    heads_per_g = H // G
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_mat = jnp.pad(B_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_mat = jnp.pad(C_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))

    xc = jnp.moveaxis(x.reshape(Bsz, nc, Q, H, P), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(Bsz, nc, Q, H), 1, 0)
    Bc = jnp.moveaxis(B_mat.reshape(Bsz, nc, Q, G, N), 1, 0)
    Cc = jnp.moveaxis(C_mat.reshape(Bsz, nc, Q, G, N), 1, 0)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    Af = A.astype(jnp.float32)

    def chunk_step(h, inp):
        """One chunk: intra term + inter term + state update.

        Scanning chunk-by-chunk keeps the (B, Q, Q, H) decay matrix a
        transient of one chunk rather than materializing all chunks.
        """
        xq, dtq, Bq, Cq = inp
        xq = xq.astype(jnp.float32)
        dtq = dtq.astype(jnp.float32)
        Bq = Bq.astype(jnp.float32)
        Cq = Cq.astype(jnp.float32)
        la = dtq * Af                                         # (B, Q, H)
        cs = jnp.cumsum(la, axis=1)
        # L[i, j] = exp(cs_i - cs_j) for i >= j (decay j+1..i).
        # Mask BEFORE exp: upper-triangle differences are positive and
        # exp would overflow -> NaN gradients through the where().
        Lm = cs[:, :, None, :] - cs[:, None, :, :]            # (B, Q, Q, H)
        Lm = jnp.exp(jnp.where(tri[None, :, :, None], Lm, -1e30))
        CB = jnp.einsum("bqgn,bkgn->bqkg", Cq, Bq)            # (B, Q, Q, G)
        CB = jnp.repeat(CB, heads_per_g, axis=-1)
        W = CB * Lm * dtq[:, None, :, :]
        y = jnp.einsum("bqkh,bkhp->bqhp", W, xq)              # intra
        # inter-chunk: y_i += C_i exp(cs_i) h_prev
        Ch = jnp.repeat(Cq, heads_per_g, axis=2)              # (B, Q, H, N)
        y = y + jnp.einsum("bqhn,bhpn,bqh->bqhp", Ch, h, jnp.exp(cs))
        # state update: h = exp(cs_Q) h + sum_j exp(cs_Q - cs_j) dt_j B_j x_j
        decay_to_end = jnp.exp(cs[:, -1:, :] - cs)            # (B, Q, H)
        Bh = jnp.repeat(Bq, heads_per_g, axis=2)              # (B, Q, H, N)
        Sc = jnp.einsum("bqh,bqhn,bqhp->bhpn", decay_to_end * dtq, Bh, xq)
        h_new = h * jnp.exp(cs[:, -1, :])[..., None, None] + Sc
        return h_new, y.astype(out_dtype or y.dtype)

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    hT, yc = jax.lax.scan(chunk_step, h0, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(yc, 0, 1).reshape(Bsz, nc * Q, H, P)[:, :S]
    return y, hT


def ssm_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Full-sequence mamba2 block.  x: (B, S, d_model).

    Returns (out (B,S,d_model), (ssm_state, conv_state)) for decode handoff.
    """
    s = cfg.ssm
    d_inner, n_heads, conv_ch = ssm_dims(cfg)
    B, S, _ = x.shape
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xBC, dt_raw = _split_proj(cfg, proj)

    # causal depthwise conv over (x, B, C) — bf16 storage, fp32 accum
    K = s.d_conv
    w = p["conv_w"].astype(jnp.float32)                        # (K, C)
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + S].astype(jnp.float32) * w[i] for i in range(K))
    conv = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32))
    conv = conv.astype(x.dtype)
    conv_state = xBC[:, S - (K - 1):] if S >= K - 1 else jnp.pad(
        xBC, ((0, 0), (K - 1 - S, 0), (0, 0)))

    xs, Bm, Cm = jnp.split(
        conv, [d_inner, d_inner + s.n_groups * s.d_state], axis=-1)
    xs = xs.reshape(B, S, n_heads, s.head_dim)
    Bm = Bm.reshape(B, S, s.n_groups, s.d_state)
    Cm = Cm.reshape(B, S, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y, hT = ssd_chunked(xs, dt, A, Bm, Cm, s.chunk_size, out_dtype=x.dtype)
    y = (y.astype(jnp.float32)
         + xs.astype(jnp.float32) * p["D"].astype(jnp.float32)[:, None])
    y = y.reshape(B, S, d_inner)
    y = _gated_norm(y, z, p["norm_scale"]).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, (hT, conv_state.astype(x.dtype))


def ssm_decode(cfg: ModelConfig, p: Params, x: jnp.ndarray,
               ssm_state: jnp.ndarray, conv_state: jnp.ndarray,
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single-token recurrent step.  x: (B, 1, d_model).

    ssm_state: (B, H, P, N) fp32; conv_state: (B, K-1, conv_ch).
    """
    s = cfg.ssm
    d_inner, n_heads, conv_ch = ssm_dims(cfg)
    B = x.shape[0]
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"])[:, 0]       # (B, e)
    z, xBC, dt_raw = _split_proj(cfg, proj)

    # conv ring update
    K = s.d_conv
    hist = jnp.concatenate([conv_state.astype(jnp.float32),
                            xBC.astype(jnp.float32)[:, None]], axis=1)
    w = p["conv_w"].astype(jnp.float32)
    conv = jnp.einsum("bkc,kc->bc", hist, w) + p["conv_b"].astype(jnp.float32)
    conv = jax.nn.silu(conv)
    new_conv_state = hist[:, 1:].astype(conv_state.dtype)

    xs, Bm, Cm = jnp.split(
        conv, [d_inner, d_inner + s.n_groups * s.d_state], axis=-1)
    xs = xs.reshape(B, n_heads, s.head_dim)
    Bm = Bm.reshape(B, s.n_groups, s.d_state)
    Cm = Cm.reshape(B, s.n_groups, s.d_state)
    heads_per_g = n_heads // s.n_groups
    Bh = jnp.repeat(Bm, heads_per_g, axis=1)                   # (B,H,N)
    Ch = jnp.repeat(Cm, heads_per_g, axis=1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)                                    # (B,H)
    h = (ssm_state * decay[..., None, None]
         + jnp.einsum("bh,bhn,bhp->bhpn", dt, Bh, xs.astype(jnp.float32)))
    y = jnp.einsum("bhn,bhpn->bhp", Ch, h)
    y = y + xs.astype(jnp.float32) * p["D"].astype(jnp.float32)[:, None]
    y = y.reshape(B, d_inner)
    y = _gated_norm(y, z, p["norm_scale"]).astype(x.dtype)
    out = jnp.einsum("be,ed->bd", y, p["w_out"])[:, None]      # (B,1,d)
    return out, h, new_conv_state
