"""Model substrate: unified transformer covering every assigned family."""
from repro.models.transformer import (  # noqa: F401
    init_params,
    forward_train_loss,
    forward_prefill,
    forward_decode,
    init_decode_cache,
)
