"""Mixture-of-Experts with expert parallelism via ``shard_map``.

Dispatch uses scatter/gather with a static per-shard capacity instead of the
(tokens, E, capacity) one-hot einsum — the one-hot dispatch tensor is
O(T·E·C) and does not fit HBM at 1M-token global batches; scatter dispatch is
O(E·C·D) and is how MegaBlocks-style implementations behave.

Expert weights are sharded over the ``model`` axis on the expert dim when
``E % model_size == 0`` (deepseek: 160/16), otherwise on the expert-FFN dim
(grok: 8 experts -> TP inside experts).  The FSDP (``data``/``pod``) shard on
d_model is all-gathered explicitly inside the shard_map body right before
use, which lets XLA overlap the gather with the router math.

The same code path serves train, prefill and decode (S=1): only the token
count changes.  Outside a mesh (CPU smoke tests) the single-shard fallback
runs the identical inner function.
"""
from __future__ import annotations

from typing import Dict, Tuple

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models.layers import param_dtype

Params = Dict[str, jnp.ndarray]


def moe_sharding_plan(cfg: ModelConfig, model_size: int) -> str:
    """'expert' — shard expert dim; 'ffn' — shard expert-FFN dim."""
    e = cfg.moe
    return "expert" if e.n_experts % model_size == 0 else "ffn"


def init_moe(cfg: ModelConfig, key) -> Params:
    e = cfg.moe
    d, f = cfg.d_model, e.expert_d_ff
    dt = param_dtype(cfg)
    ks = jax.random.split(key, 8)
    s_in, s_out = 0.02, 0.02 / math.sqrt(2.0 * cfg.n_layers)

    def mk(k, shape, s):
        return (jax.random.normal(k, shape, jnp.float32) * s).astype(dt)

    p = {
        "router": mk(ks[0], (d, e.n_experts), s_in),
        "w_gate": mk(ks[1], (e.n_experts, d, f), s_in),
        "w_up": mk(ks[2], (e.n_experts, d, f), s_in),
        "w_down": mk(ks[3], (e.n_experts, f, d), s_out),
    }
    if e.n_shared_experts:
        fs = f * e.n_shared_experts
        p["shared_gate"] = mk(ks[4], (d, fs), s_in)
        p["shared_up"] = mk(ks[5], (d, fs), s_in)
        p["shared_down"] = mk(ks[6], (fs, d), s_out)
    return p


def _capacity(tokens: int, cfg: ModelConfig, n_local_experts: int) -> int:
    e = cfg.moe
    c = int(tokens * e.top_k / e.n_experts * e.capacity_factor) + 1
    return max(c, e.top_k)


def _expert_ffn(cfg: ModelConfig, xin, wg, wu, wd):
    """xin: (E_loc, C, D); weights (E_loc, D, F) / (E_loc, F, D).

    bf16 inputs, fp32 MXU accumulation."""
    g = jnp.einsum("ecd,edf->ecf", xin, wg,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", xin, wu,
                   preferred_element_type=jnp.float32)
    act = jax.nn.silu(g) if cfg.mlp_variant != "geglu" else jax.nn.gelu(g)
    return jnp.einsum("ecf,efd->ecd", (act * u).astype(xin.dtype), wd,
                      preferred_element_type=jnp.float32)


def _moe_local(cfg: ModelConfig, x2d, router_w, wg, wu, wd,
               expert_offset: int, n_local: int, model_size: int,
               plan: str):
    """Per-shard MoE body.  x2d: (T, D) local tokens (full D).

    Returns (y_partial (T, D) — needs psum over 'model', aux_stats).
    """
    e = cfg.moe
    T, D = x2d.shape
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    gates, idx = jax.lax.top_k(probs, e.top_k)               # (T, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    # aux load-balance stats (Switch-style), computed on full E locally
    assign = jnp.zeros((T, e.n_experts), jnp.float32)
    for r in range(e.top_k):
        assign = assign + jax.nn.one_hot(idx[:, r], e.n_experts)
    frac_tokens = jnp.mean(assign, axis=0) / e.top_k
    frac_probs = jnp.mean(probs, axis=0)
    aux = jnp.sum(frac_tokens * frac_probs) * e.n_experts

    # local experts owned by this shard
    local = (idx >= expert_offset) & (idx < expert_offset + n_local)
    lidx = jnp.where(local, idx - expert_offset, n_local)    # n_local = drop
    C = _capacity(T, cfg, n_local) if plan == "expert" else _capacity(
        T, cfg, e.n_experts)

    # slot position per (t, r): running count per local expert
    flat_e = lidx.reshape(-1)                                # (T*k,)
    onehot = jax.nn.one_hot(flat_e, n_local + 1, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot                # exclusive
    slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = (flat_e < n_local) & (slot < C)
    dest_e = jnp.where(keep, flat_e, n_local)                # overflow row
    dest_c = jnp.where(keep, slot, 0)

    # scatter tokens into (E_loc+1, C, D); last row collects drops.
    # bf16 buffers: the expert matmuls accumulate in fp32 via
    # preferred_element_type, so only the token copies lose precision.
    cdt = x2d.dtype
    tok = jnp.repeat(x2d, e.top_k, axis=0)                   # (T*k, D)
    buf = jnp.zeros((n_local + 1, C, D), cdt)
    buf = buf.at[dest_e, dest_c].add(tok)
    xin = buf[:n_local]

    y_exp = _expert_ffn(cfg, xin, wg, wu, wd).astype(cdt)
    # gather back: token (t, r) reads y_exp[dest_e, dest_c]
    y_pad = jnp.concatenate(
        [y_exp, jnp.zeros((1, C, D), cdt)], axis=0)
    y_tok = y_pad[dest_e, dest_c].astype(jnp.float32)        # (T*k, D)
    g_flat = (gates.reshape(-1) * keep.astype(jnp.float32))
    y = jnp.sum((y_tok * g_flat[:, None]).reshape(T, e.top_k, D), axis=1)
    return y, aux


def _ep_data_forward(cfg: ModelConfig, p: Params, x, mesh, data_axes,
                     model_axis):
    """Serve-EP: experts sharded over the DATA axes (E % dp == 0), FFN dim
    over the model axis — weights fully resident, ZERO per-step weight
    gathers.  Tokens are all-gathered over data (tiny at decode batch
    sizes), each shard runs its local experts over ALL tokens, and outputs
    reduce-scatter back to the token owners.  This is the classic MoE
    dispatch/combine all-to-all realized as AG+RS (§Perf hillclimb for the
    collective-bound MoE decode cells)."""
    e = cfg.moe
    B, S, D = x.shape
    dp_size = 1
    for a in data_axes:
        dp_size *= mesh.shape[a]
    n_local = e.n_experts // dp_size

    def body(xl, router_w, wg, wu, wd):
        # gather all tokens over the data axes
        xa = xl
        for a in reversed(data_axes):
            xa = jax.lax.all_gather(xa, a, axis=0, tiled=True)
        T = xa.shape[0] * xa.shape[1]
        off = 0
        mult = 1
        for a in reversed(data_axes):
            off = off + jax.lax.axis_index(a) * mult * n_local
            mult *= mesh.shape[a]
        y, aux = _moe_local(cfg, xa.reshape(T, D), router_w, wg, wu, wd,
                            off, n_local, dp_size, "expert")
        y = y.astype(xl.dtype)
        # partial sums: over model (F-sharded down proj is NOT sharded in
        # this plan, but psum over model keeps replicas consistent when F
        # is sharded) and return tokens to their owners over data
        y = jax.lax.psum(y, model_axis)
        y = y.reshape(xa.shape)
        for a in data_axes:
            y = jax.lax.psum_scatter(y, a, scatter_dimension=0, tiled=True)
        aux = jax.lax.pmean(aux, model_axis)
        for a in data_axes:
            aux = jax.lax.pmean(aux, a)
        return y, aux

    in_specs = (P(data_axes, None, None),
                P(None, None),
                P(data_axes, None, model_axis),    # (E, D, F)
                P(data_axes, None, model_axis),
                P(data_axes, model_axis, None))    # (E, F, D)
    from repro.compat import shard_map
    out, aux = shard_map(
        body, mesh=mesh, in_specs=in_specs,
        out_specs=(P(data_axes, None, None), P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out, aux


def moe_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray, *,
                mesh=None, data_axes: Tuple[str, ...] = ("data",),
                model_axis: str = "model", fsdp: bool = True,
                ep_data: bool = False,
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    e = cfg.moe
    B, S, D = x.shape
    shape3 = x.shape

    if mesh is None:
        # single-shard fallback (CPU smoke tests)
        y, aux = _moe_local(cfg, x.reshape(-1, D), p["router"], p["w_gate"],
                            p["w_up"], p["w_down"], 0, e.n_experts, 1,
                            "expert")
        out = y.reshape(shape3).astype(x.dtype)
    elif ep_data:
        out, aux = _ep_data_forward(cfg, p, x, mesh, data_axes, model_axis)
    else:
        msize = mesh.shape[model_axis]
        plan = moe_sharding_plan(cfg, msize)
        dp = P(data_axes)

        wdp = data_axes if fsdp else None
        if plan == "expert":
            n_local = e.n_experts // msize
            in_specs = (P(data_axes, None, None),            # x
                        P(None, None),                       # router (repl)
                        P(model_axis, wdp, None),            # w_gate (E, D, F)
                        P(model_axis, wdp, None),            # w_up
                        P(model_axis, None, wdp))            # w_down (E, F, D)
        else:
            n_local = e.n_experts
            in_specs = (P(data_axes, None, None),
                        P(None, None),
                        P(None, wdp, model_axis),            # shard F
                        P(None, wdp, model_axis),
                        P(None, model_axis, wdp))

        def body(xl, router_w, wg, wu, wd):
            # all-gather the FSDP (data) shard of the expert weights
            def ag(w, axis):
                if not fsdp:
                    return w
                for a in reversed(data_axes):
                    w = jax.lax.all_gather(w, a, axis=axis, tiled=True)
                return w
            wg = ag(wg, 1)
            wu = ag(wu, 1)
            wd = ag(wd, 2)
            if plan == "expert":
                off = jax.lax.axis_index(model_axis) * n_local
            else:
                off = 0
            Tl = xl.shape[0] * xl.shape[1]
            y, aux = _moe_local(cfg, xl.reshape(Tl, D), router_w, wg, wu, wd,
                                off, n_local, msize, plan)
            # bf16 on the wire: halves the psum bytes; the fp32 partial sums
            # were already MXU-accumulated per shard
            y = jax.lax.psum(y.astype(xl.dtype), model_axis)
            aux = jax.lax.pmean(aux, model_axis)
            for a in data_axes:
                aux = jax.lax.pmean(aux, a)
            return y.reshape(xl.shape), aux

        from repro.compat import shard_map
        out, aux = shard_map(
            body, mesh=mesh,
            in_specs=in_specs,
            out_specs=(P(data_axes, None, None), P()),
            check_vma=False,
        )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if e.n_shared_experts:
        g = jnp.einsum("bsd,df->bsf", x, p["shared_gate"])
        u = jnp.einsum("bsd,df->bsf", x, p["shared_up"])
        shared = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                            p["shared_down"])
        out = out + shared
    return out, aux * e.aux_loss_weight
