"""Unified transformer covering all assigned families.

One layer-body implementation handles: dense GQA decoders (llama3, qwen,
minitron, olmo), MoE decoders (grok, deepseek-MLA), pure SSM (mamba2),
hybrid attn∥SSM (hymba), encoder-decoder (whisper), and VLM prefix models
(llava).  Layers are stacked on a leading axis and executed with
``lax.scan`` so the HLO stays O(1) in depth; training wraps the body in
``jax.checkpoint`` (remat).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models.frontend import (apply_frontend, enc_len_for, init_frontend,
                                   sinusoidal_positions)
from repro.models.layers import (apply_mlp, apply_norm, embed_tokens,
                                 init_embedding, init_lm_head, init_mlp,
                                 init_norm, lm_head_logits, param_dtype)
from repro.models.moe import init_moe, moe_forward

Params = Dict[str, Any]


def _constrain(x, mesh, spec: P):
    """Anchor activation sharding (no-op outside a mesh)."""
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_decoder_layer(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {"norm1": init_norm(cfg, ks[0])}
    if cfg.family != "ssm":
        p["attn"] = attn.init_attention(cfg, ks[1])
    if cfg.family in ("ssm", "hybrid"):
        p["ssm"] = ssm_mod.init_ssm(cfg, ks[2])
    if cfg.family == "encdec":
        p["norm_x"] = init_norm(cfg, ks[3])
        p["xattn"] = attn.init_attention(cfg, ks[4])
    if cfg.family == "moe":
        p["norm2"] = init_norm(cfg, ks[5])
        p["moe"] = init_moe(cfg, ks[6])
    elif cfg.d_ff > 0:
        p["norm2"] = init_norm(cfg, ks[5])
        p["mlp"] = init_mlp(cfg, ks[6])
    return p


def _init_encoder_layer(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "norm1": init_norm(cfg, ks[0]),
        "attn": attn.init_attention(cfg, ks[1]),
        "norm2": init_norm(cfg, ks[2]),
        "mlp": init_mlp(cfg, ks[3]),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 8)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    params: Params = {
        "embed": init_embedding(cfg, ks[1]),
        "layers": jax.vmap(lambda k: _init_decoder_layer(cfg, k))(layer_keys),
        "final_norm": init_norm(cfg, ks[2]),
        "lm_head": init_lm_head(cfg, ks[3]),
    }
    if cfg.family == "encdec":
        enc_keys = jax.random.split(ks[4], cfg.n_encoder_layers)
        params["enc_layers"] = jax.vmap(
            lambda k: _init_encoder_layer(cfg, k))(enc_keys)
        params["enc_final_norm"] = init_norm(cfg, ks[5])
    if cfg.frontend != "none":
        params["frontend"] = init_frontend(cfg, ks[6])
    return params


# ---------------------------------------------------------------------------
# Layer bodies (full-sequence)
# ---------------------------------------------------------------------------

def _decoder_layer_fwd(cfg: ModelConfig, p: Params, x, positions, *,
                       mesh, data_axes, block_skip: bool,
                       enc_states=None, want_cache: bool,
                       moe_fsdp: bool = True):
    """Returns (x, cache_dict_or_None, aux)."""
    aux = jnp.zeros((), jnp.float32)
    cache = {}
    h = apply_norm(cfg, p["norm1"], x)

    if cfg.family == "ssm":
        out, (hT, conv) = ssm_mod.ssm_forward(cfg, p["ssm"], h)
        if want_cache:
            cache["ssm"] = hT
            cache["conv"] = conv
    elif cfg.family == "hybrid":
        a_out, (k, v) = attn.gqa_forward(cfg, p["attn"], h,
                                         positions=positions,
                                         block_skip=block_skip,
                                         mesh=mesh, data_axes=data_axes)
        s_out, (hT, conv) = ssm_mod.ssm_forward(cfg, p["ssm"], h)
        out = (a_out + s_out) * 0.5
        if want_cache:
            cache["k"], cache["v"] = k, v
            cache["ssm"], cache["conv"] = hT, conv
    elif cfg.mla.enabled:
        out, (ckv, krope) = attn.mla_forward(cfg, p["attn"], h,
                                             positions=positions,
                                             block_skip=block_skip)
        if want_cache:
            cache["ckv"], cache["krope"] = ckv, krope
    else:
        out, (k, v) = attn.gqa_forward(cfg, p["attn"], h,
                                       positions=positions,
                                       block_skip=block_skip,
                                       mesh=mesh, data_axes=data_axes)
        if want_cache:
            cache["k"], cache["v"] = k, v
    x = x + out

    if cfg.family == "encdec":
        hx = apply_norm(cfg, p["norm_x"], x)
        xk, xv = attn.cross_kv(cfg, p["xattn"], enc_states)
        xo, _ = attn.gqa_forward(cfg, p["xattn"], hx, positions=positions,
                                 causal=False, kv_override=(xk, xv))
        x = x + xo
        if want_cache:
            cache["xk"], cache["xv"] = xk, xv

    if cfg.family == "moe":
        h2 = apply_norm(cfg, p["norm2"], x)
        out2, aux = moe_forward(cfg, p["moe"], h2, mesh=mesh,
                                data_axes=data_axes, fsdp=moe_fsdp)
        x = x + out2
    elif cfg.d_ff > 0:
        h2 = apply_norm(cfg, p["norm2"], x)
        x = x + apply_mlp(cfg, p["mlp"], h2)
    return x, (cache if want_cache else None), aux


def _encoder_layer_fwd(cfg: ModelConfig, p: Params, x):
    h = apply_norm(cfg, p["norm1"], x)
    out, _ = attn.gqa_forward(cfg, p["attn"], h, positions=None, causal=False)
    x = x + out
    h2 = apply_norm(cfg, p["norm2"], x)
    return x + apply_mlp(cfg, p["mlp"], h2)


# ---------------------------------------------------------------------------
# Full-model forward (train / prefill)
# ---------------------------------------------------------------------------

def _run_encoder(cfg: ModelConfig, params: Params, frame_embeds, *, remat,
                 mesh=None, data_axes=("data",)):
    x = apply_frontend(cfg, params["frontend"], frame_embeds)
    pe = sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    x = x + pe[None]
    act_spec = P(data_axes, None, None)
    x = _constrain(x, mesh, act_spec)

    def body(x, layer_p):
        return _constrain(_encoder_layer_fwd(cfg, layer_p, x), mesh,
                          act_spec), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(cfg, params["enc_final_norm"], x)


def _embed_inputs(cfg: ModelConfig, params: Params, batch):
    """Returns (x (B,S,D), positions (S,), labels-aligned-extras)."""
    if cfg.family == "vlm":
        tok_emb = embed_tokens(params["embed"], batch["tokens"])
        patches = apply_frontend(cfg, params["frontend"],
                                 batch["patch_embeds"]).astype(tok_emb.dtype)
        x = jnp.concatenate([patches, tok_emb], axis=1)
    else:
        x = embed_tokens(params["embed"], batch["tokens"])
    positions = jnp.arange(x.shape[1])
    return x, positions


def _block_size(n_layers: int) -> int:
    """Largest divisor of n_layers <= sqrt(n_layers) (sqrt-remat blocks)."""
    import math as _m
    best = 1
    for b in range(1, int(_m.isqrt(n_layers)) + 1):
        if n_layers % b == 0:
            best = b
    return best


def forward_hidden(cfg: ModelConfig, params: Params, batch, *,
                   mesh=None, data_axes=("data",), remat: bool = False,
                   block_skip: bool = False, want_cache: bool = False,
                   moe_fsdp: bool = True, remat_policy: str = "layer"):
    """Embed + all decoder layers.  Returns (hidden, cache_stack, aux).

    ``remat_policy='block'`` uses sqrt-remat: an outer scan over layer
    blocks stores only block-boundary residuals; the inner scan recomputes
    within a block during backward.  Memory O(sqrt(L)) instead of O(L).
    """
    enc_states = None
    if cfg.family == "encdec":
        enc_states = _run_encoder(cfg, params, batch["frame_embeds"],
                                  remat=remat, mesh=mesh,
                                  data_axes=data_axes)
    x, positions = _embed_inputs(cfg, params, batch)
    act_spec = P(data_axes, None, None)
    x = _constrain(x, mesh, act_spec)

    def body(carry, layer_p):
        x, aux = carry
        x, cache, aux_l = _decoder_layer_fwd(
            cfg, layer_p, x, positions, mesh=mesh, data_axes=data_axes,
            block_skip=block_skip, enc_states=enc_states,
            want_cache=want_cache, moe_fsdp=moe_fsdp)
        x = _constrain(x, mesh, act_spec)
        return (x, aux + aux_l), cache

    carry0 = (x, jnp.zeros((), jnp.float32))
    if remat and remat_policy == "block" and not want_cache:
        bs = _block_size(cfg.n_layers)
        nb = cfg.n_layers // bs
        blocked = jax.tree.map(
            lambda l: l.reshape((nb, bs) + l.shape[1:]), params["layers"])

        def block_body(carry, block_p):
            inner = jax.checkpoint(body)
            carry, _ = jax.lax.scan(inner, carry, block_p)
            return carry, None

        (x, aux), _ = jax.lax.scan(jax.checkpoint(block_body), carry0,
                                   blocked)
        caches = None
    else:
        if remat:
            body = jax.checkpoint(body)
        (x, aux), caches = jax.lax.scan(body, carry0, params["layers"])
    x = apply_norm(cfg, params["final_norm"], x)
    return x, caches, aux, enc_states


def chunked_lm_loss(cfg: ModelConfig, params: Params, hidden, labels,
                    chunk: int = 1024, mesh=None, data_axes=("data",)):
    """Cross-entropy without materializing full (B,S,V) fp32 logits."""
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    n = -(-S // chunk)
    pad = n * chunk - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = hidden.reshape(B, n, chunk, D).swapaxes(0, 1)
    lc = labels.reshape(B, n, chunk).swapaxes(0, 1)

    def step(carry, xs):
        tot, cnt = carry
        h, l = xs
        logits = lm_head_logits(cfg, params["embed"], params.get("lm_head", {}),
                                h).astype(jnp.float32)
        logits = _constrain(logits, mesh, P(data_axes, None, "model"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(l, 0)[..., None], axis=-1)[..., 0]
        valid = (l >= 0).astype(jnp.float32)
        nll = (lse - gold) * valid
        return (tot + jnp.sum(nll), cnt + jnp.sum(valid)), None

    (tot, cnt), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def forward_train_loss(cfg: ModelConfig, params: Params, batch, *,
                       mesh=None, data_axes=("data",), remat: bool = True,
                       block_skip: bool = False, remat_policy: str = "layer"):
    hidden, _, aux, _ = forward_hidden(cfg, params, batch, mesh=mesh,
                                       data_axes=data_axes, remat=remat,
                                       block_skip=block_skip,
                                       want_cache=False,
                                       remat_policy=remat_policy)
    if cfg.family == "vlm":
        # loss on text tokens only; hidden includes the patch prefix
        n_p = batch["patch_embeds"].shape[1]
        hidden = hidden[:, n_p:]
    loss = chunked_lm_loss(cfg, params, hidden, batch["labels"], mesh=mesh,
                           data_axes=data_axes)
    return loss + aux, {"lm_loss": loss, "aux_loss": aux}


# ---------------------------------------------------------------------------
# Serving: prefill
# ---------------------------------------------------------------------------

def _ring_align(cache_full: jnp.ndarray, S: int, W: int) -> jnp.ndarray:
    """Take the last W of S prefill K/V rows into ring-buffer slot order."""
    sl = jax.lax.dynamic_slice_in_dim(cache_full, S - W, W, axis=1)
    slots = (S - W + jnp.arange(W)) % W
    out = jnp.zeros_like(sl)
    return out.at[:, slots].set(sl)


def forward_prefill(cfg: ModelConfig, params: Params, batch, *,
                    mesh=None, data_axes=("data",), block_skip: bool = False,
                    moe_fsdp: bool = True, quantize_kv_cache: bool = False):
    """Returns (last-token logits (B, V), decode cache pytree)."""
    hidden, caches, aux, enc_states = forward_hidden(
        cfg, params, batch, mesh=mesh, data_axes=data_axes, remat=False,
        block_skip=block_skip, want_cache=True, moe_fsdp=moe_fsdp)
    last = hidden[:, -1]
    logits = lm_head_logits(cfg, params["embed"], params.get("lm_head", {}),
                            last)
    S = hidden.shape[1]
    W = cfg.sliding_window
    if W and W < S and "k" in caches:
        caches = dict(caches)
        caches["k"] = jax.vmap(lambda c: _ring_align(c, S, W))(caches["k"])
        caches["v"] = jax.vmap(lambda c: _ring_align(c, S, W))(caches["v"])
    cache = dict(caches)
    if quantize_kv_cache and "k" in cache:
        kq, ks = attn.quantize_kv(cache["k"])
        vq, vs = attn.quantize_kv(cache["v"])
        cache.update(k=kq, v=vq, k_s=ks, v_s=vs)
    cache["pos"] = jnp.array(S, jnp.int32)
    return logits, cache


# ---------------------------------------------------------------------------
# Serving: decode
# ---------------------------------------------------------------------------

def kv_cache_bytes(cfg: ModelConfig, batch_size: int, max_seq: int) -> int:
    """bf16 K/V cache footprint (cluster-total) for auto-quantization."""
    W = cfg.sliding_window
    S = min(max_seq, W) if W else max_seq
    if cfg.attn_free or cfg.mla.enabled:
        return 0
    return 2 * cfg.n_layers * batch_size * S * cfg.n_kv_heads \
        * cfg.d_head * 2


def init_decode_cache(cfg: ModelConfig, batch_size: int, max_seq: int,
                      dtype=None, quantize_kv_cache: bool = False) -> Params:
    """Zero cache sized for ``max_seq`` history (ring-buffered if windowed).

    ``quantize_kv_cache``: int8 K/V with per-(token, head) f32 scales —
    halves cache HBM and doubles effective decode bandwidth."""
    dt = dtype or param_dtype(cfg)
    L = cfg.n_layers
    cache: Params = {"pos": jnp.array(0, jnp.int32)}
    W = cfg.sliding_window
    S = min(max_seq, W) if W else max_seq
    if cfg.family in ("dense", "moe", "hybrid", "encdec", "vlm"):
        if cfg.mla.enabled:
            m = cfg.mla
            cache["ckv"] = jnp.zeros((L, batch_size, max_seq, m.kv_lora_rank), dt)
            cache["krope"] = jnp.zeros(
                (L, batch_size, max_seq, m.qk_rope_head_dim), dt)
        elif quantize_kv_cache:
            cache["k"] = jnp.zeros(
                (L, batch_size, S, cfg.n_kv_heads, cfg.d_head), jnp.int8)
            cache["v"] = jnp.zeros_like(cache["k"])
            cache["k_s"] = jnp.zeros((L, batch_size, S), jnp.float32)
            cache["v_s"] = jnp.zeros_like(cache["k_s"])
        else:
            cache["k"] = jnp.zeros(
                (L, batch_size, S, cfg.n_kv_heads, cfg.d_head), dt)
            cache["v"] = jnp.zeros_like(cache["k"])
    if cfg.family in ("ssm", "hybrid"):
        d_inner, n_heads, conv_ch = ssm_mod.ssm_dims(cfg)
        cache["ssm"] = jnp.zeros(
            (L, batch_size, n_heads, cfg.ssm.head_dim, cfg.ssm.d_state),
            jnp.float32)
        cache["conv"] = jnp.zeros(
            (L, batch_size, cfg.ssm.d_conv - 1, conv_ch), dt)
    if cfg.family == "encdec":
        enc_len = enc_len_for(cfg, max_seq)
        cache["xk"] = jnp.zeros(
            (L, batch_size, enc_len, cfg.n_kv_heads, cfg.d_head), dt)
        cache["xv"] = jnp.zeros_like(cache["xk"])
    return cache


def _decoder_layer_decode(cfg: ModelConfig, p: Params, x, cache_l, position,
                          *, mesh, data_axes, moe_fsdp: bool = True,
                          moe_ep_data: bool = False):
    new_cache = dict(cache_l)
    h = apply_norm(cfg, p["norm1"], x)

    if cfg.family == "ssm":
        out, hT, conv = ssm_mod.ssm_decode(cfg, p["ssm"], h,
                                           cache_l["ssm"], cache_l["conv"])
        new_cache["ssm"], new_cache["conv"] = hT, conv
    elif cfg.family == "hybrid":
        if "k_s" in cache_l:
            a_out, ck, cv, ks, vs = attn.gqa_decode(
                cfg, p["attn"], h, cache_l["k"], cache_l["v"], position,
                k_scale=cache_l["k_s"], v_scale=cache_l["v_s"])
            new_cache.update(k_s=ks, v_s=vs)
        else:
            a_out, ck, cv = attn.gqa_decode(cfg, p["attn"], h, cache_l["k"],
                                            cache_l["v"], position)
        s_out, hT, conv = ssm_mod.ssm_decode(cfg, p["ssm"], h,
                                             cache_l["ssm"], cache_l["conv"])
        out = (a_out + s_out) * 0.5
        new_cache.update(k=ck, v=cv, ssm=hT, conv=conv)
    elif cfg.mla.enabled:
        out, ckv, krope = attn.mla_decode(cfg, p["attn"], h[:, 0:1],
                                          cache_l["ckv"], cache_l["krope"],
                                          position)
        new_cache.update(ckv=ckv, krope=krope)
    else:
        if "k_s" in cache_l:
            out, ck, cv, ks, vs = attn.gqa_decode(
                cfg, p["attn"], h, cache_l["k"], cache_l["v"], position,
                k_scale=cache_l["k_s"], v_scale=cache_l["v_s"])
            new_cache.update(k_s=ks, v_s=vs)
        else:
            out, ck, cv = attn.gqa_decode(cfg, p["attn"], h, cache_l["k"],
                                          cache_l["v"], position)
        new_cache.update(k=ck, v=cv)
    x = x + out

    if cfg.family == "encdec":
        hx = apply_norm(cfg, p["norm_x"], x)
        out_x, _, _ = attn.gqa_decode(
            cfg, p["xattn"], hx, cache_l["xk"], cache_l["xv"],
            jnp.array(cache_l["xk"].shape[1] - 1, jnp.int32),
            update_cache=False)
        x = x + out_x

    if cfg.family == "moe":
        h2 = apply_norm(cfg, p["norm2"], x)
        out2, _ = moe_forward(cfg, p["moe"], h2, mesh=mesh,
                              data_axes=data_axes, fsdp=moe_fsdp,
                              ep_data=moe_ep_data)
        x = x + out2
    elif cfg.d_ff > 0:
        h2 = apply_norm(cfg, p["norm2"], x)
        x = x + apply_mlp(cfg, p["mlp"], h2)
    return x, new_cache


def forward_decode(cfg: ModelConfig, params: Params, tokens, cache, *,
                   mesh=None, data_axes=("data",), moe_fsdp: bool = True,
                   moe_ep_data: bool = False):
    """One decode step.  tokens: (B, 1) int32.  Returns (logits, new cache)."""
    position = cache["pos"]
    x = embed_tokens(params["embed"], tokens)
    x = _constrain(x, mesh, P(data_axes, None, None))

    layer_cache = {k: v for k, v in cache.items() if k != "pos"}

    def body(x, xs):
        layer_p, cache_l = xs
        x, new_c = _decoder_layer_decode(cfg, layer_p, x, cache_l, position,
                                         mesh=mesh, data_axes=data_axes,
                                         moe_fsdp=moe_fsdp,
                                         moe_ep_data=moe_ep_data)
        x = _constrain(x, mesh, P(data_axes, None, None))
        return x, new_c

    x, new_caches = jax.lax.scan(body, x, (params["layers"], layer_cache))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_head_logits(cfg, params["embed"], params.get("lm_head", {}),
                            x[:, 0])
    new_cache = dict(new_caches)
    new_cache["pos"] = position + 1
    return logits, new_cache
