"""Modality frontend STUBS (per assignment).

``[audio]`` (whisper) and ``[vlm]`` (llava) entries specify the transformer
backbone only; ``input_specs()`` provides precomputed frame/patch embeddings.
Here we keep only the learnable glue: a projection of the precomputed
embeddings into the backbone width (llava's mm-projector; whisper's
post-conv linear), plus sinusoidal positions for the audio encoder.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import param_dtype

Params = Dict[str, jnp.ndarray]


def enc_len_for(cfg: ModelConfig, seq_len: int) -> int:
    """Audio stub: conv frontend downsamples dec_len by encoder_ratio."""
    return max(1, seq_len // cfg.encoder_ratio)


def init_frontend(cfg: ModelConfig, key) -> Params:
    if cfg.frontend == "none":
        return {}
    d = cfg.d_model
    dt = param_dtype(cfg)
    w = (jax.random.normal(key, (d, d), jnp.float32) * 0.02).astype(dt)
    return {"proj_w": w, "proj_b": jnp.zeros((d,), dt)}


def apply_frontend(cfg: ModelConfig, p: Params,
                   embeds: jnp.ndarray) -> jnp.ndarray:
    """Project precomputed frame/patch embeddings into the backbone."""
    return jnp.einsum("bsd,de->bse", embeds, p["proj_w"]) + p["proj_b"]


def sinusoidal_positions(length: int, d_model: int) -> jnp.ndarray:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, dim / d_model)
    pe = jnp.zeros((length, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang[:, : (d_model - d_model // 2)]))
    return pe
