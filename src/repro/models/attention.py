"""Attention: GQA with blockwise (flash-style) softmax, sliding windows,
single-token decode against a KV cache, and DeepSeek-V2 MLA (multi-head
latent attention) with matrix absorption for decode.

The blockwise implementation is pure JAX (``lax.scan`` online softmax) so the
same code lowers for the CPU dry-run and for TPU.  Two schedules exist:

* rectangular (default): every (q-chunk, kv-chunk) block is computed and
  masked — simple, but computes ~2x the needed FLOPs for causal masks.
* triangular (``block_skip=True``): scans only the lower-triangle blocks —
  the §Perf hillclimb for compute-bound prefill cells.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models.layers import apply_rope, param_dtype

Params = Dict[str, jnp.ndarray]

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# int8 KV-cache quantization (serving): per-(token, head) scales
# ---------------------------------------------------------------------------

def quantize_kv(x: jnp.ndarray, head_dims: int = 2):
    """x: (..., KVH, dh) -> (int8 values, f32 per-token scales).

    Scales are shared across the trailing ``head_dims`` axes (heads and
    head_dim): per-(token, head) scales do not shard on meshes where the
    head count is not divisible (qwen: 40 heads / 16), and at 32k x 128
    batch they alone cost GiBs/chip.  Accuracy is validated against the
    bf16 cache in tests."""
    ax = tuple(range(x.ndim - head_dims, x.ndim))
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=ax) / 127.0
    s = jnp.maximum(s, 1e-8)
    sb = s.reshape(s.shape + (1,) * head_dims)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / sb),
                 -127, 127).astype(jnp.int8)
    return q, s


def dequantize_kv(q: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    head_dims = q.ndim - s.ndim
    return q.astype(jnp.float32) * s.reshape(s.shape + (1,) * head_dims)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key) -> Params:
    dt = param_dtype(cfg)
    d, h, kvh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    s_in, s_out = 0.02, 0.02 / math.sqrt(2.0 * cfg.n_layers)
    ks = jax.random.split(key, 8)

    def mk(k, shape, s):
        return (jax.random.normal(k, shape, jnp.float32) * s).astype(dt)

    if cfg.mla.enabled:
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        p = {
            "wq_a": mk(ks[0], (d, m.q_lora_rank), s_in) if m.q_lora_rank else None,
            "wq_b": mk(ks[1], (m.q_lora_rank or d, h, qk), s_in),
            "wkv_a": mk(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), s_in),
            "wkv_b_nope": mk(ks[3], (m.kv_lora_rank, h, m.qk_nope_head_dim), s_in),
            "wkv_b_v": mk(ks[4], (m.kv_lora_rank, h, m.v_head_dim), s_in),
            "wo": mk(ks[5], (h, m.v_head_dim, d), s_out),
            "q_norm": jnp.ones((m.q_lora_rank,), dt) if m.q_lora_rank else None,
            "kv_norm": jnp.ones((m.kv_lora_rank,), dt),
        }
        return {k: v for k, v in p.items() if v is not None}

    p = {
        "wq": mk(ks[0], (d, h, dh), s_in),
        "wk": mk(ks[1], (d, kvh, dh), s_in),
        "wv": mk(ks[2], (d, kvh, dh), s_in),
        "wo": mk(ks[3], (h, dh, d), s_out),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh), dt)
        p["bk"] = jnp.zeros((kvh, dh), dt)
        p["bv"] = jnp.zeros((kvh, dh), dt)
    return p


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention — pure JAX
# ---------------------------------------------------------------------------

def _block_mask(qpos: jnp.ndarray, kpos: jnp.ndarray, causal: bool,
                window: int) -> jnp.ndarray:
    """(qc, kc) boolean mask: True = attend."""
    diff = qpos[:, None] - kpos[None, :]
    mask = jnp.ones(diff.shape, bool)
    if causal:
        mask &= diff >= 0
    if window > 0:
        mask &= diff < window
    return mask


def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, q_offset: int = 0,
                        window: int = 0, q_chunk: int = 512,
                        kv_chunk: int = 512,
                        block_skip: bool = False) -> jnp.ndarray:
    """q: (B, Sq, H, dh); k, v: (B, Sk, KVH, dh) -> (B, Sq, H, dh).

    Online-softmax over kv chunks; GQA via head grouping.  fp32 accumulation.
    """
    B, Sq, H, dh = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    scale = 1.0 / math.sqrt(dh)

    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Sk)
    # pad to multiples
    nq = -(-Sq // qc)
    nk = -(-Sk // kc)
    q_pad, k_pad = nq * qc - Sq, nk * kc - Sk
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0)))

    qg = q.reshape(B, nq, qc, KVH, G, dh)
    kg = k.reshape(B, nk, kc, KVH, dh)
    vg = v.reshape(B, nk, kc, KVH, dh)

    def block(qi_blk, kj_blk, i, j, m, l, acc):
        """One (qc x kc) attention block with online-softmax update."""
        qpos = q_offset + i * qc + jnp.arange(qc)
        kpos = j * kc + jnp.arange(kc)
        mask = _block_mask(qpos, kpos, causal, window)
        mask &= (kpos < Sk)[None, :]
        s = jnp.einsum("bqkgd,bckd->bqkgc", qi_blk.astype(jnp.float32),
                       kj_blk.astype(jnp.float32)) * scale
        s = jnp.where(mask[None, :, None, None, :], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgc,bckd->bqkgd", p, vg[:, j].astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return m_new, l_new, acc_new

    def init_stats():
        m = jnp.full((B, qc, KVH, G), _NEG_INF, jnp.float32)
        l = jnp.zeros((B, qc, KVH, G), jnp.float32)
        acc = jnp.zeros((B, qc, KVH, G, dh), jnp.float32)
        return m, l, acc

    if block_skip and causal and window == 0 and qc == kc and q_offset == 0:
        # Triangular schedule: flatten (i, j<=i) pairs; sequential scan keeps
        # the online-softmax state per-row valid because rows are contiguous.
        pairs = [(i, j) for i in range(nq) for j in range(i + 1)]
        ii = jnp.array([p[0] for p in pairs], jnp.int32)
        jj = jnp.array([p[1] for p in pairs], jnp.int32)
        row_done = jnp.array([j == i for i, j in pairs], bool)
        out = jnp.zeros((B, nq, qc, KVH, G, dh), jnp.float32)

        def step(carry, idx):
            m, l, acc, out = carry
            i, j, done = ii[idx], jj[idx], row_done[idx]
            qi = jax.lax.dynamic_index_in_dim(qg, i, 1, keepdims=False)
            kj = jax.lax.dynamic_index_in_dim(kg, j, 1, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vg, j, 1, keepdims=False)
            qpos = i * qc + jnp.arange(qc)
            kpos = j * kc + jnp.arange(kc)
            mask = (qpos[:, None] >= kpos[None, :]) & (kpos < Sk)[None, :]
            s = jnp.einsum("bqkgd,bckd->bqkgc", qi.astype(jnp.float32),
                           kj.astype(jnp.float32)) * scale
            s = jnp.where(mask[None, :, None, None, :], s, _NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqkgc,bckd->bqkgd", p, vj.astype(jnp.float32))
            row_out = acc_new / jnp.maximum(l_new, 1e-20)[..., None]
            out = jax.lax.cond(
                done,
                lambda o: jax.lax.dynamic_update_index_in_dim(o, row_out, i, 1),
                lambda o: o, out)
            m0, l0, acc0 = init_stats()
            m_next = jnp.where(done, m0, m_new)
            l_next = jnp.where(done, l0, l_new)
            acc_next = jnp.where(done, acc0, acc_new)
            return (m_next, l_next, acc_next, out), None

        m0, l0, acc0 = init_stats()
        (_, _, _, out), _ = jax.lax.scan(
            step, (m0, l0, acc0, out), jnp.arange(len(pairs)))
        o = out
    else:
        def q_row(qi_blk, i):
            def kv_step(carry, j):
                m, l, acc = carry
                kj = jax.lax.dynamic_index_in_dim(kg, j, 1, keepdims=False)
                m, l, acc = block(qi_blk, kj, i, j, m, l, acc)
                return (m, l, acc), None

            (m, l, acc), _ = jax.lax.scan(kv_step, init_stats(), jnp.arange(nk))
            return acc / jnp.maximum(l, 1e-20)[..., None]

        o = jax.lax.map(lambda args: q_row(*args),
                        (jnp.moveaxis(qg, 1, 0), jnp.arange(nq)))
        o = jnp.moveaxis(o, 0, 1)                    # (B, nq, qc, KVH, G, dh)

    o = o.reshape(B, nq * qc, H, dh)[:, :Sq]
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA forward (train / prefill) and decode
# ---------------------------------------------------------------------------

def _project_qkv(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                 positions: jnp.ndarray):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if positions is not None:
        q = apply_rope(q.swapaxes(1, 2), positions, cfg.rope_theta).swapaxes(1, 2)
        k = apply_rope(k.swapaxes(1, 2), positions, cfg.rope_theta).swapaxes(1, 2)
    return q, k, v


def _seq_sharded_attention(q, k, v, *, mesh, data_axes, causal, window,
                           model_axis="model"):
    """Sequence-parallel attention for head counts that do not divide the
    model axis (whisper 12H, qwen 40H, hymba 25H).

    Q is sharded over the model axis on the SEQUENCE dim; K/V are
    all-gathered inside the shard (one bf16 gather per layer), and the
    causal mask uses the shard's sequence offset.  Scores never materialize
    beyond (B_loc, S/tp, H, kc)."""
    dp = P(data_axes)

    def body(q_l, k_l, v_l):
        k_f = jax.lax.all_gather(k_l, model_axis, axis=1, tiled=True)
        v_f = jax.lax.all_gather(v_l, model_axis, axis=1, tiled=True)
        off = jax.lax.axis_index(model_axis) * q_l.shape[1]
        return blockwise_attention(q_l, k_f, v_f, causal=causal,
                                   q_offset=off, window=window)

    spec = P(data_axes, model_axis, None, None)
    from repro.compat import shard_map
    return shard_map(body, mesh=mesh, in_specs=(spec, spec, spec),
                     out_specs=spec, check_vma=False)(q, k, v)


def gqa_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray, *,
                positions: jnp.ndarray, causal: bool = True,
                block_skip: bool = False,
                kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                mesh=None, data_axes=("data",),
                ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Full-sequence attention.  Returns (out, (k, v)) for cache building.

    ``kv_override`` supplies external K/V (cross-attention)."""
    q, k, v = _project_qkv(cfg, p, x,
                           None if kv_override is not None else positions)
    if kv_override is not None:
        k, v = kv_override
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        if cfg.qkv_bias:
            q = q + p["bq"]
    use_seq_shard = False
    if mesh is not None and "model" in getattr(mesh, "shape", {}):
        tp = mesh.shape["model"]
        seq_ok = (q.shape[1] % tp == 0 and k.shape[1] % tp == 0
                  and q.shape[1] == k.shape[1])
        use_seq_shard = (cfg.n_heads % tp != 0) and seq_ok and causal
    if use_seq_shard:
        o = _seq_sharded_attention(q, k, v, mesh=mesh, data_axes=data_axes,
                                   causal=causal, window=cfg.sliding_window)
    else:
        o = blockwise_attention(q, k, v, causal=causal,
                                window=cfg.sliding_window,
                                block_skip=block_skip)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, (k, v)


def cross_kv(cfg: ModelConfig, p: Params, enc: jnp.ndarray):
    """Precompute cross-attention K/V from encoder states."""
    k = jnp.einsum("bsd,dhk->bshk", enc, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc, p["wv"])
    if cfg.qkv_bias:
        k = k + p["bk"]
        v = v + p["bv"]
    return k, v


def gqa_decode(cfg: ModelConfig, p: Params, x: jnp.ndarray,
               cache_k: jnp.ndarray, cache_v: jnp.ndarray,
               position: jnp.ndarray, *, update_cache: bool = True,
               k_scale: Optional[jnp.ndarray] = None,
               v_scale: Optional[jnp.ndarray] = None):
    """Single-token decode.  x: (B, 1, d); cache: (B, S, KVH, dh).

    The cache sequence axis may be sharded (model axis) — the softmax
    reductions over it become psums under GSPMD.  With a sliding window the
    cache is a ring buffer of size ``window``.  int8 caches carry
    per-(token, head) ``k_scale``/``v_scale`` (B, S, KVH) and are
    dequantized inline (doubles effective decode bandwidth).

    Returns (out, cache_k, cache_v[, k_scale, v_scale])."""
    B, _, _ = x.shape
    S = cache_k.shape[1]
    H, KVH, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    G = H // KVH
    scale = 1.0 / math.sqrt(dh)
    quantized = k_scale is not None

    pos_vec = position.reshape(1)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k_new, v_new = q + p["bq"], k_new + p["bk"], v_new + p["bv"]
    q = apply_rope(q.swapaxes(1, 2), pos_vec, cfg.rope_theta).swapaxes(1, 2)
    k_new = apply_rope(k_new.swapaxes(1, 2), pos_vec,
                       cfg.rope_theta).swapaxes(1, 2)

    if update_cache:
        slot = position % S if cfg.sliding_window > 0 else position
        if quantized:
            kq, ks = quantize_kv(k_new)        # ks: (B, 1)
            vq, vs = quantize_kv(v_new)
            cache_k = jax.lax.dynamic_update_slice(cache_k, kq,
                                                   (0, slot, 0, 0))
            cache_v = jax.lax.dynamic_update_slice(cache_v, vq,
                                                   (0, slot, 0, 0))
            k_scale = jax.lax.dynamic_update_slice(k_scale, ks, (0, slot))
            v_scale = jax.lax.dynamic_update_slice(v_scale, vs, (0, slot))
        else:
            cache_k = jax.lax.dynamic_update_slice(
                cache_k, k_new.astype(cache_k.dtype), (0, slot, 0, 0))
            cache_v = jax.lax.dynamic_update_slice(
                cache_v, v_new.astype(cache_v.dtype), (0, slot, 0, 0))

    kpos = jnp.arange(S)
    if cfg.sliding_window > 0:
        # ring buffer: slot i holds the latest position p with p % S == i
        latest = position - ((position - kpos) % S)
        valid = (latest >= 0) & (latest >= position - cfg.sliding_window + 1)
        valid = valid | (kpos == (position % S))
    else:
        valid = kpos <= position

    qg = q.reshape(B, KVH, G, dh)
    if quantized:
        # dequantize on the fly: scores = (q·k_q) * s_k   (k_scale: (B, S))
        s_ = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        cache_k.astype(jnp.float32))
        s_ = s_ * k_scale[:, None, None, :] * scale
    else:
        s_ = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        cache_k.astype(jnp.float32)) * scale
    s_ = jnp.where(valid[None, None, None, :], s_, _NEG_INF)
    w = jax.nn.softmax(s_, axis=-1)
    if quantized:
        w_eff = w * v_scale[:, None, None, :]
        o = jnp.einsum("bkgs,bskd->bkgd", w_eff,
                       cache_v.astype(jnp.float32))
    else:
        o = jnp.einsum("bkgs,bskd->bkgd", w, cache_v.astype(jnp.float32))
    o = o.reshape(B, 1, H, dh).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if quantized:
        return out, cache_k, cache_v, k_scale, v_scale
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def _rms(x, scale):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
            * scale.astype(jnp.float32)).astype(x.dtype)


def _mla_q(cfg: ModelConfig, p: Params, x, positions):
    m = cfg.mla
    if m.q_lora_rank:
        ql = _rms(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_norm"])
    else:
        ql = x
    q = jnp.einsum("bsr,rhk->bshk", ql, p["wq_b"])
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:].swapaxes(1, 2),
                        positions, cfg.rope_theta).swapaxes(1, 2)
    return q_nope, q_rope


def _mla_latent(cfg: ModelConfig, p: Params, x, positions):
    m = cfg.mla
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    ckv = _rms(kv[..., :m.kv_lora_rank], p["kv_norm"])
    k_rope = kv[..., m.kv_lora_rank:]                       # (B, S, rope)
    k_rope = apply_rope(k_rope[:, None], positions,
                        cfg.rope_theta)[:, 0]
    return ckv, k_rope


def mla_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray, *,
                positions: jnp.ndarray, block_skip: bool = False,
                ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Full-sequence MLA.  Returns (out, (ckv, k_rope)) latent cache."""
    m = cfg.mla
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    ckv, k_rope = _mla_latent(cfg, p, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["wkv_b_nope"])
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["wkv_b_v"])
    H = cfg.n_heads
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :],
                                k_rope.shape[:2] + (H, m.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    # pad v head dim up to qk dim so the blockwise helper can be reused
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_dim - m.v_head_dim)))
    o = blockwise_attention(q, k, v_pad, causal=True, block_skip=block_skip)
    o = o[..., :m.v_head_dim]
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, (ckv, k_rope)


def mla_decode(cfg: ModelConfig, p: Params, x: jnp.ndarray,
               cache_ckv: jnp.ndarray, cache_krope: jnp.ndarray,
               position: jnp.ndarray,
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Matrix-absorbed MLA decode (DeepSeek-V2 inference optimization).

    Scores are computed directly in the latent space: the per-head nope
    projection is absorbed into the query, so the cache stays (B, S, r).
    """
    m = cfg.mla
    B = x.shape[0]
    S = cache_ckv.shape[1]
    pos_vec = position.reshape(1)

    q_nope, q_rope = _mla_q(cfg, p, x, pos_vec)             # (B,1,H,*)
    ckv_new, krope_new = _mla_latent(cfg, p, x, pos_vec)
    cache_ckv = jax.lax.dynamic_update_slice(
        cache_ckv, ckv_new.astype(cache_ckv.dtype), (0, position, 0))
    cache_krope = jax.lax.dynamic_update_slice(
        cache_krope, krope_new.astype(cache_krope.dtype), (0, position, 0))

    # absorb W_k_nope into q:  (B,1,H,nope) x (r,H,nope) -> (B,H,r)
    q_lat = jnp.einsum("bshk,rhk->bhr", q_nope.astype(jnp.float32),
                       p["wkv_b_nope"].astype(jnp.float32))
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = (jnp.einsum("bhr,bsr->bhs", q_lat,
                    cache_ckv.astype(jnp.float32))
         + jnp.einsum("bshk,bSk->bhS", q_rope.astype(jnp.float32),
                      cache_krope.astype(jnp.float32))) * scale
    valid = jnp.arange(S) <= position
    s = jnp.where(valid[None, None, :], s, _NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", w, cache_ckv.astype(jnp.float32))
    o = jnp.einsum("bhr,rhk->bhk", o_lat,
                   p["wkv_b_v"].astype(jnp.float32))        # (B,H,v)
    out = jnp.einsum("bhk,hkd->bd", o.astype(x.dtype), p["wo"])[:, None]
    return out, cache_ckv, cache_krope
