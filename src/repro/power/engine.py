"""The power engine: ``simulate(workload, operating_point) → PowerTrace``.

Time-stepped driver in the ExaDigiT/RAPS mold: a workload supplies a
relative load profile (synthetic shape or telemetry replay), the layered
cluster model converts load → per-component watts at each tick, and a
:class:`TraceRecorder` assembles the fixed-interval trace that the
Green500 methodology and the paper-table benchmarks consume.

The same module exposes ``evaluate_operating_point`` — node (perf,
power) at one knob setting — which is the query surface the autotuner's
cost model uses instead of carrying its own power model.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Protocol, Tuple

import numpy as np

from repro.power.layers import ClusterModel, NodeModel, lcsc_cluster
from repro.power.model import (OperatingPoint, fan_curve,
                               hpl_block_perf_scale, lookahead_perf_scale)
from repro.power.trace import PowerTrace, TraceRecorder


def node_hpl_gflops(op: OperatingPoint, node: Optional[NodeModel] = None,
                    ) -> float:
    """Node Linpack GFLOPS at an operating point (throttle-aware perf
    model × blocking/lookahead calibration curves)."""
    from repro.core.energy.throttle import hpl_node_perf
    node = node or NodeModel()
    return (hpl_node_perf(op.f_mhz, node.vids, temp_c=op.temperature(),
                          util=op.gpu_util())
            * hpl_block_perf_scale(op.nb) * lookahead_perf_scale(op.lookahead))


def evaluate_operating_point(op: OperatingPoint,
                             node: Optional[NodeModel] = None,
                             ) -> Tuple[float, float]:
    """(perf_gflops, wall_power_w) of one node at ``op`` — the engine
    query the autotuner's analytic cost model is built on."""
    node = node or NodeModel()
    perf = node_hpl_gflops(op, node)
    power = node.power(op)
    return perf, power


# ---------------------------------------------------------------------------
# Workloads: synthetic shapes and telemetry replay
# ---------------------------------------------------------------------------


class Workload(Protocol):
    """A relative GPU-load profile over time (both values in [0, 1])."""

    duration_s: float

    def load(self, t: float) -> float:
        ...


@dataclass(frozen=True)
class SyntheticHPL:
    """One HPL run: full load through factorization, N³-ish decay in the
    final quarter as the trailing matrix shrinks — the shape that makes
    Level-1 window-picking exploitable (paper §3).  Delegates to the
    single load-curve definition in :mod:`repro.power.green500`."""

    duration_s: float = 3600.0
    tail_start: float = 0.75
    tail_floor: float = 0.35

    def load(self, t: float) -> float:
        from repro.power.green500 import hpl_load_profile
        x = np.clip(t / self.duration_s, 0.0, 1.0)
        return float(hpl_load_profile(x, tail_start=self.tail_start,
                                      tail_floor=self.tail_floor))


@dataclass(frozen=True)
class ConstantLoad:
    """Steady-state operation (single-node calibration runs)."""

    duration_s: float = 600.0
    level: float = 1.0

    def load(self, t: float) -> float:
        return self.level


@dataclass(frozen=True)
class ReplayWorkload:
    """Replay a recorded utilization series (RAPS telemetry-replay mode):
    piecewise-linear interpolation of (t, util) samples."""

    t: np.ndarray
    util: np.ndarray

    @property
    def duration_s(self) -> float:
        return float(self.t[-1] - self.t[0])

    @classmethod
    def from_trace(cls, trace: PowerTrace,
                   key: str = "util") -> "ReplayWorkload":
        if key not in trace.aux:
            raise KeyError(f"trace has no {key!r} aux series "
                           f"(has {sorted(trace.aux)})")
        u = np.asarray(trace.aux[key], dtype=float)
        peak = float(np.max(u)) or 1.0
        return cls(np.asarray(trace.t, dtype=float), u / peak)

    def load(self, t: float) -> float:
        return float(np.interp(t, self.t, self.util))


# ---------------------------------------------------------------------------
# The driver
# ---------------------------------------------------------------------------


def simulate(workload: Workload,
             op: Optional[OperatingPoint] = None, *,
             cluster: Optional[ClusterModel] = None,
             dt_s: float = 5.0,
             adaptive_fan: bool = True,
             recorder: Optional[TraceRecorder] = None) -> PowerTrace:
    """Run ``workload`` on ``cluster`` at ``op`` and return the telemetry.

    The workload's relative load is sampled on the tick grid, the fan
    duty derives from it (load-adaptive derating below the set point
    when ``adaptive_fan``, the paper's end-of-run fan curve), and the
    whole series is evaluated in one pass through the batched layer API
    (``ClusterModel.component_watts_series``) — per-sample results are
    identical to ticking the scalar layers.  FLOPS rate scales with
    load from the node perf model, so Green500 efficiency figures come
    straight off the returned :class:`PowerTrace`.
    """
    op = op or OperatingPoint.green500()
    cluster = cluster or lcsc_cluster()
    # explicit None check: an empty recorder is falsy (__len__ == 0) but
    # still the caller's bus
    rec = recorder if recorder is not None \
        else TraceRecorder(dt_s=dt_s, source="power.simulate")
    # a shared bus may carry earlier phases: stack after its latest
    # sample (the convention every emitter on the bus follows)
    t0 = rec.t_last
    cluster_gflops = float(sum(node_hpl_gflops(op, n)
                               for n in cluster.nodes))
    ts = np.arange(0.0, workload.duration_s + dt_s, dt_s)
    loads = np.clip([workload.load(min(float(t), workload.duration_s))
                     for t in ts], 0.0, 1.0)
    fans = np.minimum(op.fan, fan_curve(loads)) if adaptive_fan \
        else np.full(ts.shape, op.fan)
    watts = cluster.component_watts_series(op, load=loads, fan=fans)
    rec.emit_series(t0 + ts, watts, flops_rate=cluster_gflops * loads,
                    util=op.gpu_util() * loads, f_mhz=op.f_mhz,
                    fan=fans, temp_c=op.temperature())
    trace = rec.trace()
    trace.meta.setdefault("n_nodes", cluster.n_nodes)
    trace.meta.setdefault("operating_point", {
        "f_mhz": op.f_mhz, "vid": op.vid, "fan": op.fan, "nb": op.nb,
        "lookahead": op.lookahead})
    return trace
