"""Device-level power models — the calibration layer of ``repro.power``.

This module is the **single definition point** for every electrical
calibration constant in the repo (the dedup test in
``tests/test_power_dedup.py`` enforces it).  It merges what used to live
in three places:

  * ``core/energy/power_model.py`` — the GPU/fan/TPU electrical models;
  * ``core/energy/throttle.py`` — the power side of TDP throttling
    (``sustained_frequency`` / ``gpu_power_throttled``; the *performance*
    curves stay in ``core.energy.throttle``);
  * ``autotune/measure.py`` — the fan→temperature and HPL-blocking
    utilization curves that had been forked into the autotuner.

Calibration targets (all published, paper Fig. 1 and §2–4):
  * S9150 TDP 275 W; stock 900 MHz, efficiency clock 774 MHz
  * voltage IDs span 1.1425 V … 1.2 V at 900 MHz (Fig. 1a)
  * optimum fan duty 40%, power slope steeper above 40% (Fig. 1b)
  * Green500 run: 56 nodes, 57.2 kW → 1021 W/node at 774 MHz
  * node Linpack 6175–6280 GFLOPS @900 MHz, ≈5384 GFLOPS @774 MHz
    (301.5 TFLOPS / 56), efficiency 5271.8 MFLOPS/W

GPU model:  P_gpu = P_static(V, T) + K_DYN · f · V² · util   (f in GHz)
The node/rack/cluster composition (host, fans, PSU-efficiency curve,
network switches) lives in :mod:`repro.power.layers`.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Protocol, Tuple, runtime_checkable

import numpy as np

# ---------------------------------------------------------------------------
# Device specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GPUSpec:
    name: str
    stream_processors: int
    fp64_flops_per_sp_per_cycle: float
    tdp_w: float
    mem_bw_gbs: float
    mem_gb: int

    def peak_fp64_gflops(self, f_ghz: float) -> float:
        return (self.stream_processors * self.fp64_flops_per_sp_per_cycle
                * f_ghz)


S9150 = GPUSpec("FirePro S9150", 2816, 1.0, 275.0, 320.0, 16)
S10000_CHIP = GPUSpec("FirePro S10000 (per chip)", 1792, 0.5, 187.5, 240.0, 6)

# Published clocks / voltages
STOCK_MHZ = 900
EFFICIENT_MHZ = 774
V_MIN = 1.1425           # best chips' voltage ID at 900 MHz
V_MAX = 1.2              # worst chips'

# Calibrated constants
P_GPU_STATIC_40C = 35.0  # W at 40 °C, V_MIN
TEMP_SLOPE_W_PER_C = 0.30
K_DYN = 200.0            # W / (GHz · V²): V_MIN chips just avoid throttle at 900
FAN_BASE_W = 12.0
FAN_CUBIC_W = 160.0      # node fans at 100% ≈ 172 W
V_F_SLOPE = 0.0006       # V per MHz of downclock


def voltage_at(f_mhz, vid_900):
    """Operating voltage at frequency f for a chip with voltage-ID vid_900.
    Array-aware over both axes: the per-bin batched layer entry points
    hand whole (clock, vid) spreads in at once."""
    v = np.maximum(0.8, vid_900 - V_F_SLOPE * (STOCK_MHZ
                                               - np.asarray(f_mhz)))
    return float(v) if np.ndim(v) == 0 else v


def gpu_static_power(vid_900, temp_c=55.0):
    """Static (leakage) draw at a voltage ID and temperature.  Array-aware
    over both axes (per-chip vid / per-sample temperature spreads)."""
    scale = (np.asarray(vid_900) / V_MIN) ** 2
    p = (P_GPU_STATIC_40C
         + TEMP_SLOPE_W_PER_C * np.maximum(np.asarray(temp_c) - 40.0, 0.0)) \
        * scale
    return float(p) if np.ndim(p) == 0 else p


def gpu_dynamic_power(f_ghz: float, v: float, util: float = 1.0) -> float:
    return K_DYN * f_ghz * v * v * util


def gpu_power(f_mhz: float, vid_900: float, *, temp_c: float = 55.0,
              util: float = 1.0, spec: GPUSpec = S9150) -> float:
    """Un-throttled electrical power draw (may exceed TDP — the throttle
    clamp reduces frequency, not physics; see ``gpu_power_throttled``)."""
    v = voltage_at(f_mhz, vid_900)
    return gpu_static_power(vid_900, temp_c) + gpu_dynamic_power(
        f_mhz / 1000.0, v, util)


def fan_power(speed):
    """Node fan power vs duty cycle in [0, 1] (cubic — Fig. 1b shape).
    Array-aware: an ndarray of duties returns an ndarray of watts."""
    s = np.clip(speed, 0.0, 1.0)
    p = FAN_BASE_W + FAN_CUBIC_W * s ** 3
    return float(p) if np.ndim(speed) == 0 else p


def sample_vids(rng: np.random.Generator, n: int) -> np.ndarray:
    """Manufacturing voltage-ID spread (paper: every ASIC differs)."""
    # triangular-ish spread within the published [V_MIN, V_MAX]
    return np.clip(rng.normal((V_MIN + V_MAX) / 2, 0.015, n), V_MIN, V_MAX)


# ---------------------------------------------------------------------------
# TDP throttle — the power side (paper §2, Fig. 1a)
# ---------------------------------------------------------------------------


def sustained_frequency(f_set_mhz: float, vid_900: float, *,
                        temp_c: float = 55.0, util: float = 1.0,
                        tdp_w: float = S9150.tdp_w) -> Tuple[float, bool]:
    """Highest clock the TDP allows; returns (f_sustained_MHz, throttled)."""
    v = voltage_at(f_set_mhz, vid_900)
    p_static = gpu_static_power(vid_900, temp_c)
    p_dyn = K_DYN * (f_set_mhz / 1000.0) * v * v * util
    if p_static + p_dyn <= tdp_w:
        return f_set_mhz, False
    # clamp: solve P_static + K f v(f)^2 util = TDP (v approximately fixed
    # at the set-point voltage — firmware lowers f, not V, under TDP)
    f = (tdp_w - p_static) / (K_DYN * v * v * util) * 1000.0
    return max(f, 100.0), True


def gpu_power_throttled(f_set_mhz: float, vid_900: float, *,
                        temp_c: float = 55.0, util=1.0,
                        tdp_w: float = S9150.tdp_w):
    """Actual draw: TDP when throttling, model power otherwise.
    Array-aware over ``util`` (the batched layer entry points hand a
    whole duty-cycle series in at once)."""
    v = voltage_at(f_set_mhz, vid_900)
    p = gpu_static_power(vid_900, temp_c) \
        + K_DYN * (f_set_mhz / 1000.0) * v * v * util
    if np.ndim(p) == 0:
        return min(float(p), tdp_w)
    return np.minimum(p, tdp_w)


# ---------------------------------------------------------------------------
# Calibration curves shared by the autotuner and the power engine
# (formerly private copies in ``autotune/measure.py``)
# ---------------------------------------------------------------------------

# Efficiency- vs performance-mode HPL update blocking (HPL-GPU's NB) and
# the Green500 run's sustained GPU duty cycle at efficiency NB.
NB_EFFICIENCY = 512
NB_PERFORMANCE = 1024
HPL_GPU_UTIL = 0.908


def temp_from_fan(fan: float, *, ambient_c: float = 40.0) -> float:
    """GPU steady-state temperature vs fan duty (calibrated: 55 °C @ 40%).

    The Fig. 1b trade is fan power (cubic in duty) vs the GPU
    static-power temperature slope; cooling degrades quadratically below
    the 40% optimum (airflow starves fast at low duty)."""
    return ambient_c + 2.4 / max(float(fan), 0.05) ** 2


def hpl_block_util(nb: float) -> float:
    """Sustained GPU duty cycle vs HPL update blocking.  Efficiency-mode
    NB (512) is the calibrated Green500-run value; bigger blocks keep the
    DGEMM pipeline fuller (and hotter)."""
    return float(np.clip(HPL_GPU_UTIL + 0.042 * np.log2(nb / NB_EFFICIENCY),
                         0.85, 0.95))


def hpl_block_perf_scale(nb: float) -> float:
    """Throughput vs blocking.  Saturating with a knee at the efficiency
    NB: going 512 → 1024 buys ~1.1% (GEMM amortization is nearly flat up
    there), while every halving below 512 costs quadratically (panel
    latency and pipeline drain stop amortizing)."""
    return float(max(1.0 - 0.015 * (NB_EFFICIENCY / nb) ** 2, 0.01))


def lookahead_perf_scale(depth: int) -> float:
    """Lookahead ≥ 1 fully overlaps panel factorization with the trailing
    update (HPL-GPU); depth 0 serializes it."""
    return 1.0 if depth >= 1 else 0.96


def fan_curve(load):
    """Load-adaptive fan duty (paper: 'a curve that defines different FAN
    duty cycles for different load levels', used at the end of the run).
    Array-aware: a load series returns a duty series."""
    duty = np.clip(0.15 + 0.25 * np.asarray(load) / 0.9, 0.15, 0.40)
    return float(duty) if np.ndim(load) == 0 else duty


# ---------------------------------------------------------------------------
# Operating point — the knob vector every layer of the engine accepts
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class OperatingPoint:
    """One point in the paper's search space: clock, voltage ID, fan
    duty, HPL blocking and lookahead depth.

    ``temp_c``/``util`` default to the calibrated curves
    (``temp_from_fan`` / ``hpl_block_util``) and can be pinned
    explicitly, which is how the legacy ``node_power`` signature maps
    onto the engine."""

    f_mhz: float = float(EFFICIENT_MHZ)
    vid: float = V_MIN
    fan: float = 0.40
    # float: the autotuner maps CPU-scale HPL blocks onto a continuous
    # NB-equivalent axis (block · 2048 / n)
    nb: float = NB_EFFICIENCY
    lookahead: int = 1
    temp_c: Optional[float] = None
    util: Optional[float] = None

    @classmethod
    def green500(cls) -> "OperatingPoint":
        """The published record point: 774 MHz, VID floor, 40% fan,
        efficiency-mode blocking."""
        return cls()

    @classmethod
    def from_point(cls, point: Dict) -> "OperatingPoint":
        """Build from an autotuner point dict (``space.operating_space``)."""
        return cls(f_mhz=float(point["f_mhz"]), vid=float(point["vid"]),
                   fan=float(point["fan"]),
                   nb=float(point.get("nb", NB_EFFICIENCY)),
                   lookahead=int(point.get("lookahead", 1)))

    def temperature(self) -> float:
        return self.temp_c if self.temp_c is not None \
            else temp_from_fan(self.fan)

    def gpu_util(self) -> float:
        return self.util if self.util is not None \
            else hpl_block_util(self.nb)

    def replace(self, **kw) -> "OperatingPoint":
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# PowerModel protocol — what every layer of the composition implements
# ---------------------------------------------------------------------------


@runtime_checkable
class PowerModel(Protocol):
    """Anything that can report component watts at an operating point.

    ``load`` scales the *dynamic* portion (GPU duty cycle) in [0, 1];
    ``fan`` overrides the operating point's duty (the engine's adaptive
    fan mode).  ``component_watts`` keys are stable component names
    (``gpu``, ``host``, ``fan``, ``psu_loss``, ``network``) whose values
    sum to ``power``."""

    def component_watts(self, op: OperatingPoint, *, load: float = 1.0,
                        fan: Optional[float] = None) -> Dict[str, float]:
        ...

    def power(self, op: OperatingPoint, *, load: float = 1.0,
              fan: Optional[float] = None) -> float:
        ...


# ---------------------------------------------------------------------------
# TPU-side power model (the framework target; assumed constants, documented)
# ---------------------------------------------------------------------------

TPU_IDLE_W = 60.0
TPU_DYN_COMPUTE_W = 110.0    # MXU-bound at full clock
TPU_DYN_MEM_W = 30.0         # HBM-bound component
TPU_TDP_W = 200.0            # per-chip budget (v5e-class, assumed)


def tpu_chip_power(freq_scale: float, compute_util: float,
                   mem_util: float) -> float:
    """P(f) for a TPU chip: dynamic compute power scales ~ f·V(f)² ≈ f²."""
    f = float(np.clip(freq_scale, 0.3, 1.0))
    return (TPU_IDLE_W + TPU_DYN_COMPUTE_W * f * f * compute_util
            + TPU_DYN_MEM_W * mem_util)


@dataclass(frozen=True)
class TPUChipModel:
    """:class:`PowerModel` adapter for the TPU chip constants, so the
    jax-side drivers (train/serve/linpack) emit telemetry through the
    same engine as the GPU cluster."""

    freq_scale: float = 1.0
    compute_util: float = 1.0
    mem_util: float = 0.5

    def component_watts(self, op: OperatingPoint = OperatingPoint(), *,
                        load: float = 1.0,
                        fan: Optional[float] = None) -> Dict[str, float]:
        dyn = tpu_chip_power(self.freq_scale, self.compute_util * load,
                             self.mem_util * load) - TPU_IDLE_W
        return {"chip_idle": TPU_IDLE_W, "chip_dyn": dyn}

    def power(self, op: OperatingPoint = OperatingPoint(), *,
              load: float = 1.0, fan: Optional[float] = None) -> float:
        return float(sum(self.component_watts(op, load=load).values()))


# re-exported field helper so layers can build default populations
def uniform_vids(n: int, vid: float = V_MIN) -> Tuple[float, ...]:
    return tuple([vid] * n)


__all__ = [
    "GPUSpec", "S9150", "S10000_CHIP", "STOCK_MHZ", "EFFICIENT_MHZ",
    "V_MIN", "V_MAX", "P_GPU_STATIC_40C", "TEMP_SLOPE_W_PER_C", "K_DYN",
    "FAN_BASE_W", "FAN_CUBIC_W", "V_F_SLOPE", "voltage_at",
    "gpu_static_power", "gpu_dynamic_power", "gpu_power", "fan_power",
    "sample_vids", "sustained_frequency", "gpu_power_throttled",
    "NB_EFFICIENCY", "NB_PERFORMANCE", "HPL_GPU_UTIL", "temp_from_fan",
    "hpl_block_util", "hpl_block_perf_scale", "lookahead_perf_scale",
    "fan_curve", "OperatingPoint", "PowerModel", "TPU_IDLE_W",
    "TPU_DYN_COMPUTE_W", "TPU_DYN_MEM_W", "TPU_TDP_W", "tpu_chip_power",
    "TPUChipModel", "uniform_vids",
]
