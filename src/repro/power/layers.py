"""Layered power composition: GPU → node → rack → cluster.

The paper's headline numbers are *cluster-level* wall-plug measurements
(compute nodes + PSU losses + fans + network switches, §3–4).  This
module composes them from the device models in :mod:`repro.power.model`
so the published 1021 W/node and 57.2 kW cluster figures fall out of
aggregation rather than being hard-coded:

  :class:`GPUModel`      one ASIC (voltage ID binds the chip's bin)
  :class:`NodeModel`     host + 4×S9150 + fans, behind a PSU-efficiency
                         curve (DC components / η(load) = wall watts)
  :class:`RackModel`     nodes, aggregated per component
  :class:`ClusterModel`  racks + network switches (measured separately
                         at Green500 Level 3: 257 W for L-CSC)

Every layer implements the :class:`repro.power.model.PowerModel`
protocol, so traces, benchmarks and the autotuner can query any level.

Calibration: the GPU/fan curves are wall-calibrated legacy constants
re-interpreted as DC-side draw; ``P_HOST_DC_W`` and the PSU curve are
chosen so the composed wall power at the Green500 operating point
reproduces the published ~1021 W/node (ESC4000-class servers: 1620 W
redundant PSUs, ~94% peak efficiency near half load).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.power.model import (S9150, GPUSpec, OperatingPoint, V_MIN,
                               fan_power, gpu_power, gpu_power_throttled,
                               uniform_vids)

# A batched entry point accepts either one shared operating point or a
# per-chip / per-sample spread of them (resolved through op_bins).
OpOrSpread = Union[OperatingPoint, Sequence[OperatingPoint]]


def op_bins(ops: Sequence[OperatingPoint],
            ) -> Tuple[List[OperatingPoint], np.ndarray]:
    """Dedupe an operating-point spread into ``(bins, index)``: ``bins``
    holds the distinct points in first-seen order and ``index[i]`` is the
    bin of ``ops[i]``.  The batched layer entry points evaluate the
    scalar device model once per *bin* — not once per chip or sample —
    and gather through the index, so a heterogeneous population costs
    as many model evaluations as it has distinct operating points."""
    bins: List[OperatingPoint] = []
    where: Dict[OperatingPoint, int] = {}
    index = np.empty(len(ops), dtype=np.intp)
    for i, o in enumerate(ops):
        b = where.get(o)
        if b is None:
            b = where[o] = len(bins)
            bins.append(o)
        index[i] = b
    return bins, index

# Host DC draw: 2x10-core CPUs + 256 GB DIMMs + chipset + IB HCA.  The
# legacy flat model charged the host 200 W *at the wall*; the composed
# model splits that into 137.8 W of DC draw plus its share of PSU loss.
P_HOST_DC_W = 137.8

# PSU calibration (1620 W redundant supplies, platinum-class curve)
PSU_RATED_W = 1620.0
PSU_EFF_PEAK = 0.94
PSU_LOAD_PEAK = 0.5
PSU_EFF_CURVATURE = 0.12


@dataclass(frozen=True)
class PSUCurve:
    """Wall↔DC conversion: η(load) peaks near half load and falls off
    quadratically toward idle and full load (80 Plus Platinum shape)."""

    rated_w: float = PSU_RATED_W
    eff_peak: float = PSU_EFF_PEAK
    load_peak: float = PSU_LOAD_PEAK
    curvature: float = PSU_EFF_CURVATURE

    def efficiency(self, dc_w):
        """η(load) — array-aware: a DC-draw series maps elementwise."""
        load = np.clip(np.asarray(dc_w, dtype=float) / self.rated_w,
                       0.02, 1.2)
        eff = self.eff_peak - self.curvature * (load - self.load_peak) ** 2
        return float(eff) if np.ndim(dc_w) == 0 else eff

    def wall_power(self, dc_w):
        return dc_w / self.efficiency(dc_w)

    def loss_w(self, dc_w):
        return self.wall_power(dc_w) - dc_w


LCSC_PSU = PSUCurve()


@dataclass(frozen=True)
class GPUModel:
    """One ASIC: the voltage ID binds the chip's manufacturing bin."""

    vid: float = V_MIN
    spec: GPUSpec = S9150

    def component_watts(self, op: OperatingPoint, *, load: float = 1.0,
                        fan: Optional[float] = None) -> Dict[str, float]:
        return {"gpu": self.power(op, load=load)}

    def power(self, op: OperatingPoint, *, load: float = 1.0,
              fan: Optional[float] = None) -> float:
        """TDP-clamped board draw at the operating point; ``load`` scales
        the duty cycle (telemetry replay / end-of-run tail)."""
        return gpu_power_throttled(op.f_mhz, self.vid,
                                   temp_c=op.temperature(),
                                   util=op.gpu_util() * load,
                                   tdp_w=self.spec.tdp_w)

    def power_batch(self, op: OpOrSpread, *, load) -> np.ndarray:
        """Vectorized :meth:`power`: an array of duty-cycle loads maps
        elementwise to board watts (same model, one ufunc pass).

        ``op`` may also be a per-sample *spread* of operating points
        (zipped elementwise with ``load``): the spread is deduped into
        per-bin (clock, temperature, utilization) lookup tables via
        :func:`op_bins`, so sample ``i`` draws exactly what
        ``power(op[i], load=load[i])`` returns — bit-for-bit."""
        if isinstance(op, OperatingPoint):
            return gpu_power_throttled(op.f_mhz, self.vid,
                                       temp_c=op.temperature(),
                                       util=op.gpu_util()
                                       * np.asarray(load, dtype=float),
                                       tdp_w=self.spec.tdp_w)
        bins, idx = op_bins(op)
        f = np.array([o.f_mhz for o in bins])[idx]
        temp = np.array([o.temperature() for o in bins])[idx]
        util = np.array([o.gpu_util() for o in bins])[idx]
        return gpu_power_throttled(f, self.vid, temp_c=temp,
                                   util=util * np.asarray(load, dtype=float),
                                   tdp_w=self.spec.tdp_w)

    def component_watts_batch(self, op: OpOrSpread, *,
                              load) -> Dict[str, np.ndarray]:
        return {"gpu": self.power_batch(op, load=load)}

    def unconstrained_power(self, op: OperatingPoint, *,
                            load: float = 1.0) -> float:
        """Model draw ignoring the TDP clamp (Fig. 1b style sweeps)."""
        return gpu_power(op.f_mhz, self.vid, temp_c=op.temperature(),
                         util=op.gpu_util() * load, spec=self.spec)


@dataclass(frozen=True)
class NodeModel:
    """Host + GPUs + fans behind the PSU-efficiency curve.

    ``component_watts`` values are wall-referred: the DC components are
    reported as-is and the conversion loss appears as ``psu_loss``, so
    the dict sums to wall power."""

    gpus: Tuple[GPUModel, ...] = field(
        default_factory=lambda: tuple(GPUModel() for _ in range(4)))
    host_dc_w: float = P_HOST_DC_W
    psu: PSUCurve = LCSC_PSU

    @classmethod
    def from_vids(cls, vids: Sequence[float], *,
                  spec: GPUSpec = S9150) -> "NodeModel":
        return cls(gpus=tuple(GPUModel(float(v), spec) for v in vids))

    @property
    def vids(self) -> Tuple[float, ...]:
        return tuple(g.vid for g in self.gpus)

    def component_watts(self, op: OperatingPoint, *, load: float = 1.0,
                        fan: Optional[float] = None,
                        gpu_w_override: Optional[Sequence[float]] = None,
                        ) -> Dict[str, float]:
        gpu_dc = None if gpu_w_override is None \
            else float(np.sum(gpu_w_override))
        watts = self.component_watts_series(op, load=load, fan=fan,
                                            gpu_dc=gpu_dc)
        return {k: float(v) for k, v in watts.items()}

    def component_watts_series(self, op: OpOrSpread, *, load=1.0,
                               fan=None, gpu_dc=None,
                               ) -> Dict[str, np.ndarray]:
        """Batched :meth:`component_watts` over a *time series*: ``load``
        and/or ``fan`` may be arrays (one entry per sample) and every
        returned component is an array of the common broadcast shape.
        ``op`` may be a per-sample spread of operating points (see
        :func:`op_bins`); the fan duty then defaults to each sample's
        own point.  ``gpu_dc`` short-circuits the GPU model with a
        precomputed DC draw per sample (the occupancy engine's path)."""
        if isinstance(op, OperatingPoint):
            duty = op.fan if fan is None else fan
        else:
            duty = np.array([o.fan for o in op]) if fan is None else fan
        if gpu_dc is None:
            gpu_dc = 0.0
            for g in self.gpus:
                gpu_dc = gpu_dc + g.power_batch(op, load=load)
        fan_dc = fan_power(duty)
        dc = self.host_dc_w + gpu_dc + fan_dc
        shape = np.shape(dc)

        def full(v):
            return np.broadcast_to(np.asarray(v, dtype=float), shape).copy()

        return {"gpu": full(gpu_dc), "host": full(self.host_dc_w),
                "fan": full(fan_dc), "psu_loss": full(self.psu.loss_w(dc))}

    def component_watts_batch(self, op: OperatingPoint, busy_counts, *,
                              fan=None, chip_ops:
                              Optional[Sequence[OperatingPoint]] = None,
                              ) -> Dict[str, np.ndarray]:
        """Batched :meth:`component_watts` over *occupancy*.

        Homogeneous form (``chip_ops=None``): ``busy_counts`` is an
        integer array of busy-chip counts (0 … ``len(self.gpus)``); each
        distinct count is evaluated once through the scalar GPU model (a
        ``len(gpus)+1``-entry lookup table) and broadcast — ``gpus[0]``
        binds the bin for the whole population.

        Heterogeneous form: ``chip_ops`` gives every chip its own
        operating point (clock/vid/fan spread) and ``busy_counts``
        becomes a boolean occupancy mask whose trailing axis is the chip
        axis.  Each chip's busy/idle watts are evaluated once through
        *its own* scalar model (a per-chip two-entry lookup table — the
        per-bin generalization), summed in chip order, so per-sample
        totals match the scalar ``component_watts(gpu_w_override=...)``
        path bit-for-bit.  ``op`` still sets the node-level fan default.

        NOTE: the homogeneous count table adds busy chips first, which
        may differ in the last ulp from a mixed chip-order sum, so that
        convenience form must not replace the engine's chip-order sum."""
        g = len(self.gpus)
        if chip_ops is not None:
            if len(chip_ops) != g:
                raise ValueError(f"chip_ops must give one operating point "
                                 f"per chip ({g}), got {len(chip_ops)}")
            mask = np.asarray(busy_counts, dtype=bool)
            if mask.shape[-1:] != (g,):
                raise ValueError(f"with chip_ops, busy_counts is a boolean "
                                 f"mask whose last axis is the chip axis "
                                 f"({g}); got shape {mask.shape}")
            w_busy = np.array([gpu.power(o, load=1.0)
                               for gpu, o in zip(self.gpus, chip_ops)])
            w_idle = np.array([gpu.power(o, load=0.0)
                               for gpu, o in zip(self.gpus, chip_ops)])
            gpu_dc = np.sum(np.where(mask, w_busy, w_idle), axis=-1)
            return self.component_watts_series(op, fan=fan, gpu_dc=gpu_dc)
        counts = np.asarray(busy_counts, dtype=np.intp)
        if counts.size and (counts.min() < 0 or counts.max() > g):
            raise ValueError(f"busy counts must lie in [0, {g}]")
        w_busy = self.gpus[0].power(op, load=1.0)
        w_idle = self.gpus[0].power(op, load=0.0)
        table = np.array([float(np.sum([w_busy] * b + [w_idle] * (g - b)))
                          for b in range(g + 1)])
        return self.component_watts_series(op, fan=fan,
                                           gpu_dc=table[counts])

    def power(self, op: OperatingPoint, *, load: float = 1.0,
              fan: Optional[float] = None,
              gpu_w_override: Optional[Sequence[float]] = None) -> float:
        return float(sum(self.component_watts(
            op, load=load, fan=fan, gpu_w_override=gpu_w_override).values()))


@dataclass(frozen=True)
class RackModel:
    """Per-component aggregation over a rack's nodes."""

    nodes: Tuple[NodeModel, ...]

    def component_watts(self, op: OperatingPoint, *, load: float = 1.0,
                        fan: Optional[float] = None) -> Dict[str, float]:
        return {k: float(v) for k, v in self.component_watts_series(
            op, load=load, fan=fan).items()}

    def component_watts_series(self, op: OperatingPoint, *, load=1.0,
                               fan=None) -> Dict[str, np.ndarray]:
        """Batched :meth:`component_watts` over a load/fan time series
        (the scalar API is a thin wrapper over this path)."""
        total: Dict[str, np.ndarray] = {}
        for node in self.nodes:
            for name, w in node.component_watts_series(op, load=load,
                                                       fan=fan).items():
                total[name] = total.get(name, 0.0) + w
        return total

    def power(self, op: OperatingPoint, *, load: float = 1.0,
              fan: Optional[float] = None) -> float:
        return float(sum(self.component_watts(op, load=load,
                                              fan=fan).values()))


@dataclass(frozen=True)
class ClusterModel:
    """Racks + network switches (the L3-measured 257 W for L-CSC)."""

    racks: Tuple[RackModel, ...]
    network_w: float = 0.0

    @property
    def nodes(self) -> Tuple[NodeModel, ...]:
        return tuple(n for r in self.racks for n in r.nodes)

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    def component_watts(self, op: OperatingPoint, *, load: float = 1.0,
                        fan: Optional[float] = None,
                        include_network: bool = True) -> Dict[str, float]:
        return {k: float(v) for k, v in self.component_watts_series(
            op, load=load, fan=fan,
            include_network=include_network).items()}

    def component_watts_series(self, op: OperatingPoint, *, load=1.0,
                               fan=None, include_network: bool = True,
                               ) -> Dict[str, np.ndarray]:
        """Batched :meth:`component_watts` over a load/fan time series —
        what the vectorized :func:`repro.power.engine.simulate` drives
        (the scalar API is a thin wrapper over this path)."""
        total: Dict[str, np.ndarray] = {}
        for rack in self.racks:
            for name, w in rack.component_watts_series(op, load=load,
                                                       fan=fan).items():
                total[name] = total.get(name, 0.0) + w
        if include_network:
            shape = np.shape(next(iter(total.values()))) if total \
                else np.broadcast(np.asarray(load, dtype=float),
                                  np.asarray(op.fan if fan is None else fan,
                                             dtype=float)).shape
            total["network"] = np.full(shape, self.network_w)
        return total

    def power(self, op: OperatingPoint, *, load: float = 1.0,
              fan: Optional[float] = None,
              include_network: bool = True) -> float:
        return float(sum(self.component_watts(
            op, load=load, fan=fan,
            include_network=include_network).values()))


def lcsc_node(vids: Optional[Sequence[float]] = None) -> NodeModel:
    """One L-CSC compute node: host + 4×S9150 + fans + PSU."""
    return NodeModel.from_vids(uniform_vids(4) if vids is None else vids)


def lcsc_cluster(n_nodes: int = 56, *, nodes_per_rack: int = 8,
                 network_w: Optional[float] = None,
                 vids: Optional[Sequence[Sequence[float]]] = None,
                 ) -> ClusterModel:
    """The Green500-run cluster: 56 nodes in racks of 8, plus the
    separately-metered Mellanox switches (paper §3: 257 W)."""
    if network_w is None:
        from repro.configs.lcsc_lqcd import GREEN500_SWITCH_POWER_W
        network_w = GREEN500_SWITCH_POWER_W
    if vids is None:
        node_vids: Sequence[Sequence[float]] = [uniform_vids(4)] * n_nodes
    else:
        node_vids = vids
        if len(node_vids) != n_nodes:
            raise ValueError(f"need {n_nodes} vid tuples, got "
                             f"{len(node_vids)}")
    nodes = [lcsc_node(v) for v in node_vids]
    racks = tuple(RackModel(tuple(nodes[i:i + nodes_per_rack]))
                  for i in range(0, n_nodes, nodes_per_rack))
    return ClusterModel(racks, network_w=float(network_w))


# ---------------------------------------------------------------------------
# Legacy flat-node API (kept for the pre-refactor call sites; the shim in
# core/energy/power_model.py re-exports these)
# ---------------------------------------------------------------------------


def node_power(f_mhz: float, vids: Sequence[float], *, fan: float = 0.40,
               temp_c: float = 55.0, util: float = 1.0,
               gpu_clamped_w: Optional[Sequence[float]] = None) -> float:
    """Total node wall power via the composed model.  If
    ``gpu_clamped_w`` is given (post-throttle), use it; otherwise
    evaluate the unconstrained GPU model (legacy semantics)."""
    op = OperatingPoint(f_mhz=f_mhz, fan=fan, temp_c=temp_c, util=util)
    node = NodeModel.from_vids(vids)
    if gpu_clamped_w is None:
        gpu_clamped_w = [g.unconstrained_power(op) for g in node.gpus]
    return node.power(op, gpu_w_override=gpu_clamped_w)


@dataclass
class NodePowerModel:
    """Convenience wrapper binding a node's chip population."""

    vids: Sequence[float]
    fan: float = 0.40
    temp_c: float = 55.0
    spec: GPUSpec = S9150

    def power(self, f_mhz: float, util: float = 1.0,
              gpu_clamped_w: Optional[Sequence[float]] = None) -> float:
        return node_power(f_mhz, self.vids, fan=self.fan, temp_c=self.temp_c,
                          util=util, gpu_clamped_w=gpu_clamped_w)

    def with_fan(self, fan: float) -> "NodePowerModel":
        import dataclasses
        return dataclasses.replace(self, fan=fan)
