"""Green500 power-measurement methodology (paper §3, EEHPC v1.2).

Implements the three measurement levels over a :class:`PowerTrace`, the
node-variability estimate, the median-node selection the authors used,
and the Level-1 exploit they demonstrated (+30% overestimate).

Window rules (Table 2 of the paper; enforced here):
  * L1 — ≥1/64 of the system, a window of ≥20% of the middle 80% of the
    run, compute nodes only (network excluded).  Explicit windows are
    validated against both rules; traces whose core phase holds fewer
    than two samples are rejected.
  * L2 — ≥1/8 of the system, the full runtime, network power estimated.
  * L3 — full system, full runtime, network power measured.  L2/L3
    never window: on short traces they still average the whole run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.power.model import fan_curve, fan_power
from repro.power.trace import PowerTrace

LEVEL_MIN_FRACTION = {1: 1 / 64, 2: 1 / 8, 3: 1.0}
L1_CORE_MARGIN = 0.1          # middle 80% of the run
L1_MIN_WINDOW = 0.2           # ≥20% of the core phase


def LinpackTrace(t, power_w, flops_rate, network_w: float = 0.0,
                 ) -> PowerTrace:
    """Legacy constructor shim: the pre-refactor ``LinpackTrace``
    dataclass is now a single-component :class:`PowerTrace`."""
    return PowerTrace.from_arrays(t, power_w, flops_rate,
                                  network_w=network_w)


def hpl_load_profile(x: np.ndarray, *, tail_start: float = 0.75,
                     tail_floor: float = 0.35) -> np.ndarray:
    """Relative HPL load vs run fraction: ~1 until ``tail_start``, then an
    N³-ish tail down to ``tail_floor``."""
    x = np.asarray(x, dtype=float)
    s = np.clip((1.0 - x) / (1.0 - tail_start), 0.0, 1.0)
    return np.where(x < tail_start,
                    1.0, tail_floor + (1.0 - tail_floor) * s ** 1.5)


def linpack_power_trace(n_nodes: int, node_peak_w: float,
                        node_gflops: float, *, duration_s: float = 3600.0,
                        network_w: float = 257.0,
                        adaptive_fan: bool = True,
                        dyn_frac: float = 0.75,
                        dt: float = 5.0) -> PowerTrace:
    """Synthetic HPL trace from *given* node peak watts (the legacy
    entry point — ``repro.power.simulate`` derives the watts from the
    composed layer model instead).  ``dyn_frac`` is the node-level
    dynamic power fraction applied to the load profile."""
    t = np.arange(0.0, duration_s + dt, dt)
    load = hpl_load_profile(t / duration_s)
    power = n_nodes * node_peak_w * (1 - dyn_frac + dyn_frac * load)
    if adaptive_fan:
        # end-of-run fan derating (paper §2 last para of the fan discussion)
        fan_delta = np.array([fan_power(0.40) - fan_power(fan_curve(l))
                              for l in load])
        power = power - n_nodes * fan_delta
    flops = n_nodes * node_gflops * load
    return PowerTrace.from_arrays(t, power, flops, network_w=network_w)


# ---------------------------------------------------------------------------
# Measurement levels (EEHPC methodology v1.2 — paper Table 2)
# ---------------------------------------------------------------------------

@dataclass
class MeasurementResult:
    level: int
    measured_fraction: float
    window: Tuple[float, float]
    avg_power_w: float
    perf_gflops: float
    mflops_per_w: float
    notes: str = ""


def _l1_core_phase(trace: PowerTrace) -> Tuple[float, float]:
    lo = float(trace.t[0]) + L1_CORE_MARGIN * trace.duration
    hi = float(trace.t[-1]) - L1_CORE_MARGIN * trace.duration
    return lo, hi


def _validate_l1_window(trace: PowerTrace,
                        window: Tuple[float, float]) -> None:
    lo, hi = _l1_core_phase(trace)
    t0, t1 = window
    eps = 1e-9 * max(trace.duration, 1.0)
    if t0 < lo - eps or t1 > hi + eps:
        raise ValueError(
            f"L1 window {window} outside the middle 80% of the run "
            f"[{lo:.1f}, {hi:.1f}]")
    if (t1 - t0) < L1_MIN_WINDOW * (hi - lo) - eps:
        raise ValueError(
            f"L1 window {window} shorter than 20% of the core phase "
            f"({L1_MIN_WINDOW * (hi - lo):.1f}s)")


def measure_efficiency(trace: PowerTrace, level: int, *,
                       measured_fraction: float = 1.0,
                       window: Optional[Tuple[float, float]] = None,
                       ) -> MeasurementResult:
    """Apply one of the three measurement levels to a run trace.

    L1: >=1/64 of the system, >=20% of the middle 80% of the run,
        compute nodes only (network excluded).
    L2: >=1/8, full runtime, network estimated (we add it).
    L3: full system, full runtime, network measured.
    """
    if level not in LEVEL_MIN_FRACTION:
        raise ValueError(f"unknown measurement level {level}")
    if len(trace.t) < 2 or trace.duration <= 0.0:
        raise ValueError("trace too short to measure (need >=2 samples "
                         "spanning a nonzero duration)")
    perf = trace.total_flops() / trace.duration      # sustained GFLOPS
    if level == 1:
        lo, hi = _l1_core_phase(trace)
        if np.count_nonzero((trace.t >= lo) & (trace.t <= hi)) < 2:
            raise ValueError("trace too short for Level 1: the middle-80% "
                             "core phase holds fewer than two samples")
        if window is None:
            window = (lo, lo + L1_MIN_WINDOW * (hi - lo))
        _validate_l1_window(trace, window)
        p = trace.avg_power(window[0], window[1], include_network=False)
        notes = "compute nodes only; window inside middle 80%"
    elif level == 2:
        window = (float(trace.t[0]), float(trace.t[-1]))
        p = trace.avg_power(include_network=True)
        notes = "full runtime; network estimated"
    else:
        window = (float(trace.t[0]), float(trace.t[-1]))
        p = trace.avg_power(include_network=True)
        notes = "full runtime; network measured"
    frac = max(measured_fraction, LEVEL_MIN_FRACTION[level])
    return MeasurementResult(level, frac, window, p, perf,
                             perf / p * 1000.0, notes)


def level1_exploit(trace: PowerTrace) -> MeasurementResult:
    """Best (highest) efficiency obtainable within the letter of L1: slide
    the minimum 20%-of-middle-80% window to the lowest-power region.

    The paper showed this overestimates L-CSC's true efficiency by up to
    ~30% — and that several top-ranked systems measured this way."""
    lo, hi = _l1_core_phase(trace)
    win = L1_MIN_WINDOW * (hi - lo)
    best = None
    for start in np.linspace(lo, hi - win, 200):
        r = measure_efficiency(trace, 1, window=(start, start + win))
        if best is None or r.mflops_per_w > best.mflops_per_w:
            best = r
    best.notes = "L1 exploit: lowest-power window"
    return best


# ---------------------------------------------------------------------------
# Node variability & median-node selection (paper §3)
# ---------------------------------------------------------------------------

def node_efficiencies(rng: np.random.Generator, n_nodes: int,
                      base_mflops_w: float = 5215.0,
                      sigma_frac: float = 0.008) -> np.ndarray:
    """Single-node Linpack efficiencies across the population."""
    return rng.normal(base_mflops_w, base_mflops_w * sigma_frac, n_nodes)


def select_median_nodes(effs: Sequence[float], k: int = 2) -> List[int]:
    """Paper: 'we used nodes with middle power consumption among the nodes
    we had measured individually' — pick the k median nodes."""
    order = np.argsort(effs)
    mid = len(order) // 2
    lo = max(0, mid - k // 2)
    return list(order[lo:lo + k])


def extrapolation_error(effs: Sequence[float], k: int = 2) -> float:
    """|median-node estimate − population mean| / mean — the paper argues
    this is <1% given the ±1.2% spread."""
    effs = np.asarray(effs)
    sel = select_median_nodes(effs, k)
    est = float(np.mean(effs[sel]))
    return abs(est - float(np.mean(effs))) / float(np.mean(effs))
