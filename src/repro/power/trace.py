"""Time-stepped power telemetry: the ``PowerTrace`` type and the
``TraceRecorder`` event bus.

RAPS-style design (ExaDigiT): one fixed-interval, per-component power
time series that every workload emits into and every consumer (Green500
methodology, paper-table benchmarks, launch drivers) reads from.  The
trace is a struct-of-arrays:

  * ``t``           sample times [s]
  * ``components``  component name → watts array (``gpu``, ``host``,
                    ``fan``, ``psu_loss``, ``network``, ``chip_*`` …)
  * ``flops_rate``  instantaneous GFLOPS (for efficiency figures)
  * ``aux``         optional extra series (utilization, clocks [MHz],
                    temperature [°C], …)

Compute power (``power_w``) excludes the ``network`` component — the
Green500 methodology treats switches separately per measurement level.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.compat import trapezoid

NETWORK = "network"


@dataclass
class PowerTrace:
    """Fixed- or variable-interval per-component power time series."""

    t: np.ndarray
    components: Dict[str, np.ndarray]
    flops_rate: np.ndarray
    aux: Dict[str, np.ndarray] = field(default_factory=dict)
    meta: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        self.t = np.asarray(self.t, dtype=float)
        n = self.t.shape[0]
        self.components = {k: np.broadcast_to(
            np.asarray(v, dtype=float), (n,)).copy()
            for k, v in self.components.items()}
        self.flops_rate = np.broadcast_to(
            np.asarray(self.flops_rate, dtype=float), (n,)).copy()
        self.aux = {k: np.asarray(v, dtype=float) for k, v in self.aux.items()}

    # -- construction -------------------------------------------------------

    @classmethod
    def from_arrays(cls, t, power_w, flops_rate, *, network_w: float = 0.0,
                    component: str = "node", **meta) -> "PowerTrace":
        """Single-component trace (the legacy ``LinpackTrace`` shape)."""
        t = np.asarray(t, dtype=float)
        comps = {component: np.asarray(power_w, dtype=float)}
        if network_w:
            comps[NETWORK] = np.full(t.shape, float(network_w))
        return cls(t, comps, np.asarray(flops_rate, dtype=float), meta=meta)

    # -- views --------------------------------------------------------------

    @property
    def power_w(self) -> np.ndarray:
        """Compute-subsystem wall power (all components except network)."""
        out = np.zeros_like(self.t)
        for name, w in self.components.items():
            if name != NETWORK:
                out = out + w
        return out

    @property
    def network_w(self) -> float:
        """Average switch power (0 when the trace has no network data)."""
        w = self.components.get(NETWORK)
        return float(np.mean(w)) if w is not None and len(w) else 0.0

    @property
    def duration(self) -> float:
        return float(self.t[-1] - self.t[0])

    def total_flops(self, t0: Optional[float] = None,
                    t1: Optional[float] = None) -> float:
        """∫flops_rate dt — over [t0, t1] when given, else the whole
        trace (the flops counterpart of :meth:`energy_j`)."""
        if t0 is None and t1 is None:
            return float(trapezoid(self.flops_rate, self.t))
        t0 = float(self.t[0]) if t0 is None else t0
        t1 = float(self.t[-1]) if t1 is None else t1
        return self._window_integral(self.flops_rate, t0, t1)

    def _window_integral(self, y: np.ndarray, t0: float, t1: float) -> float:
        """∫y dt over [t0, t1], linearly interpolating at the window edges
        (windows need not land on sample times)."""
        m = (self.t > t0) & (self.t < t1)
        ts = np.concatenate(([t0], self.t[m], [t1]))
        ys = np.concatenate(([np.interp(t0, self.t, y)], y[m],
                             [np.interp(t1, self.t, y)]))
        return float(trapezoid(ys, ts))

    def avg_power(self, t0: Optional[float] = None,
                  t1: Optional[float] = None,
                  include_network: bool = True) -> float:
        """Time-averaged power over [t0, t1] (defaults: the full trace)."""
        t0 = float(self.t[0]) if t0 is None else t0
        t1 = float(self.t[-1]) if t1 is None else t1
        if t1 <= t0:
            raise ValueError(f"empty averaging window [{t0}, {t1}]")
        p = self._window_integral(self.power_w, t0, t1) / (t1 - t0)
        net = self.components.get(NETWORK)
        if include_network and net is not None:
            p += self._window_integral(net, t0, t1) / (t1 - t0)
        return p

    def energy_j(self, include_network: bool = True,
                 t0: Optional[float] = None,
                 t1: Optional[float] = None) -> float:
        """∫P dt — over [t0, t1] when given, else the whole trace."""
        total = self.power_w
        net = self.components.get(NETWORK)
        if include_network and net is not None:
            total = total + net
        if t0 is None and t1 is None:
            return float(trapezoid(total, self.t))
        t0 = float(self.t[0]) if t0 is None else t0
        t1 = float(self.t[-1]) if t1 is None else t1
        return self._window_integral(total, t0, t1)

    def component_energy_j(self) -> Dict[str, float]:
        return {name: float(trapezoid(w, self.t))
                for name, w in self.components.items()}

    def scaled(self, factor: float) -> "PowerTrace":
        """Power/flops scaled by ``factor`` (e.g. node trace → k nodes)."""
        return PowerTrace(self.t.copy(),
                          {k: w * factor for k, w in self.components.items()},
                          self.flops_rate * factor,
                          aux=dict(self.aux), meta=dict(self.meta))


class TraceRecorder:
    """Telemetry event bus: workloads ``emit`` samples, consumers take the
    assembled :class:`PowerTrace`.

    With ``dt_s`` set, ``trace()`` resamples every series onto the fixed
    interval grid (RAPS-style); otherwise the raw emission times are
    kept.  Components missing from a sample read as 0 W at that time.
    """

    def __init__(self, *, dt_s: Optional[float] = None, source: str = ""):
        self.dt_s = dt_s
        self.source = source
        self._rows: List[Tuple[float, Dict[str, float], float,
                               Dict[str, float]]] = []

    def __len__(self) -> int:
        return len(self._rows)

    @property
    def t_last(self) -> float:
        """Latest emitted sample time (0.0 on an empty recorder) — lets
        sequential phases stack onto one shared bus."""
        return max(r[0] for r in self._rows) if self._rows else 0.0

    def emit(self, t: float, watts: Dict[str, float], *,
             flops_rate: float = 0.0, **aux: float) -> None:
        """Record one sample: absolute time [s], component watts,
        instantaneous GFLOPS, and any extra series (util=, f_mhz=,
        temp_c=, …)."""
        self._rows.append((float(t), {k: float(v) for k, v in watts.items()},
                           float(flops_rate),
                           {k: float(v) for k, v in aux.items()}))

    def trace(self) -> PowerTrace:
        if not self._rows:
            raise ValueError("TraceRecorder has no samples")
        rows = sorted(self._rows, key=lambda r: r[0])
        t = np.array([r[0] for r in rows])
        comp_names = sorted({k for r in rows for k in r[1]})
        aux_names = sorted({k for r in rows for k in r[3]})
        comps = {name: np.array([r[1].get(name, 0.0) for r in rows])
                 for name in comp_names}
        flops = np.array([r[2] for r in rows])
        aux = {name: np.array([r[3].get(name, 0.0) for r in rows])
               for name in aux_names}
        if self.dt_s is not None and len(rows) > 1:
            grid = np.arange(t[0], t[-1] + 0.5 * self.dt_s, self.dt_s)
            comps = {n: np.interp(grid, t, w) for n, w in comps.items()}
            aux = {n: np.interp(grid, t, w) for n, w in aux.items()}
            flops = np.interp(grid, t, flops)
            t = grid
        meta: Dict[str, Any] = {}
        if self.source:
            meta["source"] = self.source
        if self.dt_s is not None:
            meta["dt_s"] = self.dt_s
        return PowerTrace(t, comps, flops, aux=aux, meta=meta)
