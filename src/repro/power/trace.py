"""Time-stepped power telemetry: the ``PowerTrace`` type and the
``TraceRecorder`` event bus.

RAPS-style design (ExaDigiT): one fixed-interval, per-component power
time series that every workload emits into and every consumer (Green500
methodology, paper-table benchmarks, launch drivers) reads from.  The
trace is a struct-of-arrays:

  * ``t``           sample times [s]
  * ``components``  component name → watts array (``gpu``, ``host``,
                    ``fan``, ``psu_loss``, ``network``, ``chip_*`` …)
  * ``flops_rate``  instantaneous GFLOPS (for efficiency figures)
  * ``aux``         optional extra series (utilization, clocks [MHz],
                    temperature [°C], …)

Compute power (``power_w``) excludes the ``network`` component — the
Green500 methodology treats switches separately per measurement level.

Storage is columnar (struct-of-arrays, the RAPS idiom): scalar ``emit``
calls append to per-series Python lists, bulk ``emit_series`` calls seal
whole numpy chunks, and ``trace()`` concatenates — no per-sample dict
rows, so the vectorized cluster engine can land a 160-node run in a
handful of array appends.  ``t_last`` is a running maximum (O(1)) and
``trace()`` only sorts when emissions actually arrived out of order.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.compat import trapezoid

NETWORK = "network"


@dataclass
class PowerTrace:
    """Fixed- or variable-interval per-component power time series."""

    t: np.ndarray
    components: Dict[str, np.ndarray]
    flops_rate: np.ndarray
    aux: Dict[str, np.ndarray] = field(default_factory=dict)
    meta: Dict[str, Any] = field(default_factory=dict)
    # traces are effectively immutable post-construction, so the component
    # sum is computed once (the Green500 L1/L2/L3 window scans hit
    # ``power_w`` per call) — never invalidated
    _power_w_cache: Optional[np.ndarray] = field(
        default=None, init=False, repr=False, compare=False)

    def __post_init__(self):
        self.t = np.asarray(self.t, dtype=float)
        n = self.t.shape[0]
        self.components = {k: np.broadcast_to(
            np.asarray(v, dtype=float), (n,)).copy()
            for k, v in self.components.items()}
        self.flops_rate = np.broadcast_to(
            np.asarray(self.flops_rate, dtype=float), (n,)).copy()
        self.aux = {k: np.asarray(v, dtype=float) for k, v in self.aux.items()}

    # -- construction -------------------------------------------------------

    @classmethod
    def from_arrays(cls, t, power_w, flops_rate, *, network_w: float = 0.0,
                    component: str = "node", **meta) -> "PowerTrace":
        """Single-component trace (the legacy ``LinpackTrace`` shape)."""
        t = np.asarray(t, dtype=float)
        comps = {component: np.asarray(power_w, dtype=float)}
        if network_w:
            comps[NETWORK] = np.full(t.shape, float(network_w))
        return cls(t, comps, np.asarray(flops_rate, dtype=float), meta=meta)

    # -- views --------------------------------------------------------------

    @property
    def power_w(self) -> np.ndarray:
        """Compute-subsystem wall power (all components except network).
        Cached on first access (traces are immutable by convention)."""
        if self._power_w_cache is None:
            out = np.zeros_like(self.t)
            for name, w in self.components.items():
                if name != NETWORK:
                    out = out + w
            self._power_w_cache = out
        return self._power_w_cache

    @property
    def network_w(self) -> float:
        """Average switch power (0 when the trace has no network data)."""
        w = self.components.get(NETWORK)
        return float(np.mean(w)) if w is not None and len(w) else 0.0

    @property
    def duration(self) -> float:
        return float(self.t[-1] - self.t[0])

    def total_flops(self, t0: Optional[float] = None,
                    t1: Optional[float] = None) -> float:
        """∫flops_rate dt — over [t0, t1] when given, else the whole
        trace (the flops counterpart of :meth:`energy_j`)."""
        if t0 is None and t1 is None:
            return float(trapezoid(self.flops_rate, self.t))
        t0 = float(self.t[0]) if t0 is None else t0
        t1 = float(self.t[-1]) if t1 is None else t1
        return self._window_integral(self.flops_rate, t0, t1)

    def _window_integral(self, y: np.ndarray, t0: float, t1: float) -> float:
        """∫y dt over [t0, t1], linearly interpolating at the window edges
        (windows need not land on sample times)."""
        m = (self.t > t0) & (self.t < t1)
        ts = np.concatenate(([t0], self.t[m], [t1]))
        ys = np.concatenate(([np.interp(t0, self.t, y)], y[m],
                             [np.interp(t1, self.t, y)]))
        return float(trapezoid(ys, ts))

    def avg_power(self, t0: Optional[float] = None,
                  t1: Optional[float] = None,
                  include_network: bool = True) -> float:
        """Time-averaged power over [t0, t1] (defaults: the full trace)."""
        t0 = float(self.t[0]) if t0 is None else t0
        t1 = float(self.t[-1]) if t1 is None else t1
        if t1 <= t0:
            raise ValueError(f"empty averaging window [{t0}, {t1}]")
        p = self._window_integral(self.power_w, t0, t1) / (t1 - t0)
        net = self.components.get(NETWORK)
        if include_network and net is not None:
            p += self._window_integral(net, t0, t1) / (t1 - t0)
        return p

    def energy_j(self, t0: Optional[float] = None,
                 t1: Optional[float] = None, *,
                 include_network: bool = True) -> float:
        """∫P dt — over [t0, t1] when given (mirroring
        :meth:`total_flops`'s windowed form, edge-interpolated), else
        the whole trace."""
        total = self.power_w
        net = self.components.get(NETWORK)
        if include_network and net is not None:
            total = total + net
        if t0 is None and t1 is None:
            return float(trapezoid(total, self.t))
        t0 = float(self.t[0]) if t0 is None else t0
        t1 = float(self.t[-1]) if t1 is None else t1
        return self._window_integral(total, t0, t1)

    def component_energy_j(self) -> Dict[str, float]:
        return {name: float(trapezoid(w, self.t))
                for name, w in self.components.items()}

    def scaled(self, factor: float) -> "PowerTrace":
        """Power/flops scaled by ``factor`` (e.g. node trace → k nodes)."""
        return PowerTrace(self.t.copy(),
                          {k: w * factor for k, w in self.components.items()},
                          self.flops_rate * factor,
                          aux=dict(self.aux), meta=dict(self.meta))


@dataclass
class _Chunk:
    """One sealed columnar block of samples (all arrays share a length)."""

    t: np.ndarray
    comps: Dict[str, np.ndarray]
    flops: np.ndarray
    aux: Dict[str, np.ndarray]


class TraceRecorder:
    """Telemetry event bus: workloads ``emit`` samples (or whole series
    via ``emit_series``), consumers take the assembled
    :class:`PowerTrace`.

    With ``dt_s`` set, ``trace()`` resamples every series onto the fixed
    interval grid (RAPS-style); otherwise the raw emission times are
    kept.  Components missing from a sample read as 0 W at that time.

    Internally columnar: scalar emissions append to per-series lists
    (sealed into a chunk lazily), bulk emissions become chunks directly,
    and ``trace()`` concatenates — sorting only if some emission
    actually arrived out of time order.
    """

    def __init__(self, *, dt_s: Optional[float] = None, source: str = ""):
        self.dt_s = dt_s
        self.source = source
        self._chunks: List[_Chunk] = []
        # open scalar-append buffer (column lists, zero-backfilled)
        self._buf_t: List[float] = []
        self._buf_flops: List[float] = []
        self._buf_comp: Dict[str, List[float]] = {}
        self._buf_aux: Dict[str, List[float]] = {}
        self._n = 0
        self._t_max = -np.inf      # running max → O(1) t_last
        self._t_prev = -np.inf     # last emission time → ordered flag
        self._ordered = True

    def __len__(self) -> int:
        return self._n

    @property
    def t_last(self) -> float:
        """Latest emitted sample time (0.0 on an empty recorder) — lets
        sequential phases stack onto one shared bus."""
        return float(self._t_max) if self._n else 0.0

    def _note_times(self, t_first: float, t_last: float,
                    monotonic: bool) -> None:
        if not monotonic or t_first < self._t_prev:
            self._ordered = False
        self._t_prev = t_last
        if t_last > self._t_max:
            self._t_max = t_last

    def emit(self, t: float, watts: Dict[str, float], *,
             flops_rate: float = 0.0, **aux: float) -> None:
        """Record one sample: absolute time [s], component watts,
        instantaneous GFLOPS, and any extra series (util=, f_mhz=,
        temp_c=, …)."""
        t = float(t)
        self._note_times(t, t, True)
        n = len(self._buf_t)
        self._buf_t.append(t)
        self._buf_flops.append(float(flops_rate))
        for k, v in watts.items():
            col = self._buf_comp.get(k)
            if col is None:             # late-appearing component: backfill
                col = self._buf_comp[k] = [0.0] * n
            col.append(float(v))
        for k, v in aux.items():
            col = self._buf_aux.get(k)
            if col is None:
                col = self._buf_aux[k] = [0.0] * n
            col.append(float(v))
        m = n + 1
        for col in self._buf_comp.values():
            if len(col) < m:            # component absent this sample: 0 W
                col.append(0.0)
        for col in self._buf_aux.values():
            if len(col) < m:
                col.append(0.0)
        self._n += 1

    def emit_series(self, t, watts: Dict[str, np.ndarray], *,
                    flops_rate=0.0, **aux) -> None:
        """Bulk columnar emission: a whole time series of samples in one
        call — the vectorized engines' path.  ``t`` is a 1-D array of
        sample times; component/aux values and ``flops_rate`` may be
        arrays of the same length or scalars (broadcast)."""
        t = np.asarray(t, dtype=float)
        if t.ndim != 1 or t.size == 0:
            raise ValueError("emit_series needs a non-empty 1-D time array")
        self._seal_buffer()
        n = t.shape[0]

        def col(v) -> np.ndarray:
            return np.broadcast_to(np.asarray(v, dtype=float), (n,)).copy()

        self._chunks.append(_Chunk(
            t.copy(), {k: col(v) for k, v in watts.items()},
            col(flops_rate), {k: col(v) for k, v in aux.items()}))
        self._note_times(float(t[0]), float(t[-1]),
                         bool(np.all(np.diff(t) >= 0.0)))
        self._t_max = max(self._t_max, float(np.max(t)))
        self._n += n

    def emit_intervals(self, starts, watts: Dict[str, np.ndarray], *,
                       span: float, dt_s: float, flops_rate=0.0,
                       **aux) -> None:
        """Piecewise-constant interval ingestion — the event-driven
        engines' path.  ``starts`` are non-decreasing interval start
        times; interval ``i`` spans ``[starts[i], starts[i+1])`` and the
        last one runs to ``span``.  Component/aux values and
        ``flops_rate`` are per-interval arrays (or scalars, broadcast).

        The intervals are broadcast onto a fixed ``dt_s`` sample grid
        over ``[starts[0], span]``: each sample reads the interval it
        falls in, and the final sample at ``t == span`` reads the last
        interval's value (the left limit) so the trapezoid energy covers
        the full span and bills nothing after it."""
        starts = np.asarray(starts, dtype=float)
        if starts.ndim != 1 or starts.size == 0:
            raise ValueError("emit_intervals needs a non-empty 1-D array "
                             "of interval start times")
        if np.any(np.diff(starts) < 0.0):
            raise ValueError("interval starts must be non-decreasing")
        span = float(span)
        if span <= starts[0]:
            raise ValueError(f"span {span} must exceed the first interval "
                             f"start {starts[0]}")
        n_int = starts.shape[0]

        def per_interval(v) -> np.ndarray:
            return np.broadcast_to(np.asarray(v, dtype=float), (n_int,))

        ts = np.arange(starts[0], span, dt_s)
        if not ts.size or ts[-1] < span:
            ts = np.append(ts, span)
        idx = np.searchsorted(starts, np.minimum(ts, span - 1e-9),
                              side="right") - 1
        idx = np.clip(idx, 0, n_int - 1)
        self.emit_series(
            ts, {k: per_interval(v)[idx] for k, v in watts.items()},
            flops_rate=per_interval(flops_rate)[idx],
            **{k: per_interval(v)[idx] for k, v in aux.items()})

    def _seal_buffer(self) -> None:
        """Convert the open scalar-append buffer into a sealed chunk."""
        if not self._buf_t:
            return
        self._chunks.append(_Chunk(
            np.array(self._buf_t),
            {k: np.array(v) for k, v in self._buf_comp.items()},
            np.array(self._buf_flops),
            {k: np.array(v) for k, v in self._buf_aux.items()}))
        self._buf_t, self._buf_flops = [], []
        self._buf_comp, self._buf_aux = {}, {}

    def trace(self) -> PowerTrace:
        if not self._n:
            raise ValueError("TraceRecorder has no samples")
        self._seal_buffer()
        chunks = self._chunks
        comp_names = sorted({k for c in chunks for k in c.comps})
        aux_names = sorted({k for c in chunks for k in c.aux})
        t = np.concatenate([c.t for c in chunks])
        flops = np.concatenate([c.flops for c in chunks])
        comps = {name: np.concatenate(
            [c.comps.get(name, np.zeros(c.t.shape[0])) for c in chunks])
            for name in comp_names}
        aux = {name: np.concatenate(
            [c.aux.get(name, np.zeros(c.t.shape[0])) for c in chunks])
            for name in aux_names}
        if not self._ordered:           # only sort when actually needed
            order = np.argsort(t, kind="stable")
            t, flops = t[order], flops[order]
            comps = {k: w[order] for k, w in comps.items()}
            aux = {k: w[order] for k, w in aux.items()}
        if self.dt_s is not None and t.shape[0] > 1:
            grid = np.arange(t[0], t[-1] + 0.5 * self.dt_s, self.dt_s)
            comps = {n: np.interp(grid, t, w) for n, w in comps.items()}
            aux = {n: np.interp(grid, t, w) for n, w in aux.items()}
            flops = np.interp(grid, t, flops)
            t = grid
        meta: Dict[str, Any] = {}
        if self.source:
            meta["source"] = self.source
        if self.dt_s is not None:
            meta["dt_s"] = self.dt_s
        return PowerTrace(t, comps, flops, aux=aux, meta=meta)
