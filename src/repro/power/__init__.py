"""Unified power-telemetry engine — the single source of truth for
power and energy in this repo.

Layered like ExaDigiT/RAPS: calibrated device models compose into a
node→rack→cluster simulation that any workload emits telemetry into and
every consumer (Green500 methodology, autotuner, HPL/LQCD/launch
drivers, paper-table benchmarks) reads from.

  :mod:`repro.power.model`     calibrated electrical constants + curves
                               (GPU, fan, PSU-side throttle, TPU chip)
  :mod:`repro.power.layers`    GPU → node (host + 4×S9150 + fans + PSU
                               curve) → rack → cluster (+ switches)
  :mod:`repro.power.trace`     ``PowerTrace`` + ``TraceRecorder`` bus
  :mod:`repro.power.engine`    ``simulate(workload, op) → PowerTrace``
  :mod:`repro.power.green500`  L1/L2/L3 methodology over ``PowerTrace``

Quick use::

    from repro.power import OperatingPoint, SyntheticHPL, simulate
    trace = simulate(SyntheticHPL(1800.0), OperatingPoint.green500())
    trace.avg_power()        # ≈ 57.2 kW + 257 W of switches

The old entry points (``repro.core.energy.power_model`` and friends)
remain importable as thin shims over this package.
"""
from repro.power.model import (  # noqa: F401
    EFFICIENT_MHZ,
    HPL_GPU_UTIL,
    K_DYN,
    NB_EFFICIENCY,
    NB_PERFORMANCE,
    STOCK_MHZ,
    S9150,
    V_MAX,
    V_MIN,
    GPUSpec,
    OperatingPoint,
    PowerModel,
    TPUChipModel,
    fan_curve,
    fan_power,
    gpu_power,
    gpu_power_throttled,
    hpl_block_perf_scale,
    hpl_block_util,
    lookahead_perf_scale,
    sample_vids,
    sustained_frequency,
    temp_from_fan,
    tpu_chip_power,
    voltage_at,
)
from repro.power.layers import (  # noqa: F401
    LCSC_PSU,
    ClusterModel,
    GPUModel,
    NodeModel,
    NodePowerModel,
    PSUCurve,
    RackModel,
    lcsc_cluster,
    lcsc_node,
    node_power,
)
from repro.power.trace import NETWORK, PowerTrace, TraceRecorder  # noqa: F401
from repro.power.engine import (  # noqa: F401
    ConstantLoad,
    ReplayWorkload,
    SyntheticHPL,
    Workload,
    evaluate_operating_point,
    node_hpl_gflops,
    simulate,
)
from repro.power.green500 import (  # noqa: F401
    LinpackTrace,
    MeasurementResult,
    extrapolation_error,
    level1_exploit,
    linpack_power_trace,
    measure_efficiency,
    node_efficiencies,
    select_median_nodes,
)
