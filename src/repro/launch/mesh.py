"""Production mesh construction.

Defined as functions (not module constants) so importing this module never
touches JAX device state.
"""
from __future__ import annotations

import jax

from repro.config import MULTI_POD_MESH, SINGLE_POD_MESH, MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD_MESH if multi_pod else SINGLE_POD_MESH


def make_mesh_from_config(mesh_cfg: MeshConfig):
    return jax.make_mesh(mesh_cfg.shape, mesh_cfg.axis_names)


def make_smoke_mesh(n_data: int = 2, n_model: int = 2):
    """Tiny mesh for CPU integration tests (requires >=4 host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
