"""Training driver: data pipeline -> jitted train step -> checkpoints,
with energy accounting (the paper's technique) and fault tolerance.

CPU-scale by default (smoke config); the full configs run through the same
code path on a real mesh.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \\
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.cluster.workload import TrainWorkload
from repro.config import ARCH_IDS, ShapeConfig, TrainConfig, get_arch
from repro.data import make_batch_iterator
from repro.distributed.fault import FaultPolicy, FaultTolerantLoop
from repro.models import init_params
from repro.optim import adamw_init
from repro.power.trace import TraceRecorder
from repro.runtime.steps import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="olmo-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    entry = get_arch(args.arch)
    cfg = entry.smoke() if args.smoke else entry.full()
    shape = ShapeConfig("custom", args.seq, args.batch, "train")
    tc = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                     warmup_steps=max(args.steps // 10, 1), remat="none")

    key = jax.random.PRNGKey(tc.seed)
    params = init_params(cfg, key)
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, tc))
    data = make_batch_iterator(cfg, shape)
    ckpt = CheckpointManager(Path(args.ckpt_dir) / cfg.name)
    loop = FaultTolerantLoop(FaultPolicy(checkpoint_every=args.ckpt_every))

    # energy plan for this step shape (paper C5): roofline-coupled clock,
    # built through the unified Workload adapter (repro.cluster) so the
    # driver and the cluster scheduler share one definition
    workload = TrainWorkload(arch=args.arch, steps=args.steps,
                             batch=args.batch, seq=args.seq,
                             smoke=args.smoke)
    plan, ac = workload.energy_plan()
    print(f"[energy] dominant={plan.dominant} freq={plan.freq_scale:.2f} "
          f"power={plan.power_w:.0f}W perf_loss={plan.perf_loss:.3%}")

    # telemetry: each step emits a chip-power sample into the shared bus
    # (energy comes from integrating the trace, not a private W×s product)
    recorder = TraceRecorder(source="launch.train")
    recorder.emit(0.0, {"chip": plan.power_w}, flops_rate=0.0,
                  freq_scale=plan.freq_scale)
    t_run = 0.0
    last_good = None
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        t0 = time.time()
        new_params, new_opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        wall = time.time() - t0
        h = loop.observe(step, wall, loss)
        t_run += wall
        recorder.emit(t_run, {"chip": plan.power_w},
                      flops_rate=ac.flops / max(wall, 1e-9) / 1e9,
                      freq_scale=plan.freq_scale)
        if not h.ok and loop.should_rollback(h):
            print(f"[fault] step {step}: {h.reason}; rolling back")
            if last_good is not None:
                params, opt = last_good
            continue
        params, opt = new_params, new_opt
        if step % args.ckpt_every == 0:
            ckpt.save(step, params)
            last_good = (params, opt)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"wall {wall*1e3:7.1f}ms gnorm "
                  f"{float(metrics['grad_norm']):.3f}")
    ckpt.wait()
    trace = recorder.trace()
    print(f"[energy] total {trace.energy_j()/3600:.4f} Wh over "
          f"{args.steps} steps, avg {trace.avg_power():.0f}W "
          f"({loop.straggler_report()})")


if __name__ == "__main__":
    main()
