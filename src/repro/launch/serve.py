"""Serving driver: prefill + batched greedy decode with energy accounting,
plus recorded-trace replay through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \\
      --batch 4 --prompt-len 64 --gen 32

Replay a recorded (or synthesized) request trace instead:

  PYTHONPATH=src python -m repro.launch.serve --make-demo-trace /tmp/day.npz
  PYTHONPATH=src python -m repro.launch.serve --replay /tmp/day.npz
  PYTHONPATH=src python -m repro.launch.serve --replay /tmp/day.npz --executed
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.workload import ServeWorkload
from repro.config import ARCH_IDS, get_arch
from repro.models.frontend import enc_len_for
from repro.power.trace import TraceRecorder
from repro.runtime.steps import (grow_decode_cache, make_decode_step,
                                 make_prefill_step)


def _replay(args) -> None:
    """--replay: feed a RequestTrace through the analytic
    continuous-batching engine (optionally with executed token
    generation) and print the per-request serve report."""
    from repro.serve import (ContinuousBatchingEngine, ExecutedGroupRuntime,
                             RequestTrace, ServeCostModel)
    trace = RequestTrace.load(args.replay)
    print(f"[replay] {trace.n_requests} requests over "
          f"{trace.duration_s:.3g}s ({trace.meta.get('generator', '?')})")
    cost = ServeCostModel(args.arch, max_batch=args.batch,
                          prompt_len=args.prompt_len, gen=args.gen,
                          smoke=args.smoke, kv_int8=args.kv_int8)
    runtime = None
    if args.executed:
        runtime = ExecutedGroupRuntime(args.arch, smoke=args.smoke,
                                       kv_int8=args.kv_int8)
    engine = ContinuousBatchingEngine(cost, runtime=runtime)
    res = engine.replay(trace, slo_s=args.slo_s)
    print(f"[energy] decode dominant={res.plan.dominant} "
          f"freq={res.plan.freq_scale:.2f} power={res.plan.power_w:.0f}W")
    print("[replay]", res.stats.summary())
    done = [r for r in res.records if r.done_s is not None]
    if done:
        r = done[0]
        print(f"[replay] request {r.idx}: wait {r.wait_s:.3g}s "
              f"ttft {r.ttft_s:.3g}s latency {r.latency_s:.3g}s "
              f"{res.request_energy_j(r.idx):.3g} J")
        if r.tokens is not None:
            print("sample:", np.asarray(r.tokens)[:16])


def _make_demo_trace(args) -> None:
    """--make-demo-trace: write a seeded diurnal day scaled to this
    serve shape's analytic capacity."""
    from repro.serve import ServeCostModel, diurnal_trace
    cost = ServeCostModel(args.arch, max_batch=args.batch,
                          prompt_len=args.prompt_len, gen=args.gen,
                          smoke=args.smoke, kv_int8=args.kv_int8)
    plan, _, _ = cost.plan()
    t_pre, _ = cost.prefill_cost(args.prompt_len, args.batch)
    service_s = t_pre + args.gen * plan.step_time_s
    cap_rps = args.batch / service_s
    day = 512.0 * service_s
    tr = diurnal_trace(day, rate_peak_per_s=0.6 * cap_rps,
                       rate_floor_per_s=0.05 * cap_rps,
                       prompt_lens=(args.prompt_len,),
                       gen_lens=(args.gen,), seed=0)
    tr.save(args.make_demo_trace)
    print(f"[trace] wrote {tr.n_requests} requests over {day:.3g}s "
          f"to {args.make_demo_trace}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--replay", metavar="PATH", default=None,
                    help="replay a RequestTrace npz through the "
                         "continuous-batching engine instead of one "
                         "batched generation")
    ap.add_argument("--executed", action="store_true",
                    help="with --replay: run real jitted prefill/decode "
                         "per admitted group (tokens become real; timing "
                         "stays analytic)")
    ap.add_argument("--slo-s", type=float, default=None,
                    help="with --replay: p99 latency SLO for the "
                         "compliance report")
    ap.add_argument("--make-demo-trace", metavar="PATH", default=None,
                    help="write a seeded diurnal demo trace npz sized to "
                         "this serve shape, then exit")
    args = ap.parse_args()

    if args.make_demo_trace:
        _make_demo_trace(args)
        return
    if args.replay:
        _replay(args)
        return

    entry = get_arch(args.arch)
    cfg = entry.smoke() if args.smoke else entry.full()
    B, S = args.batch, args.prompt_len
    total = S + args.gen

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        n_p = cfg.n_patches
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, n_p, cfg.d_model)), jnp.bfloat16)
    elif cfg.family == "encdec":
        batch["frame_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, enc_len_for(cfg, S), cfg.d_model)),
            jnp.bfloat16)

    from repro.models import init_params
    params = init_params(cfg, jax.random.PRNGKey(0))

    prefill = jax.jit(make_prefill_step(
        cfg, quantize_kv_cache=args.kv_int8))
    decode = jax.jit(make_decode_step(cfg))

    # energy plan (decode is memory-bound -> deep clock derate, paper C5),
    # built through the unified Workload adapter (repro.cluster) so the
    # driver and the cluster scheduler share one definition; ac is the
    # per-decode-step cost, ac_prefill the prefill-shape cost
    workload = ServeWorkload(arch=args.arch, batch=B, prompt_len=S,
                             gen=args.gen, smoke=args.smoke,
                             kv_int8=args.kv_int8)
    plan, ac_prefill, ac = workload.energy_plan()
    print(f"[energy] decode dominant={plan.dominant} "
          f"freq={plan.freq_scale:.2f} power={plan.power_w:.0f}W")
    # telemetry bus: prefill + every decoded token emit chip samples
    recorder = TraceRecorder(source="launch.serve")
    recorder.emit(0.0, {"chip": plan.power_w}, flops_rate=0.0,
                  freq_scale=plan.freq_scale)

    t0 = time.time()
    logits, cache = prefill(params, batch)
    # grow the cache to the full generation length
    cache = grow_decode_cache(cfg, cache, B, total,
                              quantize_kv_cache=args.kv_int8)
    t_prefill = time.time() - t0
    recorder.emit(t_prefill, {"chip": plan.power_w},
                  flops_rate=ac_prefill.flops / max(t_prefill, 1e-9) / 1e9,
                  freq_scale=plan.freq_scale)
    print(f"prefill {S} tokens x {B}: {t_prefill:.2f}s")

    out_tokens = []
    tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)[:, None]
    t0 = time.time()
    for _ in range(args.gen):
        out_tokens.append(np.asarray(tok))
        logits, cache = decode(params, tok.astype(jnp.int32), cache)
        tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)[:, None]
    jax.block_until_ready(logits)
    dt = time.time() - t0
    recorder.emit(t_prefill + dt, {"chip": plan.power_w},
                  flops_rate=ac.flops * args.gen / max(dt, 1e-9) / 1e9,
                  freq_scale=plan.freq_scale)
    gen = np.concatenate(out_tokens, axis=1)
    trace = recorder.trace()
    print(f"decoded {args.gen} tokens x {B} in {dt:.2f}s "
          f"({args.gen*B/dt:.1f} tok/s)")
    # split the bus energy at the prefill/decode boundary and divide by
    # the tokens each phase actually processed (B·S prompt tokens through
    # prefill, B·gen generated tokens through decode) — the old print
    # billed everything to generated tokens only
    e_pre = trace.energy_j(0.0, t_prefill)
    e_dec = trace.energy_j(t_prefill, t_prefill + dt)
    n_pre = B * S
    n_dec = B * args.gen
    print(f"[energy] prefill {e_pre:.1f} J / {n_pre} prompt tokens "
          f"= {e_pre / max(n_pre, 1):.3f} J/token")
    print(f"[energy] decode  {e_dec:.1f} J / {n_dec} generated tokens "
          f"= {e_dec / max(n_dec, 1):.3f} J/token")
    print(f"[energy] total   {trace.energy_j():.1f} J over "
          f"{trace.duration:.2f}s "
          f"({trace.energy_j() / max(n_pre + n_dec, 1):.3f} J/token over "
          f"all processed tokens)")
    print("sample:", gen[0][:16])


if __name__ == "__main__":
    main()
