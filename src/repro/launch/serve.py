"""Serving driver: prefill + batched greedy decode with energy accounting.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \\
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.workload import ServeWorkload
from repro.config import ARCH_IDS, get_arch
from repro.models.frontend import enc_len_for
from repro.power.trace import TraceRecorder
from repro.runtime.steps import make_decode_step, make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--kv-int8", action="store_true")
    args = ap.parse_args()

    entry = get_arch(args.arch)
    cfg = entry.smoke() if args.smoke else entry.full()
    B, S = args.batch, args.prompt_len
    total = S + args.gen

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        n_p = cfg.n_patches
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, n_p, cfg.d_model)), jnp.bfloat16)
    elif cfg.family == "encdec":
        batch["frame_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, enc_len_for(cfg, S), cfg.d_model)),
            jnp.bfloat16)

    from repro.models import init_params, init_decode_cache
    params = init_params(cfg, jax.random.PRNGKey(0))

    prefill = jax.jit(make_prefill_step(
        cfg, quantize_kv_cache=args.kv_int8))
    decode = jax.jit(make_decode_step(cfg))

    # energy plan (decode is memory-bound -> deep clock derate, paper C5),
    # built through the unified Workload adapter (repro.cluster) so the
    # driver and the cluster scheduler share one definition; ac is the
    # per-decode-step cost, ac_prefill the prefill-shape cost
    workload = ServeWorkload(arch=args.arch, batch=B, prompt_len=S,
                             gen=args.gen, smoke=args.smoke,
                             kv_int8=args.kv_int8)
    plan, ac_prefill, ac = workload.energy_plan()
    print(f"[energy] decode dominant={plan.dominant} "
          f"freq={plan.freq_scale:.2f} power={plan.power_w:.0f}W")
    # telemetry bus: prefill + every decoded token emit chip samples
    recorder = TraceRecorder(source="launch.serve")
    recorder.emit(0.0, {"chip": plan.power_w}, flops_rate=0.0,
                  freq_scale=plan.freq_scale)

    t0 = time.time()
    logits, cache = prefill(params, batch)
    # grow the cache to the full generation length
    full_cache = init_decode_cache(cfg, B, total,
                                   quantize_kv_cache=args.kv_int8)
    for k in cache:
        if k == "pos":
            full_cache["pos"] = cache["pos"]
        elif full_cache[k].shape == cache[k].shape:
            full_cache[k] = cache[k]
        else:
            sl = tuple(slice(0, s) for s in cache[k].shape)
            full_cache[k] = full_cache[k].at[sl].set(cache[k])
    cache = full_cache
    t_prefill = time.time() - t0
    recorder.emit(t_prefill, {"chip": plan.power_w},
                  flops_rate=ac_prefill.flops / max(t_prefill, 1e-9) / 1e9,
                  freq_scale=plan.freq_scale)
    print(f"prefill {S} tokens x {B}: {t_prefill:.2f}s")

    out_tokens = []
    tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)[:, None]
    t0 = time.time()
    for _ in range(args.gen):
        out_tokens.append(np.asarray(tok))
        logits, cache = decode(params, tok.astype(jnp.int32), cache)
        tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)[:, None]
    jax.block_until_ready(logits)
    dt = time.time() - t0
    recorder.emit(t_prefill + dt, {"chip": plan.power_w},
                  flops_rate=ac.flops * args.gen / max(dt, 1e-9) / 1e9,
                  freq_scale=plan.freq_scale)
    gen = np.concatenate(out_tokens, axis=1)
    trace = recorder.trace()
    print(f"decoded {args.gen} tokens x {B} in {dt:.2f}s "
          f"({args.gen*B/dt:.1f} tok/s)")
    print(f"[energy] {trace.energy_j():.1f} J over {trace.duration:.2f}s "
          f"({trace.energy_j()/max(args.gen*B, 1):.2f} J/token)")
    print("sample:", gen[0][:16])


if __name__ == "__main__":
    main()
