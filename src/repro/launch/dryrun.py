import os
# 512 placeholder devices for the production mesh; LICM disabled because
# XLA:CPU hoists bf16->f32 weight upcasts out of the layer scan (a CPU
# artifact — TPU MXUs consume bf16 natively), inflating memory_analysis.
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion")

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
against the production mesh, record memory/cost/collective analysis.

The two lines above MUST stay first: JAX locks the device count on first
initialization, and the dry-run (only) needs 512 placeholder host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--resume]
"""
import argparse
import json
import time
import traceback
from functools import partial
from pathlib import Path

import jax

from repro.config import (ARCH_IDS, SHAPES, MeshConfig, ModelConfig,
                          ShapeConfig, full_config, shape_applicable)
from repro.distributed.sharding import (batch_pspecs, cache_pspecs,
                                        named_shardings, param_bytes,
                                        param_pspecs)
from repro.launch.mesh import make_production_mesh, mesh_config
from repro.launch.specs import (decode_input_specs, input_specs,
                                should_quantize_kv)
from repro.models import init_params
from repro.optim import adamw_init
from repro.roofline import analyze_compiled, model_flops
from repro.roofline import hw
from repro.roofline.analytic import cost_for
from repro.runtime.memplan import auto_train_plan
from repro.runtime.steps import (make_decode_step, make_prefill_step,
                                 make_train_step)

from jax.sharding import PartitionSpec as P, NamedSharding

from repro.distributed.sharding import pick

DEFAULT_OUT = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _cell_name(arch: str, shape: str, multi_pod: bool, variant: str) -> str:
    mesh = "pod2" if multi_pod else "pod1"
    return f"{arch}--{shape}--{mesh}--{variant}"


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, mesh_cfg: MeshConfig,
               variant: str = "baseline"):
    """Build + lower + compile one cell.

    Returns (compiled, lower_s, compile_s, plan_info)."""
    import jax.numpy as jnp
    key = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(partial(init_params, cfg), key)
    block_skip = "block_skip" in variant
    serve_mode = "serve" if shape.kind != "train" else "train"
    if "serve_fsdp" in variant:
        serve_mode = "train"          # force FSDP specs even for serving
    ep_data = "serve_ep" in variant and cfg.moe.enabled
    # serve mode: TP-only weights must leave room for the KV cache
    tp_only = False
    if serve_mode == "serve":
        from repro.models.transformer import kv_cache_bytes
        from repro.launch.specs import should_quantize_kv
        cache_b = kv_cache_bytes(cfg, shape.global_batch, shape.seq_len)
        if should_quantize_kv(cfg, shape, mesh_cfg.n_devices):
            cache_b //= 2
        budget = SERVE_TP_ONLY_BUDGET
        if "tp_push" in variant:
            budget = 15 * 2**30       # push closer to the 16 GiB chip
        budget_left = budget - cache_b // mesh_cfg.n_devices
        tp_only = (param_bytes(params_sds) // mesh_cfg.model_size
                   <= max(budget_left, 0))
    pspecs = param_pspecs(cfg, params_sds, mesh_cfg, mode=serve_mode,
                          serve_tp_only=tp_only, moe_ep_data=ep_data)
    pshard = named_shardings(mesh, pspecs)
    moe_fsdp = not (tp_only or ep_data)
    plan_info = {"serve_tp_only": tp_only, "moe_ep_data": ep_data}

    if shape.kind == "train":
        tc = auto_train_plan(cfg, shape, mesh_cfg)
        plan_info.update(microbatches=tc.microbatches,
                         moment_dtype=tc.moment_dtype,
                         grad_accum_dtype=tc.grad_accum_dtype,
                         remat=tc.remat)
        plan_info["tc"] = tc
        batch = input_specs(cfg, shape)
        bshard = named_shardings(
            mesh, batch_pspecs(cfg, batch, mesh_cfg))
        opt_sds = jax.eval_shape(
            partial(adamw_init, moment_dtype=jnp.dtype(tc.moment_dtype)),
            params_sds)
        ospecs = {"m": pspecs, "v": pspecs, "step": P()}
        oshard = named_shardings(mesh, ospecs)
        step = make_train_step(cfg, tc, mesh=mesh, mesh_cfg=mesh_cfg,
                               block_skip=block_skip)
        jitted = jax.jit(step,
                         in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None),
                         donate_argnums=(0, 1))
        t0 = time.time()
        lowered = jitted.lower(params_sds, opt_sds, batch)
    elif shape.kind == "prefill":
        quant = should_quantize_kv(cfg, shape, mesh_cfg.n_devices)
        plan_info["kv_cache_int8"] = quant
        batch = input_specs(cfg, shape)
        bshard = named_shardings(mesh, batch_pspecs(cfg, batch, mesh_cfg))
        step = make_prefill_step(cfg, mesh=mesh, mesh_cfg=mesh_cfg,
                                 block_skip=block_skip, moe_fsdp=moe_fsdp,
                                 quantize_kv_cache=quant)
        cache_sds = jax.eval_shape(step, params_sds, batch)[1]
        cspecs = cache_pspecs(cfg, cache_sds, mesh_cfg)
        cshard = named_shardings(mesh, cspecs)
        logits_shard = NamedSharding(mesh, pick(
            (shape.global_batch, cfg.vocab_padded),
            [P(mesh_cfg.data_axes, "model"), P(None, "model"), P()],
            mesh_cfg))
        jitted = jax.jit(step, in_shardings=(pshard, bshard),
                         out_shardings=(logits_shard, cshard))
        t0 = time.time()
        lowered = jitted.lower(params_sds, batch)
    elif "replica1" in variant:         # replica-parallel: 1 chip/stream
        quant = should_quantize_kv(cfg, shape, 1)
        plan_info["kv_cache_int8"] = quant
        plan_info["replicas"] = mesh_cfg.n_devices
        tokens, cache_sds = decode_input_specs(cfg, shape,
                                               quantize_kv_cache=quant)
        step = make_decode_step(cfg)     # unsharded per-replica program
        jitted = jax.jit(step)
        t0 = time.time()
        lowered = jitted.lower(params_sds, tokens, cache_sds)
    else:                               # decode
        quant = should_quantize_kv(cfg, shape, mesh_cfg.n_devices)
        plan_info["kv_cache_int8"] = quant
        tokens, cache_sds = decode_input_specs(cfg, shape,
                                               quantize_kv_cache=quant)
        cspecs = cache_pspecs(cfg, cache_sds, mesh_cfg)
        cshard = named_shardings(mesh, cspecs)
        tshard = named_shardings(
            mesh, batch_pspecs(cfg, {"tokens": tokens}, mesh_cfg))["tokens"]
        logits_shard = NamedSharding(mesh, pick(
            (shape.global_batch, cfg.vocab_padded),
            [P(mesh_cfg.data_axes, "model"), P(None, "model"), P()],
            mesh_cfg))
        step = make_decode_step(cfg, mesh=mesh, mesh_cfg=mesh_cfg,
                                moe_fsdp=moe_fsdp, moe_ep_data=ep_data)
        jitted = jax.jit(step, in_shardings=(pshard, tshard, cshard),
                         out_shardings=(logits_shard, cshard),
                         donate_argnums=(2,))
        t0 = time.time()
        lowered = jitted.lower(params_sds, tokens, cache_sds)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    print(compiled.memory_analysis())   # proves it fits
    ca = compiled.cost_analysis()
    print({k: v for k, v in (ca or {}).items()
           if k in ("flops", "bytes accessed")})  # FLOPs/bytes for §Roofline
    return compiled, t1 - t0, t2 - t1, plan_info


from repro.distributed.sharding import SERVE_TP_ONLY_BUDGET


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Path, variant: str = "baseline",
             resume: bool = False) -> dict:
    name = _cell_name(arch, shape_name, multi_pod, variant)
    out_path = out_dir / f"{name}.json"
    if resume and out_path.exists():
        rec = json.loads(out_path.read_text())
        print(f"[dryrun] {name}: cached ({rec.get('status')})")
        return rec

    cfg = full_config(arch)
    shape = SHAPES[shape_name]
    rec: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": variant, "status": "pending",
    }
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec.update(status="skip", reason=reason)
        _write(out_path, rec)
        print(f"[dryrun] {name}: SKIP ({reason})")
        return rec

    try:
        mesh_cfg = mesh_config(multi_pod=multi_pod)
        mesh = make_production_mesh(multi_pod=multi_pod)
        compiled, lower_s, compile_s, plan = lower_cell(
            cfg, shape, mesh, mesh_cfg, variant)
        n_dev = mesh_cfg.n_devices
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                       else 1)
        mf = model_flops(cfg.param_count(), cfg.active_param_count(), tokens,
                         shape.kind)
        terms = analyze_compiled(compiled, n_dev, mf,
                                 pod_size=256 if multi_pod else 0)
        # analytic model (XLA:CPU cost analysis counts loop bodies once)
        replicas = mesh_cfg.n_devices if "replica1" in variant else 1
        ac = cost_for(cfg, shape, mesh_cfg, plan.get("tc"),
                      block_skip="block_skip" in variant,
                      serve_tp_only=plan.get("serve_tp_only", True),
                      kv_int8=plan.get("kv_cache_int8", False),
                      moe_ep=plan.get("moe_ep_data", False),
                      replicas=replicas)
        plan.pop("tc", None)
        # decode is bandwidth-bound: useful bytes = one read of the (active)
        # weights + one read of the KV/state cache per step, per chip
        bw_useful = None
        if shape.kind == "decode":
            key2 = jax.random.PRNGKey(0)
            params_sds2 = jax.eval_shape(partial(init_params, cfg), key2)
            _, cache_sds2 = decode_input_specs(
                cfg, shape,
                quantize_kv_cache=plan.get("kv_cache_int8", False))
            pb = param_bytes(params_sds2)
            cb = param_bytes(cache_sds2)
            active_frac = cfg.active_param_count() / max(cfg.param_count(), 1)
            # replica-parallel serving: each replica holds the full model
            chips_per_replica = n_dev // replicas
            useful = (pb * active_frac + cb) / chips_per_replica
            bw_useful = useful / max(ac.hbm_bytes, 1.0)
        mem = compiled.memory_analysis()
        mem_rec = {}
        if mem is not None:
            for f in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
                mem_rec[f] = int(getattr(mem, f, 0))
            mem_rec["total_hbm_bytes"] = (
                mem_rec.get("argument_size_in_bytes", 0)
                + mem_rec.get("output_size_in_bytes", 0)
                + mem_rec.get("temp_size_in_bytes", 0)
                - mem_rec.get("alias_size_in_bytes", 0))
        an_terms = {"compute": ac.compute_s, "memory": ac.memory_s,
                    "collective": ac.collective_s}
        dominant = max(an_terms, key=an_terms.get)
        step_lb = max(an_terms.values())
        useful_frac = (mf / n_dev / step_lb) / hw.PEAK_BF16_FLOPS             if step_lb > 0 else 0.0
        rec.update(
            status="ok",
            lower_s=round(lower_s, 2), compile_s=round(compile_s, 2),
            n_devices=n_dev,
            plan=plan,
            memory=mem_rec,
            fits_hbm=bool(mem_rec.get("total_hbm_bytes", 0) <= 16 * 2**30),
            roofline={
                "compute_s": ac.compute_s,
                "memory_s": ac.memory_s,
                "collective_s": ac.collective_s,
                "dominant": dominant,
                "flops_per_chip": ac.flops,
                "hbm_bytes_per_chip": ac.hbm_bytes,
                "ici_bytes_per_chip": ac.ici_bytes,
                "dcn_bytes_per_chip": ac.dcn_bytes,
                "model_flops": mf,
                "useful_ratio": mf / max(ac.flops * n_dev, 1.0),
                "step_lower_bound_s": step_lb,
                "roofline_fraction": useful_frac,
                "bw_useful_ratio": bw_useful,
                "detail": ac.detail,
            },
            xla_cost={
                "flops_per_chip_body_once": terms.hlo_flops,
                "bytes_per_chip_body_once": terms.hlo_bytes,
                "ici_bytes_body_once": terms.ici_bytes,
                "dcn_bytes_body_once": terms.dcn_bytes,
            },
            collectives=terms.collectives,
        )
        print(f"[dryrun] {name}: OK compile={compile_s:.0f}s "
              f"dominant={dominant} "
              f"hbm={mem_rec.get('total_hbm_bytes', 0)/2**30:.2f}GiB "
              f"frac={useful_frac:.3f}")
    except Exception as e:  # noqa: BLE001 — sweep must survive cell failures
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] {name}: ERROR {type(e).__name__}: {e}")
    _write(out_path, rec)
    return rec


def _write(path: Path, rec: dict) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(rec, indent=1, default=float))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) for the chosen mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    args = ap.parse_args()

    cells = []
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        for mp in meshes:
            for a in ARCH_IDS:
                for s in SHAPES:
                    cells.append((a, s, mp))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    n_ok = n_skip = n_err = 0
    for a, s, mp in cells:
        rec = run_cell(a, s, mp, args.out, args.variant, args.resume)
        st = rec["status"]
        n_ok += st == "ok"
        n_skip += st == "skip"
        n_err += st == "error"
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skip, {n_err} error "
          f"of {len(cells)}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
