"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs`` builds the training/prefill batch; ``decode_input_specs``
builds (tokens, cache) for one serve_step against a full KV/state cache.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from repro.models import init_decode_cache
from repro.models.frontend import enc_len_for

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Batch stand-ins for train_step / prefill_step."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    batch: Dict[str, Any] = {}
    if cfg.family == "vlm":
        n_p = cfg.n_patches
        batch["tokens"] = SDS((B, S - n_p), jnp.int32)
        batch["patch_embeds"] = SDS((B, n_p, cfg.d_model), dt)
        if shape.kind == "train":
            batch["labels"] = SDS((B, S - n_p), jnp.int32)
    elif cfg.family == "encdec":
        batch["tokens"] = SDS((B, S), jnp.int32)
        batch["frame_embeds"] = SDS((B, enc_len_for(cfg, S), cfg.d_model), dt)
        if shape.kind == "train":
            batch["labels"] = SDS((B, S), jnp.int32)
    else:
        batch["tokens"] = SDS((B, S), jnp.int32)
        if shape.kind == "train":
            batch["labels"] = SDS((B, S), jnp.int32)
    return batch


KV_QUANT_THRESHOLD = 6 * 2**30      # per-chip bf16 cache bytes triggering int8


def should_quantize_kv(cfg: ModelConfig, shape: ShapeConfig,
                       n_devices: int = 256) -> bool:
    from repro.models.transformer import kv_cache_bytes
    return (kv_cache_bytes(cfg, shape.global_batch, shape.seq_len)
            / n_devices > KV_QUANT_THRESHOLD)


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig,
                       quantize_kv_cache: bool = False,
                       ) -> Tuple[Any, Dict[str, Any]]:
    """(token, cache) stand-ins for one decode step at cache length S."""
    B, S = shape.global_batch, shape.seq_len
    tokens = SDS((B, 1), jnp.int32)
    cache = jax.eval_shape(
        lambda: init_decode_cache(cfg, B, S,
                                  quantize_kv_cache=quantize_kv_cache))
    return tokens, cache
