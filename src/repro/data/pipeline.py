"""Deterministic synthetic LM data pipeline.

Produces a reproducible Zipf-ish token stream with local n-gram structure
(so the loss actually decreases when training), shifted labels, and
host-sharded loading: each host materializes only its slice of the global
batch — the pattern a 1000-node data pipeline needs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.config import ModelConfig, ShapeConfig


@dataclass
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1

    def __post_init__(self):
        assert self.global_batch % self.host_count == 0
        self.local_batch = self.global_batch // self.host_count

    def _sequence(self, rng: np.random.Generator) -> np.ndarray:
        """Zipf unigrams + a repeating motif so next-token is learnable."""
        v = self.vocab_size
        base = rng.zipf(1.3, size=self.seq_len + 1).clip(1, v - 1)
        motif = rng.integers(1, v, size=8)
        out = base.copy()
        for start in range(0, self.seq_len + 1 - 8, 24):
            out[start:start + 8] = motif
        return out.astype(np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        tokens = np.empty((self.local_batch, self.seq_len), np.int32)
        labels = np.empty_like(tokens)
        for i in range(self.local_batch):
            seq_id = step * self.global_batch \
                + self.host_index * self.local_batch + i
            rng = np.random.default_rng(self.seed * 1_000_003 + seq_id)
            s = self._sequence(rng)
            tokens[i] = s[:-1]
            labels[i] = s[1:]
        return {"tokens": tokens, "labels": labels}


def make_batch_iterator(cfg: ModelConfig, shape: ShapeConfig, *,
                        seed: int = 0, host_index: int = 0,
                        host_count: int = 1,
                        batch_override: Optional[int] = None,
                        ) -> Iterator[Dict[str, np.ndarray]]:
    data = SyntheticLMData(cfg.vocab_size, shape.seq_len,
                           batch_override or shape.global_batch,
                           seed=seed, host_index=host_index,
                           host_count=host_count)
    step = 0
    while True:
        b = data.batch(step)
        if cfg.family == "vlm":
            n_p = cfg.n_patches
            rng = np.random.default_rng(seed + step)
            b["patch_embeds"] = rng.normal(
                0, 1, (data.local_batch, n_p, cfg.d_model)).astype(np.float32)
            b["tokens"] = b["tokens"][:, : shape.seq_len - n_p]
            b["labels"] = b["labels"][:, : shape.seq_len - n_p]
        elif cfg.family == "encdec":
            from repro.models.frontend import enc_len_for
            rng = np.random.default_rng(seed + step)
            b["frame_embeds"] = rng.normal(
                0, 1, (data.local_batch, enc_len_for(cfg, shape.seq_len),
                       cfg.d_model)).astype(np.float32)
        yield b
        step += 1
