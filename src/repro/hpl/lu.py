"""Blocked right-looking LU with partial pivoting and HPL-GPU-style
lookahead, in JAX.

Structure mirrors HPL-GPU (paper ref [1]): per block-step
  1. panel factorization (latency-critical, unblocked, with row pivoting)
  2. pivot application + triangular solve for the U block row
  3. trailing-matrix DGEMM update (throughput; the Pallas ``dgemm`` kernel
     is the TPU hot spot)
Lookahead: the *next* panel's columns are updated and factorized before the
bulk of the trailing update, breaking the dependency chain so the big GEMM
overlaps with the next panel factorization — on TPU both run on the same
chip, so the overlap materializes as one fused step per scan iteration
(DESIGN.md records this as a weakened analogue).

JAX needs static shapes: we keep the full N x N matrix and mask the active
region per step (≈3x the flops of a shrinking-window implementation — the
benchmark reports effective vs raw flops).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class LUResult(NamedTuple):
    lu: jnp.ndarray          # packed L\U
    piv: jnp.ndarray         # row swaps applied at each elimination column
    n_steps: int


def _panel_factor(a: jnp.ndarray, k0: jnp.ndarray, nb: int,
                  n: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Factor columns [k0, k0+nb) with partial pivoting over rows >= column.

    Operates on the full matrix (masked); returns (a, piv_rows)."""

    def col_step(carry, j):
        a, piv = carry
        col = k0 + j
        rows = jnp.arange(n)
        colvals = jnp.where(rows >= col, jnp.abs(a[:, col]), -jnp.inf)
        p = jnp.argmax(colvals)
        # swap rows p <-> col
        rp, rc = a[p], a[col]
        a = a.at[p].set(rc).at[col].set(rp)
        piv = piv.at[j].set(p)
        pivot = a[col, col]
        safe = jnp.where(jnp.abs(pivot) < 1e-30, 1.0, pivot)
        scale = jnp.where(rows > col, a[:, col] / safe, 0.0)
        a = a.at[:, col].set(jnp.where(rows > col, scale, a[:, col]))
        # rank-1 update restricted to the panel's remaining columns
        cols = jnp.arange(n)
        in_panel = (cols > col) & (cols < k0 + nb)
        upd = jnp.outer(scale, jnp.where(in_panel, a[col], 0.0))
        a = a - upd
        return (a, piv), None

    piv0 = jnp.zeros((nb,), jnp.int32)
    (a, piv), _ = jax.lax.scan(col_step, (a, piv0), jnp.arange(nb))
    return a, piv


def blocked_lu(a: jnp.ndarray, nb: int, *, lookahead: int = 1) -> LUResult:
    """LU-factor a (n, n) matrix in blocks of nb."""
    n = a.shape[0]
    assert n % nb == 0, "n must be a multiple of the block size"
    steps = n // nb
    pivs = jnp.zeros((steps, nb), jnp.int32)

    def step_collect(carry, k):
        a, pivs = carry
        k0 = k * nb
        a, piv = _panel_factor(a, k0, nb, n)   # swaps full rows
        rows = jnp.arange(n)
        cols = jnp.arange(n)
        block = jax.lax.dynamic_slice(a, (k0, k0), (nb, nb))
        tri = jnp.tril(block, -1) + jnp.eye(nb, dtype=a.dtype)
        u12 = jax.lax.dynamic_slice(a, (k0, 0), (nb, n))
        mask_right = cols[None, :] >= k0 + nb
        u12_new = jnp.where(
            mask_right,
            jax.scipy.linalg.solve_triangular(
                tri, jnp.where(mask_right, u12, 0.0), lower=True,
                unit_diagonal=True),
            u12)
        a = jax.lax.dynamic_update_slice(a, u12_new, (k0, 0))
        panel = jax.lax.dynamic_slice(a, (0, k0), (n, nb))
        l21 = jnp.where(rows[:, None] >= k0 + nb, panel, 0.0)
        u12m = jnp.where(mask_right, u12_new, 0.0)
        if lookahead > 0:
            next_cols = mask_right & (cols[None, :] < k0 + 2 * nb)
            a = a - l21 @ jnp.where(next_cols, u12m, 0.0)
            a = a - l21 @ jnp.where(next_cols, 0.0, u12m)
        else:
            a = a - l21 @ u12m
        pivs = pivs.at[k].set(piv)
        return (a, pivs), None

    (a, pivs), _ = jax.lax.scan(step_collect, (a, pivs), jnp.arange(steps))
    return LUResult(a, pivs, steps)


def lu_solve(res: LUResult, b: jnp.ndarray, nb: int) -> jnp.ndarray:
    """Solve A x = b given the packed LU and pivots."""
    n = b.shape[0]
    steps = res.n_steps

    def apply_piv(b, idx):
        k, j = idx // nb, idx % nb
        col = k * nb + j
        p = res.piv[k, j]
        bp, bc = b[p], b[col]
        return b.at[p].set(bc).at[col].set(bp), None

    b, _ = jax.lax.scan(apply_piv, b, jnp.arange(steps * nb))
    lo = jnp.tril(res.lu, -1) + jnp.eye(n, dtype=res.lu.dtype)
    y = jax.scipy.linalg.solve_triangular(lo, b, lower=True,
                                          unit_diagonal=True)
    x = jax.scipy.linalg.solve_triangular(jnp.triu(res.lu), y, lower=False)
    return x
