"""Linpack driver: factor + solve + HPL residual check + energy accounting.

Two operating modes (paper §2):
  * ``performance``  — big update blocks, full clock
  * ``efficiency``   — smaller blocks + the DVFS plan's derated clock; a
    small perf sacrifice for better MFLOPS/W (used for the Green500 run)
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import EnergyConfig
from repro.configs.hpl import HPLConfig
from repro.core.energy.dvfs import plan_frequency
from repro.hpl.lu import blocked_lu, lu_solve
from repro.power.trace import PowerTrace, TraceRecorder


@dataclass
class LinpackResult:
    n: int
    block: int
    mode: str
    residual: float
    passed: bool
    useful_flops: float
    raw_flops: float
    wall_s: float
    gflops: float
    energy_plan: Optional[Dict] = None
    power_trace: Optional[PowerTrace] = None


def linpack_residual(a: jnp.ndarray, x: jnp.ndarray, b: jnp.ndarray) -> float:
    """HPL acceptance: ||Ax-b||_inf / (||A||_inf ||x||_inf n eps)."""
    n = a.shape[0]
    eps = float(jnp.finfo(a.dtype).eps)
    r = jnp.max(jnp.abs(a @ x - b))
    denom = jnp.max(jnp.sum(jnp.abs(a), axis=1)) * jnp.max(jnp.abs(x)) \
        * n * eps
    return float(r / jnp.maximum(denom, 1e-30))


def linpack_run(cfg: HPLConfig, *, energy: Optional[EnergyConfig] = None,
                tuned: bool = False,
                recorder: Optional[TraceRecorder] = None) -> LinpackResult:
    """Factor + solve + HPL residual + (optional) energy plan.

    ``tuned=True`` swaps ``cfg``'s blocking for the autotune-cache
    winner at this problem size (see ``HPLConfig.tuned``) before
    running — the efficiency-mode replacement for the hard-coded block
    constants.  A shared ``recorder`` stacks this run's telemetry after
    anything already on the bus (the Workload API's merged-trace path)."""
    if tuned:
        cfg = cfg.tuned()
    key = jax.random.PRNGKey(cfg.seed)
    ka, kb = jax.random.split(key)
    dt = jnp.dtype(cfg.dtype)
    a = jax.random.normal(ka, (cfg.n, cfg.n), dt)
    b = jax.random.normal(kb, (cfg.n,), dt)

    factor = jax.jit(lambda m: blocked_lu(m, cfg.block,
                                          lookahead=cfg.lookahead))
    res = factor(a)                      # compile
    jax.block_until_ready(res.lu)
    t0 = time.time()
    res = factor(a)
    jax.block_until_ready(res.lu)
    wall = time.time() - t0
    x = lu_solve(res, b, cfg.block)
    rnorm = linpack_residual(a, x, b)

    useful = 2.0 / 3.0 * cfg.n ** 3
    steps = cfg.n // cfg.block
    raw = 2.0 * cfg.n ** 2 * cfg.block * steps  # masked full-width updates

    plan = None
    trace = None
    if energy is not None:
        # roofline terms of the trailing update on the TARGET chip (v5e):
        from repro.roofline import hw
        compute_s = useful / hw.PEAK_BF16_FLOPS
        memory_s = (cfg.n * cfg.n * dt.itemsize * steps) / hw.HBM_BW
        fp = plan_frequency(compute_s, memory_s, 0.0, flops_per_step=useful,
                            cfg=energy)
        plan = {"freq_scale": fp.freq_scale, "power_w": fp.power_w,
                "energy_per_run_j": fp.energy_per_step_j,
                "perf_loss": fp.perf_loss, "dominant": fp.dominant}
        # emit the run into the telemetry bus: chip power at the planned
        # operating point over the measured wall time (appended after any
        # earlier phases when the caller shares a bus)
        rec = recorder if recorder is not None \
            else TraceRecorder(source="hpl.linpack")
        t0 = rec.t_last
        for t in (t0, t0 + wall):
            rec.emit(t, {"chip": fp.power_w},
                     flops_rate=useful / wall / 1e9,
                     freq_scale=fp.freq_scale, util=1.0)
        trace = rec.trace()

    return LinpackResult(
        n=cfg.n, block=cfg.block, mode=cfg.mode, residual=rnorm,
        passed=bool(rnorm < 16.0), useful_flops=useful, raw_flops=raw,
        wall_s=wall, gflops=useful / wall / 1e9, energy_plan=plan,
        power_trace=trace)
