"""HPL (Linpack) — the paper's §2 benchmark, as blocked LU in JAX."""
from repro.hpl.lu import blocked_lu, lu_solve  # noqa: F401
from repro.hpl.linpack import linpack_run, linpack_residual  # noqa: F401
