"""Multi-chip even-odd D-slash / CG (repro.lqcd.multichip_eo) and the
spin-projected halo compression of the full-lattice path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import need_devices
from repro.distributed.sharding import lattice_mesh
from repro.lqcd.eo import dslash_half, eo_pack, pack_gauge
from repro.lqcd.multichip import dslash_sharded, halo_perms
from repro.lqcd.multichip_eo import ShardedWilsonEO, dslash_half_sharded
from repro.lqcd.su3 import random_su3_field


def _fields(lat, seed=0):
    ku, kr, ki = jax.random.split(jax.random.PRNGKey(seed), 3)
    U = random_su3_field(ku, lat)
    b = (jax.random.normal(kr, lat + (4, 3))
         + 1j * jax.random.normal(ki, lat + (4, 3))).astype(jnp.complex64)
    return U, b


def _ref_half(U_e, U_o, psi, src_parity):
    u_out, u_src = (U_o, U_e) if src_parity == 0 else (U_e, U_o)
    return dslash_half(u_out, u_src, psi, src_parity)


# ---------------------------------------------------------------------------
# Sharded EO D-slash: property grid vs single-device reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lat", [(8, 8, 8, 8), (12, 12, 12, 24)])
@pytest.mark.parametrize("ndev", [2, 4, 8])
def test_sharded_eo_dslash_matches_reference(lat, ndev):
    need_devices(ndev)
    U, b = _fields(lat)
    U_e, U_o = pack_gauge(U)
    mesh = lattice_mesh(lat[3], ndev)
    for src_parity in (0, 1):
        psi = eo_pack(b, src_parity)
        ref = np.asarray(_ref_half(U_e, U_o, psi, src_parity))
        for overlap in (True, False):
            got = np.asarray(dslash_half_sharded(
                U_e, U_o, psi, src_parity, mesh, overlap=overlap))
            np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_overlap_matches_halo_then_compute_baseline():
    """The interior/boundary split must agree with the serialized
    exchange-then-compute formulation (same operator, same inputs)."""
    need_devices(8)
    U, b = _fields((8, 8, 8, 8), seed=3)
    U_e, U_o = pack_gauge(U)
    mesh = lattice_mesh(8, 8)
    psi = eo_pack(b, 0)
    a = np.asarray(dslash_half_sharded(U_e, U_o, psi, 0, mesh, overlap=True))
    c = np.asarray(dslash_half_sharded(U_e, U_o, psi, 0, mesh, overlap=False))
    np.testing.assert_allclose(a, c, rtol=1e-6, atol=1e-6)


def test_odd_local_t_extent_supported():
    """8^4 over 8 devices leaves T_local=1: the traced global-t parity
    offset must keep the x-hop pattern alternating across shards."""
    need_devices(8)
    U, b = _fields((8, 8, 8, 8), seed=1)
    U_e, U_o = pack_gauge(U)
    mesh = jax.make_mesh((8,), ("model",))
    psi = eo_pack(b, 1)
    got = np.asarray(dslash_half_sharded(U_e, U_o, psi, 1, mesh))
    ref = np.asarray(_ref_half(U_e, U_o, psi, 1))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_pallas_backend_matches_reference():
    need_devices(4)
    U, b = _fields((8, 8, 8, 16), seed=2)
    U_e, U_o = pack_gauge(U)
    mesh = lattice_mesh(16, 4)
    for src_parity in (0, 1):
        psi = eo_pack(b, src_parity)
        got = np.asarray(dslash_half_sharded(
            U_e, U_o, psi, src_parity, mesh, backend="pallas"))
        ref = np.asarray(_ref_half(U_e, U_o, psi, src_parity))
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def test_invalid_shardings_raise():
    need_devices(8)
    U, b = _fields((4, 4, 4, 8))
    U_e, U_o = pack_gauge(U)
    with pytest.raises(ValueError, match="not divisible"):
        ShardedWilsonEO(U_e, U_o, 0.1, jax.make_mesh((3,), ("model",)))
    # pallas needs an even local T extent (halo pad shifts parity)
    with pytest.raises(ValueError, match="even local T"):
        ShardedWilsonEO(U_e, U_o, 0.1, jax.make_mesh((8,), ("model",)),
                        backend="pallas")
    with pytest.raises(ValueError, match="backend"):
        ShardedWilsonEO(U_e, U_o, 0.1, jax.make_mesh((2,), ("model",)),
                        backend="rocm")


# ---------------------------------------------------------------------------
# Sharded full CG vs single-device solver
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ndev", [2, 4, 8])
@pytest.mark.parametrize("inner_dtype", [None, "bfloat16"])
def test_sharded_eo_cg_matches_single_device(ndev, inner_dtype):
    from repro.lqcd.cg import solve_wilson_eo
    need_devices(ndev)
    lat, kappa, tol = (8, 8, 8, 8), 0.12, 1e-6
    dt = None if inner_dtype is None else jnp.dtype(inner_dtype)
    U, b = _fields(lat, seed=4)
    ref = solve_wilson_eo(U, b, kappa, tol=tol, max_iters=400,
                          inner_dtype=dt)
    mesh = lattice_mesh(lat[3], ndev)
    got = solve_wilson_eo(U, b, kappa, tol=tol, max_iters=400,
                          inner_dtype=dt, mesh=mesh)
    assert ref.converged and got.converged
    assert got.rel_residual <= tol
    # both solve the same system to tol: solutions agree to solver accuracy
    np.testing.assert_allclose(np.asarray(got.x), np.asarray(ref.x),
                               rtol=1e-3, atol=1e-4)


def test_sharded_eo_cg_larger_lattice():
    from repro.lqcd.cg import solve_wilson_eo
    need_devices(8)
    U, b = _fields((12, 12, 12, 24), seed=5)
    res = solve_wilson_eo(U, b, 0.1, tol=1e-5, max_iters=300,
                          mesh=lattice_mesh(24, 8))
    assert res.converged and res.rel_residual <= 1e-5


def test_solve_dirac_mesh_dispatch():
    from repro.config import SolverConfig
    from repro.lqcd.cg import solve_dirac
    need_devices(4)
    U, b = _fields((4, 4, 4, 8), seed=6)
    mesh = lattice_mesh(8, 4)
    cfg = SolverConfig(preconditioner="even_odd", tol=1e-5, max_iters=300)
    res = solve_dirac(U, b, 0.1, cfg, mesh=mesh)
    assert res.converged
    with pytest.raises(ValueError, match="even-odd"):
        solve_dirac(U, b, 0.1, SolverConfig(preconditioner="none"),
                    mesh=mesh)


# ---------------------------------------------------------------------------
# Satellite: spin-projected halo compression (full-lattice path)
# ---------------------------------------------------------------------------

def test_compressed_halos_bit_compatible():
    """Half the spinor wire bytes, *bit-compatible* result: the zero-filled
    spin components are annihilated by the projector through the identical
    hop assembly, so compress=True equals compress=False exactly."""
    need_devices(4)
    lat = (4, 4, 4, 8)
    U, _ = _fields(lat, seed=7)
    kr, ki = jax.random.split(jax.random.PRNGKey(8))
    psi = (jax.random.normal(kr, lat + (4, 3))
           + 1j * jax.random.normal(ki, lat + (4, 3))).astype(jnp.complex64)
    mesh = lattice_mesh(8, 4)
    c = np.asarray(dslash_sharded(U, psi, mesh, compress=True))
    u = np.asarray(dslash_sharded(U, psi, mesh, compress=False))
    assert np.array_equal(c, u)


def test_halo_perm_tables_cached():
    """The per-axis-size ppermute pair lists are built once (satellite:
    no per-call Python list rebuilding in the traced exchange)."""
    a, b = halo_perms(4), halo_perms(4)
    assert a is b
    fwd, bwd = a
    assert fwd == ((0, 3), (1, 0), (2, 1), (3, 2))
    assert bwd == ((0, 1), (1, 2), (2, 3), (3, 0))
    assert halo_perms(2) is halo_perms(2)


# ---------------------------------------------------------------------------
# Calibration: measured GFLOPS/W on the telemetry bus -> cluster layer
# ---------------------------------------------------------------------------

def test_analytic_calibration_restates_roofline():
    from repro.configs.lcsc_lqcd import (DSLASH_BW_FRACTION,
                                         MULTI_GPU_SLOWDOWN, S9150_BW_GBS)
    from repro.lqcd.multichip_eo import analytic_lqcd_calibration
    one = analytic_lqcd_calibration((8, 8, 8, 16), n_devices=1)
    assert one.source == "analytic"
    assert one.eff_bw_gbs == pytest.approx(S9150_BW_GBS * DSLASH_BW_FRACTION)
    four = analytic_lqcd_calibration((8, 8, 8, 16), n_devices=4)
    # multi-chip pays the paper's observed halo-exchange slowdown
    assert four.eff_bw_gbs == pytest.approx(
        4 * one.eff_bw_gbs * (1 - MULTI_GPU_SLOWDOWN))
    assert four.busy_w == pytest.approx(4 * one.busy_w)
    assert four.gflops_per_w < 4 * one.gflops_per_w / 3  # sublinear


def test_measured_calibration_emits_trace():
    from repro.lqcd.multichip_eo import measured_lqcd_calibration
    need_devices(4)
    cal = measured_lqcd_calibration((4, 4, 4, 8), reps=2,
                                    mesh=lattice_mesh(8, 4))
    assert cal.source == "measured"
    assert cal.n_devices == 4
    assert cal.gflops > 0 and cal.eff_bw_gbs > 0 and cal.wall_s > 0
    assert cal.gflops_per_w == pytest.approx(cal.gflops / cal.busy_w)
    # joules were integrated from the telemetry bus, not watts*seconds math
    assert cal.trace is not None
    assert cal.energy_j == pytest.approx(cal.busy_w * cal.wall_s, rel=1e-6)


def test_workload_consumes_calibration():
    from repro.cluster.workload import LQCDSolveWorkload
    from repro.lqcd.multichip_eo import analytic_lqcd_calibration
    from repro.power.model import OperatingPoint
    op = OperatingPoint.green500()
    base = LQCDSolveWorkload().execute(op)
    assert "calibration_source" not in base.details   # default path untouched
    cal = analytic_lqcd_calibration((8, 8, 8, 16), n_devices=4)
    res = LQCDSolveWorkload(calibration=cal).execute(op)
    assert res.details["calibration_source"] == "analytic"
    assert res.details["cal_n_devices"] == 4
    # an analytic-shaped calibration reproduces the roofline exactly
    assert res.details["cal_vs_analytic"] == pytest.approx(1.0)
    # same solve, calibrated hw: energy scales with the calibrated watts
    assert res.energy_j > 0 and res.wall_s > 0
