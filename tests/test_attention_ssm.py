"""Attention & SSM equivalence properties."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:               # deterministic grid fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.config import smoke_config
from repro.models.attention import (blockwise_attention, gqa_decode,
                                    mla_decode, mla_forward, quantize_kv)
from repro.models.ssm import ssd_chunked


def _naive_attention(q, k, v, causal=True, window=0):
    B, Sq, H, dh = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qg = q.reshape(B, Sq, KVH, G, dh).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bckd->bqkgc", qg, k.astype(jnp.float32))
    s = s / np.sqrt(dh)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgc,bckd->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, dh)


@settings(max_examples=10, deadline=None)
@given(seq=st.sampled_from([16, 48, 64]), h=st.sampled_from([4, 6]),
       kvh=st.sampled_from([1, 2]), causal=st.booleans())
def test_blockwise_matches_naive(seq, h, kvh, causal):
    if h % kvh:
        h = kvh * (h // kvh)
    key = jax.random.PRNGKey(seq * h)
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (2, seq, h, 16), jnp.float32)
    k = jax.random.normal(kk, (2, seq, kvh, 16), jnp.float32)
    v = jax.random.normal(kv_, (2, seq, kvh, 16), jnp.float32)
    got = blockwise_attention(q, k, v, causal=causal, q_chunk=16,
                              kv_chunk=16)
    want = _naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_block_skip_matches_rectangular():
    key = jax.random.PRNGKey(0)
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (2, 64, 4, 16), jnp.float32)
    k = jax.random.normal(kk, (2, 64, 2, 16), jnp.float32)
    v = jax.random.normal(kv_, (2, 64, 2, 16), jnp.float32)
    a = blockwise_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16,
                            block_skip=False)
    b = blockwise_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16,
                            block_skip=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)


def test_sliding_window_mask():
    key = jax.random.PRNGKey(1)
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (1, 64, 4, 16), jnp.float32)
    k = jax.random.normal(kk, (1, 64, 4, 16), jnp.float32)
    v = jax.random.normal(kv_, (1, 64, 4, 16), jnp.float32)
    got = blockwise_attention(q, k, v, causal=True, window=8, q_chunk=16,
                              kv_chunk=16)
    want = _naive_attention(q, k, v, causal=True, window=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_full_attention():
    """Decoding position t equals full attention's row t."""
    cfg = smoke_config("llama3-8b")
    from repro.models.attention import init_attention, gqa_forward
    p = init_attention(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          jnp.float32)
    full, (k, v) = gqa_forward(cfg, p, x, positions=jnp.arange(S))
    # decode the last position against the cache of the first S-1
    cache_k = jnp.zeros((B, S, cfg.n_kv_heads, cfg.d_head))
    cache_v = jnp.zeros_like(cache_k)
    cache_k = cache_k.at[:, : S - 1].set(k[:, : S - 1])
    cache_v = cache_v.at[:, : S - 1].set(v[:, : S - 1])
    out, _, _ = gqa_decode(cfg, p, x[:, S - 1: S], cache_k, cache_v,
                           jnp.array(S - 1))
    np.testing.assert_allclose(np.asarray(out[:, 0]),
                               np.asarray(full[:, S - 1]), rtol=2e-2,
                               atol=2e-2)


def test_mla_decode_absorption_matches_forward():
    cfg = smoke_config("deepseek-v2-236b")
    from repro.models.attention import init_attention
    p = init_attention(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          jnp.float32)
    full, (ckv, krope) = mla_forward(cfg, p, x, positions=jnp.arange(S))
    cache_ckv = jnp.zeros((B, S, cfg.mla.kv_lora_rank))
    cache_kr = jnp.zeros((B, S, cfg.mla.qk_rope_head_dim))
    cache_ckv = cache_ckv.at[:, : S - 1].set(ckv[:, : S - 1])
    cache_kr = cache_kr.at[:, : S - 1].set(krope[:, : S - 1])
    out, _, _ = mla_decode(cfg, p, x[:, S - 1: S], cache_ckv, cache_kr,
                           jnp.array(S - 1))
    np.testing.assert_allclose(np.asarray(out[:, 0]),
                               np.asarray(full[:, S - 1]), rtol=3e-2,
                               atol=3e-2)


def test_int8_kv_cache_quality():
    """int8 cache decode matches bf16-cache decode closely."""
    cfg = smoke_config("llama3-8b")
    from repro.models.attention import init_attention
    p = init_attention(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    kk, kv_, kx = jax.random.split(jax.random.PRNGKey(1), 3)
    cache_k = jax.random.normal(kk, (B, S, cfg.n_kv_heads, cfg.d_head))
    cache_v = jax.random.normal(kv_, (B, S, cfg.n_kv_heads, cfg.d_head))
    x = jax.random.normal(kx, (B, 1, cfg.d_model), jnp.float32)
    ref_out, _, _ = gqa_decode(cfg, p, x, cache_k, cache_v,
                               jnp.array(S - 1))
    kq, ks = quantize_kv(cache_k)
    vq, vs = quantize_kv(cache_v)
    got_out = gqa_decode(cfg, p, x, kq, vq, jnp.array(S - 1),
                         k_scale=ks, v_scale=vs)[0]
    a = np.asarray(ref_out).ravel()
    b = np.asarray(got_out).ravel()
    cos = float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-9))
    assert cos > 0.99, cos


@settings(max_examples=8, deadline=None)
@given(seq=st.sampled_from([8, 32, 64]), chunk=st.sampled_from([4, 8, 16]))
def test_ssd_chunked_matches_recurrence(seq, chunk):
    """SSD chunked scan == naive per-step recurrence."""
    B, H, P, N = 2, 3, 4, 5
    key = jax.random.PRNGKey(seq * chunk)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, seq, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, seq, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, seq, 1, N), jnp.float32)
    Cm = jax.random.normal(jax.random.PRNGKey(9), (B, seq, 1, N))
    y, hT = ssd_chunked(x, dt, A, Bm, Cm, chunk)

    # naive recurrence
    h = np.zeros((B, H, P, N), np.float32)
    ys = np.zeros((B, seq, H, P), np.float32)
    xn, dtn = np.asarray(x), np.asarray(dt)
    An, Bn, Cn = np.asarray(A), np.asarray(Bm), np.asarray(Cm)
    for t in range(seq):
        decay = np.exp(dtn[:, t] * An)                       # (B, H)
        outer = np.einsum("bh,bn,bhp->bhpn", dtn[:, t], Bn[:, t, 0],
                          xn[:, t])
        h = h * decay[..., None, None] + outer
        ys[:, t] = np.einsum("bn,bhpn->bhp", Cn[:, t, 0], h)
    np.testing.assert_allclose(np.asarray(y), ys, rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(hT), h, rtol=5e-3, atol=5e-3)


def test_ssm_decode_matches_prefill_state():
    """Prefill final state then one decode step == prefill of S+1 tokens."""
    cfg = smoke_config("mamba2-370m")
    from repro.models.ssm import init_ssm, ssm_forward, ssm_decode
    p = init_ssm(cfg, jax.random.PRNGKey(0))
    B, S = 2, 17
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S + 1, cfg.d_model),
                          jnp.float32)
    full, _ = ssm_forward(cfg, p, x)
    part, (h, conv) = ssm_forward(cfg, p, x[:, :S])
    step, _, _ = ssm_decode(cfg, p, x[:, S: S + 1], h, conv)
    np.testing.assert_allclose(np.asarray(step[:, 0]),
                               np.asarray(full[:, S]), rtol=2e-2, atol=2e-2)
