"""Pallas kernel validation: shape/dtype sweeps against pure-jnp oracles
(interpret mode on CPU; TPU is the lowering target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dgemm import dgemm, dgemm_ref
from repro.kernels.dslash import dslash_pallas, dslash_ref
from repro.kernels.rmsnorm import rmsnorm, rmsnorm_ref
from repro.lqcd import random_su3_field


@pytest.mark.parametrize("m,n,k", [(128, 128, 128), (256, 128, 384),
                                   (512, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dgemm_sweep(m, n, k, dtype):
    kx, ky = jax.random.split(jax.random.PRNGKey(m + n + k))
    x = jax.random.normal(kx, (m, k), dtype)
    y = jax.random.normal(ky, (k, n), dtype)
    got = dgemm(x, y, bm=128, bn=128, bk=128)
    want = dgemm_ref(x, y)
    rtol = 2e-5 if dtype == jnp.float32 else 0.1
    atol = 1e-3 if dtype == jnp.float32 else 0.1
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=atol)


@pytest.mark.parametrize("rows,d", [(64, 128), (256, 512), (33 * 4, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(rows, d, dtype):
    kx, kw = jax.random.split(jax.random.PRNGKey(rows + d))
    x = jax.random.normal(kx, (rows, d), dtype)
    w = jax.random.normal(kw, (d,), dtype)
    got = rmsnorm(x, w)
    want = rmsnorm_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("lattice", [(4, 4, 4, 4), (4, 4, 4, 8),
                                     (8, 4, 4, 8)])
@pytest.mark.parametrize("t_block", [1, 2, 4])
def test_dslash_sweep(lattice, t_block):
    if lattice[3] % t_block:
        pytest.skip("t_block must divide T")
    key = jax.random.PRNGKey(sum(lattice))
    U = random_su3_field(key, lattice)
    kr, ki = jax.random.split(key)
    psi = (jax.random.normal(kr, lattice + (4, 3))
           + 1j * jax.random.normal(ki, lattice + (4, 3))
           ).astype(jnp.complex64)
    got = dslash_pallas(U, psi, t_block=t_block)
    want = dslash_ref(U, psi)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_dslash_linearity():
    """D-slash is linear: D(a x + b y) = a D x + b D y."""
    key = jax.random.PRNGKey(0)
    U = random_su3_field(key, (4, 4, 4, 4))
    k1, k2 = jax.random.split(key)
    mk = lambda k: (jax.random.normal(k, (4, 4, 4, 4, 4, 3))
                    + 1j * jax.random.normal(k, (4, 4, 4, 4, 4, 3))
                    ).astype(jnp.complex64)
    x, y = mk(k1), mk(k2)
    lhs = dslash_pallas(U, 2.0 * x + 3.0 * y)
    rhs = 2.0 * dslash_pallas(U, x) + 3.0 * dslash_pallas(U, y)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-3, atol=1e-3)
