"""Green500 methodology edge cases against the unified PowerTrace type:
measurement-window rules on short traces, network-power handling per
level, and the Level-1 exploit bounds (paper §3, EEHPC v1.2)."""
import numpy as np
import pytest

from repro.power import (OperatingPoint, PowerTrace, SyntheticHPL,
                         level1_exploit, measure_efficiency, simulate)
from repro.power.green500 import LinpackTrace, linpack_power_trace


def _flat_trace(duration=100.0, n=11, power=1000.0, flops=5000.0,
                network=50.0):
    t = np.linspace(0.0, duration, n)
    return PowerTrace.from_arrays(t, np.full(n, power), np.full(n, flops),
                                  network_w=network)


# -- window rules -------------------------------------------------------------

def test_l1_default_window_sits_in_middle_80_percent():
    tr = _flat_trace()
    r = measure_efficiency(tr, 1)
    lo, hi = 10.0, 90.0                       # middle 80% of [0, 100]
    assert r.window[0] >= lo and r.window[1] <= hi
    assert r.window[1] - r.window[0] == pytest.approx(0.2 * (hi - lo))


def test_l1_rejects_window_outside_core_phase():
    tr = _flat_trace()
    with pytest.raises(ValueError, match="middle 80%"):
        measure_efficiency(tr, 1, window=(0.0, 40.0))       # starts too early
    with pytest.raises(ValueError, match="middle 80%"):
        measure_efficiency(tr, 1, window=(60.0, 99.0))      # ends too late


def test_l1_rejects_too_short_window():
    tr = _flat_trace()
    with pytest.raises(ValueError, match="20%"):
        measure_efficiency(tr, 1, window=(40.0, 45.0))      # 5s < 16s floor


def test_l1_rejects_trace_too_short_to_window():
    """Two samples 10s apart: the middle-80% core phase holds fewer than
    two samples — L1 cannot produce a meaningful average."""
    tr = PowerTrace.from_arrays([0.0, 10.0], [1000.0, 1000.0],
                                [5000.0, 5000.0])
    with pytest.raises(ValueError, match="Level 1"):
        measure_efficiency(tr, 1)


def test_l2_l3_use_full_runtime_even_on_short_traces():
    """L2/L3 never window: a 3-sample, 10-second trace still averages the
    whole run."""
    t = [0.0, 5.0, 10.0]
    tr = PowerTrace.from_arrays(t, [1000.0, 1000.0, 500.0],
                                [5000.0] * 3, network_w=25.0)
    for level in (2, 3):
        r = measure_efficiency(tr, level)
        assert r.window == (0.0, 10.0)
        # trapezoid mean of [1000, 1000, 500] + 25 W of switches
        assert r.avg_power_w == pytest.approx(875.0 + 25.0)


def test_degenerate_traces_rejected():
    one = PowerTrace.from_arrays([0.0], [1000.0], [1.0])
    for level in (1, 2, 3):
        with pytest.raises(ValueError, match="short"):
            measure_efficiency(one, level)
    with pytest.raises(ValueError):
        measure_efficiency(_flat_trace(), 4)                # unknown level


def test_measured_fraction_floors():
    tr = _flat_trace()
    assert measure_efficiency(tr, 1, measured_fraction=0.001) \
        .measured_fraction == pytest.approx(1 / 64)
    assert measure_efficiency(tr, 2, measured_fraction=0.5) \
        .measured_fraction == pytest.approx(0.5)
    assert measure_efficiency(tr, 3).measured_fraction == 1.0


# -- network-power handling ---------------------------------------------------

def test_network_excluded_at_l1_included_at_l3():
    tr = _flat_trace(power=1000.0, network=100.0)
    l1 = measure_efficiency(tr, 1)
    l3 = measure_efficiency(tr, 3)
    assert l1.avg_power_w == pytest.approx(1000.0)          # nodes only
    assert l3.avg_power_w == pytest.approx(1100.0)          # + switches
    assert l1.mflops_per_w > l3.mflops_per_w


def test_l3_network_inclusion_on_simulated_cluster_trace():
    """Through the engine: the L3 average must carry the switch watts the
    cluster model attaches, L1 must not."""
    from repro.power import lcsc_cluster
    cl = lcsc_cluster(8, nodes_per_rack=4, network_w=40.0)
    tr = simulate(SyntheticHPL(duration_s=400.0), OperatingPoint.green500(),
                  cluster=cl, dt_s=5.0)
    l1 = measure_efficiency(tr, 1)
    l3 = measure_efficiency(tr, 3)
    # network shows up in L3 only (trace power is load-shaped, so compare
    # via the explicit component)
    assert tr.network_w == pytest.approx(40.0)
    assert l3.avg_power_w == pytest.approx(
        tr.avg_power(include_network=False) + 40.0)
    w0, w1 = l1.window
    assert l1.avg_power_w == pytest.approx(
        tr.avg_power(w0, w1, include_network=False))


# -- the L1 exploit -----------------------------------------------------------

def test_l1_exploit_on_engine_trace_bounds():
    """The paper's +30%-class overestimate: sliding the minimal L1 window
    into the low-power tail inflates efficiency by 10–45%."""
    tr = simulate(SyntheticHPL(duration_s=1800.0), OperatingPoint.green500(),
                  dt_s=10.0)
    l3 = measure_efficiency(tr, 3)
    ex = level1_exploit(tr)
    over = ex.mflops_per_w / l3.mflops_per_w - 1.0
    assert 0.10 < over < 0.45
    # the exploit stayed within the letter of the rules
    lo = tr.t[0] + 0.1 * tr.duration
    hi = tr.t[-1] - 0.1 * tr.duration
    assert ex.window[0] >= lo - 1e-6 and ex.window[1] <= hi + 1e-6


def test_l1_exploit_flat_trace_gains_nothing():
    tr = _flat_trace(duration=1000.0, n=201)
    l1 = measure_efficiency(tr, 1)
    ex = level1_exploit(tr)
    assert ex.mflops_per_w == pytest.approx(l1.mflops_per_w, rel=1e-9)


# -- legacy constructor shim --------------------------------------------------

def test_linpack_trace_shim_matches_powertrace():
    t = np.linspace(0.0, 100.0, 21)
    tr = LinpackTrace(t, np.full(21, 900.0), np.full(21, 4000.0),
                      network_w=30.0)
    assert isinstance(tr, PowerTrace)
    assert tr.network_w == pytest.approx(30.0)
    legacy = linpack_power_trace(4, 1000.0, 5000.0, duration_s=600.0)
    assert isinstance(legacy, PowerTrace)
    assert legacy.avg_power(include_network=False) \
        < 4 * 1000.0                       # tail + fan derate below peak
