"""The benchmark perf-regression gate (``benchmarks/compare.py``):
machine-readable REGRESSION lines for wall-time blowups, gated-value
drift, missing tables/rows and errored tables; timing-derived fields
exempt; new tables tolerated."""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.compare import (MIN_BASE_SECONDS, compare, compare_derived,
                                main, parse_derived)


def _table(seconds=1.0, **rows):
    return {"seconds": seconds,
            "value": {name: {"us_per_call": 0.0, "derived": derived}
                      for name, derived in rows.items()}}


BASE = {
    "cluster_hetero": _table(
        seconds=0.5,
        **{"hetero/mixed_56": "mflops_w=4912.3;clocks=774+900;makespan=484",
           "hetero/green500_record": "kw=57.13;paper=57.13"}),
    "cluster_scale": _table(
        seconds=2.0,
        **{"scale/speedup_56": "loop_s=1.2;vector_s=0.01;speedup=120x;"
                               "samples=113"}),
}


def test_identical_runs_pass():
    regs, report = compare(BASE, json.loads(json.dumps(BASE)))
    assert regs == []
    assert all(t["status"] == "ok" for t in report["tables"].values())


def test_wall_time_regression_flagged():
    cur = json.loads(json.dumps(BASE))
    cur["cluster_scale"]["seconds"] = 6.0            # > 2.5 x 2.0
    regs, _ = compare(BASE, cur)
    assert len(regs) == 1
    assert regs[0].startswith("REGRESSION:cluster_scale:time")


def test_small_baselines_floored_before_time_gate():
    base = {"t": _table(seconds=0.001, r="x=1")}
    cur = {"t": _table(seconds=MIN_BASE_SECONDS * 2.0, r="x=1")}
    regs, _ = compare(base, cur)                     # 2x the floor: fine
    assert regs == []


def test_gated_value_drift_flagged():
    cur = json.loads(json.dumps(BASE))
    row = cur["cluster_hetero"]["value"]["hetero/green500_record"]
    row["derived"] = "kw=58.90;paper=57.13"          # > 1% drift
    regs, report = compare(BASE, cur)
    assert len(regs) == 1
    assert regs[0].startswith("REGRESSION:cluster_hetero:")
    assert "kw=58.9" in regs[0]
    assert report["tables"]["cluster_hetero"]["status"] == "drift"


def test_timing_fields_are_exempt_from_value_gate():
    cur = json.loads(json.dumps(BASE))
    row = cur["cluster_scale"]["value"]["scale/speedup_56"]
    row["derived"] = "loop_s=9.9;vector_s=0.5;speedup=19x;samples=113"
    regs, _ = compare(BASE, cur)
    assert regs == []                                # time gate's job


def test_missing_table_row_and_error_flagged():
    cur = json.loads(json.dumps(BASE))
    del cur["cluster_scale"]
    cur["cluster_hetero"] = {"error": "assert failed", "seconds": 0.1}
    regs, _ = compare(BASE, cur)
    details = "\n".join(regs)
    assert "REGRESSION:cluster_scale:table missing" in details
    assert "REGRESSION:cluster_hetero:errored: assert failed" in details

    cur = json.loads(json.dumps(BASE))
    del cur["cluster_hetero"]["value"]["hetero/green500_record"]
    regs, _ = compare(BASE, cur)
    assert any("row 'hetero/green500_record' missing" in r for r in regs)


def test_new_tables_and_rows_are_fine():
    cur = json.loads(json.dumps(BASE))
    cur["brand_new_bench"] = _table(seconds=3.0, r="y=2")
    cur["cluster_hetero"]["value"]["hetero/extra"] = {
        "us_per_call": 0.0, "derived": "z=3"}
    regs, _ = compare(BASE, cur)
    assert regs == []


def test_non_numeric_fields_compared_exactly():
    assert compare_derived("clocks=774+900", "clocks=774+900", 0.01) == []
    probs = compare_derived("clocks=774+900", "clocks=774", 0.01)
    assert probs and "clocks" in probs[0]


def test_percentage_fields_compare_numerically():
    assert compare_derived("gain=3.7%", "gain=3.7%", 0.01) == []
    assert compare_derived("gain=3.7%", "gain=5.0%", 0.01)


def test_parse_derived_ignores_unkeyed_parts():
    assert parse_derived("a=1;junk;b=x=y") == {"a": "1", "b": "x=y"}


def test_main_exit_codes_and_report(tmp_path):
    base_p = tmp_path / "base.json"
    cur_p = tmp_path / "cur.json"
    rep_p = tmp_path / "report.json"
    base_p.write_text(json.dumps(BASE))

    cur = json.loads(json.dumps(BASE))
    cur_p.write_text(json.dumps(cur))
    assert main([str(base_p), str(cur_p), "--report", str(rep_p)]) == 0
    assert json.loads(rep_p.read_text())["regressions"] == []

    cur["cluster_scale"]["seconds"] = 99.0
    cur_p.write_text(json.dumps(cur))
    assert main([str(base_p), str(cur_p), "--report", str(rep_p)]) == 1
    rep = json.loads(rep_p.read_text())
    assert rep["regressions"] and rep["tables"]["cluster_scale"][
        "status"] == "slow"


def test_committed_baseline_is_loadable_and_error_free():
    baseline = Path(__file__).resolve().parents[1] / "benchmarks" / \
        "baseline" / "BENCH_cluster.json"
    if not baseline.exists():
        pytest.skip("baseline not generated yet")
    data = json.loads(baseline.read_text())
    assert data, "baseline must not be empty"
    assert all("error" not in t for t in data.values()), \
        "baseline must only record passing tables"
    # self-comparison is the identity: no regressions against itself
    regs, _ = compare(data, data)
    assert regs == []
