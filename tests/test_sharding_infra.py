"""Sharding rules, memory planner, checkpointing, data pipeline, analytic
cost model — pure-CPU infrastructure tests (no multi-device needed: the
rules operate on MeshConfig, not jax devices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from functools import partial

from repro.config import (ARCH_IDS, MULTI_POD_MESH, SHAPES, SINGLE_POD_MESH,
                          TrainConfig, full_config, shape_applicable,
                          smoke_config)
from repro.distributed.sharding import (batch_pspecs, cache_pspecs, fits,
                                        param_pspecs)
from repro.launch.specs import decode_input_specs, input_specs
from repro.models import init_params
from repro.roofline.analytic import cost_for
from repro.runtime.memplan import auto_train_plan, estimate_train_bytes


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh_cfg", [SINGLE_POD_MESH, MULTI_POD_MESH],
                         ids=["pod1", "pod2"])
def test_param_specs_divide(arch, mesh_cfg):
    """Every parameter's spec must shard evenly on both meshes."""
    cfg = full_config(arch)
    sds = jax.eval_shape(partial(init_params, cfg), jax.random.PRNGKey(0))
    specs = param_pspecs(cfg, sds, mesh_cfg)
    leaves = jax.tree.leaves(sds)
    spec_leaves = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert len(leaves) == len(spec_leaves)
    for leaf, spec in zip(leaves, spec_leaves):
        assert fits(leaf.shape, spec, mesh_cfg), (leaf.shape, spec)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_and_cache_specs_divide(arch, shape_name):
    cfg = full_config(arch)
    shape = SHAPES[shape_name]
    ok, _ = shape_applicable(cfg, shape)
    if not ok:
        pytest.skip("assignment skip")
    for mesh_cfg in (SINGLE_POD_MESH, MULTI_POD_MESH):
        if shape.kind == "decode":
            tokens, cache = decode_input_specs(cfg, shape)
            specs = cache_pspecs(cfg, cache, mesh_cfg)
            for k, leaf in cache.items():
                assert fits(leaf.shape, specs[k], mesh_cfg), (k, leaf.shape)
        else:
            batch = input_specs(cfg, shape)
            specs = batch_pspecs(cfg, batch, mesh_cfg)
            for k, leaf in batch.items():
                assert fits(leaf.shape, specs[k], mesh_cfg), (k, leaf.shape)


def test_serve_mode_strips_fsdp_for_small_models():
    cfg = full_config("llama3-8b")
    sds = jax.eval_shape(partial(init_params, cfg), jax.random.PRNGKey(0))
    train_specs = jax.tree.leaves(
        param_pspecs(cfg, sds, SINGLE_POD_MESH, mode="train"),
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    serve_specs = jax.tree.leaves(
        param_pspecs(cfg, sds, SINGLE_POD_MESH, mode="serve"),
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    t_axes = {a for s in train_specs for a in s if a}
    s_axes = {a for s in serve_specs for a in s if a}
    assert "data" in str(t_axes)
    assert "data" not in str(s_axes)          # TP-only serving


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_memplan_produces_valid_microbatching(arch):
    cfg = full_config(arch)
    shape = SHAPES["train_4k"]
    for mesh_cfg in (SINGLE_POD_MESH, MULTI_POD_MESH):
        tc = auto_train_plan(cfg, shape, mesh_cfg)
        assert shape.global_batch % (tc.microbatches * mesh_cfg.data_size) \
            == 0
        assert estimate_train_bytes(cfg, shape, mesh_cfg, tc) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_analytic_cost_sane(arch):
    cfg = full_config(arch)
    tr = cost_for(cfg, SHAPES["train_4k"], SINGLE_POD_MESH, TrainConfig())
    de = cost_for(cfg, SHAPES["decode_32k"], SINGLE_POD_MESH)
    assert tr.flops > 0 and tr.hbm_bytes > 0 and tr.ici_bytes >= 0
    # training does far more flops per chip than one decode step
    assert tr.flops > 100 * de.flops
    # decode is never compute-dominant on these shapes
    assert de.memory_s + de.collective_s > de.compute_s


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import CheckpointManager
    cfg = smoke_config("olmo-1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(0, params, blocking=True)
    mgr.save(10, params, blocking=True)
    mgr.save(20, params, blocking=True)
    assert mgr.latest_step() == 20
    # keep=2 garbage-collects step 0
    assert not (tmp_path / "step_00000000").exists()
    restored = mgr.restore(20, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_ignores_incomplete(tmp_path):
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(tmp_path)
    (tmp_path / "step_00000099").mkdir()       # no manifest -> incomplete
    assert mgr.latest_step() is None


def test_data_pipeline_determinism_and_sharding():
    from repro.data import SyntheticLMData
    d0 = SyntheticLMData(1000, 64, 8, seed=3, host_index=0, host_count=2)
    d0b = SyntheticLMData(1000, 64, 8, seed=3, host_index=0, host_count=2)
    d1 = SyntheticLMData(1000, 64, 8, seed=3, host_index=1, host_count=2)
    b0, b0b, b1 = d0.batch(0), d0b.batch(0), d1.batch(0)
    np.testing.assert_array_equal(b0["tokens"], b0b["tokens"])
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    # labels are the shifted stream
    assert b0["tokens"].shape == (4, 64)


def test_grad_compression_error_feedback():
    from repro.optim.grad_compress import (dequantize_int8, quantize_int8)
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 1e-3, (256,)), jnp.float32)
    q, s = quantize_int8(g)
    err = g - dequantize_int8(q, s)
    # error bounded by one quantization step
    assert float(jnp.max(jnp.abs(err))) <= float(s) * 0.5 + 1e-12
    # error feedback makes the AVERAGE over steps unbiased: simulate
    acc = jnp.zeros_like(g)
    e = jnp.zeros_like(g)
    for _ in range(50):
        q, s = quantize_int8(g + e)
        deq = dequantize_int8(q, s)
        e = (g + e) - deq
        acc = acc + deq
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g),
                               atol=float(s) * 0.2)
