"""ssd_chunk kernel sweep + cross-pod compressed gradient mean on a mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import need_devices
from repro.kernels.ssd_chunk import ssd_chunk, ssd_chunk_ref


@pytest.mark.parametrize("B,Q,H,P,N", [(2, 16, 3, 8, 4), (1, 32, 2, 16, 8),
                                       (3, 8, 4, 4, 16)])
def test_ssd_chunk_sweep(B, Q, H, P, N):
    ks = jax.random.split(jax.random.PRNGKey(Q + H), 6)
    x = jax.random.normal(ks[0], (B, Q, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, Q, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, Q, N))
    Cm = jax.random.normal(ks[4], (B, Q, N))
    h = jax.random.normal(ks[5], (B, H, P, N))
    y, hn = ssd_chunk(x, dt, A, Bm, Cm, h)
    yr, hr = ssd_chunk_ref(x, dt, A, Bm, Cm, h)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(hn), np.asarray(hr), rtol=1e-4,
                               atol=1e-4)


def test_compressed_pod_mean_on_mesh():
    """int8 cross-pod gradient mean with error feedback converges to the
    true mean over steps (2x2 pod x data CPU device mesh)."""
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.optim.grad_compress import compressed_psum_leaf
    need_devices(4)
    mesh = jax.make_mesh((2, 2), ("pod", "data"))

    def step(g, err):
        def body(g_l, e_l):
            # compressed_psum_leaf already returns the cross-pod MEAN
            red, e = compressed_psum_leaf(g_l, e_l, "pod")
            return red, e
        return shard_map(body, mesh=mesh,
                         in_specs=(P("pod"), P("pod")),
                         out_specs=(P("pod"), P("pod")),
                         check_vma=False)(g, err)

    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(0, 1e-2, (2, 256)), jnp.float32)
    err = jnp.zeros_like(g_true)
    acc = jnp.zeros((2, 256), jnp.float32)
    steps = 150
    f = jax.jit(step)
    for _ in range(steps):
        red, err = f(g_true, err)
        acc = acc + red
    true_mean = jnp.mean(g_true, axis=0, keepdims=True)
    got = np.asarray(acc / steps)
    want = np.broadcast_to(np.asarray(true_mean), got.shape)
    # error feedback makes the running average unbiased (single-step
    # int8 error is ~1%; the average converges ~1/steps)
    np.testing.assert_allclose(got, want, atol=3e-4)


def test_serve_ep_moe_matches_local():
    """EP-over-data MoE == single-shard fallback (2x2 CPU device mesh)."""
    from dataclasses import replace
    from repro.config import smoke_config
    from repro.models.moe import init_moe, moe_forward
    need_devices(4)
    cfg = smoke_config("grok-1-314b")
    cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=8.0))
    p = init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 2, cfg.d_model),
                          jnp.float32)
    local, _ = moe_forward(cfg, p, x, mesh=None)
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    ep, _ = moe_forward(cfg, p, x, mesh=mesh, ep_data=True)
    np.testing.assert_allclose(np.asarray(local), np.asarray(ep),
                               rtol=3e-2, atol=3e-2)
