"""Serve-traffic replay subsystem: trace format round-trip + seeded
generators, the continuous-batching engine against its analytic oracle
(``ServeWorkload``), per-request energy accounting from the telemetry
bus, KV-budget/batch-slot admission, the ``serve_replay`` cluster
workload through the online simulator, and the autoscaling fleet
(static flat-out vs SLO-aware parking under a wall power cap)."""
import json

import numpy as np
import pytest

from repro.power.model import OperatingPoint, tpu_chip_power
from repro.power.trace import PowerTrace, TraceRecorder
from repro.serve import (AutoscalePolicy, ContinuousBatchingEngine,
                         HOST_SHARE_W, ReplayServeWorkload, RequestTrace,
                         ServeCostModel, constant_trace, diurnal_trace,
                         flat_out, poisson_trace, replay_shards, run_fleet)
from repro.serve.engine import Replica, emit_step_intervals
from repro.serve.stats import request_energy_j, step_window_integral

OP = OperatingPoint.green500()


@pytest.fixture(scope="module")
def cost():
    return ServeCostModel("llama3-8b", max_batch=4, prompt_len=64, gen=32)


# -- RequestTrace: format, validation, persistence ---------------------------


def test_trace_roundtrip(tmp_path):
    tr = poisson_trace(32, 10.0, prompt_lens=(16, 64), gen_lens=(8, 32),
                       seed=3)
    path = tmp_path / "tr.npz"
    tr.save(path)
    back = RequestTrace.load(path)
    assert np.array_equal(back.arrival_s, tr.arrival_s)
    assert np.array_equal(back.prompt_len, tr.prompt_len)
    assert np.array_equal(back.gen_len, tr.gen_len)
    assert back.meta == tr.meta
    assert back.meta["generator"] == "poisson"


def test_trace_sorts_by_arrival():
    tr = RequestTrace(np.array([3.0, 1.0, 2.0]), np.array([8, 16, 32]),
                      np.array([1, 2, 3]))
    assert np.array_equal(tr.arrival_s, [1.0, 2.0, 3.0])
    assert np.array_equal(tr.prompt_len, [16, 32, 8])
    assert np.array_equal(tr.gen_len, [2, 3, 1])
    assert tr.duration_s == pytest.approx(2.0)
    assert tr.total_prompt_tokens == 56 and tr.total_gen_tokens == 6


@pytest.mark.parametrize("arrival,prompt,gen", [
    ([0.0, 1.0], [8], [4, 4]),               # length mismatch
    ([0.0, -1.0], [8, 8], [4, 4]),           # negative arrival
    ([0.0, np.inf], [8, 8], [4, 4]),         # non-finite arrival
    ([0.0, 1.0], [8, 0], [4, 4]),            # zero prompt_len
    ([0.0, 1.0], [8, 8], [4, 2.5]),          # fractional gen_len
])
def test_trace_rejects_malformed(arrival, prompt, gen):
    with pytest.raises(ValueError):
        RequestTrace(np.array(arrival), np.array(prompt), np.array(gen))


def test_trace_rejects_2d():
    with pytest.raises(ValueError, match="1-D"):
        RequestTrace(np.zeros((2, 2)), np.ones((2, 2)), np.ones((2, 2)))


def test_trace_load_missing_key(tmp_path):
    path = tmp_path / "bad.npz"
    np.savez(path, arrival_s=np.zeros(2), prompt_len=np.ones(2))
    with pytest.raises(ValueError, match="gen_len"):
        RequestTrace.load(path)


def test_trace_load_bad_meta(tmp_path):
    path = tmp_path / "badmeta.npz"
    np.savez(path, arrival_s=np.zeros(2), prompt_len=np.ones(2),
             gen_len=np.ones(2), meta=np.array("{not json"))
    with pytest.raises(ValueError, match="bad meta"):
        RequestTrace.load(path)


def test_meta_json_roundtrip(tmp_path):
    tr = constant_trace(2)
    tr.meta["nested"] = {"a": [1, 2], "b": "x"}
    path = tmp_path / "m.npz"
    tr.save(path)
    assert RequestTrace.load(path).meta["nested"] == \
        json.loads(json.dumps({"a": [1, 2], "b": "x"}))


# -- generators --------------------------------------------------------------


def test_constant_trace_burst_and_rate():
    burst = constant_trace(5, t0=2.0)
    assert np.array_equal(burst.arrival_s, np.full(5, 2.0))
    paced = constant_trace(5, rate_per_s=10.0)
    assert np.allclose(np.diff(paced.arrival_s), 0.1)


def test_generators_seed_deterministic():
    a = poisson_trace(64, 5.0, seed=11)
    b = poisson_trace(64, 5.0, seed=11)
    c = poisson_trace(64, 5.0, seed=12)
    assert np.array_equal(a.arrival_s, b.arrival_s)
    assert not np.array_equal(a.arrival_s, c.arrival_s)
    d = diurnal_trace(100.0, rate_peak_per_s=20.0, seed=4)
    e = diurnal_trace(100.0, rate_peak_per_s=20.0, seed=4)
    assert np.array_equal(d.arrival_s, e.arrival_s)


def test_diurnal_concentrates_midday():
    tr = diurnal_trace(1000.0, rate_peak_per_s=10.0, rate_floor_per_s=0.0,
                       seed=0)
    mid = np.sum((tr.arrival_s > 250.0) & (tr.arrival_s < 750.0))
    # sinusoid with zero floor puts ~82% of mass in the middle half
    assert mid / len(tr) > 0.7
    assert tr.arrival_s.max() < 1000.0


def test_diurnal_validates():
    with pytest.raises(ValueError):
        diurnal_trace(0.0, rate_peak_per_s=1.0)
    with pytest.raises(ValueError):
        diurnal_trace(10.0, rate_peak_per_s=1.0, rate_floor_per_s=2.0)


def test_shard_round_robin():
    tr = poisson_trace(30, 5.0, seed=1)
    shards = tr.shard(4)
    assert sum(len(s) for s in shards) == 30
    assert [s.meta["shard"] for s in shards] == [0, 1, 2, 3]
    merged = np.sort(np.concatenate([s.arrival_s for s in shards]))
    assert np.array_equal(merged, tr.arrival_s)
    with pytest.raises(ValueError):
        tr.shard(0)


# -- windowed integrals (satellite: PowerTrace.energy_j(t0, t1)) -------------


def test_step_window_integral_exact_on_boundaries():
    t = np.array([0.0, 1.0, 1.0, 2.0])
    y = np.array([10.0, 10.0, 20.0, 20.0])
    assert step_window_integral(t, y, 0.0, 2.0) == pytest.approx(30.0)
    assert step_window_integral(t, y, 1.0, 2.0) == pytest.approx(20.0)
    assert step_window_integral(t, y, 0.5, 1.5) == pytest.approx(15.0)
    assert step_window_integral(t, y, 2.0, 2.0) == 0.0
    assert step_window_integral(t, y, 2.0, 1.0) == 0.0


def test_power_trace_windowed_energy():
    tr = PowerTrace(np.array([0.0, 10.0]), {"chip": np.array([0.0, 100.0])},
                    np.zeros(2))
    assert tr.energy_j() == pytest.approx(500.0)
    # edge interpolation: p(5) = 50 → trapezoid over [5, 10] = 375
    assert tr.energy_j(5.0, 10.0) == pytest.approx(375.0)
    assert tr.energy_j(0.0, 5.0) == pytest.approx(125.0)
    assert tr.energy_j(0.0, 5.0) + tr.energy_j(5.0, 10.0) == \
        pytest.approx(tr.energy_j())


def test_power_trace_windowed_energy_network_flag():
    tr = PowerTrace(np.array([0.0, 10.0]),
                    {"chip": np.array([100.0, 100.0]),
                     "network": np.array([10.0, 10.0])},
                    np.zeros(2))
    assert tr.energy_j(0.0, 10.0) == pytest.approx(1100.0)
    assert tr.energy_j(0.0, 10.0, include_network=False) == \
        pytest.approx(1000.0)


# -- continuous-batching engine ----------------------------------------------


def test_oracle_burst_matches_serve_workload(cost):
    """The constant-rate (burst) trace at the full batch must reproduce
    ``ServeWorkload.execute``'s analytic plan exactly: same wall, same
    joules — the engine and the cluster adapter price one step
    identically."""
    burst = constant_trace(cost.max_batch, prompt_len=cost.prompt_len,
                           gen_len=cost.gen)
    res = ContinuousBatchingEngine(cost).replay(burst, op=OP)
    ref = cost.workload.execute(OP)
    assert res.span_s == pytest.approx(ref.wall_s, rel=1e-12)
    assert res.stats.energy_j == pytest.approx(ref.energy_j, rel=1e-9)
    assert res.stats.completed == cost.max_batch


def test_per_request_energy_sums_to_total(cost):
    burst = constant_trace(cost.max_batch, prompt_len=cost.prompt_len,
                           gen_len=cost.gen)
    res = ContinuousBatchingEngine(cost).replay(burst, op=OP)
    per_req = [res.request_energy_j(i) for i in range(cost.max_batch)]
    assert all(e > 0.0 for e in per_req)
    # identical shapes → identical shares
    assert np.allclose(per_req, per_req[0], rtol=1e-12)
    assert sum(per_req) == pytest.approx(res.stats.energy_j, rel=1e-9)


def test_request_energy_requires_batch_aux():
    tr = PowerTrace(np.array([0.0, 1.0]), {"chip": np.array([5.0, 5.0])},
                    np.zeros(2))
    with pytest.raises(ValueError, match="batch"):
        request_energy_j(tr, 0.0, 1.0)


def test_admission_serializes_at_batch_one(cost):
    eng = ContinuousBatchingEngine(cost, max_batch=1)
    res = eng.replay(constant_trace(3, prompt_len=cost.prompt_len,
                                    gen_len=cost.gen), op=OP)
    done = sorted(r.done_s for r in res.records)
    assert len(done) == 3
    # strictly serialized: each request takes a full service time
    gaps = np.diff([0.0] + done)
    assert np.allclose(gaps, gaps[0], rtol=1e-9)
    assert res.stats.mean_wait_s > 0.0
    # in-flight count on the bus never exceeds the single slot
    assert res.trace.aux["batch"].max() <= 1.0


def test_kv_budget_bounds_concurrency(cost):
    need = cost.prompt_len + cost.gen
    eng = ContinuousBatchingEngine(cost, kv_budget_tokens=2 * need)
    res = eng.replay(constant_trace(4, prompt_len=cost.prompt_len,
                                    gen_len=cost.gen), op=OP)
    assert res.stats.completed == 4
    assert res.trace.aux["batch"].max() <= 2.0


def test_oversized_request_rejected(cost):
    eng = ContinuousBatchingEngine(cost, kv_budget_tokens=16)
    with pytest.raises(ValueError, match="never be admitted"):
        eng.replay(constant_trace(1, prompt_len=cost.prompt_len,
                                  gen_len=cost.gen), op=OP)


def test_empty_trace_rejected(cost):
    with pytest.raises(ValueError, match="empty"):
        ContinuousBatchingEngine(cost).replay(constant_trace(0), op=OP)


def test_idle_gap_billed_at_chip_idle_floor(cost):
    plan, _, _ = cost.plan(OP)
    service = 100.0 * (cost.gen * plan.step_time_s)
    tr = RequestTrace(np.array([0.0, service]),
                      np.full(2, cost.prompt_len), np.full(2, cost.gen))
    res = ContinuousBatchingEngine(cost).replay(tr, op=OP)
    p_idle = tpu_chip_power(plan.freq_scale, 0.0, 0.0)
    # the gap between the two requests is emitted at the idle floor
    assert res.trace.power_w.min() == pytest.approx(p_idle)
    assert res.trace.aux["batch"].min() == 0.0
    assert res.stats.completed == 2


def test_lifecycle_timestamps_ordered(cost):
    plan, _, _ = cost.plan(OP)
    rate = 0.5 * cost.max_batch / (cost.gen * plan.step_time_s)
    tr = poisson_trace(40, rate, prompt_lens=(cost.prompt_len,),
                       gen_lens=(cost.gen,), seed=5)
    res = ContinuousBatchingEngine(cost).replay(tr, op=OP, slo_s=1.0)
    assert res.stats.completed == 40
    for r in res.records:
        assert r.admit_s >= r.arrival_s - 1e-12
        assert r.first_token_s > r.admit_s
        assert r.done_s > r.first_token_s
    assert 0.0 <= res.stats.slo_compliance <= 1.0
    assert "compliance" in res.stats.summary()


def test_replay_appends_to_shared_bus(cost):
    rec = TraceRecorder(source="test")
    rec.emit(0.0, {"chip": 42.0}, flops_rate=0.0)
    rec.emit(5.0, {"chip": 42.0}, flops_rate=0.0)
    burst = constant_trace(cost.max_batch, prompt_len=cost.prompt_len,
                           gen_len=cost.gen)
    res = ContinuousBatchingEngine(cost).replay(burst, op=OP, recorder=rec)
    assert res.t_off == pytest.approx(5.0)
    # the replay's own stats window excludes the earlier phase's energy
    ref = cost.workload.execute(OP)
    assert res.stats.energy_j == pytest.approx(ref.energy_j, rel=1e-9)


def test_emit_step_intervals_rejects_gaps():
    rec = TraceRecorder(source="test")
    with pytest.raises(ValueError, match="contiguous"):
        emit_step_intervals(rec, [(0.0, 1.0, 5.0, 0.0, 1),
                                  (2.0, 3.0, 5.0, 0.0, 1)])
    with pytest.raises(ValueError, match="no intervals"):
        emit_step_intervals(rec, [])


def test_freq_scale_on_bus(cost):
    burst = constant_trace(cost.max_batch, prompt_len=cost.prompt_len,
                           gen_len=cost.gen)
    res = ContinuousBatchingEngine(cost).replay(burst, op=OP)
    fs = res.trace.aux["freq_scale"]
    assert np.allclose(fs, res.plan.freq_scale)


# -- serve_replay as a cluster workload --------------------------------------


def test_replay_workload_job_and_execute():
    wl = ReplayServeWorkload(max_batch=4, seed=2)
    job = wl.job()
    assert job.kind == "serve_replay"
    assert not job.shardable
    assert job.work_units > 0.0
    res = wl.execute(OP)
    assert res.kind == "serve_replay"
    assert res.details["completed"] == len(wl.trace)
    assert res.details["j_per_request"] > 0.0
    assert res.details["j_per_token"] > 0.0
    assert res.details["p99_latency_s"] >= res.details["p50_latency_s"]
    assert res.energy_j > 0.0


def test_replay_workload_registered_lazily():
    from repro.cluster.workload import make_workload
    wl = make_workload("serve_replay", max_batch=4)
    assert isinstance(wl, ReplayServeWorkload)
    with pytest.raises(KeyError, match="serve_replay|unknown"):
        make_workload("not_a_kind")


def test_replay_shards_are_placeable():
    tr = poisson_trace(24, 1e5, seed=9)
    shards = replay_shards(tr, 3, max_batch=4)
    assert [w.name for w in shards] == ["serve_replay/0", "serve_replay/1",
                                        "serve_replay/2"]
    assert sum(len(w.trace) for w in shards) == 24
    for w in shards:
        assert w.job().kind == "serve_replay"


def test_replay_workload_through_online_simulator():
    from repro.cluster import ClusterTopology, simulate
    wl = ReplayServeWorkload(max_batch=4, seed=3)
    res = simulate([(0.0, wl)], topology=ClusterTopology(n_nodes=1),
                   op=OP, dt_s=30.0, execute=True)
    assert res.stats.jobs_completed == 1
    assert len(res.results) == 1
    (wr,) = res.results.values()
    assert wr.kind == "serve_replay"
    assert wr.details["completed"] == len(wl.trace)
    assert wr.details["slo_compliance"] <= 1.0


def test_simulator_without_execute_skips_results():
    from repro.cluster import ClusterTopology, simulate
    wl = ReplayServeWorkload(max_batch=4, seed=3)
    res = simulate([(0.0, wl)], topology=ClusterTopology(n_nodes=1),
                   op=OP, dt_s=30.0)
    assert res.stats.jobs_completed == 1
    assert res.results == {}


def test_arrivals_accept_workload_objects():
    from repro.cluster.events import as_arrivals
    wl = ReplayServeWorkload(max_batch=4)
    (a,) = as_arrivals([wl])
    assert a.t == 0.0 and a.workload is wl
    assert a.job.kind == "serve_replay"
    with pytest.raises(TypeError, match="Workload"):
        as_arrivals([object()])


def test_serve_replay_is_memory_bound_kind():
    from repro.cluster.scheduler import MEMORY_BOUND_KINDS, op_rate_scale
    assert "serve_replay" in MEMORY_BOUND_KINDS
    job = ReplayServeWorkload(max_batch=4).job()
    # a deep derate leaves a memory-bound placement at full rate
    assert op_rate_scale(job, OperatingPoint(f_mhz=500.0)) == 1.0


# -- autoscaling fleet -------------------------------------------------------


def _fleet_case(n_max=4, seed=7, util=0.55):
    cost = ServeCostModel("llama3-8b", max_batch=8, prompt_len=64, gen=32)
    plan, _, _ = cost.plan()
    t_pre, _ = cost.prefill_cost(64, 8)
    service = t_pre + 32 * plan.step_time_s
    cap_rps = 8 / service
    day = 600.0 / (util * n_max * cap_rps)
    tr = diurnal_trace(day, rate_peak_per_s=0.75 * n_max * cap_rps,
                       rate_floor_per_s=0.05 * n_max * cap_rps,
                       prompt_lens=(64,), gen_lens=(32,), seed=seed)
    probe = Replica(cost)
    cap = n_max * (probe.p_busy + HOST_SHARE_W) + 1.0
    dt_ctrl = day / 288.0
    slo = 8.0 * service + 3.0 * dt_ctrl
    return cost, tr, day, cap, dt_ctrl, slo


def test_fleet_autoscaled_beats_static_flat_out():
    cost, tr, day, cap, dt_ctrl, slo = _fleet_case()
    static = run_fleet(cost, tr, flat_out(4, power_cap_w=cap), slo_s=slo)
    auto = run_fleet(
        cost, tr,
        AutoscalePolicy(name="auto", n_max=4, n_min=1, dt_ctrl_s=dt_ctrl,
                        power_cap_w=cap),
        slo_s=slo)
    # no lost requests under either policy
    assert static.stats.completed == len(tr) == auto.stats.completed
    # the ISSUE gate: cheaper joules per request at >= compliance, under cap
    assert auto.stats.j_per_request < static.stats.j_per_request
    assert auto.stats.slo_compliance >= static.stats.slo_compliance - 1e-12
    assert auto.stats.peak_power_w <= cap + 1e-6
    assert static.stats.peak_power_w <= cap + 1e-6
    # static keeps the whole fleet live; the autoscaler parks replicas
    assert static.n_live_min == static.n_live_peak == 4
    assert auto.n_live_min < 4


def test_fleet_static_energy_within_physical_bounds():
    cost, tr, day, cap, dt_ctrl, slo = _fleet_case()
    r = run_fleet(cost, tr, flat_out(4, power_cap_w=cap), slo_s=slo)
    probe = Replica(cost)
    lo = r.span_s * 4 * (probe.p_idle + HOST_SHARE_W)
    hi = r.span_s * 4 * (probe.p_busy + HOST_SHARE_W)
    assert lo * (1 - 1e-9) <= r.stats.energy_j <= hi * (1 + 1e-9)
    assert r.span_s >= day * 0.99  # span covers the whole day


def test_fleet_power_cap_limits_live_replicas():
    cost, tr, day, cap, dt_ctrl, slo = _fleet_case()
    probe = Replica(cost)
    cap2 = 2 * (probe.p_busy + HOST_SHARE_W) + 1.0   # room for 2 of 4
    r = run_fleet(cost, tr,
                  AutoscalePolicy(name="capped", n_max=4, n_min=1,
                                  dt_ctrl_s=dt_ctrl, power_cap_w=cap2),
                  slo_s=slo)
    assert r.n_live_peak <= 2
    assert r.stats.peak_power_w <= cap2 + 1e-6
    assert r.stats.completed == len(tr)


def test_fleet_cap_below_n_min_rejected():
    cost, tr, day, cap, dt_ctrl, slo = _fleet_case()
    with pytest.raises(ValueError, match="power cap"):
        run_fleet(cost, tr,
                  AutoscalePolicy(n_max=4, n_min=2, power_cap_w=50.0))


def test_fleet_scales_up_and_down():
    cost, tr, day, cap, dt_ctrl, slo = _fleet_case()
    r = run_fleet(cost, tr,
                  AutoscalePolicy(name="auto", n_max=4, n_min=1,
                                  dt_ctrl_s=dt_ctrl, power_cap_w=cap),
                  slo_s=slo)
    # the diurnal peak forces growth; the trough lets it shrink again
    assert r.n_live_peak > 1
    diffs = np.diff(r.live_n)
    assert np.any(diffs > 0) and np.any(diffs < 0)
    # host share rides the live count on the bus
    assert "host" in r.trace.components
    assert np.isclose(r.trace.components["host"].max(),
                      r.n_live_peak * HOST_SHARE_W)


def test_fleet_rejects_empty_trace():
    cost = ServeCostModel("llama3-8b", max_batch=4)
    with pytest.raises(ValueError, match="empty"):
        run_fleet(cost, constant_trace(0), AutoscalePolicy())


# -- grow_decode_cache (satellite extraction) --------------------------------


@pytest.fixture(scope="module")
def tiny_model():
    import jax
    from repro.config import get_arch
    from repro.models import init_params
    from repro.runtime.steps import make_prefill_step
    cfg = get_arch("olmo-1b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    prefill = jax.jit(make_prefill_step(cfg))
    return cfg, params, prefill


def test_grow_decode_cache_preserves_prefix(tiny_model):
    import jax.numpy as jnp
    from repro.runtime.steps import grow_decode_cache
    cfg, params, prefill = tiny_model
    B, S, total = 2, 8, 12
    batch = {"tokens": jnp.zeros((B, S), jnp.int32)}
    _, cache = prefill(params, batch)
    grown = grow_decode_cache(cfg, cache, B, total)
    assert set(grown) == set(cache)
    assert np.array_equal(np.asarray(grown["pos"]),
                          np.asarray(cache["pos"]))
    for k in cache:
        if k == "pos":
            continue
        old = np.asarray(cache[k])
        new = np.asarray(grown[k])
        if old.shape == new.shape:
            assert np.array_equal(new, old), k
        else:
            sl = tuple(slice(0, s) for s in old.shape)
            assert np.array_equal(new[sl], old), k


def test_grow_decode_cache_decodes(tiny_model):
    import jax
    import jax.numpy as jnp
    from repro.runtime.steps import grow_decode_cache, make_decode_step
    cfg, params, prefill = tiny_model
    B, S, gen = 2, 8, 3
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    logits, cache = prefill(params, batch)
    cache = grow_decode_cache(cfg, cache, B, S + gen)
    decode = jax.jit(make_decode_step(cfg))
    tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)[:, None]
    for _ in range(gen):
        logits, cache = decode(params, tok.astype(jnp.int32), cache)
        tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)[:, None]
    assert int(cache["pos"]) == S + gen
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


def test_executed_runtime_attaches_real_tokens(tiny_model):
    from repro.serve import ExecutedGroupRuntime
    cfg, params, _ = tiny_model
    cost = ServeCostModel("olmo-1b", max_batch=4, prompt_len=8, gen=4)
    runtime = ExecutedGroupRuntime("olmo-1b", params=params)
    tr = constant_trace(3, prompt_len=8, gen_len=4)
    res = ContinuousBatchingEngine(cost, runtime=runtime).replay(tr, op=OP)
    analytic = ContinuousBatchingEngine(cost).replay(tr, op=OP)
    # timing/energy stay analytic; only token content is executed
    assert res.span_s == pytest.approx(analytic.span_s, rel=1e-12)
    assert res.stats.energy_j == pytest.approx(analytic.stats.energy_j,
                                               rel=1e-12)
    for r in res.records:
        assert r.tokens is not None and r.tokens.shape == (4,)
        assert np.all((r.tokens >= 0) & (r.tokens < cfg.vocab_size))
    assert all(r.tokens is None for r in analytic.records)


def test_executed_runtime_rejects_multimodal():
    from repro.serve import ExecutedGroupRuntime
    with pytest.raises(ValueError, match="token-only"):
        ExecutedGroupRuntime("llava-next-mistral-7b")
