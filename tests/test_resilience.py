"""Checkpoint/restart resilience layer: Daly-interval math, the
progress-preserving failure path, eager-vs-lazy failure-draw identity,
elastic restart, the storage trace component, and retry-aware serving
under replica fault injection."""
import math

import numpy as np
import pytest

from repro.cluster import (AttemptPlan, CheckpointPolicy, ClusterTopology,
                           Job, daly_interval_s, job_state_bytes, run,
                           simulate)
from repro.cluster.resilience import DEFAULT_STORAGE_BW_BS, DEFAULT_WRITE_W
from repro.distributed.fault import WeibullFailureModel
from repro.power.model import OperatingPoint
from test_cluster_sim import (assert_no_double_booking,
                              assert_traces_identical, batch_order,
                              _SIM_META)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                  # pragma: no cover
    from _hypothesis_fallback import given, settings, strategies as st

OP = OperatingPoint.green500()


# -- Daly interval & cost model ----------------------------------------------


def test_daly_interval_formula():
    assert daly_interval_s(10.0, 3600.0) == pytest.approx(
        math.sqrt(2.0 * 10.0 * 3600.0))
    assert daly_interval_s(10.0, math.inf) == math.inf
    assert daly_interval_s(0.0, 3600.0) == math.inf
    assert daly_interval_s(10.0, 0.0) == math.inf


def test_job_state_bytes_precedence():
    assert job_state_bytes(Job("a", 13.0, 1.0)) == pytest.approx(13.0e9)
    assert job_state_bytes(
        Job("b", 13.0, 1.0, state_bytes=2.0e9)) == pytest.approx(2.0e9)
    # explicit 0.0 = stateless, NOT a fallback to mem_gb
    assert job_state_bytes(Job("c", 13.0, 1.0, state_bytes=0.0)) == 0.0


def test_policy_interval_scales_with_node_span():
    pol = CheckpointPolicy(min_interval_s=0.0)
    job = Job("j", 13.0, 1.0)
    t1 = pol.interval_for(job, n_nodes=1, mtbf_node_s=3.6e5)
    t4 = pol.interval_for(job, n_nodes=4, mtbf_node_s=3.6e5)
    # 4 nodes fail 4x as often → interval shrinks by 2
    assert t4 == pytest.approx(t1 / 2.0)


def test_policy_fixed_override_and_floor():
    job = Job("j", 13.0, 1.0)
    pol = CheckpointPolicy(interval_s=120.0)
    assert pol.interval_for(job, mtbf_node_s=1.0) == 120.0
    floor = CheckpointPolicy(interval_s=1.0, min_interval_s=30.0)
    assert floor.interval_for(job) == 30.0
    assert CheckpointPolicy().interval_for(job) == math.inf  # MTBF=∞
    with pytest.raises(ValueError):
        CheckpointPolicy(storage_bw_bs=0.0)
    with pytest.raises(ValueError):
        CheckpointPolicy(interval_s=-1.0)


def test_stateless_job_never_checkpoints():
    pol = CheckpointPolicy()
    job = Job("serve", 13.0, 1.0, state_bytes=0.0)
    assert pol.write_time_s(job) == 0.0
    assert pol.interval_for(job, mtbf_node_s=100.0) == math.inf


def test_write_time_from_bandwidth():
    pol = CheckpointPolicy(storage_bw_bs=1.0e9)
    assert pol.write_time_s(Job("j", 13.0, 1.0)) == pytest.approx(13.0)
    assert DEFAULT_STORAGE_BW_BS > 0 and DEFAULT_WRITE_W >= 0


# -- AttemptPlan timeline ----------------------------------------------------


def test_attempt_plan_counts_and_duration():
    # 100 s of work at τ=30, δ=5: ⌈100/30⌉−1 = 3 checkpoints, 15 s overhead
    plan = AttemptPlan(100.0, 30.0, 5.0)
    assert plan.n_checkpoints == 3
    assert plan.overhead_s == pytest.approx(15.0)
    assert plan.duration_s == pytest.approx(115.0)
    # work an exact multiple of τ: no checkpoint at the very end
    assert AttemptPlan(60.0, 30.0, 5.0).n_checkpoints == 1
    assert AttemptPlan(30.0, 30.0, 5.0).n_checkpoints == 0
    assert AttemptPlan(100.0, math.inf, 5.0).n_checkpoints == 0


def test_attempt_plan_windows_and_clipping():
    plan = AttemptPlan(100.0, 30.0, 5.0)
    assert plan.checkpoint_windows() == [(30.0, 35.0), (65.0, 70.0),
                                         (100.0, 105.0)]
    # a kill mid-second-write truncates it (billed) and drops the third
    assert plan.checkpoint_windows(until_s=67.0) == [(30.0, 35.0),
                                                     (65.0, 67.0)]
    assert plan.checkpoint_windows(until_s=30.0) == []


def test_attempt_plan_progress_rounds_down():
    plan = AttemptPlan(100.0, 30.0, 5.0)
    # killed mid-write: the in-progress write preserves nothing
    preserved, wasted = plan.progress_at(33.0)
    assert preserved == 0.0 and wasted == pytest.approx(30.0)
    # killed after the first write completes: 30 s durable
    preserved, wasted = plan.progress_at(40.0)
    assert preserved == pytest.approx(30.0)
    assert wasted == pytest.approx(5.0)
    # killed at the very start
    assert plan.progress_at(0.0) == (0.0, 0.0)


@settings(max_examples=40, deadline=None)
@given(work=st.floats(1.0, 5000.0), tau=st.floats(5.0, 2000.0),
       delta=st.floats(0.1, 60.0), frac=st.floats(0.0, 1.0))
def test_attempt_plan_progress_invariants(work, tau, delta, frac):
    plan = AttemptPlan(work, tau, delta)
    e = frac * plan.duration_s
    preserved, wasted = plan.progress_at(e)
    assert 0.0 <= preserved <= work + 1e-9
    assert wasted >= 0.0
    assert preserved + wasted <= work + 1e-9
    # preserved is always a whole number of τ-intervals
    k = preserved / plan.tau_s if plan.tau_s > 0 else 0.0
    assert abs(k - round(k)) < 1e-6


# -- eager vs lazy failure draws ---------------------------------------------


def test_sim_outages_match_eager_iterator():
    fm = WeibullFailureModel(mtbf_s=1800.0, shape=1.0, repair_s=300.0)
    top = ClusterTopology(n_nodes=3)
    jobs = [Job(f"j{i}", 13.0, 4000.0) for i in range(6)]
    res = simulate(jobs, topology=top, op=OP, dt_s=60.0, failure_model=fm,
                   seed=11, max_requeues=100)
    assert res.outages, "scenario must actually draw failures"
    horizon = max(t for _, t, _ in res.outages)
    eager = [o for o in fm.node_outages(11, top.n_nodes, horizon + 1e-9)]
    # the sim's lazy per-repair draws replay the eager per-node streams
    # draw-for-draw: every sim outage appears in the eager sequence
    eager_set = {(n, round(a, 9), round(b, 9)) for n, a, b in eager}
    for n, a, b in res.outages:
        assert (n, round(a, 9), round(b, 9)) in eager_set


def test_node_streams_are_per_node_stable():
    fm = WeibullFailureModel(mtbf_s=900.0, shape=1.2, repair_s=100.0)
    a = list(fm.node_outages(5, 4, 5000.0))
    b = list(fm.node_outages(5, 4, 5000.0))
    assert a == b
    # node i's sequence is independent of n_nodes
    solo = [(n, t0, t1) for n, t0, t1 in fm.node_outages(5, 1, 5000.0)]
    first = [(n, t0, t1) for n, t0, t1 in a if n == 0]
    assert solo == first


@settings(max_examples=12, deadline=None)
@given(mtbf=st.floats(200.0, 5000.0), shape=st.floats(0.7, 1.8))
def test_weibull_outage_statistics(mtbf, shape):
    fm = WeibullFailureModel(mtbf_s=mtbf, shape=shape, repair_s=10.0)
    outs = list(fm.node_outages(3, 64, 40.0 * mtbf))
    assert outs
    # uptimes between outages average ≈ MTBF (renewal process)
    ups = []
    last = {}
    for n, t0, t1 in outs:
        ups.append(t0 - last.get(n, 0.0))
        last[n] = t1
    assert np.mean(ups) == pytest.approx(mtbf, rel=0.15)
    assert all(t1 - t0 == pytest.approx(10.0) for _, t0, t1 in outs)


# -- simulator integration ---------------------------------------------------


_FM = WeibullFailureModel(mtbf_s=1200.0, shape=1.0, repair_s=300.0)


def test_no_failure_oracle_stays_bit_identical_with_policy():
    """MTBF=∞ ⇒ zero checkpoints ⇒ the checkpointed sim is bit-identical
    to batch cluster.run(), including the component set (no storage)."""
    top = ClusterTopology(n_nodes=2)
    jobs = batch_order([Job(f"j{i}", 13.0, 300.0 + 41.0 * i)
                        for i in range(10)])
    batch = run(jobs, topology=top, op=OP, dt_s=13.0)
    sim = simulate(jobs, topology=top, op=OP, dt_s=13.0, backfill=False,
                   checkpoint=CheckpointPolicy(), elastic=True)
    assert_traces_identical(sim.trace, batch.trace, ignore_meta=_SIM_META)
    assert "storage" not in sim.trace.components
    assert sim.stats.checkpoints == 0
    assert sim.stats.wasted_energy_j == 0.0
    assert sim.stats.wasted_node_s == 0.0
    assert sim.stats.wasted_chip_s == 0.0
    assert sim.stats.goodput == 1.0


def test_checkpointing_preserves_progress():
    jobs = [Job("hero", 13.0, 3600.0)]
    top = ClusterTopology(n_nodes=1)
    plain = simulate(jobs, topology=top, op=OP, dt_s=30.0,
                     failure_model=_FM, seed=3, max_requeues=50)
    ckpt = simulate(jobs, topology=top, op=OP, dt_s=30.0,
                    failure_model=_FM, seed=3, max_requeues=50,
                    checkpoint=CheckpointPolicy())
    assert plain.stats.node_failures >= 1
    assert ckpt.stats.checkpoints >= 1
    # progress preservation strictly shortens the run and cuts the waste
    assert ckpt.stats.makespan_s < plain.stats.makespan_s
    assert ckpt.stats.wasted_chip_s < plain.stats.wasted_chip_s
    assert ckpt.stats.goodput > plain.stats.goodput
    # the storage component is on the trace and integrates to the stats
    assert "storage" in ckpt.trace.components
    storage_j = np.trapezoid(ckpt.trace.components["storage"], ckpt.trace.t)
    assert storage_j == pytest.approx(ckpt.stats.checkpoint_energy_j,
                                      rel=0.05)
    rec = ckpt.records[0]
    assert rec.state == "completed" and rec.progress == 1.0
    assert rec.checkpoints == ckpt.stats.checkpoints


def test_wasted_work_accounting_consistency():
    jobs = [Job(f"j{i}", 13.0, 2500.0) for i in range(4)]
    top = ClusterTopology(n_nodes=2)
    res = simulate(jobs, topology=top, op=OP, dt_s=30.0, failure_model=_FM,
                   seed=9, max_requeues=60, checkpoint=CheckpointPolicy())
    st_ = res.stats
    assert st_.node_failures >= 1
    assert st_.wasted_chip_s >= st_.wasted_node_s >= 0.0
    assert st_.wasted_energy_j >= 0.0
    assert 0.0 <= st_.goodput <= 1.0
    assert st_.checkpoint_overhead_s >= 0.0
    # the RAPS block mentions the new rows
    s = st_.summary()
    assert "waste" in s and "goodput" in s and "ckpt" in s
    assert_no_double_booking(res.schedule.placements, top.gpus_per_node)


def test_elastic_restart_shrinks_requeued_round_robin_job():
    """round_robin inflates a shardable job to node width; after its node
    dies, elastic restart lands it on the one chip that is actually free
    instead of stalling until the long repair completes."""
    fm = WeibullFailureModel(mtbf_s=5000.0, shape=1.0, repair_s=12000.0)
    top = ClusterTopology(n_nodes=2)
    jobs = [Job("big", 13.0, 24000.0, shardable=True),
            Job("f0", 13.0, 15000.0, shardable=False),
            Job("f1", 13.0, 15000.0, shardable=False),
            Job("f2", 13.0, 15000.0, shardable=False)]
    kw = dict(topology=top, policy="round_robin", op=OP, dt_s=60.0,
              failure_model=fm, seed=14, max_requeues=200,
              checkpoint=CheckpointPolicy())
    rigid = simulate(jobs, **kw)
    elastic = simulate(jobs, **kw, elastic=True)
    assert elastic.stats.node_failures >= 1
    full_width = top.gpus_per_node
    big_widths = {len(p.chips) for p in elastic.schedule.placements
                  if p.job.name == "big"}
    # the requeued attempt ran narrower than the round_robin batch width
    assert any(w < full_width for w in big_widths)
    assert full_width in big_widths          # ...but the first was full
    assert elastic.stats.jobs_completed == len(jobs)
    assert rigid.stats.jobs_completed == len(jobs)
    assert elastic.stats.makespan_s < rigid.stats.makespan_s
    assert_no_double_booking(elastic.schedule.placements, top.gpus_per_node)


def test_daly_beats_naive_fixed_intervals_on_energy():
    """The tentpole gate in miniature: under a seeded failure stream,
    the Daly interval beats no-checkpointing and a too-frequent fixed
    interval on energy-to-completion."""
    fm = WeibullFailureModel(mtbf_s=4000.0, shape=1.0, repair_s=300.0)
    top = ClusterTopology(n_nodes=2)
    jobs = [Job(f"j{i}", 13.0, 6000.0) for i in range(8)]

    def energy(checkpoint):
        r = simulate(jobs, topology=top, op=OP, dt_s=120.0,
                     failure_model=fm, seed=3, max_requeues=300,
                     checkpoint=checkpoint)
        assert r.stats.jobs_completed == len(jobs)
        return r.stats.energy_j

    e_none = energy(None)
    e_daly = energy(CheckpointPolicy())
    e_spam = energy(CheckpointPolicy(interval_s=30.0))
    assert e_daly < e_none
    assert e_daly < e_spam


# -- serve retry layer -------------------------------------------------------


def _serve_setup():
    from repro.serve import ServeCostModel, poisson_trace
    cost = ServeCostModel(max_batch=8, gen=256, smoke=False)
    reqs = poisson_trace(300, rate_per_s=20.0, seed=0, gen_lens=(256,))
    return cost, reqs


def test_serve_failures_inject_retries():
    from repro.serve import AutoscalePolicy, RetryPolicy, run_fleet
    cost, reqs = _serve_setup()
    pol = AutoscalePolicy(n_max=4, n_min=2, dt_ctrl_s=2.0)
    fm = WeibullFailureModel(mtbf_s=15.0, shape=1.0, repair_s=5.0)
    base = run_fleet(cost, reqs, pol, slo_s=2.0)
    faulty = run_fleet(cost, reqs, pol, slo_s=2.0, failures=fm,
                       retry=RetryPolicy(max_retries=2), failure_seed=7)
    assert faulty.stats.replica_failures >= 1
    assert faulty.stats.retries >= 1
    assert faulty.outages
    # every request is terminal: completed or gave up
    assert all(r.done_s is not None or r.gave_up for r in faulty.records)
    assert faulty.stats.completed + faulty.stats.gave_up == len(reqs)
    # degraded but honest: compliance never *improves* under failures
    assert faulty.stats.slo_compliance <= base.stats.slo_compliance + 1e-12
    assert faulty.stats.p99_latency_s >= base.stats.p99_latency_s - 1e-12
    # same seed replays exactly
    again = run_fleet(cost, reqs, pol, slo_s=2.0, failures=fm,
                      retry=RetryPolicy(max_retries=2), failure_seed=7)
    assert again.stats == faulty.stats


def test_serve_no_failure_path_is_untouched():
    from repro.serve import AutoscalePolicy, run_fleet
    cost, reqs = _serve_setup()
    pol = AutoscalePolicy(n_max=3, n_min=1, dt_ctrl_s=2.0)
    a = run_fleet(cost, reqs, pol, slo_s=2.0)
    b = run_fleet(cost, reqs, pol, slo_s=2.0)
    assert np.array_equal(a.trace.t, b.trace.t)
    assert np.array_equal(a.trace.power_w, b.trace.power_w)
    assert a.stats == b.stats
    assert a.stats.retries == 0 and a.stats.gave_up == 0
    assert a.stats.replica_failures == 0 and a.outages == []


def test_serve_retry_budget_exhaustion_drops_requests():
    from repro.serve import AutoscalePolicy, RetryPolicy, run_fleet
    cost, reqs = _serve_setup()
    pol = AutoscalePolicy(n_max=2, n_min=2, dt_ctrl_s=2.0)
    fm = WeibullFailureModel(mtbf_s=4.0, shape=1.0, repair_s=6.0)
    res = run_fleet(cost, reqs, pol, slo_s=2.0, failures=fm,
                    retry=RetryPolicy(max_retries=0), failure_seed=3)
    assert res.stats.replica_failures >= 1
    assert res.stats.gave_up >= 1
    # gave-up requests depress compliance (the denominator is honest)
    done = [r for r in res.records if r.done_s is not None]
    lat = [r.done_s - r.arrival_s for r in done]
    ok = sum(1 for v in lat if v <= 2.0)
    expect = ok / (len(done) + res.stats.gave_up)
    assert res.stats.slo_compliance == pytest.approx(expect)


def test_retry_policy_backoff_caps():
    from repro.serve import RetryPolicy
    rp = RetryPolicy(max_retries=5, backoff_s=0.5, backoff_cap_s=4.0)
    assert rp.delay_s(1) == 0.5
    assert rp.delay_s(2) == 1.0
    assert rp.delay_s(4) == 4.0
    assert rp.delay_s(10) == 4.0
    with pytest.raises(ValueError):
        RetryPolicy(backoff_s=0.0)


# -- slow fault-injection sweep (bench-smoke CI leg) -------------------------


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(8))
def test_fault_injection_sweep_invariants(seed):
    """Many-seed requeue/checkpoint invariants: every job terminal, no
    chip double-booked, energy above the idle floor, accounting sane."""
    from repro.power.layers import NodeModel
    rng = np.random.default_rng(seed)
    n_nodes = int(rng.integers(1, 4))
    top = ClusterTopology(n_nodes=n_nodes)
    jobs = [Job(f"j{i}", 13.0, float(rng.uniform(500.0, 6000.0)))
            for i in range(int(rng.integers(2, 12)))]
    fm = WeibullFailureModel(mtbf_s=float(rng.uniform(900.0, 5000.0)),
                             shape=float(rng.uniform(0.7, 1.8)),
                             repair_s=300.0)
    ckpt = CheckpointPolicy() if seed % 2 == 0 else \
        CheckpointPolicy(interval_s=float(rng.uniform(60.0, 1200.0)))
    res = simulate(jobs, topology=top, op=OP, dt_s=60.0, failure_model=fm,
                   seed=seed, max_requeues=100, checkpoint=ckpt,
                   elastic=bool(seed % 3 == 0))
    st_ = res.stats
    assert st_.jobs_completed + st_.jobs_dropped == len(jobs)
    assert 0.0 <= st_.utilization <= 1.0 + 1e-9
    assert 0.0 <= st_.goodput <= 1.0
    assert st_.wasted_chip_s >= 0.0 and st_.wasted_energy_j >= 0.0
    assert st_.checkpoints >= 0
    assert_no_double_booking(res.schedule.placements, top.gpus_per_node)
    idle_w = (NodeModel().power(OP, load=0.0) * n_nodes + top.network_w)
    assert st_.energy_j >= idle_w * res.trace.duration * (1 - 1e-9)
    for rec in res.records:
        assert rec.state in ("completed", "dropped")
        assert 0.0 <= rec.completed_fraction <= 1.0
