"""Calibration-constant dedup: after the repro.power refactor exactly
one definition of each calibrated power constant/curve may exist.

Two enforcement angles:
  * import identity — the legacy paths (``core.energy.*``, ``autotune``)
    must re-export the *same objects* as ``repro.power``, not copies;
  * source scan — the modules that used to carry private copies must
    not define them (or their literal values) anymore.
"""
import importlib
import inspect
from pathlib import Path

import repro.autotune as autotune
import repro.autotune.measure as measure
import repro.autotune.space as space
import repro.core.energy.dvfs as dvfs
import repro.core.energy.green500 as legacy_green500
import repro.core.energy.power_model as legacy_pm
import repro.core.energy.throttle as throttle
import repro.power.green500 as power_green500
import repro.power.layers as layers
import repro.power.model as pm

# (the package re-exports the solver_energy *function* under this name,
# so fetch the module explicitly)
solver_energy = importlib.import_module("repro.core.energy.solver_energy")

SHARED_FUNCTIONS = [
    "voltage_at", "gpu_static_power", "gpu_dynamic_power", "gpu_power",
    "fan_power", "sample_vids", "tpu_chip_power",
]
SHARED_CONSTANTS = [
    "K_DYN", "P_GPU_STATIC_40C", "TEMP_SLOPE_W_PER_C", "FAN_BASE_W",
    "FAN_CUBIC_W", "V_F_SLOPE", "V_MIN", "V_MAX", "STOCK_MHZ",
    "EFFICIENT_MHZ", "TPU_IDLE_W", "TPU_DYN_COMPUTE_W", "TPU_DYN_MEM_W",
    "TPU_TDP_W",
]


def test_legacy_power_model_is_a_pure_reexport():
    for name in SHARED_FUNCTIONS:
        assert getattr(legacy_pm, name) is getattr(pm, name), name
    for name in SHARED_CONSTANTS:
        assert getattr(legacy_pm, name) == getattr(pm, name), name
    assert legacy_pm.S9150 is pm.S9150
    assert legacy_pm.node_power is layers.node_power
    assert legacy_pm.NodeModel is layers.NodeModel


def test_throttle_power_side_is_shared():
    assert throttle.sustained_frequency is pm.sustained_frequency
    assert throttle.gpu_power_throttled is pm.gpu_power_throttled
    assert throttle.HPL_GPU_UTIL == pm.HPL_GPU_UTIL


def test_autotune_has_no_private_power_model():
    """The calibration curves the autotuner duplicated pre-refactor must
    be the repro.power objects, and its source must not re-define them."""
    assert measure.temp_from_fan is pm.temp_from_fan
    assert autotune.temp_from_fan is pm.temp_from_fan
    assert space.NB_EFFICIENCY is pm.NB_EFFICIENCY
    assert autotune.NB_EFFICIENCY is pm.NB_EFFICIENCY
    src = Path(measure.__file__).read_text()
    for marker in ("def temp_from_fan", "def hpl_block_util",
                   "def hpl_block_perf_scale", "def lookahead_perf_scale",
                   "def node_power"):
        assert marker not in src, f"{marker} re-defined in autotune.measure"


def test_green500_and_dvfs_are_shims():
    assert legacy_green500.measure_efficiency \
        is power_green500.measure_efficiency
    assert legacy_green500.linpack_power_trace \
        is power_green500.linpack_power_trace
    assert legacy_green500.level1_exploit is power_green500.level1_exploit
    assert dvfs.fan_curve is pm.fan_curve
    src = Path(dvfs.__file__).read_text()
    assert "def fan_curve" not in src


def test_solver_energy_references_the_spec_not_literals():
    hw = solver_energy.S9150_HW
    assert hw.power_w == pm.S9150.tdp_w
    assert hw.bandwidth_gbs == pm.S9150.mem_bw_gbs
    src = inspect.getsource(solver_energy)
    # the pre-refactor private literals (275.0 TDP / 320.0 GB/s) are gone
    assert "275.0" not in src and "320.0" not in src


def test_no_stray_calibration_literals_outside_repro_power():
    """The node-power calibration literals live only in repro/power; any
    other module needing them must import, not re-declare.  (Scans the
    src tree for the distinctive constant values.)"""
    src_root = Path(pm.__file__).resolve().parents[1]   # .../src/repro
    offenders = []
    for py in src_root.rglob("*.py"):
        rel = py.relative_to(src_root)
        if rel.parts[0] == "power":
            continue
        text = py.read_text()
        for literal in ("2816", "K_DYN = ", "FAN_CUBIC_W = ",
                        "P_GPU_STATIC_40C = ", "0.908"):
            if literal in text:
                offenders.append(f"{rel}: {literal}")
    assert not offenders, offenders
