import os
import sys
from pathlib import Path

# tests run with PYTHONPATH=src; make it robust when invoked differently
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# smoke tests and benches must see 1 device (the dry-run alone uses 512,
# in its own process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
