import os
import sys
from pathlib import Path

# tests run with PYTHONPATH=src; make it robust when invoked differently
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# CPU backend (never probe for accelerators — the TPU plugin's metadata
# lookup hangs on hosts without one), with 8 virtual host devices so the
# multi-device sharding tests run in-process.  Must happen before jax
# initializes a backend; conftest imports first, so it does.  The
# dry-run subprocess test overrides with its own 512-device flag.
flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in flags:
    os.environ["XLA_FLAGS"] = (
        flags + " --xla_force_host_platform_device_count=8").strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")


def need_devices(n: int) -> None:
    """Skip (don't fail) a multi-device test when the virtual-device
    flag above was overridden away and fewer than ``n`` are visible."""
    import jax
    import pytest
    if jax.device_count() < n:
        pytest.skip(f"needs {n} devices; set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={n}")
