"""Checkpoint manager hardening: truncated/corrupt checkpoints fall
back instead of killing a restart, async write failures surface via
``wait()``, and save→restore round-trips stay exact."""
import json

import numpy as np
import pytest

from repro.checkpoint import CheckpointError, CheckpointManager


def _tree(seed: int = 0):
    rng = np.random.default_rng(seed)
    return {"w": rng.normal(size=(4, 3)).astype(np.float32),
            "b": rng.normal(size=(3,)).astype(np.float32),
            "opt": {"mu": rng.normal(size=(4, 3)).astype(np.float32)}}


def _save(mgr: CheckpointManager, step: int, seed: int):
    tree = _tree(seed)
    mgr.save(step, tree, blocking=True)
    return tree


def _assert_trees_equal(a, b):
    assert np.array_equal(a["w"], b["w"])
    assert np.array_equal(a["b"], b["b"])
    assert np.array_equal(a["opt"]["mu"], b["opt"]["mu"])


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    tree = _save(mgr, 10, seed=1)
    assert mgr.latest_step() == 10
    got = mgr.restore(10, _tree(99))
    _assert_trees_equal(got, tree)


def test_steps_listing_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3):
        _save(mgr, s, seed=s)
    assert mgr.steps() == [2, 3]          # keep=2 dropped step 1
    assert mgr.latest_step() == 3


def test_truncated_leaf_raises_checkpoint_error(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    _save(mgr, 5, seed=0)
    leaf = next((tmp_path / "step_00000005").glob("leaf_*.npy"))
    leaf.write_bytes(leaf.read_bytes()[:16])   # truncate mid-header
    with pytest.raises(CheckpointError):
        mgr.restore(5, _tree())


def test_shape_mismatch_raises_checkpoint_error(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    _save(mgr, 5, seed=0)
    d = tmp_path / "step_00000005"
    manifest = json.loads((d / "manifest.json").read_text())
    name, meta = next(iter(manifest["leaves"].items()))
    np.save(d / meta["file"], np.zeros((1,), dtype=np.float32))
    with pytest.raises(CheckpointError, match="shape"):
        mgr.restore(5, _tree())


def test_corrupt_manifest_raises_checkpoint_error(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    _save(mgr, 5, seed=0)
    (tmp_path / "step_00000005" / "manifest.json").write_text("{not json")
    with pytest.raises(CheckpointError, match="manifest"):
        mgr.restore(5, _tree())


def test_restore_latest_falls_back_past_corrupt_step(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    good = _save(mgr, 7, seed=7)
    _save(mgr, 8, seed=8)
    # the newest checkpoint was truncated by a crash mid-write
    leaf = next((tmp_path / "step_00000008").glob("leaf_*.npy"))
    leaf.write_bytes(b"")
    with pytest.warns(RuntimeWarning, match="corrupt"):
        step, tree = mgr.restore_latest(_tree())
    assert step == 7
    _assert_trees_equal(tree, good)


def test_restore_latest_empty_dir_returns_none(tmp_path):
    mgr = CheckpointManager(tmp_path)
    assert mgr.restore_latest(_tree()) == (None, None)


def test_restore_latest_all_corrupt_returns_none(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    _save(mgr, 1, seed=1)
    next((tmp_path / "step_00000001").glob("leaf_*.npy")).write_bytes(b"")
    with pytest.warns(RuntimeWarning):
        assert mgr.restore_latest(_tree()) == (None, None)


def test_incomplete_step_dir_is_invisible(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    _save(mgr, 3, seed=3)
    # a crash before the manifest write leaves no manifest.json
    broken = tmp_path / "step_00000009"
    broken.mkdir()
    np.save(broken / "leaf_00000.npy", np.zeros(2))
    assert mgr.steps() == [3]
    assert mgr.latest_step() == 3


def test_async_write_failure_surfaces_via_wait(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(1, _tree(), blocking=False)
    mgr.wait()
    # sabotage the target: the step dir becomes a file the writer can't
    # replace, so the background rename fails
    mgr._write_error = OSError("disk full")   # simulate a thread failure
    with pytest.raises(CheckpointError, match="disk full"):
        mgr.wait()
    # the error is consumed: the manager is usable again
    mgr.save(2, _tree(), blocking=True)
    assert mgr.latest_step() == 2


def test_bf16_roundtrip_casts_back(tmp_path):
    jnp = pytest.importorskip("jax.numpy")
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"p": jnp.ones((3,), dtype=jnp.bfloat16)}
    mgr.save(1, tree, blocking=True)
    got = mgr.restore(1, tree)
    assert got["p"].dtype == jnp.bfloat16
    assert np.allclose(np.asarray(got["p"], dtype=np.float32), 1.0)
