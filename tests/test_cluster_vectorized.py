"""The vectorized interval-driven cluster engine and its supporting
machinery: sample-for-sample (bit-level) equivalence of
``cluster.run._merged_trace`` against the legacy per-tick loop oracle
``_merged_trace_reference`` across schedule shapes, the columnar
``TraceRecorder`` (scalar + bulk emission, ordering, resampling), the
cached ``PowerTrace.power_w``, and the batched layer entry points."""
import numpy as np
import pytest

from repro.cluster.run import _merged_trace, _merged_trace_reference
from repro.cluster.scheduler import ClusterTopology, Job, Scheduler
from repro.power.layers import GPUModel, NodeModel, lcsc_cluster
from repro.power.model import OperatingPoint
from repro.power.trace import PowerTrace, TraceRecorder

OP = OperatingPoint.green500()


def assert_traces_identical(a: PowerTrace, b: PowerTrace):
    """Bit-level: every series equal sample-for-sample, no tolerance."""
    assert np.array_equal(a.t, b.t)
    assert sorted(a.components) == sorted(b.components)
    for name in a.components:
        assert np.array_equal(a.components[name], b.components[name]), name
    assert np.array_equal(a.flops_rate, b.flops_rate)
    assert sorted(a.aux) == sorted(b.aux)
    for name in a.aux:
        assert np.array_equal(a.aux[name], b.aux[name]), name
    assert a.meta == b.meta


# -- vectorized merge vs the per-tick loop oracle ----------------------------


def _schedule(topology, jobs, *, policy="packed", power_cap_w=None, op=OP):
    sch = Scheduler(topology, policy=policy,
                    power_cap_w=power_cap_w).schedule(jobs, op=op)
    sch.meta["policy"] = policy
    return sch


def _compare(schedule, dt_s=13.0, network_w=257.0):
    vec = _merged_trace(schedule, dt_s=dt_s, network_w=network_w)
    ref = _merged_trace_reference(schedule, dt_s=dt_s, network_w=network_w)
    assert_traces_identical(vec, ref)
    return vec


def test_equivalence_packed_uniform_batch():
    top = ClusterTopology(n_nodes=4)
    jobs = [Job(f"lat{i}", 13.0, 600.0) for i in range(top.n_chips)]
    tr = _compare(_schedule(top, jobs), dt_s=30.0)
    # all chips busy for the whole batch: full-load composition
    expect = NodeModel().power(OP) * top.n_nodes
    assert float(tr.power_w[0]) == pytest.approx(expect, rel=1e-9)


def test_equivalence_packed_queued_mixed_durations():
    # more jobs than chips with staggered durations: multiple placements
    # per chip, boundary-sharing intervals, makespan off the dt grid
    rng = np.random.default_rng(0)
    top = ClusterTopology(n_nodes=3)
    jobs = [Job(f"j{i}", 13.0, float(rng.uniform(50.0, 700.0)))
            for i in range(40)]
    _compare(_schedule(top, jobs), dt_s=7.0)


def test_equivalence_round_robin_sharded():
    rng = np.random.default_rng(1)
    top = ClusterTopology(n_nodes=2)
    jobs = [Job(f"j{i}", 13.0, float(rng.uniform(100.0, 500.0)))
            for i in range(10)]
    sch = _schedule(top, jobs, policy="round_robin")
    assert all(p.sharded for p in sch.placements)
    _compare(sch, dt_s=11.0)


def test_equivalence_power_capped_derated_op():
    top = ClusterTopology(n_nodes=4)
    jobs = [Job(f"j{i}", 13.0, 300.0) for i in range(8)]
    sch = _schedule(top, jobs, power_cap_w=3.5e3)
    assert sch.derated and sch.op.f_mhz < OP.f_mhz
    _compare(sch, dt_s=17.0)


def test_equivalence_heterogeneous_pacing():
    # per-chip perf spread: every placement gets its own rate and
    # duration, so interval boundaries land on irrational-ish times
    rng = np.random.default_rng(2)
    top = ClusterTopology(n_nodes=4,
                          perf_scales=tuple(rng.uniform(0.8, 1.0, 16)))
    jobs = [Job(f"j{i}", float(rng.choice([13.0, 30.0])),
                float(rng.uniform(50.0, 400.0))) for i in range(30)]
    _compare(_schedule(top, jobs), dt_s=9.0)


def test_equivalence_partial_occupancy_and_idle_nodes():
    top = ClusterTopology(n_nodes=4)
    jobs = [Job("only", 13.0, 100.0)]
    tr = _compare(_schedule(top, jobs), dt_s=30.0)
    assert float(tr.aux["util"][0]) == pytest.approx(1 / 16)


def test_equivalence_empty_schedule_idle_trace():
    sch = _schedule(ClusterTopology(n_nodes=2), [])
    tr = _compare(sch, dt_s=30.0)
    # one idle interval spanning dt_s, nothing computed
    assert np.all(tr.flops_rate == 0.0)
    assert float(tr.t[-1]) == 30.0


def test_equivalence_zero_work_job_is_invisible():
    top = ClusterTopology(n_nodes=2)
    jobs = [Job("real", 13.0, 200.0), Job("noop", 13.0, 0.0)]
    _compare(_schedule(top, jobs), dt_s=30.0)


def test_vectorized_trace_feeds_green500():
    top = ClusterTopology(n_nodes=4)
    jobs = [Job(f"j{i}", 13.0, 1800.0) for i in range(top.n_chips)]
    tr = _merged_trace(_schedule(top, jobs), dt_s=30.0, network_w=257.0)
    from repro.power.green500 import measure_efficiency
    assert measure_efficiency(tr, 3).mflops_per_w > 4000.0


# -- heterogeneous per-placement operating points -----------------------------


OP900 = OperatingPoint(f_mhz=900.0)
OP655 = OperatingPoint(f_mhz=655.0)


def _hetero_jobs(n, rng):
    """A mixed batch: compute-bound HPL-ish jobs at 900 MHz, memory-bound
    LQCD-ish jobs at the Green500 point, and no-preference stragglers."""
    mixes = [(OP900, "hpl"), (OP, "lqcd"), (OP655, "lqcd"), (None, "lqcd")]
    jobs = []
    for i in range(n):
        pref, kind = mixes[int(rng.integers(len(mixes)))]
        jobs.append(Job(f"j{i}", float(rng.choice([13.0, 30.0])),
                        float(rng.uniform(50.0, 600.0)),
                        preferred_op=pref, kind=kind))
    return jobs


def test_equivalence_hetero_packed():
    rng = np.random.default_rng(3)
    top = ClusterTopology(n_nodes=3)
    sch = _schedule(top, _hetero_jobs(30, rng), op=None)
    assert len({p.op for p in sch.placements}) > 1
    tr = _compare(sch, dt_s=7.0)
    assert tr.meta["heterogeneous"]
    assert tr.meta["placement_clocks_mhz"] == [655.0, 774.0, 900.0]


def test_equivalence_hetero_round_robin():
    rng = np.random.default_rng(4)
    top = ClusterTopology(n_nodes=2)
    sch = _schedule(top, _hetero_jobs(12, rng), policy="round_robin",
                    op=None)
    assert all(p.sharded for p in sch.placements)
    assert len({p.op for p in sch.placements}) > 1
    _compare(sch, dt_s=11.0)


def test_equivalence_hetero_power_capped():
    # a cap that fits the Green500 point on 2 nodes but not 900 MHz:
    # only the 900-preferring placements walk down the DPM ladder, and
    # the mixed-op trace still matches the loop oracle bit-for-bit
    top = ClusterTopology(n_nodes=2)
    jobs = [Job(f"hot{i}", 13.0, 300.0, preferred_op=OP900, kind="hpl")
            for i in range(4)]
    jobs += [Job(f"cool{i}", 13.0, 300.0, preferred_op=OP, kind="lqcd")
             for i in range(4)]
    sch = _schedule(top, jobs, power_cap_w=2.6e3, op=None)
    assert sch.derated
    ops = {p.job.name[:3]: p.op for p in sch.placements}
    assert ops["hot"].f_mhz < 900.0
    assert ops["coo"] == OP
    _compare(sch, dt_s=17.0)


def test_equivalence_hetero_failure_requeue():
    # the online simulator's as-executed schedule (failure-truncated
    # attempts + requeues, per-job ops) rides the same engine: vectorized
    # vs loop oracle on the very schedule simulate() produced
    from repro.cluster.sim import simulate
    from repro.distributed.fault import WeibullFailureModel

    rng = np.random.default_rng(5)
    fm = WeibullFailureModel(mtbf_s=1200.0, shape=1.0, repair_s=300.0)
    res = simulate(_hetero_jobs(24, rng),
                   topology=ClusterTopology(n_nodes=2),
                   failure_model=fm, seed=7, dt_s=13.0)
    assert len({p.op for p in res.schedule.placements}) > 1
    _compare(res.schedule, dt_s=13.0)


def test_hetero_compute_bound_jobs_finish_faster_at_900():
    # op_rate_scale: the same HPL work at 900 MHz beats 774 in the
    # published clock-for-perf ratio; memory-bound LQCD doesn't move
    top = ClusterTopology(n_nodes=1)
    hpl_774 = _schedule(top, [Job("h", 13.0, 600.0, kind="hpl")], op=OP)
    hpl_900 = _schedule(top, [Job("h", 13.0, 600.0, kind="hpl")], op=OP900)
    assert hpl_900.makespan < hpl_774.makespan
    lqcd_774 = _schedule(top, [Job("l", 13.0, 600.0, kind="lqcd")], op=OP)
    lqcd_900 = _schedule(top, [Job("l", 13.0, 600.0, kind="lqcd")], op=OP900)
    assert lqcd_900.makespan == lqcd_774.makespan


# -- columnar TraceRecorder ---------------------------------------------------


def test_emit_series_matches_scalar_emits():
    t = np.arange(0.0, 50.0, 5.0)
    gpu = np.linspace(100.0, 200.0, t.size)
    util = np.linspace(0.1, 1.0, t.size)
    scalar = TraceRecorder(source="s")
    for i, ti in enumerate(t):
        scalar.emit(ti, {"gpu": gpu[i], "host": 137.8}, flops_rate=7.0,
                    util=util[i])
    bulk = TraceRecorder(source="s")
    bulk.emit_series(t, {"gpu": gpu, "host": 137.8}, flops_rate=7.0,
                     util=util)
    assert len(bulk) == len(scalar) == t.size
    assert_traces_identical(scalar.trace(), bulk.trace())


def test_mixed_scalar_and_series_chunks_zero_backfill():
    rec = TraceRecorder()
    rec.emit(0.0, {"gpu": 100.0}, util=0.5)           # no "net" yet
    rec.emit_series([1.0, 2.0], {"net": [5.0, 6.0]})  # no "gpu" here
    rec.emit(3.0, {"gpu": 50.0, "net": 7.0}, temp_c=55.0)
    tr = rec.trace()
    assert np.array_equal(tr.components["gpu"], [100.0, 0.0, 0.0, 50.0])
    assert np.array_equal(tr.components["net"], [0.0, 5.0, 6.0, 7.0])
    assert np.array_equal(tr.aux["util"], [0.5, 0.0, 0.0, 0.0])
    assert np.array_equal(tr.aux["temp_c"], [0.0, 0.0, 0.0, 55.0])


def test_out_of_order_emissions_are_sorted():
    rec = TraceRecorder()
    rec.emit(10.0, {"p": 2.0}, flops_rate=2.0)
    rec.emit(0.0, {"p": 1.0}, flops_rate=1.0)
    rec.emit_series([5.0], {"p": [1.5]}, flops_rate=1.5)
    assert not rec._ordered
    tr = rec.trace()
    assert np.array_equal(tr.t, [0.0, 5.0, 10.0])
    assert np.array_equal(tr.components["p"], [1.0, 1.5, 2.0])
    assert np.array_equal(tr.flops_rate, [1.0, 1.5, 2.0])


def test_ordered_emissions_skip_the_sort():
    rec = TraceRecorder()
    rec.emit(0.0, {"p": 1.0})
    rec.emit_series([1.0, 2.0], {"p": [2.0, 3.0]})
    rec.emit(2.0, {"p": 4.0})        # ties keep insertion order (stable)
    assert rec._ordered
    assert np.array_equal(rec.trace().components["p"],
                          [1.0, 2.0, 3.0, 4.0])


def test_t_last_is_a_running_max():
    rec = TraceRecorder()
    assert rec.t_last == 0.0
    rec.emit(5.0, {"p": 1.0})
    assert rec.t_last == 5.0
    rec.emit_series([1.0, 9.0, 3.0], {"p": 0.0})   # interior max
    assert rec.t_last == 9.0
    rec.emit(2.0, {"p": 1.0})
    assert rec.t_last == 9.0


def test_emit_series_resamples_on_dt_grid():
    rec = TraceRecorder(dt_s=1.0)
    rec.emit_series([0.0, 2.0], {"p": [0.0, 4.0]}, flops_rate=[0.0, 2.0])
    tr = rec.trace()
    assert np.array_equal(tr.t, [0.0, 1.0, 2.0])
    assert np.array_equal(tr.components["p"], [0.0, 2.0, 4.0])
    assert tr.meta["dt_s"] == 1.0


def test_emit_series_broadcasts_scalars_and_validates():
    rec = TraceRecorder()
    rec.emit_series([0.0, 1.0, 2.0], {"p": 3.0}, flops_rate=1.0, fan=0.4)
    tr = rec.trace()
    assert np.array_equal(tr.components["p"], [3.0, 3.0, 3.0])
    assert np.array_equal(tr.aux["fan"], [0.4, 0.4, 0.4])
    with pytest.raises(ValueError, match="1-D"):
        rec.emit_series([], {"p": 1.0})
    with pytest.raises(ValueError, match="1-D"):
        rec.emit_series([[0.0, 1.0]], {"p": 1.0})


def test_empty_recorder_still_raises():
    with pytest.raises(ValueError, match="no samples"):
        TraceRecorder().trace()


def test_power_w_is_cached_and_correct():
    tr = PowerTrace(np.arange(3.0), {"gpu": np.ones(3),
                                     "host": 2.0 * np.ones(3),
                                     "network": 9.0 * np.ones(3)},
                    np.zeros(3))
    first = tr.power_w
    assert np.array_equal(first, [3.0, 3.0, 3.0])   # network excluded
    assert tr.power_w is first                       # cached object


# -- batched layer entry points ----------------------------------------------


def test_node_component_watts_batch_matches_scalar():
    node = NodeModel()
    w_busy = node.gpus[0].power(OP, load=1.0)
    w_idle = node.gpus[0].power(OP, load=0.0)
    counts = np.array([0, 1, 2, 3, 4, 4, 0])
    batch = node.component_watts_batch(OP, counts)
    for i, b in enumerate(counts):
        scalar = node.component_watts(
            OP, gpu_w_override=[w_busy] * b + [w_idle] * (4 - b))
        for name, w in scalar.items():
            assert w == batch[name][i], (name, b)


def test_node_component_watts_batch_rejects_bad_counts():
    with pytest.raises(ValueError, match=r"busy counts"):
        NodeModel().component_watts_batch(OP, np.array([5]))
    with pytest.raises(ValueError, match=r"busy counts"):
        NodeModel().component_watts_batch(OP, np.array([-1]))


def test_gpu_power_batch_matches_scalar():
    gpu = GPUModel()
    loads = np.linspace(0.0, 1.0, 7)
    batch = gpu.power_batch(OP, load=loads)
    for i, ld in enumerate(loads):
        assert gpu.power(OP, load=float(ld)) == batch[i]
    assert gpu.component_watts_batch(OP, load=loads)["gpu"][3] == batch[3]


def test_op_bins_dedupes_in_first_seen_order():
    from repro.power.layers import op_bins
    ops = [OP900, OP, OP900, OP655, OP]
    bins, idx = op_bins(ops)
    assert bins == [OP900, OP, OP655]
    assert np.array_equal(idx, [0, 1, 0, 2, 1])
    assert all(bins[idx[i]] == o for i, o in enumerate(ops))


def test_gpu_power_batch_per_sample_ops_matches_scalar():
    # per-bin lookup-table property: a spread of operating points zipped
    # with a load series draws exactly what the scalar model returns for
    # each (op, load) pair — bit-for-bit
    gpu = GPUModel(vid=1.2)
    ops = [OP, OP900, OP655, OP900, OP]
    loads = np.linspace(0.0, 1.0, len(ops))
    batch = gpu.power_batch(ops, load=loads)
    for i, (o, ld) in enumerate(zip(ops, loads)):
        assert gpu.power(o, load=float(ld)) == batch[i], i
    assert gpu.component_watts_batch(ops, load=loads)["gpu"][2] == batch[2]


def test_component_watts_batch_per_chip_ops_matches_scalar():
    # heterogeneous form: every chip at its own operating point, boolean
    # occupancy mask — per-sample totals equal the scalar
    # component_watts(gpu_w_override=...) path exactly
    node = NodeModel.from_vids([1.1425, 1.15, 1.2, 1.25])
    chip_ops = [OP900, OP, OP655, OP]
    rng = np.random.default_rng(6)
    mask = rng.integers(0, 2, size=(9, 4)).astype(bool)
    batch = node.component_watts_batch(OP, mask, chip_ops=chip_ops)
    for i in range(mask.shape[0]):
        override = [gpu.power(o, load=1.0 if mask[i, c] else 0.0)
                    for c, (gpu, o) in enumerate(zip(node.gpus, chip_ops))]
        scalar = node.component_watts(OP, gpu_w_override=override)
        for name, w in scalar.items():
            assert w == batch[name][i], (name, i)


def test_component_watts_batch_chip_ops_validates():
    node = NodeModel()
    with pytest.raises(ValueError, match="one operating point per chip"):
        node.component_watts_batch(OP, np.ones((3, 4), dtype=bool),
                                   chip_ops=[OP, OP900])
    with pytest.raises(ValueError, match="chip axis"):
        node.component_watts_batch(OP, np.ones((4, 3), dtype=bool),
                                   chip_ops=[OP, OP900, OP655, OP])


def test_node_series_accepts_op_spread():
    # per-sample op spread through the node composition: each sample
    # priced at its own point, fan duty defaulting to the sample's op
    node = NodeModel()
    ops = [OP, OP900, OP655]
    series = node.component_watts_series(ops, load=1.0)
    for i, o in enumerate(ops):
        scalar = node.component_watts(o, load=1.0)
        for name, w in scalar.items():
            assert w == series[name][i], (name, i)


def test_node_series_matches_scalar_per_sample():
    node = NodeModel()
    loads = np.linspace(0.0, 1.0, 5)
    fans = np.clip(loads, 0.15, 0.40)
    series = node.component_watts_series(OP, load=loads, fan=fans)
    for i in range(loads.size):
        scalar = node.component_watts(OP, load=float(loads[i]),
                                      fan=float(fans[i]))
        for name, w in scalar.items():
            assert w == series[name][i], name


def test_cluster_series_matches_scalar_per_sample():
    cluster = lcsc_cluster(n_nodes=2, nodes_per_rack=2)
    loads = np.array([0.0, 0.5, 1.0])
    series = cluster.component_watts_series(OP, load=loads)
    for i, ld in enumerate(loads):
        scalar = cluster.component_watts(OP, load=float(ld))
        for name, w in scalar.items():
            assert w == series[name][i], name


def test_simulate_is_equivalent_to_scalar_ticking():
    from repro.power.engine import ConstantLoad, SyntheticHPL, simulate
    from repro.power.model import fan_curve

    cluster = lcsc_cluster(n_nodes=2, nodes_per_rack=2)
    wl = SyntheticHPL(duration_s=600.0)
    tr = simulate(wl, OP, cluster=cluster, dt_s=60.0)
    # the batched series path must reproduce the scalar per-tick layers
    for i, t in enumerate(np.arange(0.0, wl.duration_s + 60.0, 60.0)):
        load = float(np.clip(wl.load(min(t, wl.duration_s)), 0.0, 1.0))
        fan = min(OP.fan, fan_curve(load))
        watts = cluster.component_watts(OP, load=load, fan=fan)
        for name, w in watts.items():
            assert w == tr.components[name][i], (name, i)
    # constant load never derates the fan below the set point
    flat = simulate(ConstantLoad(duration_s=120.0), OP, cluster=cluster,
                    dt_s=60.0, adaptive_fan=False)
    assert np.all(flat.aux["fan"] == OP.fan)
