"""Even-odd preconditioned / mixed-precision solver suite.

Covers the compact checkerboard decomposition (pack/unpack, hopping
operators), the Schur-complement solve against the full-lattice CGNE, the
bf16 defect-correction loop, the even-odd Pallas kernel, the config
dispatch, and the energy-to-solution accounting.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.lqcd import (dslash, random_su3_field, solve_dirac, solve_wilson,
                        solve_wilson_eo, wilson_matvec)
from repro.lqcd.dirac import eo_matvec, parity_mask
from repro.lqcd import eo as EO

SHAPE = (4, 4, 4, 4)


def _fields(shape=SHAPE, seed=0):
    ku, kr, ki = jax.random.split(jax.random.PRNGKey(seed), 3)
    U = random_su3_field(ku, shape)
    b = (jax.random.normal(kr, shape + (4, 3))
         + 1j * jax.random.normal(ki, shape + (4, 3))).astype(jnp.complex64)
    return U, b


def test_eo_pack_unpack_roundtrip():
    _, psi = _fields((4, 6, 4, 6))
    pe, po = EO.eo_pack(psi, 0), EO.eo_pack(psi, 1)
    assert pe.shape == (2, 6, 4, 6, 4, 3)
    back = EO.eo_unpack(pe, po)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(psi))


def test_eo_pack_selects_parities():
    """Packed halves hold exactly the (x+y+z+t) even / odd sites."""
    shape = (4, 4, 4, 4)
    x, y, z, t = np.indices(shape)
    par = ((x + y + z + t) % 2).astype(np.complex64)
    field = jnp.asarray(par)[..., None, None] * jnp.ones(shape + (4, 3),
                                                         jnp.complex64)
    assert float(jnp.max(jnp.abs(EO.eo_pack(field, 0)))) == 0.0
    assert float(jnp.min(jnp.abs(EO.eo_pack(field, 1)))) == 1.0


@pytest.mark.parametrize("src_parity", [0, 1])
@pytest.mark.parametrize("shape", [(4, 4, 4, 4), (4, 6, 4, 8)])
def test_dslash_half_matches_masked_full(shape, src_parity):
    """Compact hop == full-lattice D-slash on the masked field."""
    U, psi = _fields(shape, seed=1)
    mask_e = parity_mask(shape)
    U_e, U_o = EO.pack_gauge(U)
    src_mask = mask_e if src_parity == 0 else ~mask_e
    full_src = jnp.where(src_mask[..., None, None], psi, 0)
    want = EO.eo_pack(dslash(U, full_src), 1 - src_parity)
    half = EO.eo_pack(psi, src_parity)
    U_out, U_src = (U_o, U_e) if src_parity == 0 else (U_e, U_o)
    got = EO.dslash_half(U_out, U_src, half, src_parity=src_parity)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_schur_matches_masked_eo_operator():
    """Compact Schur A == the masked full-lattice A of dirac.eo_matvec."""
    U, psi = _fields(seed=2)
    kappa = 0.11
    mask_e = parity_mask(SHAPE)
    psi_e_full = jnp.where(mask_e[..., None, None], psi, 0)
    want_full = eo_matvec(U, psi_e_full, kappa, mask_e)
    U_e, U_o = EO.pack_gauge(U)
    got = EO.schur_matvec(U_e, U_o, EO.eo_pack(psi, 0), kappa)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(EO.eo_pack(want_full, 0)),
                               rtol=1e-5, atol=1e-5)


def test_eo_solution_matches_full_cgne():
    U, b = _fields(seed=3)
    kappa = 0.1
    full = solve_wilson(U, b, kappa, tol=1e-6, max_iters=600)
    eo = solve_wilson_eo(U, b, kappa, tol=1e-6, max_iters=600)
    assert bool(full.converged) and eo.converged
    # both solve the same (nonsingular) system -> same solution
    np.testing.assert_allclose(np.asarray(eo.x), np.asarray(full.x),
                               rtol=2e-4, atol=2e-4)
    # the residual the solver reports is the true one
    r = b - wilson_matvec(U, eo.x, kappa)
    rel = float(jnp.linalg.norm(r.reshape(-1))
                / jnp.linalg.norm(b.reshape(-1)))
    assert rel == pytest.approx(eo.rel_residual, rel=1e-3)
    assert rel <= 1e-6


def test_preconditioning_cuts_iterations():
    """The Schur spectrum contracts quadratically: fewer normal ops."""
    U, b = _fields((8, 8, 8, 8), seed=0)
    kappa = 0.12
    full = solve_wilson(U, b, kappa, tol=1e-6, max_iters=1000)
    eo = solve_wilson_eo(U, b, kappa, tol=1e-6, max_iters=1000)
    assert bool(full.converged) and eo.converged
    assert eo.iters + eo.outer_iters < int(full.iters)


def test_mixed_precision_bf16_converges_to_tol():
    """bf16 inner + f32 reliable updates reaches the f32 tolerance on the
    acceptance lattice, in fewer normal ops than the plain solver."""
    U, b = _fields((8, 8, 8, 8), seed=0)
    kappa = 0.12
    plain = solve_wilson(U, b, kappa, tol=1e-6, max_iters=1000)
    eo = solve_wilson_eo(U, b, kappa, tol=1e-6, max_iters=1000,
                         inner_dtype=jnp.bfloat16)
    assert eo.converged and eo.rel_residual <= 1e-6
    assert eo.outer_iters > 1          # bf16 alone can't reach 1e-6
    assert eo.iters + eo.outer_iters < int(plain.iters)


def test_mixed_precision_inner_really_rounds():
    """The inner operator must quantize: bf16 path differs from f32 path
    on a single inner application (guards against a silent no-op cast)."""
    from repro.lqcd.cg import _round_complex
    v = (jnp.arange(1, 13, dtype=jnp.float32) / 7.0).astype(jnp.complex64)
    rounded = _round_complex(v, jnp.bfloat16)
    assert float(jnp.max(jnp.abs(rounded - v))) > 0
    assert float(jnp.max(jnp.abs(rounded - v))) < 1e-2


def test_eo_pallas_kernel_matches_reference():
    from repro.kernels.dslash import dslash_half_pallas
    U, psi = _fields((4, 6, 4, 8), seed=4)
    U_e, U_o = EO.pack_gauge(U)
    for p in (0, 1):
        half = EO.eo_pack(psi, p)
        U_out, U_src = (U_o, U_e) if p == 0 else (U_e, U_o)
        want = EO.dslash_half(U_out, U_src, half, src_parity=p)
        got = dslash_half_pallas(U_e, U_o, half, p, t_block=4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_solve_dirac_config_dispatch():
    from repro.configs.lcsc_lqcd import (EO_MIXED_SOLVER, EO_SOLVER,
                                         PLAIN_SOLVER)
    U, b = _fields(seed=5)
    kappa = 0.1
    for cfg in (PLAIN_SOLVER, EO_SOLVER, EO_MIXED_SOLVER):
        res = solve_dirac(U, b, kappa, cfg)
        assert bool(res.converged), cfg
        r = b - wilson_matvec(U, res.x, kappa)
        rel = float(jnp.linalg.norm(r.reshape(-1))
                    / jnp.linalg.norm(b.reshape(-1)))
        assert rel < 1e-5, cfg


def test_solver_energy_accounting():
    from repro.core.energy import solver_energy
    vol = 8 ** 4
    plain = solver_energy("plain", vol, 27)
    eo = solver_energy("eo", vol, 15, outer_ops=3, inner_real_bytes=2,
                       even_odd=True)
    # fewer ops at half the bytes -> less energy, better GFLOPS/W
    assert eo.energy_j < plain.energy_j
    assert eo.gflops_per_w > plain.gflops_per_w
    # scale invariance: energy is linear in ops
    assert solver_energy("p2", vol, 54).energy_j == \
        pytest.approx(2 * plain.energy_j)
