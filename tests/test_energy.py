"""Energy core: calibration against the paper's published numbers, throttle
properties, DVFS planner behaviour, Green500 methodology."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:               # deterministic grid fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.config import EnergyConfig
from repro.configs import lcsc_lqcd as paper
from repro.core.energy import (dgemm_perf_gflops, fan_power, hpl_node_perf,
                               level1_exploit, linpack_power_trace,
                               measure_efficiency, node_power,
                               plan_frequency, sustained_frequency)
from repro.core.energy.green500 import extrapolation_error, node_efficiencies
from repro.core.energy.power_model import V_MAX, V_MIN
from repro.core.energy.throttle import HPL_GPU_UTIL, gpu_power_throttled
from repro.core.energy.scheduler import (Chip, Job, drop_slowest_pod,
                                         expected_slowdown,
                                         frequency_floor_mitigation,
                                         makespan, schedule_throughput,
                                         straggler_step_time)


# -- paper-claims validation (the reproduction gates) ------------------------

def test_fig1a_dgemm_voltage_spread():
    best = dgemm_perf_gflops(900, V_MIN)
    worst = dgemm_perf_gflops(900, V_MAX)
    assert abs(best - 1250) / 1250 < 0.02          # paper: 1250
    assert 950 <= worst <= 1100                    # paper: 950-1100


def test_fig1a_flat_profile_at_774():
    perfs = [dgemm_perf_gflops(774, v)
             for v in np.linspace(V_MIN, V_MAX, 7)]
    assert max(perfs) - min(perfs) < 1e-6          # completely flat


def test_fig1a_hpl_node_range():
    lo = hpl_node_perf(900, [V_MAX] * 4)
    hi = hpl_node_perf(900, [V_MIN] * 4)
    assert abs(lo - 6175) / 6175 < 0.01
    assert abs(hi - 6280) / 6280 < 0.01


def test_green500_headline_result():
    """56 nodes, 301.5 TFLOPS @ 57.2 kW -> 5271.8 MFLOPS/W (within 1.2%,
    the paper's own stated measurement error)."""
    perf = hpl_node_perf(774, [V_MIN] * 4)
    pw = [gpu_power_throttled(774, V_MIN, util=HPL_GPU_UTIL)] * 4
    p_node = node_power(774, [V_MIN] * 4, gpu_clamped_w=pw)
    assert abs(perf * 56 - 301.5e3) / 301.5e3 < 0.012
    assert abs(p_node * 56 - 57.2e3) / 57.2e3 < 0.012
    eff = perf / p_node * 1000
    assert abs(eff - 5271.8) / 5271.8 < 0.012


def test_900mhz_less_efficient_than_774():
    pw9 = [gpu_power_throttled(900, V_MIN, util=HPL_GPU_UTIL)] * 4
    eff9 = hpl_node_perf(900, [V_MIN] * 4) / node_power(
        900, [V_MIN] * 4, gpu_clamped_w=pw9)
    pw7 = [gpu_power_throttled(774, V_MIN, util=HPL_GPU_UTIL)] * 4
    eff7 = hpl_node_perf(774, [V_MIN] * 4) / node_power(
        774, [V_MIN] * 4, gpu_clamped_w=pw7)
    assert eff7 > eff9


def test_fan_curve_shape():
    """Fig 1b: stronger slope above 40%."""
    lo_slope = fan_power(0.4) - fan_power(0.3)
    hi_slope = fan_power(0.6) - fan_power(0.5)
    assert hi_slope > lo_slope


# -- throttle properties ------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(v=st.floats(V_MIN, V_MAX), f=st.floats(500, 1000))
def test_sustained_frequency_properties(v, f):
    f_sus, throttled = sustained_frequency(f, v)
    assert f_sus <= f + 1e-9
    assert (f_sus < f) == throttled
    # power at the sustained point never exceeds TDP
    p = gpu_power_throttled(f, v)
    assert p <= 275.0 + 1e-6


def test_highest_clock_not_fastest():
    """The paper's key observation: a throttling 900 MHz set-point can lose
    to a constant lower clock (820 on L-CSC)."""
    perf_900 = dgemm_perf_gflops(900, V_MAX)
    perf_820 = dgemm_perf_gflops(820, V_MAX)
    assert perf_820 > perf_900


# -- DVFS planner -------------------------------------------------------------

def test_plan_memory_bound_derates():
    """D-slash-like step (memory-bound): efficiency plan drops the clock
    with perf loss below the paper's 1.5%."""
    plan = plan_frequency(0.2, 1.0, 0.1, flops_per_step=1e12,
                          cfg=EnergyConfig(mode="efficiency"))
    assert plan.freq_scale <= 0.6
    assert plan.perf_loss <= 0.015
    assert plan.dominant == "memory"


def test_plan_compute_bound_prefers_high_nonthrottling_clock():
    plan = plan_frequency(1.0, 0.2, 0.1, flops_per_step=1e12,
                          cfg=EnergyConfig(mode="performance"))
    assert plan.freq_scale >= 0.85
    assert not plan.throttled


def test_efficiency_mode_saves_energy():
    perf = plan_frequency(1.0, 0.5, 0.1, flops_per_step=1e12,
                          cfg=EnergyConfig(mode="performance"))
    eff = plan_frequency(1.0, 0.5, 0.1, flops_per_step=1e12,
                         cfg=EnergyConfig(mode="efficiency",
                                          max_perf_loss=0.10))
    assert eff.energy_per_step_j <= perf.energy_per_step_j


# -- Green500 methodology -----------------------------------------------------

def _trace():
    return linpack_power_trace(56, 1021.0, 5384.0, duration_s=1800.0)


def test_levels_ordering():
    tr = _trace()
    l3 = measure_efficiency(tr, 3)
    exploit = level1_exploit(tr)
    assert exploit.mflops_per_w > l3.mflops_per_w


def test_level1_exploit_magnitude():
    """Paper: L1 window-picking overestimates by up to ~30%."""
    tr = _trace()
    l3 = measure_efficiency(tr, 3)
    exploit = level1_exploit(tr)
    over = exploit.mflops_per_w / l3.mflops_per_w - 1
    assert 0.10 < over < 0.45


def test_node_variability_and_median_selection():
    rng = np.random.default_rng(0)
    effs = node_efficiencies(rng, 7)
    spread = (effs.max() - effs.min()) / effs.mean()
    assert spread < 0.06                       # ±1.2%-class spread
    assert extrapolation_error(effs, k=2) < 0.01   # paper: <1% off L3


def test_published_node_sample_consistency():
    effs = np.asarray(paper.SINGLE_NODE_EFFICIENCIES_MFLOPS_W)
    dev = (effs.max() - effs.min()) / 2 / effs.mean()
    assert dev < 0.02                          # the published ±1.2%-ish


# -- scheduler / straggler ----------------------------------------------------

def test_throughput_scheduler_prefers_single_chip():
    chips = [Chip(i, 16.0) for i in range(4)]
    jobs = [Job(f"thermal{i}", 3.0, 1.0) for i in range(8)]
    pl = schedule_throughput(jobs, chips)
    assert all(not p.sharded for p in pl)
    assert makespan(pl) == pytest.approx(2.0)


def test_big_lattice_shards_with_penalty():
    chips = [Chip(i, 16.0) for i in range(4)]
    jobs = [Job("cold", 48.0, 1.0)]            # needs 3 chips
    pl = schedule_throughput(jobs, chips)
    assert pl[0].sharded and len(pl[0].chips) == 3
    assert pl[0].end - pl[0].start > 1.0 / 3.0     # 20% penalty applied


def test_straggler_models():
    assert straggler_step_time(1.0, [1.0, 0.8, 1.0]) == pytest.approx(1.25)
    slow = expected_slowdown(1000, 0.012)
    assert 1.0 < slow < 1.15
    floor, gain = frequency_floor_mitigation([1.0, 0.95, 0.9])
    assert floor == pytest.approx(0.9)
    assert gain > 0                            # beats oscillating population


def test_drop_slowest_pod():
    keep, gain = drop_slowest_pod({"a": 1.0, "b": 1.0, "c": 0.5})
    assert keep == ["a", "b"] and gain > 0
    keep2, gain2 = drop_slowest_pod({"a": 1.0, "b": 0.99})
    assert len(keep2) == 2 and gain2 == 0      # no benefit -> keep all
