"""HPL: blocked LU vs dense solve (property), lookahead equivalence,
residual acceptance."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:               # deterministic grid fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs.hpl import HPLConfig
from repro.hpl import blocked_lu, linpack_residual, linpack_run, lu_solve


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 100), nb=st.sampled_from([16, 32]))
def test_lu_solve_matches_dense(seed, nb):
    n = 128
    key = jax.random.PRNGKey(seed)
    ka, kb = jax.random.split(key)
    a = jax.random.normal(ka, (n, n), jnp.float32)
    b = jax.random.normal(kb, (n,), jnp.float32)
    res = blocked_lu(a, nb)
    x = lu_solve(res, b, nb)
    want = jnp.linalg.solve(a, b)
    np.testing.assert_allclose(np.asarray(x), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_lookahead_is_equivalent():
    """Lookahead reorders the trailing update; the factorization is equal."""
    key = jax.random.PRNGKey(7)
    a = jax.random.normal(key, (128, 128), jnp.float32)
    r0 = blocked_lu(a, 32, lookahead=0)
    r1 = blocked_lu(a, 32, lookahead=1)
    np.testing.assert_allclose(np.asarray(r0.lu), np.asarray(r1.lu),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(r0.piv), np.asarray(r1.piv))


def test_linpack_acceptance():
    r = linpack_run(HPLConfig(n=192, block=32, dtype="float32"))
    assert r.passed, f"HPL residual {r.residual}"
    assert r.gflops > 0


def test_linpack_efficiency_mode():
    base = HPLConfig(n=192, block=64, dtype="float32")
    eff = base.efficiency()
    assert eff.block < base.block and eff.mode == "efficiency"
    r = linpack_run(eff)
    assert r.passed


def test_residual_metric_rejects_garbage():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (64, 64), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (64,), jnp.float32)
    x_bad = jnp.zeros((64,))
    assert linpack_residual(a, x_bad, b) > 16.0
