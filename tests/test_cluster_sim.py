"""Online discrete-event cluster simulator: the batch oracle (bit-level
trace equivalence against ``cluster.run()`` when every arrival is at t=0
with no failures), seeded determinism, invariant property grids (every
job terminal, utilization in [0,1], energy above the idle floor, no chip
double-booked), and the failure/requeue path."""
from collections import defaultdict

import numpy as np
import pytest

from repro.cluster import (ClusterTopology, Job, PoissonArrivals,
                           TraceArrivals, batch_arrivals, run, simulate)
from repro.cluster.events import Arrival, as_arrivals
from repro.distributed.fault import WeibullFailureModel
from repro.power.layers import NodeModel
from repro.power.model import OperatingPoint

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                  # pragma: no cover
    from _hypothesis_fallback import given, settings, strategies as st

OP = OperatingPoint.green500()

# sim-only annotations the batch trace does not carry
_SIM_META = ("online", "backfill", "failures")


def assert_traces_identical(a, b, *, ignore_meta=()):
    """Bit-level: every series equal sample-for-sample, no tolerance."""
    assert np.array_equal(a.t, b.t)
    assert sorted(a.components) == sorted(b.components)
    for name in a.components:
        assert np.array_equal(a.components[name], b.components[name]), name
    assert np.array_equal(a.flops_rate, b.flops_rate)
    assert sorted(a.aux) == sorted(b.aux)
    for name in a.aux:
        assert np.array_equal(a.aux[name], b.aux[name]), name
    ma = {k: v for k, v in a.meta.items() if k not in ignore_meta}
    mb = {k: v for k, v in b.meta.items() if k not in ignore_meta}
    assert ma == mb


def batch_order(jobs):
    """The batch scheduler's dispatch order (stable sort, widest first) —
    FCFS replays it exactly when fed jobs in this order at t=0."""
    return sorted(jobs, key=lambda j: -j.work_units)


def assert_no_double_booking(placements, gpus_per_node):
    per_chip = defaultdict(list)
    for p in placements:
        if p.end > p.start:
            for c in p.chips:
                per_chip[c].append((p.start, p.end))
    for chip, spans in per_chip.items():
        spans.sort()
        for (s0, e0), (s1, e1) in zip(spans, spans[1:]):
            assert e0 <= s1 + 1e-9, f"chip {chip} double-booked"


# -- the batch oracle --------------------------------------------------------
#
# All arrivals at t=0, no failures, FCFS without backfill, jobs pre-sorted
# in the batch scheduler's dispatch order: the event-driven simulator must
# book the *same* placements and therefore emit a bit-identical PowerTrace
# through the same _merged_trace engine.


def _oracle_case(topology, jobs, *, policy="packed", dt_s=7.0,
                 backfill=False, op=OP):
    jobs = batch_order(jobs)
    batch = run(jobs, policy=policy, topology=topology, op=op, dt_s=dt_s)
    sim = simulate(jobs, topology=topology, policy=policy, op=op,
                   dt_s=dt_s, backfill=backfill)
    assert_traces_identical(sim.trace, batch.trace, ignore_meta=_SIM_META)
    assert sim.trace.meta["online"] is True
    assert sim.makespan == batch.schedule.makespan
    return sim


def test_oracle_uniform_batch():
    top = ClusterTopology(n_nodes=4)
    jobs = [Job(f"lat{i}", 13.0, 600.0) for i in range(top.n_chips)]
    sim = _oracle_case(top, jobs, dt_s=30.0)
    assert sim.stats.jobs_completed == len(jobs)
    assert sim.stats.utilization == pytest.approx(1.0)


def test_oracle_queued_mixed_durations():
    rng = np.random.default_rng(0)
    top = ClusterTopology(n_nodes=3)
    jobs = [Job(f"j{i}", 13.0, float(rng.uniform(50.0, 700.0)))
            for i in range(40)]
    _oracle_case(top, jobs)


def test_oracle_round_robin_sharded():
    rng = np.random.default_rng(1)
    top = ClusterTopology(n_nodes=2)
    jobs = [Job(f"j{i}", 13.0, float(rng.uniform(100.0, 500.0)))
            for i in range(10)]
    sim = _oracle_case(top, jobs, policy="round_robin", dt_s=11.0)
    assert all(p.sharded for p in sim.schedule.placements)


def test_oracle_heterogeneous_perf_scales():
    top = ClusterTopology(n_nodes=2,
                          perf_scales=(1.0, 1.0, 0.9, 0.9,
                                       0.8, 0.8, 1.0, 0.9))
    jobs = [Job(f"j{i}", 13.0, 400.0 + 37.0 * i) for i in range(12)]
    _oracle_case(top, jobs)


def test_oracle_single_job():
    sim = _oracle_case(ClusterTopology(n_nodes=1),
                       [Job("solo", 13.0, 123.0)], dt_s=5.0)
    assert sim.stats.jobs_submitted == 1


def test_oracle_heterogeneous_operating_points():
    # per-job ops survive the event loop: the online simulator resolves
    # each arrival's preferred_op exactly like the batch scheduler, so
    # the mixed-frequency trace is still bit-identical to cluster.run()
    top = ClusterTopology(n_nodes=2)
    jobs = [Job(f"hpl{i}", 13.0, 400.0 + 31.0 * i,
                preferred_op=OperatingPoint(f_mhz=900.0), kind="hpl")
            for i in range(4)]
    jobs += [Job(f"lqcd{i}", 13.0, 350.0 + 17.0 * i,
                 preferred_op=OP, kind="lqcd") for i in range(8)]
    sim = _oracle_case(top, jobs, op=None)
    ops = {p.op.f_mhz for p in sim.schedule.placements}
    assert ops == {900.0, 774.0}


def test_oracle_backfill_single_width_batch():
    # with uniform single-chip jobs at t=0 backfill never finds a hole
    # (the head is only ever blocked when nothing is free), so the
    # backfill dispatcher must also replay the batch booking exactly
    rng = np.random.default_rng(2)
    top = ClusterTopology(n_nodes=2)
    jobs = [Job(f"j{i}", 13.0, float(rng.uniform(60.0, 500.0)))
            for i in range(24)]
    _oracle_case(top, jobs, backfill=True)


def test_arrival_normalization_forms_agree():
    jobs = batch_order([Job(f"j{i}", 13.0, 100.0 + i) for i in range(6)])
    top = ClusterTopology(n_nodes=1)
    a = simulate(jobs, topology=top, op=OP, backfill=False)
    b = simulate(batch_arrivals(jobs), topology=top, op=OP, backfill=False)
    c = simulate(TraceArrivals([(0.0, j) for j in jobs]), topology=top,
                 op=OP, backfill=False)
    assert_traces_identical(a.trace, b.trace)
    assert_traces_identical(a.trace, c.trace)
    assert as_arrivals(jobs) == [Arrival(0.0, j) for j in jobs]


# -- determinism -------------------------------------------------------------


def _poisson_case(seed):
    rng = np.random.default_rng(3)
    jobs = [Job(f"j{i}", 13.0 if i % 4 else 52.0,
                float(rng.uniform(600.0, 3600.0))) for i in range(60)]
    arr = PoissonArrivals(jobs, rate_per_s=1 / 120.0, seed=7)
    fm = WeibullFailureModel(mtbf_s=4 * 3600.0, repair_s=1800.0)
    return simulate(arr, topology=ClusterTopology(n_nodes=4), op=OP,
                    dt_s=60.0, failure_model=fm, seed=seed)


def test_same_seed_replays_exactly():
    a, b = _poisson_case(5), _poisson_case(5)
    assert_traces_identical(a.trace, b.trace)
    assert a.stats == b.stats
    assert [(p.start, p.end, p.chips) for p in a.schedule.placements] == \
           [(p.start, p.end, p.chips) for p in b.schedule.placements]


def test_different_seed_diverges():
    a, b = _poisson_case(5), _poisson_case(6)
    # different failure draws must change the executed schedule
    assert (a.stats.node_failures != b.stats.node_failures
            or not np.array_equal(a.trace.power_w, b.trace.power_w))


def test_poisson_arrivals_seeded():
    jobs = [Job(f"j{i}", 13.0, 60.0) for i in range(10)]
    t1 = [a.t for a in PoissonArrivals(jobs, 0.01, seed=1).arrivals()]
    t2 = [a.t for a in PoissonArrivals(jobs, 0.01, seed=1).arrivals()]
    t3 = [a.t for a in PoissonArrivals(jobs, 0.01, seed=2).arrivals()]
    assert t1 == t2 and t1 != t3
    assert all(b > a for a, b in zip(t1, t1[1:]))


# -- invariant property grid -------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(n_nodes=st.integers(1, 4),
       n_jobs=st.integers(1, 30),
       rate_scale=st.floats(0.2, 3.0),
       backfill=st.booleans(),
       fail=st.booleans())
def test_sim_invariants(n_nodes, n_jobs, rate_scale, backfill, fail):
    rng = np.random.default_rng(n_jobs * 7 + n_nodes)
    jobs = [Job(f"j{i}", 52.0 if i % 5 == 4 else 13.0,
                float(rng.uniform(120.0, 1800.0))) for i in range(n_jobs)]
    arr = PoissonArrivals(jobs, rate_per_s=rate_scale / 300.0, seed=n_jobs)
    top = ClusterTopology(n_nodes=n_nodes)
    fm = WeibullFailureModel(mtbf_s=40 * 3600.0, repair_s=900.0) \
        if fail else None
    res = simulate(arr, topology=top, op=OP, dt_s=45.0, backfill=backfill,
                   failure_model=fm, seed=n_jobs + 1)

    # every job terminal
    assert all(r.state in ("completed", "dropped") for r in res.records)
    assert res.stats.jobs_completed + res.stats.jobs_dropped == n_jobs
    # utilization is a fraction of capacity
    assert 0.0 <= res.stats.utilization <= 1.0 + 1e-9
    # no chip serves two placements at once
    assert_no_double_booking(res.schedule.placements, top.gpus_per_node)
    # waits are non-negative and the trace spans the makespan
    assert all(r.wait_s is None or r.wait_s >= -1e-9 for r in res.records)
    assert res.trace.t[-1] == pytest.approx(res.makespan)
    # energy can never dip below the always-on idle floor
    idle_w = (NodeModel().power(OP, load=0.0) * n_nodes
              + top.network_w)
    assert res.stats.energy_j >= idle_w * res.trace.duration * (1 - 1e-9)
    assert res.stats.cost_usd == pytest.approx(
        res.stats.energy_kwh * res.stats.usd_per_kwh)


# -- failures & requeue ------------------------------------------------------


def test_failure_truncates_and_requeues():
    # one long job on a 1-node cluster with an aggressive failure clock:
    # the first attempt must be cut short, the job requeued and finished
    fm = WeibullFailureModel(mtbf_s=1200.0, shape=1.0, repair_s=300.0)
    jobs = [Job("hero", 13.0, 3600.0)]
    res = simulate(jobs, topology=ClusterTopology(n_nodes=1), op=OP,
                   dt_s=30.0, failure_model=fm, seed=3, max_requeues=50)
    assert res.stats.node_failures >= 1
    assert res.stats.requeues >= 1
    rec = res.records[0]
    assert rec.state == "completed"
    # one truncated attempt per requeue plus the final full run
    attempts = [p for p in res.schedule.placements]
    assert len(attempts) == rec.requeues + 1
    full = res.records[0].job.work_units  # seconds at perf_scale 1.0
    assert sum(p.end - p.start for p in attempts) > full

    # the trace still accounts for power burned by the killed attempts
    assert res.stats.energy_j > 0.0
    assert res.stats.node_downtime_s == pytest.approx(
        res.stats.node_failures * fm.repair_s)


def test_requeue_budget_drops_job():
    fm = WeibullFailureModel(mtbf_s=600.0, shape=1.0, repair_s=60.0)
    jobs = [Job("doomed", 13.0, 50000.0)]
    res = simulate(jobs, topology=ClusterTopology(n_nodes=1), op=OP,
                   dt_s=300.0, failure_model=fm, seed=1, max_requeues=2)
    assert res.records[0].state == "dropped"
    assert res.stats.jobs_dropped == 1
    assert res.records[0].requeues == 3      # budget + the fatal one


def test_weibull_model_statistics():
    fm = WeibullFailureModel(mtbf_s=1000.0, shape=1.3)
    rng = np.random.default_rng(0)
    draws = [fm.draw_uptime_s(rng) for _ in range(4000)]
    assert np.mean(draws) == pytest.approx(1000.0, rel=0.05)
    outages = list(fm.node_outages(np.random.default_rng(1), 3, 5000.0))
    assert all(t_up == t_down + fm.repair_s for _, t_down, t_up in outages)
    assert all(0 <= node < 3 for node, _, _ in outages)
    with pytest.raises(ValueError):
        WeibullFailureModel(mtbf_s=-1.0)


# -- backfill ----------------------------------------------------------------


def _mixed_width_stream(n_nodes=4, n_jobs=80):
    rng = np.random.default_rng(8)
    jobs = [Job(f"j{i}", 52.0 if i % 3 == 0 else 13.0,
                float(rng.uniform(300.0, 2400.0))) for i in range(n_jobs)]
    return PoissonArrivals(jobs, rate_per_s=1 / 40.0, seed=9), \
        ClusterTopology(n_nodes=n_nodes)


def test_backfill_beats_fcfs_utilization():
    arr, top = _mixed_width_stream()
    fcfs = simulate(arr, topology=top, op=OP, dt_s=60.0, backfill=False)
    easy = simulate(arr, topology=top, op=OP, dt_s=60.0, backfill=True)
    assert easy.stats.utilization > fcfs.stats.utilization
    assert easy.makespan <= fcfs.makespan


def test_backfill_never_delays_the_head():
    # conservative rule: job-by-job, each head's start under backfill is
    # no later than under plain FCFS
    arr, top = _mixed_width_stream(n_nodes=2, n_jobs=40)
    fcfs = simulate(arr, topology=top, op=OP, dt_s=60.0, backfill=False)
    easy = simulate(arr, topology=top, op=OP, dt_s=60.0, backfill=True)
    f_start = {r.job.name: r.start_s for r in fcfs.records}
    for r in easy.records:
        assert r.start_s <= f_start[r.job.name] + 1e-6, r.job.name


# -- long stochastic sweeps (tier-2; run with `pytest -m slow`) --------------


@pytest.mark.slow
def test_week_of_lcsc_operation_is_interactive():
    import time

    rng = np.random.default_rng(10)
    jobs = [Job(f"j{i}", 52.0 if i % 5 == 0 else 13.0,
                float(rng.uniform(1800.0, 4 * 3600.0)))
            for i in range(3000)]
    arr = PoissonArrivals(jobs, rate_per_s=1 / 200.0, seed=11)
    fm = WeibullFailureModel(mtbf_s=1000.0 * 3600.0, repair_s=2 * 3600.0)
    t0 = time.perf_counter()
    res = simulate(arr, topology=ClusterTopology(n_nodes=160), op=OP,
                   dt_s=60.0, failure_model=fm, seed=12)
    wall = time.perf_counter() - t0
    assert wall < 10.0, f"160-node week took {wall:.1f}s"
    assert res.stats.jobs_completed + res.stats.jobs_dropped == 3000
    assert res.makespan > 6 * 24 * 3600.0     # a week-scale horizon
    # power never exceeds the all-nodes-flat-out envelope
    env = NodeModel().power(OP) * 160 + ClusterTopology(
        n_nodes=160).network_w
    assert float(np.max(res.trace.power_w)) <= env * (1 + 1e-9)


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 24))
def test_sim_invariants_wide_sweep(seed):
    rng = np.random.default_rng(seed)
    n_jobs = 20 + seed * 3
    jobs = [Job(f"j{i}", 52.0 if i % 4 == 0 else 13.0,
                float(rng.uniform(60.0, 3600.0))) for i in range(n_jobs)]
    arr = PoissonArrivals(jobs, rate_per_s=1 / 60.0, seed=seed)
    top = ClusterTopology(n_nodes=1 + seed % 6)
    fm = WeibullFailureModel(mtbf_s=(10 + seed) * 3600.0, repair_s=600.0)
    res = simulate(arr, topology=top, op=OP, dt_s=120.0,
                   failure_model=fm, seed=seed, backfill=bool(seed % 2))
    assert all(r.state in ("completed", "dropped") for r in res.records)
    assert 0.0 <= res.stats.utilization <= 1.0 + 1e-9
    assert_no_double_booking(res.schedule.placements, top.gpus_per_node)
