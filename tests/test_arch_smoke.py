"""Per-architecture smoke tests: reduced same-family config, one forward /
train / prefill / decode step on CPU; asserts shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ARCH_IDS, smoke_config
from repro.models import (forward_decode, forward_prefill,
                          forward_train_loss, init_params)
from repro.models.frontend import enc_len_for

B, S = 2, 32


def _batch(cfg, key):
    kt, kl, ke = jax.random.split(key, 3)
    batch = {}
    if cfg.family == "vlm":
        s_txt = S - cfg.n_patches
        batch["tokens"] = jax.random.randint(kt, (B, s_txt), 0,
                                             cfg.vocab_size)
        batch["patch_embeds"] = jax.random.normal(
            ke, (B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        batch["labels"] = jax.random.randint(kl, (B, s_txt), 0,
                                             cfg.vocab_size)
    elif cfg.family == "encdec":
        batch["tokens"] = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
        batch["frame_embeds"] = jax.random.normal(
            ke, (B, enc_len_for(cfg, S), cfg.d_model), jnp.bfloat16)
        batch["labels"] = jax.random.randint(kl, (B, S), 0, cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
        batch["labels"] = jax.random.randint(kl, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = forward_train_loss(cfg, params, batch, remat=False)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # random labels: loss should be near ln(vocab)
    assert 0.0 < float(loss) < 2.0 * np.log(cfg.vocab_size) + 1.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, cache = forward_prefill(cfg, params, batch)
    assert logits.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)[:, None]
    logits2, cache2 = forward_decode(cfg, params, tok.astype(jnp.int32),
                                     cache)
    assert logits2.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_grads_finite(arch):
    cfg = smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    def loss_fn(p):
        return forward_train_loss(cfg, p, batch, remat=False)[0]

    grads = jax.grad(loss_fn)(params)
    gnorm = float(jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(grads))))
    assert np.isfinite(gnorm) and gnorm > 0.0
