"""LQCD substrate: gamma algebra, hermiticity, CG convergence (property)."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:               # deterministic grid fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.lqcd import dslash, random_su3_field, solve_wilson, wilson_matvec
from repro.lqcd.dirac import (GAMMA, GAMMA5, dslash_dense_matrix, eo_matvec,
                              parity_mask)
from repro.lqcd.su3 import unitarity_defect


def test_gamma_algebra():
    """{γ_mu, γ_nu} = 2 δ_mu_nu."""
    g = np.asarray(GAMMA)
    for mu in range(4):
        for nu in range(4):
            anti = g[mu] @ g[nu] + g[nu] @ g[mu]
            want = 2 * np.eye(4) if mu == nu else np.zeros((4, 4))
            np.testing.assert_allclose(anti, want, atol=1e-6)
    g5 = np.asarray(GAMMA5)
    np.testing.assert_allclose(g5 @ g5, np.eye(4), atol=1e-6)


def test_su3_unitarity():
    U = random_su3_field(jax.random.PRNGKey(0), (4, 4, 4, 4))
    assert float(unitarity_defect(U)) < 1e-5
    det = np.linalg.det(np.asarray(U).reshape(-1, 3, 3))
    np.testing.assert_allclose(det, np.ones_like(det), atol=1e-5)


def test_gamma5_hermiticity_dense():
    """γ5 D γ5 = D† on an explicit 4^4 matrix."""
    U = random_su3_field(jax.random.PRNGKey(1), (4, 4, 4, 4))
    M = dslash_dense_matrix(U)
    g5 = np.kron(np.eye(4 ** 4), np.kron(np.asarray(GAMMA5), np.eye(3)))
    np.testing.assert_allclose(g5 @ M @ g5, M.conj().T, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), kappa=st.floats(0.05, 0.12))
def test_cg_converges(seed, kappa):
    """Property: CGNE solves M x = b for any gauge field, kappa < 1/8."""
    key = jax.random.PRNGKey(seed)
    U = random_su3_field(key, (4, 4, 4, 4))
    kr, ki = jax.random.split(key)
    b = (jax.random.normal(kr, (4, 4, 4, 4, 4, 3))
         + 1j * jax.random.normal(ki, (4, 4, 4, 4, 4, 3))
         ).astype(jnp.complex64)
    res = solve_wilson(U, b, kappa, tol=1e-5, max_iters=800)
    assert bool(res.converged), float(res.rel_residual)
    # verify against the operator directly
    r = b - wilson_matvec(U, res.x, kappa)
    rel = float(jnp.linalg.norm(r.reshape(-1))
                / jnp.linalg.norm(b.reshape(-1)))
    assert rel < 1e-4


def test_even_odd_operator_gamma5_hermitian():
    """The even-odd operator A = 1 - k^2 D_eo D_oe satisfies
    gamma5 A gamma5 = A-dagger (so CGNE on it is well-posed)."""
    key = jax.random.PRNGKey(3)
    U = random_su3_field(key, (4, 4, 4, 4))
    mask = parity_mask((4, 4, 4, 4))
    kr, ki = jax.random.split(key)

    def mk(k):
        v = (jax.random.normal(k, (4, 4, 4, 4, 4, 3))
             + 1j * jax.random.normal(k, (4, 4, 4, 4, 4, 3)))
        return jnp.where(mask[..., None, None], v, 0).astype(jnp.complex64)

    def g5(v):
        return jnp.einsum("st,...ta->...sa", GAMMA5, v)

    x, y = mk(kr), mk(ki)
    kappa = 0.1
    # <y, g5 A g5 x> == <A y, x>  (gamma5-hermiticity)
    lhs = complex(jnp.sum(jnp.conj(y) * g5(eo_matvec(U, g5(x), kappa, mask))))
    rhs = complex(jnp.sum(jnp.conj(eo_matvec(U, y, kappa, mask)) * x))
    assert abs(lhs - rhs) / max(abs(lhs), 1e-9) < 1e-3


def test_sharded_dslash_matches():
    """Halo-exchange D-slash == reference (4-way T-axis CPU device mesh).

    Runs in-process: the subprocess variant popped JAX_PLATFORMS and the
    child then probed for TPU hardware via instance metadata, which
    hangs forever on hosts without one (the seed-state timeout)."""
    from conftest import need_devices
    from repro.lqcd.multichip import dslash_sharded
    need_devices(4)
    mesh = jax.make_mesh((4,), ("model",))
    U = random_su3_field(jax.random.PRNGKey(0), (4, 4, 4, 8))
    kr, ki = jax.random.split(jax.random.PRNGKey(1))
    psi = (jax.random.normal(kr, (4, 4, 4, 8, 4, 3))
           + 1j * jax.random.normal(ki, (4, 4, 4, 8, 4, 3))
           ).astype(jnp.complex64)
    got = dslash_sharded(U, psi, mesh)
    want = dslash(U, psi)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
