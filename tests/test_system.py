"""End-to-end behaviour tests: training converges, serving round-trips,
MoE routing behaves, Green500 trace accounting is self-consistent."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import need_devices
from repro.config import ShapeConfig, TrainConfig, smoke_config
from repro.data import make_batch_iterator
from repro.models import init_params
from repro.optim import adamw_init
from repro.runtime.steps import make_train_step


def test_training_reduces_loss():
    cfg = smoke_config("olmo-1b")
    shape = ShapeConfig("t", 128, 4, "train")
    tc = TrainConfig(learning_rate=3e-3, total_steps=30, warmup_steps=3,
                     remat="none")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, tc))
    data = make_batch_iterator(cfg, shape)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]
    assert all(np.isfinite(l) for l in losses)


def test_microbatched_step_matches_plain():
    """Gradient accumulation over M microbatches == one big batch step."""
    cfg = smoke_config("llama3-8b")
    shape = ShapeConfig("t", 64, 8, "train")
    params = init_params(cfg, jax.random.PRNGKey(0))
    data = make_batch_iterator(cfg, shape)
    batch = {k: jnp.asarray(v) for k, v in next(data).items()}

    tc1 = TrainConfig(remat="none", microbatches=1)
    tc4 = TrainConfig(remat="none", microbatches=4)
    p1, o1, m1 = jax.jit(make_train_step(cfg, tc1))(
        params, adamw_init(params), batch)
    p4, o4, m4 = jax.jit(make_train_step(cfg, tc4))(
        params, adamw_init(params), batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 0.02
    # updated params agree to accumulation tolerance
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-2)


def test_block_remat_matches_layer_remat():
    cfg = smoke_config("llama3-8b")
    shape = ShapeConfig("t", 64, 4, "train")
    params = init_params(cfg, jax.random.PRNGKey(0))
    data = make_batch_iterator(cfg, shape)
    batch = {k: jnp.asarray(v) for k, v in next(data).items()}
    outs = {}
    for policy in ("layer", "block"):
        tc = TrainConfig(remat=policy)
        _, _, m = jax.jit(make_train_step(cfg, tc))(
            params, adamw_init(params), batch)
        outs[policy] = float(m["loss"])
    assert abs(outs["layer"] - outs["block"]) < 1e-3


def test_moe_routing_mass_conservation():
    """Per-token combine weights sum to ~1 (after capacity drops <= 1)."""
    from repro.models.moe import _moe_local
    cfg = smoke_config("grok-1-314b")
    e = cfg.moe
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, cfg.d_model), jnp.float32)
    router = jax.random.normal(jax.random.PRNGKey(1),
                               (cfg.d_model, e.n_experts)) * 0.1
    wg = jax.random.normal(jax.random.PRNGKey(2),
                           (e.n_experts, cfg.d_model, e.expert_d_ff)) * 0.02
    wu = jax.random.normal(jax.random.PRNGKey(3),
                           (e.n_experts, cfg.d_model, e.expert_d_ff)) * 0.02
    wd = jax.random.normal(jax.random.PRNGKey(4),
                           (e.n_experts, e.expert_d_ff, cfg.d_model)) * 0.02
    y, aux = _moe_local(cfg, x, router, wg, wu, wd, 0, e.n_experts, 1,
                        "expert")
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0.5        # aux ~ 1 for balanced-ish routing


def test_moe_sharded_matches_local():
    """shard_map MoE == single-shard fallback (2x2 CPU device mesh)."""
    from dataclasses import replace
    from repro.models.moe import init_moe, moe_forward
    need_devices(4)
    cfg = smoke_config("grok-1-314b")
    cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=8.0))  # no drops
    p = init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model),
                          jnp.float32)
    local, aux_l = moe_forward(cfg, p, x, mesh=None)
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    shard, aux_s = moe_forward(cfg, p, x, mesh=mesh)
    np.testing.assert_allclose(np.asarray(local), np.asarray(shard),
                               rtol=3e-2, atol=3e-2)


def test_train_step_small_mesh():
    """Full sharded train step on a 2x2 CPU device mesh."""
    from jax.sharding import PartitionSpec as P
    from repro.config import MeshConfig
    from repro.distributed.sharding import (batch_pspecs, named_shardings,
                                            param_pspecs)
    need_devices(4)
    cfg = smoke_config("grok-1-314b")
    mesh_cfg = MeshConfig((2, 2), ("data", "model"))
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = {"tokens": jnp.zeros((4, 32), jnp.int32),
             "labels": jnp.zeros((4, 32), jnp.int32)}
    pspecs = param_pspecs(cfg, params, mesh_cfg)
    pshard = named_shardings(mesh, pspecs)
    oshard = named_shardings(mesh, {"m": pspecs, "v": pspecs, "step": P()})
    bshard = named_shardings(mesh, batch_pspecs(cfg, batch, mesh_cfg))
    params = jax.device_put(params, pshard)
    opt = jax.device_put(opt, oshard)
    batch = jax.device_put(batch, bshard)
    tc = TrainConfig(remat="block", microbatches=2)
    step = jax.jit(make_train_step(cfg, tc, mesh=mesh, mesh_cfg=mesh_cfg),
                   in_shardings=(pshard, oshard, bshard),
                   out_shardings=(pshard, oshard, None))
    params, opt, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
