"""Tests for the unified Workload API + power-aware cluster scheduler
(``repro.cluster``): adapter normalization, placement policies,
synchronous-step straggler pacing, power-cap enforcement, the merged
cluster trace, and the deprecation shims."""
import importlib
import sys
import warnings

import numpy as np
import pytest

from repro.cluster import (Chip, ClusterTopology, HPLWorkload, Job,
                           LQCDSolveWorkload, PowerCapError, Scheduler,
                           SchedulingError, ServeWorkload,
                           SyntheticWorkload, TrainWorkload, WorkloadResult,
                           list_workloads, make_workload, run,
                           schedule_throughput, synchronous_rate,
                           with_perf_floor)
from repro.power.model import OperatingPoint
from repro.power.trace import PowerTrace


# -- Workload registry + adapters --------------------------------------------

def test_registry_lists_all_five_adapters():
    # superset: future adapters (e.g. serve-traffic replay) may register
    assert set(list_workloads()) >= {"hpl", "lqcd", "serve", "synthetic",
                                     "train"}


def test_make_workload_by_name_and_unknown():
    wl = make_workload("synthetic")
    assert wl.job().kind == "synthetic"
    with pytest.raises(KeyError, match="unknown workload"):
        make_workload("quantum")


def test_every_adapter_normalizes_to_a_job():
    for kind in list_workloads():
        job = make_workload(kind).job()
        assert isinstance(job, Job)
        assert job.mem_gb > 0 and job.work_units >= 0
        assert job.kind == kind


@pytest.mark.parametrize("kind", ["train", "serve", "synthetic"])
def test_analytic_adapters_execute_to_result_with_trace(kind):
    res = make_workload(kind).execute(OperatingPoint.green500())
    assert isinstance(res, WorkloadResult)
    assert isinstance(res.power_trace, PowerTrace)
    assert res.energy_j > 0 and res.wall_s > 0 and res.perf_gflops > 0


def test_lqcd_adapter_runs_real_solve():
    res = LQCDSolveWorkload().execute(OperatingPoint.green500())
    assert res.details["converged"]
    assert res.details["rel_residual"] <= 1e-5
    assert isinstance(res.power_trace, PowerTrace)


def test_hpl_adapter_runs_real_lu():
    from repro.configs.hpl import HPLConfig
    res = HPLWorkload(cfg=HPLConfig(n=96, block=32)).execute(
        OperatingPoint.green500())
    assert res.details["passed"]
    assert res.perf_gflops > 0
    assert isinstance(res.power_trace, PowerTrace)


def test_lattice_mem_gb_scales_with_volume():
    from repro.configs.lcsc_lqcd import COLD_LATTICE, THERMAL_LATTICE
    assert COLD_LATTICE.mem_gb == pytest.approx(
        8 * THERMAL_LATTICE.mem_gb)
    # thermal lattices fit on one S9150; that is the paper's whole point
    assert THERMAL_LATTICE.mem_gb < 16.0


# -- Scheduler: topology, policies, pacing -----------------------------------

def test_topology_chips_carry_node_ids():
    top = ClusterTopology(n_nodes=3, gpus_per_node=4)
    chips = top.chips()
    assert len(chips) == 12
    assert [c.node_id for c in chips[:5]] == [0, 0, 0, 0, 1]


def test_packed_prefers_single_chip_and_chip_local_shards():
    top = ClusterTopology(n_nodes=2)
    s = Scheduler(top, policy="packed")
    sch = s.schedule([Job(f"lat{i}", 13.0, 1.0) for i in range(8)])
    assert all(not p.sharded for p in sch.placements)
    assert sch.makespan == pytest.approx(1.0)
    # a 2-chip shard stays on one node
    sh = s.schedule([Job("cold", 30.0, 1.0)]).placements[0]
    assert sh.sharded and len(sh.nodes) == 1


def test_round_robin_shards_node_wide_and_loses():
    top = ClusterTopology(n_nodes=2)
    jobs = [Job(f"lat{i}", 13.0, 1.0) for i in range(8)]
    packed = Scheduler(top, policy="packed").schedule(jobs)
    rr = Scheduler(top, policy="round_robin").schedule(jobs)
    assert all(p.sharded for p in rr.placements)
    assert rr.makespan > packed.makespan


def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="unknown policy"):
        Scheduler(policy="steal")


def test_job_larger_than_node_memory_is_a_clean_error():
    with pytest.raises(SchedulingError, match="more than a node's total"):
        Scheduler(ClusterTopology(n_nodes=4)).schedule(
            [Job("huge", 100.0, 1.0)])


def test_unshardable_job_larger_than_chip_is_a_clean_error():
    with pytest.raises(SchedulingError, match="not .*shardable"):
        Scheduler().schedule([Job("pinned", 20.0, 1.0, shardable=False)])


def test_empty_job_list_schedules_cleanly():
    sch = Scheduler().schedule([])
    assert sch.placements == [] and sch.makespan == 0.0
    with pytest.raises(ValueError, match="empty workload batch"):
        run([])


def test_straggler_pacing_heterogeneous_perf():
    # synchronous steps: the slowest shard gates the pool
    assert synchronous_rate([1.0, 0.5], penalty=0.2) == pytest.approx(0.8)
    top = ClusterTopology(n_nodes=1, perf_scales=(1.0, 0.5, 1.0, 1.0))
    pl = Scheduler(top).schedule([Job("cold", 32.0, 1.0)]).placements[0]
    assert pl.sharded and len(pl.chips) == 2
    # NOT the optimistic sum (1.5×0.8 → 0.833s); min-paced → 1.25s
    assert pl.end - pl.start == pytest.approx(1.0 / (2 * 0.5 * 0.8))


def test_perf_floor_mitigation_flattens_topology():
    top = ClusterTopology(n_nodes=1, perf_scales=(1.0, 0.9, 1.0, 1.0))
    flat = with_perf_floor(top)
    assert set(flat.perf_scales) == {0.9}
    assert with_perf_floor(ClusterTopology(n_nodes=1)).perf_scales is None


# -- Power cap ---------------------------------------------------------------

def test_power_cap_derates_down_the_dpm_ladder():
    top = ClusterTopology(n_nodes=56)
    op, derated = Scheduler(top, power_cap_w=50e3).resolve_operating_point(
        OperatingPoint.green500())
    assert derated and op.f_mhz < 774.0
    op2, d2 = Scheduler(top, power_cap_w=60e3).resolve_operating_point(
        OperatingPoint.green500())
    assert not d2 and op2.f_mhz == 774.0


def test_power_cap_infeasible_raises():
    with pytest.raises(PowerCapError, match="infeasible"):
        Scheduler(ClusterTopology(n_nodes=56),
                  power_cap_w=1e3).resolve_operating_point()


def test_power_cap_covers_switch_power():
    # a cap that the nodes alone meet but nodes + switches exceed must
    # still force a derate (the cap is wall power)
    top = ClusterTopology(n_nodes=56)
    from repro.power.layers import NodeModel
    nodes_only = NodeModel().power(OperatingPoint.green500()) * 56
    op, derated = Scheduler(
        top, power_cap_w=nodes_only + 10.0).resolve_operating_point(
        OperatingPoint.green500())
    assert derated and op.f_mhz < 774.0


def test_power_cap_op_below_dpm_floor_is_clean_error():
    # an op already under the lowest DPM state has nowhere to derate:
    # still a PowerCapError, never a bare IndexError
    with pytest.raises(PowerCapError, match="infeasible"):
        Scheduler(ClusterTopology(n_nodes=56),
                  power_cap_w=1e3).resolve_operating_point(
            OperatingPoint(f_mhz=200.0))


# -- WorkloadResults respect the shared bus and the operating point ----------

def test_shared_bus_energy_is_windowed_per_workload():
    from repro.power.trace import TraceRecorder
    op = OperatingPoint.green500()
    solo = ServeWorkload().execute(op).energy_j
    rec = TraceRecorder()
    TrainWorkload().execute(op, recorder=rec)
    shared = ServeWorkload().execute(op, recorder=rec)
    # serve's result must not absorb train's earlier phases on the bus
    assert shared.energy_j == pytest.approx(solo, rel=1e-6)


def test_synthetic_stacks_on_shared_bus():
    from repro.power.engine import ConstantLoad
    from repro.power.trace import TraceRecorder
    op = OperatingPoint.green500()
    wl = SyntheticWorkload(profile=ConstantLoad(duration_s=100.0))
    solo = wl.execute(op).energy_j
    rec = TraceRecorder()
    TrainWorkload().execute(op, recorder=rec)
    t_prev = rec.t_last
    shared = SyntheticWorkload(
        profile=ConstantLoad(duration_s=100.0)).execute(op, recorder=rec)
    # simulate() appends after the bus's latest sample (no overlap) and
    # the result is billed only for its own window
    assert float(shared.power_trace.t[-1]) >= t_prev + 100.0
    assert shared.energy_j == pytest.approx(solo, rel=1e-6)


def test_lqcd_energy_tracks_operating_point():
    e_774 = LQCDSolveWorkload().execute(OperatingPoint.green500())
    e_900 = LQCDSolveWorkload().execute(OperatingPoint(f_mhz=900.0))
    # derated, undervolted chips draw less; the memory-bound solve time
    # barely moves (paper: <1.5%)
    assert e_774.energy_j < e_900.energy_j
    assert e_774.wall_s == pytest.approx(e_900.wall_s)


def test_train_plan_clock_capped_by_operating_point():
    plan_cap, _ = TrainWorkload().energy_plan(
        mode="performance", op=OperatingPoint.green500())
    plan_free, _ = TrainWorkload().energy_plan(mode="performance")
    assert plan_cap.freq_scale <= 774.0 / 900.0 + 1e-9
    assert plan_free.freq_scale >= plan_cap.freq_scale


def test_train_cost_matches_driver_remat():
    # launch.train compiles its step with remat="none"; the adapter's
    # default cost model must describe that step, not a remat'd one
    assert TrainWorkload().remat == "none"
    assert TrainWorkload(remat="layer")._cost().flops > \
        TrainWorkload()._cost().flops


# -- The merged cluster trace ------------------------------------------------

def test_merged_trace_composes_node_layers():
    top = ClusterTopology(n_nodes=4)
    jobs = [Job(f"lat{i}", 13.0, 600.0) for i in range(top.n_chips)]
    res = run(jobs, topology=top, op=OperatingPoint.green500(), dt_s=60.0)
    # every layer is accounted in the merged trace
    for comp in ("gpu", "host", "fan", "psu_loss", "network"):
        assert comp in res.trace.components
    # full-load compute power == the layered node model × n_nodes
    from repro.power.layers import NodeModel
    expect = NodeModel().power(OperatingPoint.green500()) * top.n_nodes
    assert float(res.trace.power_w[0]) == pytest.approx(expect, rel=1e-6)
    # Green500 methodology consumes the merged trace directly
    assert res.efficiency(3).mflops_per_w > 4000


def test_merged_trace_ends_at_makespan():
    # makespan not a multiple of dt_s: no samples (or energy) past it
    top = ClusterTopology(n_nodes=1)
    res = run([Job("j", 13.0, 100.0)], topology=top, dt_s=30.0)
    assert res.makespan == pytest.approx(100.0)
    assert float(res.trace.t[-1]) == pytest.approx(100.0)
    # batches shorter than one tick are not padded with idle energy
    short = run([Job("j", 13.0, 2.0)], topology=top, dt_s=30.0)
    assert float(short.trace.t[-1]) == pytest.approx(2.0)


def test_idle_chips_draw_static_power_only():
    top = ClusterTopology(n_nodes=2)
    busy = run([Job(f"j{i}", 13.0, 600.0) for i in range(8)],
               topology=top, dt_s=60.0)
    half = run([Job(f"j{i}", 13.0, 600.0) for i in range(4)],
               topology=top, dt_s=60.0)
    assert float(half.trace.power_w[0]) < float(busy.trace.power_w[0])
    # hosts/fans/PSU stay powered either way
    assert half.trace.components["host"][0] == \
        busy.trace.components["host"][0]


def test_mixed_adapter_batch_through_cluster_run():
    wls = [TrainWorkload(), ServeWorkload(), SyntheticWorkload()]
    res = run(wls, topology=ClusterTopology(n_nodes=1), dt_s=60.0)
    assert [r.kind for r in res.results] == ["train", "serve", "synthetic"]
    assert all(isinstance(r.power_trace, PowerTrace) for r in res.results)
    assert res.trace.meta["policy"] == "packed"


def test_preferred_op_flows_from_jobs():
    j = Job("hpl", 13.0, 1.0, preferred_op=OperatingPoint(f_mhz=900.0))
    res = run([j], topology=ClusterTopology(n_nodes=1), dt_s=60.0)
    assert res.op.f_mhz == 900.0


def test_mixed_preferred_ops_resolve_per_job():
    # regression (twice over): jobs whose preferred_op differed from the
    # batch's first used to be dropped — first silently, then with a
    # UserWarning.  Per-job resolution means every preference is now
    # honored on its own placement, and nothing warns.
    jobs = [Job("hpl", 13.0, 1.0, preferred_op=OperatingPoint(f_mhz=900.0)),
            Job("lqcd", 13.0, 1.0,
                preferred_op=OperatingPoint.green500()),
            Job("serve", 13.0, 1.0, preferred_op=OperatingPoint(f_mhz=655.0))]
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        sched = Scheduler(ClusterTopology(n_nodes=1))
        schedule = sched.schedule(jobs)
    by_name = {p.job.name: p for p in schedule.placements}
    assert by_name["hpl"].op.f_mhz == 900.0
    assert by_name["lqcd"].op == OperatingPoint.green500()
    assert by_name["serve"].op.f_mhz == 655.0
    assert not schedule.derated


def test_explicit_op_overrides_every_preference():
    jobs = [Job("hpl", 13.0, 1.0, preferred_op=OperatingPoint(f_mhz=900.0)),
            Job("lqcd", 13.0, 1.0, preferred_op=OperatingPoint.green500())]
    forced = OperatingPoint(f_mhz=655.0)
    schedule = Scheduler(ClusterTopology(n_nodes=1)).schedule(jobs, op=forced)
    assert all(p.op == forced for p in schedule.placements)
    assert schedule.op == forced


def test_power_cap_derates_per_job():
    # under a cap that fits the Green500 point but not 900 MHz, only the
    # 900-preferring job walks down the DPM ladder; the efficiency-mode
    # job keeps its point untouched
    jobs = [Job("hot", 13.0, 1.0, preferred_op=OperatingPoint(f_mhz=900.0)),
            Job("cool", 13.0, 1.0, preferred_op=OperatingPoint.green500())]
    sched = Scheduler(ClusterTopology(n_nodes=1), power_cap_w=1400.0)
    schedule = sched.schedule(jobs)
    by_name = {p.job.name: p for p in schedule.placements}
    assert by_name["hot"].op.f_mhz < 900.0
    assert by_name["cool"].op == OperatingPoint.green500()
    assert schedule.derated


def test_uniform_preferred_ops_resolve_silently():
    pref = OperatingPoint(f_mhz=900.0)
    jobs = [Job(f"j{i}", 13.0, 1.0, preferred_op=pref) for i in range(3)]
    sched = Scheduler(ClusterTopology(n_nodes=1))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        op, _ = sched.resolve_operating_point(job=jobs[0])
        assert op.f_mhz == 900.0
        # a homogeneous batch collapses to its one point
        assert sched.schedule(jobs).op == pref
        # no preference → the autotuner cost model's recommendation,
        # which rediscovers the Green500 record point
        op, _ = sched.resolve_operating_point(job=Job("plain", 13.0, 1.0))
        assert op == OperatingPoint.green500()


# -- Legacy flat API (the core/energy shim keeps these alive) ----------------

def test_legacy_schedule_throughput_still_works():
    chips = [Chip(i, 16.0) for i in range(4)]
    jobs = [Job(f"thermal{i}", 3.0, 1.0) for i in range(8)]
    pl = schedule_throughput(jobs, chips)
    assert all(not p.sharded for p in pl)
    assert max(p.end for p in pl) == pytest.approx(2.0)


def test_legacy_positional_job_and_chip():
    # the pre-refactor call shape: Job(name, mem_gb, work_units)
    j = Job("x", 3.0, 1.0)
    assert j.shardable and j.preferred_op is None and j.kind == "generic"
    c = Chip(0, 16.0)
    assert c.perf_scale == 1.0 and c.node_id == 0


# -- Deprecation shims -------------------------------------------------------

@pytest.mark.parametrize("mod", ["repro.core.energy.scheduler",
                                 "repro.core.energy.power_model",
                                 "repro.core.energy.green500"])
def test_shim_emits_deprecation_warning(mod):
    sys.modules.pop(mod, None)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        importlib.import_module(mod)
    assert any(issubclass(w.category, DeprecationWarning) for w in caught), \
        f"{mod} did not warn"


def test_core_energy_package_import_is_warning_free():
    for name in [m for m in sys.modules
                 if m.startswith("repro.core.energy")]:
        sys.modules.pop(name)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        importlib.import_module("repro.core.energy")
    assert not [w for w in caught
                if issubclass(w.category, DeprecationWarning)]


def test_scheduler_shim_reexports_cluster_types():
    import repro.cluster.scheduler as real
    shim = importlib.import_module("repro.core.energy.scheduler")
    assert shim.Job is real.Job
    assert shim.schedule_throughput is real.schedule_throughput
    assert np.isclose(shim.straggler_step_time(1.0, [1.0, 0.8]), 1.25)
