"""Autotuner: cache round-trip, perf-floor contract (property), the
paper's operating point, and the tuned=True consumer paths."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:               # deterministic grid fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.autotune import (AnalyticDgemmModel, CacheEntry, NB_EFFICIENCY,
                            Space, TuneCache, coordinate_descent,
                            default_cache, grid_search, set_default_cache,
                            tune_operating_point, tuned_config)


# -- cache ---------------------------------------------------------------

def test_cache_roundtrip(tmp_path):
    """save -> load -> identical entries (the satellite requirement)."""
    path = tmp_path / "autotune.json"
    c = TuneCache(path)
    e1 = CacheEntry(config={"bm": 256, "bn": 512, "bk": 128},
                    perf_gflops=123.4, power_w=150.0, mflops_per_w=822.7,
                    model="analytic", perf_loss=0.02)
    e2 = CacheEntry(config={"block": 64, "lookahead": 1})
    c.put("dgemm", (1024, 1024, 1024), "cpu", e1)
    c.put("hpl", (256,), "tpu", e2)
    assert path.exists()

    c2 = TuneCache(path)                   # fresh load from disk
    assert len(c2) == 2
    assert c2.get("dgemm", (1024, 1024, 1024), "cpu") == e1
    assert c2.get("hpl", (256,), "tpu") == e2
    assert c2.to_dict() == c.to_dict()
    # the file itself is versioned, sorted JSON
    raw = json.loads(path.read_text())
    assert raw["version"] == 1
    assert sorted(raw["entries"]) == list(raw["entries"])


def test_cache_rejects_unknown_version(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text(json.dumps({"version": 99, "entries": {}}))
    with pytest.raises(ValueError):
        TuneCache(path)


def test_tuned_config_memoizes(tmp_path):
    cache = TuneCache(tmp_path / "c.json")
    got = tuned_config("hpl", (256,), device="cpu", cache=cache)
    assert 256 % got["block"] == 0
    # second call is a pure cache hit (identical dict, file unchanged)
    before = (tmp_path / "c.json").read_text()
    again = tuned_config("hpl", (256,), device="cpu", cache=cache)
    assert again == got
    assert (tmp_path / "c.json").read_text() == before


def test_default_cache_env_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "env.json"))
    set_default_cache(None)                # re-resolve from env
    try:
        assert default_cache().path == tmp_path / "env.json"
    finally:
        set_default_cache(None)            # don't leak into other tests


# -- searchers -----------------------------------------------------------

def _toy_space():
    return Space({"x": tuple(range(1, 8)), "y": tuple(range(1, 6))})


@settings(max_examples=12, deadline=None)
@given(loss=st.floats(0.0, 0.45), a=st.integers(1, 7), b=st.integers(1, 5))
def test_searchers_respect_perf_floor(loss, a, b):
    """Property: neither searcher ever returns a point below its perf
    floor, even with infeasible holes in the space."""
    space = _toy_space()

    def ev(p):
        if p["x"] == a and p["y"] == min(b, 5):     # infeasible hole
            return 0.0, float("inf")
        perf = 10.0 * p["x"] + a * p["y"]
        power = 5.0 + (p["x"] - 3) ** 2 + b * p["y"]
        return perf, power

    for search in (grid_search, coordinate_descent):
        res = search(space, ev, max_perf_loss=loss)
        assert res.best.perf_gflops >= res.perf_floor_gflops - 1e-9
        assert res.perf_floor_gflops == pytest.approx(
            (1.0 - loss) * res.peak_perf_gflops)
        assert res.best.power_w < float("inf")

    # the grid's peak is the true feasible max
    gres = grid_search(space, ev, max_perf_loss=loss)
    true_peak = max(ev(p)[0] for p in space.points())
    assert gres.peak_perf_gflops == pytest.approx(true_peak)


def test_grid_search_skips_infeasible_and_is_deterministic():
    space = Space({"x": (1, 2, 3)})

    def ev(p):
        if p["x"] == 2:
            return 0.0, float("inf")
        return 10.0, 10.0 / p["x"]         # x=3 most efficient

    r1 = grid_search(space, ev, max_perf_loss=0.5)
    r2 = grid_search(space, ev, max_perf_loss=0.5)
    assert r1.best.point == r2.best.point == {"x": 3}
    assert r1.evaluations == 3


def test_grid_search_raises_when_nothing_feasible():
    space = Space({"x": (1, 2)})
    with pytest.raises(ValueError):
        grid_search(space, lambda p: (0.0, float("inf")))


# -- the paper's operating point ----------------------------------------

def test_operating_point_matches_paper():
    """The analytic searcher rediscovers §2–4's published settings."""
    res = tune_operating_point()
    best = res.best.point
    assert best["f_mhz"] == 774.0
    assert best["fan"] == pytest.approx(0.40, abs=0.051)
    assert best["nb"] == NB_EFFICIENCY
    assert abs(res.best.mflops_per_w - 5271.8) / 5271.8 < 0.02
    cd = tune_operating_point(method="coordinate")
    assert cd.best.point == best
    assert cd.evaluations < res.evaluations


# -- analytic kernel model feasibility ----------------------------------

def test_dgemm_model_rejects_nondividing_and_oversized_tiles():
    m = AnalyticDgemmModel(512, 512, 512)
    perf, power = m.evaluate({"bm": 384, "bn": 128, "bk": 128})
    assert perf == 0.0 and power == float("inf")     # 512 % 384 != 0
    perf, _ = m.evaluate({"bm": 512, "bn": 512, "bk": 512})
    assert perf > 0.0
    huge = AnalyticDgemmModel(1 << 16, 1 << 16, 1 << 16)
    perf, _ = huge.evaluate({"bm": 1 << 16, "bn": 1 << 16, "bk": 256})
    assert perf == 0.0                               # blows the VMEM budget


# -- tuned=True consumer paths ------------------------------------------

def test_dgemm_tuned_path_matches_ref(tmp_path):
    from repro.kernels.dgemm import dgemm
    from repro.kernels.dgemm.ref import dgemm_ref
    cache = TuneCache(tmp_path / "k.json")
    set_default_cache(cache)
    try:
        x = jax.random.normal(jax.random.PRNGKey(0), (256, 256), jnp.float32)
        y = jax.random.normal(jax.random.PRNGKey(1), (256, 256), jnp.float32)
        got = dgemm(x, y, tuned=True)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(dgemm_ref(x, y)),
                                   rtol=2e-4, atol=2e-4)
        assert cache.get("dgemm", (256, 256, 256), "cpu") is not None
    finally:
        set_default_cache(None)


def test_linpack_tuned_path(tmp_path):
    from repro.configs.hpl import HPLConfig
    from repro.hpl import linpack_run
    set_default_cache(TuneCache(tmp_path / "h.json"))
    try:
        r = linpack_run(HPLConfig(n=192, block=96, mode="efficiency"),
                        tuned=True)
        assert r.passed
        assert r.mode == "efficiency"      # caller's mode is preserved
        assert 192 % r.block == 0
        assert r.block < 96                # tuned blocking, not the input
    finally:
        set_default_cache(None)


def test_recommended_operating_point_is_green500_and_cached():
    # the scheduler's placement-time consult: the coordinate-descent
    # search over the analytic node model rediscovers the paper's
    # Green500 record point, and the result is cached per process
    from repro.autotune.measure import recommended_operating_point
    from repro.power.model import OperatingPoint
    op = recommended_operating_point()
    assert op == OperatingPoint.green500()
    assert recommended_operating_point() is op
