"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The test-suite uses a small slice of the hypothesis API (``given``,
``settings``, ``strategies.integers/floats/sampled_from/booleans``).  When
the real package is available the tests import it directly; otherwise they
fall back to this shim, which runs each property test over a fixed,
evenly-spaced grid of ``max_examples`` examples instead of randomized ones.
Collection therefore never fails on the missing dependency, and the
properties still get exercised on a representative sample.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence


class _Strategy:
    """A deterministic example generator."""

    def __init__(self, fn: Callable[[int, int, int], Any]):
        self._fn = fn

    def example_at(self, i: int, n: int, salt: int) -> Any:
        return self._fn(i, n, salt)


def _integers(lo: int, hi: int) -> _Strategy:
    def gen(i, n, salt):
        if n <= 1:
            return lo
        idx = (i + salt) % n              # rotate per-parameter
        return lo + round(idx * (hi - lo) / (n - 1))
    return _Strategy(gen)


def _floats(lo: float, hi: float) -> _Strategy:
    def gen(i, n, salt):
        if n <= 1:
            return lo
        frac = i / (n - 1)
        if salt % 2:                      # decorrelate from other params
            frac = 1.0 - frac
        return lo + frac * (hi - lo)
    return _Strategy(gen)


def _sampled_from(seq: Sequence[Any]) -> _Strategy:
    items = list(seq)
    return _Strategy(lambda i, n, salt: items[(i + salt) % len(items)])


def _booleans() -> _Strategy:
    return _Strategy(lambda i, n, salt: bool((i + salt) % 2))


class _Strategies:
    integers = staticmethod(_integers)
    floats = staticmethod(_floats)
    sampled_from = staticmethod(_sampled_from)
    booleans = staticmethod(_booleans)


strategies = _Strategies()


def given(**strats: _Strategy):
    """Run the wrapped test once per grid point (``max_examples`` points)."""

    def deco(fn):
        names = sorted(strats)

        def wrapper():
            # @settings may sit above @given (tagging the wrapper) or below
            # it (tagging the raw test fn) — honour both orderings
            n = getattr(wrapper, "_max_examples",
                        getattr(fn, "_max_examples", 10))
            for i in range(n):
                kwargs = {k: strats[k].example_at(i, n, j)
                          for j, k in enumerate(names)}
                fn(**kwargs)

        # NOTE: no functools.wraps — pytest must see a zero-arg signature,
        # not the example parameters (it would look for fixtures for them).
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco


def settings(*, max_examples: int = 10, **_ignored):
    """Record ``max_examples`` on the ``given`` wrapper; other hypothesis
    settings (deadline, ...) have no meaning for the grid runner."""

    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco
