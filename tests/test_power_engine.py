"""Unified power engine: layered node→rack→cluster aggregation against
the published operating point, the telemetry recorder/trace round-trip,
and the simulate() driver's synthetic + replay modes."""
import numpy as np
import pytest

from repro.power import (ClusterModel, ConstantLoad, NodeModel,
                         OperatingPoint, PowerTrace, ReplayWorkload,
                         SyntheticHPL, TraceRecorder,
                         evaluate_operating_point, lcsc_cluster, lcsc_node,
                         simulate)
from repro.power.layers import LCSC_PSU


# -- layered aggregation ------------------------------------------------------

def test_node_composition_reproduces_published_wall_power():
    """host + 4×S9150 + fans behind the PSU curve → ~1021 W at the
    Green500 operating point (published: 57.2 kW / 56 nodes)."""
    op = OperatingPoint.green500()
    node = lcsc_node()
    comps = node.component_watts(op)
    assert set(comps) == {"gpu", "host", "fan", "psu_loss"}
    total = sum(comps.values())
    assert total == pytest.approx(node.power(op))
    assert abs(total - 1021.0) / 1021.0 < 0.02
    # every layer draws something, and the PSU really loses power
    assert all(w > 0 for w in comps.values())
    dc = comps["gpu"] + comps["host"] + comps["fan"]
    assert comps["psu_loss"] == pytest.approx(LCSC_PSU.loss_w(dc))


def test_psu_curve_shape():
    """Platinum-class: peak efficiency near half load, worse at idle and
    full load; wall power always exceeds DC power."""
    peak = LCSC_PSU.efficiency(LCSC_PSU.load_peak * LCSC_PSU.rated_w)
    assert peak == pytest.approx(LCSC_PSU.eff_peak)
    assert LCSC_PSU.efficiency(0.1 * LCSC_PSU.rated_w) < peak
    assert LCSC_PSU.efficiency(1.0 * LCSC_PSU.rated_w) < peak
    for dc in (100.0, 500.0, 958.0, 1600.0):
        assert LCSC_PSU.wall_power(dc) > dc


def test_cluster_aggregation_not_hardcoded():
    """Cluster watts = Σ racks = Σ nodes (+ switches), and scale with the
    node count — the 57.2 kW figure falls out of composition."""
    op = OperatingPoint.green500()
    cl = lcsc_cluster()
    assert cl.n_nodes == 56 and len(cl.racks) == 7
    node_sum = sum(n.power(op) for n in cl.nodes)
    rack_sum = sum(r.power(op) for r in cl.racks)
    assert node_sum == pytest.approx(rack_sum)
    assert cl.power(op) == pytest.approx(rack_sum + cl.network_w)
    assert abs(node_sum - 57.2e3) / 57.2e3 < 0.02
    # half the nodes -> half the compute power, same switches
    half = lcsc_cluster(28)
    assert half.power(op) - half.network_w == pytest.approx(node_sum / 2)


def test_cluster_efficiency_matches_paper():
    op = OperatingPoint.green500()
    perf, power = evaluate_operating_point(op)
    assert abs(perf / power * 1000.0 - 5271.8) / 5271.8 < 0.02


def test_load_scales_gpu_dynamic_power_only():
    op = OperatingPoint.green500()
    node = lcsc_node()
    full = node.component_watts(op, load=1.0)
    idle = node.component_watts(op, load=0.0)
    assert idle["gpu"] < full["gpu"]          # dynamic part collapsed
    assert idle["host"] == full["host"]       # host is static
    assert idle["fan"] == full["fan"]         # fan follows duty, not load


def test_heterogeneous_vids_raise_node_power():
    op = OperatingPoint.green500()
    best = NodeModel.from_vids([1.1425] * 4)
    worst = NodeModel.from_vids([1.2] * 4)
    assert worst.power(op) > best.power(op)


# -- recorder / trace ---------------------------------------------------------

def test_recorder_roundtrip_and_component_union():
    rec = TraceRecorder(source="test")
    rec.emit(0.0, {"gpu": 100.0}, flops_rate=10.0, util=1.0)
    rec.emit(1.0, {"gpu": 100.0, "fan": 20.0}, flops_rate=10.0, util=1.0)
    rec.emit(2.0, {"gpu": 50.0, "fan": 20.0}, flops_rate=5.0, util=0.5)
    tr = rec.trace()
    assert isinstance(tr, PowerTrace)
    assert set(tr.components) == {"gpu", "fan"}
    assert tr.components["fan"][0] == 0.0      # missing component reads 0
    assert tr.meta["source"] == "test"
    assert tr.duration == pytest.approx(2.0)
    # energy = ∫P dt: totals are [100, 120, 70] W at t = [0, 1, 2]
    assert tr.energy_j() == pytest.approx(110.0 + 95.0)
    assert tr.aux["util"][-1] == pytest.approx(0.5)


def test_recorder_fixed_interval_resampling():
    rec = TraceRecorder(dt_s=0.5)
    rec.emit(0.0, {"chip": 100.0})
    rec.emit(2.0, {"chip": 300.0})
    tr = rec.trace()
    assert np.allclose(np.diff(tr.t), 0.5)     # RAPS-style fixed interval
    assert tr.components["chip"][1] == pytest.approx(150.0)
    assert tr.meta["dt_s"] == 0.5


def test_recorder_empty_raises():
    with pytest.raises(ValueError):
        TraceRecorder().trace()


def test_trace_network_excluded_from_compute_power():
    tr = PowerTrace.from_arrays([0.0, 1.0], [100.0, 100.0], [1.0, 1.0],
                                network_w=7.0)
    assert np.allclose(tr.power_w, 100.0)
    assert tr.network_w == pytest.approx(7.0)
    assert tr.avg_power(include_network=True) == pytest.approx(107.0)
    assert tr.avg_power(include_network=False) == pytest.approx(100.0)


def test_trace_scaled():
    tr = PowerTrace.from_arrays([0.0, 1.0], [100.0, 100.0], [5.0, 5.0])
    big = tr.scaled(56.0)
    assert np.allclose(big.power_w, 5600.0)
    assert big.total_flops() == pytest.approx(tr.total_flops() * 56)


# -- simulate(): synthetic and replay modes -----------------------------------

def _small_cluster() -> ClusterModel:
    return lcsc_cluster(8, nodes_per_rack=4, network_w=40.0)


def test_simulate_synthetic_hpl_shape():
    op = OperatingPoint.green500()
    tr = simulate(SyntheticHPL(duration_s=600.0), op,
                  cluster=_small_cluster(), dt_s=10.0)
    p = tr.power_w
    assert p[0] == pytest.approx(p[len(p) // 2], rel=1e-6)  # flat core
    assert p[-1] < 0.8 * p[0]                  # trailing-matrix tail
    assert tr.meta["n_nodes"] == 8
    assert tr.meta["operating_point"]["f_mhz"] == 774.0
    # telemetry carries util/clock/temp series (RAPS-style)
    for key in ("util", "f_mhz", "temp_c", "fan"):
        assert key in tr.aux
    assert np.all(np.diff(tr.aux["util"]) <= 1e-12)   # load only decays


def test_simulate_constant_load_is_flat():
    tr = simulate(ConstantLoad(duration_s=100.0, level=1.0),
                  cluster=_small_cluster(), dt_s=10.0)
    assert np.ptp(tr.power_w) < 1e-9


def test_replay_mode_reproduces_synthetic_trace():
    """Record a synthetic run, replay its utilization series: the replay
    trace must reproduce the original power trajectory."""
    op = OperatingPoint.green500()
    cl = _small_cluster()
    original = simulate(SyntheticHPL(duration_s=600.0), op, cluster=cl,
                        dt_s=10.0)
    replay = ReplayWorkload.from_trace(original, key="util")
    again = simulate(replay, op, cluster=cl, dt_s=10.0)
    np.testing.assert_allclose(again.power_w, original.power_w, rtol=1e-6)


def test_replay_missing_series_raises():
    tr = PowerTrace.from_arrays([0.0, 1.0], [1.0, 1.0], [0.0, 0.0])
    with pytest.raises(KeyError):
        ReplayWorkload.from_trace(tr)


def test_simulate_honors_caller_supplied_empty_recorder():
    """An empty recorder is falsy (__len__ == 0) but still the caller's
    bus — simulate must emit into it, keeping its dt_s/source."""
    rec = TraceRecorder(dt_s=25.0, source="mine")
    tr = simulate(ConstantLoad(duration_s=100.0), cluster=_small_cluster(),
                  dt_s=10.0, recorder=rec)
    assert len(rec) > 0
    assert tr.meta["source"] == "mine"
    assert np.allclose(np.diff(tr.t), 25.0)    # caller's grid, not dt_s


def test_solver_energy_phases_stack_on_shared_recorder():
    """Two solves on one bus append sequentially; each report's energy is
    its own phase and the bus totals the sum."""
    from repro.core.energy.solver_energy import solver_energy
    rec = TraceRecorder(source="solves")
    r1 = solver_energy("a", 4 ** 4, 10, recorder=rec)
    r2 = solver_energy("b", 4 ** 4, 30, recorder=rec)
    assert len(rec) == 4
    assert r2.trace.t[-1] == pytest.approx(r1.time_s + r2.time_s)
    assert r1.energy_j == pytest.approx(r1.time_s * 275.0)
    assert r2.energy_j == pytest.approx(r2.time_s * 275.0)
    assert rec.trace().energy_j() == pytest.approx(
        r1.energy_j + r2.energy_j)
