"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run`` prints ``name,us_per_call,derived`` CSV rows
and asserts the paper-claim reproductions.
"""
