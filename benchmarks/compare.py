"""Benchmark perf-regression gate: compare a ``benchmarks.run --json``
summary against the committed baseline.

Two regression classes, each reported as a machine-readable
``REGRESSION:<table>:<detail>`` line on stdout (CI greps for the
prefix; the exit code gates the job):

* **wall time** — a table's ``seconds`` exceeding ``--time-factor``
  (default 2.5×) of the baseline.  Sub-``MIN_BASE_SECONDS`` baselines
  are floored first so micro-tables can't trip the gate on noise.
* **gated values** — a numeric field in a row's ``derived`` string
  (``k=v;...``) drifting beyond ``--rel-tol`` from the baseline, a
  baseline row/table missing from the current run, or a table that
  errored.  Timing-derived fields (measured GFLOPS, wall seconds,
  speedups, per-call latencies) are exempt — they are what the *time*
  gate covers; the value gate pins the deterministic model-derived
  numbers the paper-claims asserts gate on.

New tables/rows in the current run are fine (that's how benches land).

Usage::

    python -m benchmarks.compare BASELINE CURRENT [--report PATH]
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from typing import Dict, List, Optional, Tuple

# Baselines shorter than this are all harness noise; the time gate
# compares against max(baseline, floor).
MIN_BASE_SECONDS = 0.05

# derived-string fields that restate measured wall time / throughput and
# therefore vary run to run: the time gate owns these, not the value gate
_SKIP_KEYS = re.compile(
    r"(_s$|^us_|_us$|^speedup$|gflops|^tuned$|^ref$|^best_us$|^wall)")

# numeric token: int/float/scientific, optional %, possibly prefixed with
# non-numeric unit text being part of the value (e.g. "57.13kW" keeps 57.13)
_NUM = re.compile(r"^[-+]?\d+\.?\d*(?:[eE][-+]?\d+)?%?$")


def parse_derived(derived: str) -> Dict[str, str]:
    """``"kw=57.13;paper=57.2;clocks=774+900"`` → field dict.  Fields
    without ``=`` (rare) are ignored."""
    out: Dict[str, str] = {}
    for part in derived.split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k.strip()] = v.strip()
    return out


def _as_number(v: str) -> Optional[float]:
    if not _NUM.match(v):
        return None
    return float(v[:-1]) / 100.0 if v.endswith("%") else float(v)


def compare_derived(base: str, cur: str, rel_tol: float) -> List[str]:
    """Field-level drift between two derived strings; returns problem
    descriptions (empty = within tolerance)."""
    problems: List[str] = []
    bf, cf = parse_derived(base), parse_derived(cur)
    for key, bval in bf.items():
        if _SKIP_KEYS.search(key):
            continue
        if key not in cf:
            problems.append(f"field {key!r} disappeared")
            continue
        bnum, cnum = _as_number(bval), _as_number(cf[key])
        if bnum is None or cnum is None:
            if bval != cf[key]:
                problems.append(f"{key}={cf[key]!r} (baseline {bval!r})")
            continue
        scale = max(abs(bnum), 1e-12)
        if abs(cnum - bnum) / scale > rel_tol:
            problems.append(f"{key}={cnum:g} drifted from baseline "
                            f"{bnum:g} (>{rel_tol:.0%})")
    return problems


def compare(baseline: dict, current: dict, *, time_factor: float = 2.5,
            rel_tol: float = 0.01) -> Tuple[List[str], dict]:
    """All regressions of ``current`` against ``baseline`` as
    ``REGRESSION:<table>:<detail>`` lines, plus a report dict."""
    regressions: List[str] = []
    report: dict = {"tables": {}, "time_factor": time_factor,
                    "rel_tol": rel_tol}

    def flag(table: str, detail: str) -> None:
        regressions.append(f"REGRESSION:{table}:{detail}")

    for table, base in sorted(baseline.items()):
        entry: dict = {"status": "ok"}
        report["tables"][table] = entry
        cur = current.get(table)
        if cur is None:
            entry["status"] = "missing"
            flag(table, "table missing from current run")
            continue
        if "error" in cur:
            entry["status"] = "error"
            flag(table, f"errored: {cur['error']}")
            continue
        if "error" in base:          # baseline must never carry failures
            entry["status"] = "bad-baseline"
            flag(table, "baseline recorded an error for this table — "
                        "regenerate the baseline")
            continue

        base_s = max(float(base.get("seconds", 0.0)), MIN_BASE_SECONDS)
        cur_s = float(cur.get("seconds", 0.0))
        entry["seconds"] = {"baseline": base_s, "current": cur_s}
        if cur_s > time_factor * base_s:
            entry["status"] = "slow"
            flag(table, f"time {cur_s:.3f}s > {time_factor:g}x baseline "
                        f"{base_s:.3f}s")

        cur_rows = cur.get("value", {})
        for row, bdata in base.get("value", {}).items():
            cdata = cur_rows.get(row)
            if cdata is None:
                entry["status"] = "drift"
                flag(table, f"row {row!r} missing from current run")
                continue
            for problem in compare_derived(bdata.get("derived", ""),
                                           cdata.get("derived", ""),
                                           rel_tol):
                entry["status"] = "drift"
                flag(table, f"{row}: {problem}")
    return regressions, report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("current", help="fresh benchmarks.run --json output")
    ap.add_argument("--time-factor", type=float, default=2.5,
                    help="wall-time regression threshold (default 2.5x)")
    ap.add_argument("--rel-tol", type=float, default=0.01,
                    help="relative drift tolerance for gated numeric "
                         "fields (default 1%%)")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="write the comparison report JSON here")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)

    regressions, report = compare(baseline, current,
                                  time_factor=args.time_factor,
                                  rel_tol=args.rel_tol)
    report["regressions"] = regressions
    if args.report:
        with open(args.report, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
    for line in regressions:
        print(line)
    if regressions:
        print(f"# {len(regressions)} regression(s) against {args.baseline}",
              file=sys.stderr)
        return 1
    print(f"# no regressions: {len(baseline)} baseline tables within "
          f"{args.time_factor:g}x time / {args.rel_tol:.0%} value drift")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
