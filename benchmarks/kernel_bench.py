"""Kernel micro-benchmarks (interpret mode on CPU; TPU is the target).

Timing numbers on CPU measure the *oracle path* (jnp) for throughput
context; the Pallas kernels are validated for correctness and their TPU
roofline expectations derived analytically.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

Row = Tuple[str, float, str]


def _timeit(fn, reps: int = 3) -> float:
    fn()
    t0 = time.time()
    for _ in range(reps):
        fn()
    return (time.time() - t0) / reps * 1e6


def dgemm_bench() -> List[Row]:
    from repro.kernels.dgemm import dgemm_ref
    from repro.roofline import hw
    rows: List[Row] = []
    for n in (512, 1024):
        x = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32)
        y = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.float32)
        f = jax.jit(dgemm_ref)
        us = _timeit(lambda: jax.block_until_ready(f(x, y)))
        fl = 2 * n ** 3
        tpu_us = fl / hw.PEAK_BF16_FLOPS * 1e6
        rows.append((f"dgemm/{n}", us,
                     f"cpu_gflops={fl/us/1e3:.1f};tpu_roofline_us={tpu_us:.1f}"))
    return rows


def rmsnorm_bench() -> List[Row]:
    from repro.kernels.rmsnorm import rmsnorm_ref
    from repro.roofline import hw
    rows: List[Row] = []
    rows_n, d = 4096, 4096
    x = jax.random.normal(jax.random.PRNGKey(0), (rows_n, d), jnp.bfloat16)
    w = jnp.ones((d,), jnp.bfloat16)
    f = jax.jit(rmsnorm_ref)
    us = _timeit(lambda: jax.block_until_ready(f(x, w)))
    by = rows_n * d * 2 * 2
    rows.append((f"rmsnorm/{rows_n}x{d}", us,
                 f"tpu_bw_bound_us={by/hw.HBM_BW*1e6:.1f}"))
    return rows


def attention_bench() -> List[Row]:
    from repro.models.attention import blockwise_attention
    rows: List[Row] = []
    B, S, H, dh = 1, 1024, 8, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, dh), jnp.float32)
    for skip in (False, True):
        f = jax.jit(lambda q, k, v, s=skip: blockwise_attention(
            q, k, v, causal=True, q_chunk=128, kv_chunk=128, block_skip=s))
        us = _timeit(lambda: jax.block_until_ready(f(q, k, v)))
        rows.append((f"attention/block_skip={skip}", us,
                     f"S={S};H={H}"))
    return rows
