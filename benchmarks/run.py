"""Benchmark harness: one function per paper table/figure + kernel benches.

Prints ``name,us_per_call,derived`` CSV and fails if any published-number
reproduction is out of tolerance.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import kernel_bench, paper_tables

    benches = [
        paper_tables.table1_nodes,
        paper_tables.fig1a_perf_vs_voltage,
        paper_tables.fig1b_power,
        paper_tables.hpl_modes,
        paper_tables.green500_levels,
        paper_tables.result_efficiency,
        paper_tables.dslash_bw,
        paper_tables.cg_energy_to_solution,
        kernel_bench.dgemm_bench,
        kernel_bench.rmsnorm_bench,
        kernel_bench.attention_bench,
    ]
    print("name,us_per_call,derived")
    failed = []
    for bench in benches:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # noqa: BLE001
            failed.append((bench.__name__, e))
            traceback.print_exc()
    if failed:
        print(f"FAILED: {[n for n, _ in failed]}", file=sys.stderr)
        raise SystemExit(1)
    print("# all paper-claim reproductions within tolerance")


if __name__ == "__main__":
    main()
