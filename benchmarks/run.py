"""Benchmark harness: one function per paper table/figure + kernel benches.

Prints ``name,us_per_call,derived`` CSV on stdout.  Failures are
reported to **stderr** as they happen — a traceback followed by a
machine-readable ``FAILED:<bench_name>:<error>`` line — and the process
exits non-zero, so CI can gate on ``FAILED:`` without parsing the CSV
(stdout stays clean CSV either way).

Every table is timed: per-table wall time goes to stderr as
``TIME:<bench_name>:<seconds>`` lines, and the whole run is summarized
in a machine-readable JSON file (``--json``, default
``BENCH_cluster.json``) mapping table → ``{value, seconds}`` — the
bench-smoke CI job uploads it next to the CSV artifact.

Usage::

    python -m benchmarks.run [--only SUBSTR] [--list] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

# the multi-chip benches need a device mesh; force the virtual-device
# flag (and CPU backend) before any bench imports jax — the CI
# bench-smoke leg only sets JAX_PLATFORMS
os.environ.setdefault("JAX_PLATFORMS", "cpu")
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()


def benches():
    from benchmarks import kernel_bench, paper_tables

    return [
        paper_tables.table1_nodes,
        paper_tables.fig1a_perf_vs_voltage,
        paper_tables.fig1b_power,
        paper_tables.hpl_modes,
        paper_tables.green500_levels,
        paper_tables.cluster_power_trace,
        paper_tables.result_efficiency,
        paper_tables.dslash_bw,
        paper_tables.dslash_multichip,
        paper_tables.autotune_operating_point,
        paper_tables.cluster_schedule,
        paper_tables.cluster_scale,
        paper_tables.cluster_online,
        paper_tables.cluster_hetero,
        paper_tables.serve_replay,
        paper_tables.cluster_resilience,
        paper_tables.cg_energy_to_solution,
        kernel_bench.dgemm_bench,
        kernel_bench.rmsnorm_bench,
        kernel_bench.attention_bench,
    ]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default="",
                    help="run only benches whose name contains SUBSTR")
    ap.add_argument("--list", action="store_true",
                    help="print registered bench names (the values --only "
                         "filters against) and exit")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the machine-readable per-table summary "
                         "(table -> {value, seconds}) here; '' disables. "
                         "Default: BENCH_cluster.json on full runs, "
                         "disabled under --only (a partial run must not "
                         "overwrite the full-suite summary)")
    args = ap.parse_args(argv)
    json_path = args.json
    if json_path is None:
        json_path = "" if args.only else "BENCH_cluster.json"

    if args.list:
        for b in benches():
            print(b.__name__)
        return

    selected = [b for b in benches() if args.only in b.__name__]
    if not selected:
        print(f"FAILED:run:no bench matches {args.only!r}", file=sys.stderr)
        raise SystemExit(2)

    print("name,us_per_call,derived", flush=True)
    report = {}
    failed = []
    for bench in selected:
        t0 = time.perf_counter()
        try:
            rows = bench()
        except Exception as e:  # noqa: BLE001 — report and keep going
            secs = time.perf_counter() - t0
            failed.append(bench.__name__)
            traceback.print_exc()
            msg = str(e).split("\n")[0][:200]
            print(f"FAILED:{bench.__name__}:{msg}", file=sys.stderr,
                  flush=True)
            print(f"TIME:{bench.__name__}:{secs:.3f}", file=sys.stderr,
                  flush=True)
            report[bench.__name__] = {"error": msg, "seconds": secs}
            continue
        secs = time.perf_counter() - t0
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}", flush=True)
        print(f"TIME:{bench.__name__}:{secs:.3f}", file=sys.stderr,
              flush=True)
        report[bench.__name__] = {
            "value": {name: {"us_per_call": us, "derived": derived}
                      for name, us, derived in rows},
            "seconds": secs}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
    if failed:
        print(f"FAILED:summary:{len(failed)} benches failed "
              f"({' '.join(failed)})", file=sys.stderr, flush=True)
        raise SystemExit(1)
    print("# all paper-claim reproductions within tolerance")


if __name__ == "__main__":
    main()
