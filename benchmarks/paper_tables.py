"""Paper tables/figures as benchmark functions.

Each function returns a list of (name, us_per_call, derived) CSV rows and
raises AssertionError if a published number is not reproduced within
tolerance — these are the paper-claims validation gates.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

Row = Tuple[str, float, str]


def _timeit(fn, reps: int = 3) -> float:
    fn()
    t0 = time.time()
    for _ in range(reps):
        fn()
    return (time.time() - t0) / reps * 1e6


# -- Table 1: LOEWE-CSC / Sanam / L-CSC node trend ---------------------------

def table1_nodes() -> List[Row]:
    from repro.configs.lcsc_lqcd import L_CSC, LOEWE_CSC, SANAM
    rows: List[Row] = []
    for node in (LOEWE_CSC, SANAM, L_CSC):
        derived = (f"gpus={node.gpus};bw={node.gpu_peak_bandwidth_gbs}GB/s;"
                   f"peak={node.peak_fp64_gflops}GF")
        rows.append((f"table1/{node.name}", 0.0, derived))
    # the published trend: each generation raises node bandwidth & peak
    assert (LOEWE_CSC.gpu_peak_bandwidth_gbs < SANAM.gpu_peak_bandwidth_gbs
            < L_CSC.gpu_peak_bandwidth_gbs)
    assert L_CSC.peak_fp64_gflops / LOEWE_CSC.peak_fp64_gflops > 10
    return rows


# -- Fig 1a: DGEMM / HPL performance vs voltage -------------------------------

def fig1a_perf_vs_voltage() -> List[Row]:
    from repro.power.model import V_MAX, V_MIN
    from repro.core.energy.throttle import dgemm_perf_gflops, hpl_node_perf
    rows: List[Row] = []
    for v in np.linspace(V_MIN, V_MAX, 5):
        d900 = dgemm_perf_gflops(900, v)
        d774 = dgemm_perf_gflops(774, v)
        h900 = hpl_node_perf(900, [v] * 4)
        h774 = hpl_node_perf(774, [v] * 4)
        rows.append((f"fig1a/v={v:.4f}", 0.0,
                     f"dgemm900={d900:.0f};dgemm774={d774:.0f};"
                     f"hpl900={h900:.0f};hpl774={h774:.0f}"))
    # published anchors
    assert abs(dgemm_perf_gflops(900, V_MIN) - 1250) < 30
    assert 950 <= dgemm_perf_gflops(900, V_MAX) <= 1100
    assert abs(hpl_node_perf(900, [V_MIN] * 4) - 6280) < 70
    assert abs(hpl_node_perf(900, [V_MAX] * 4) - 6175) < 70
    # flat profile at 774 MHz
    p774 = [dgemm_perf_gflops(774, v) for v in np.linspace(V_MIN, V_MAX, 7)]
    assert max(p774) - min(p774) < 1.0
    return rows


# -- Fig 1b: power vs fan / voltage / temperature -----------------------------

def fig1b_power() -> List[Row]:
    from repro.power import V_MIN, fan_power, gpu_power, node_power
    rows: List[Row] = []
    for s in (0.2, 0.4, 0.6, 0.8, 1.0):
        rows.append((f"fig1b/fan={s:.1f}", 0.0, f"W={fan_power(s):.1f}"))
    for t in (45, 55, 65, 75):
        p = gpu_power(774, V_MIN, temp_c=t)
        rows.append((f"fig1b/temp={t}C", 0.0, f"gpuW={p:.1f}"))
    for v in (1.1425, 1.17, 1.2):
        p = node_power(774, [v] * 4)
        rows.append((f"fig1b/vid={v}", 0.0, f"nodeW={p:.1f}"))
    # shape checks: steeper above 40% fan; power increases with V and T
    assert (fan_power(0.6) - fan_power(0.5)) > (fan_power(0.4)
                                                - fan_power(0.3))
    assert gpu_power(774, 1.2) > gpu_power(774, V_MIN)
    assert gpu_power(774, V_MIN, temp_c=75) > gpu_power(774, V_MIN,
                                                        temp_c=45)
    return rows


# -- §2: HPL efficiency mode (real LU runs) -----------------------------------

def hpl_modes() -> List[Row]:
    from repro.config import EnergyConfig
    from repro.configs.hpl import HPLConfig
    from repro.hpl import linpack_run
    rows: List[Row] = []
    base = HPLConfig(n=256, block=64)
    perf = linpack_run(base, energy=EnergyConfig(mode="performance"))
    eff = linpack_run(base.efficiency(),
                      energy=EnergyConfig(mode="efficiency",
                                          max_perf_loss=0.05))
    assert perf.passed and eff.passed
    rows.append(("hpl/performance", perf.wall_s * 1e6,
                 f"gflops={perf.gflops:.2f};resid={perf.residual:.3f};"
                 f"freq={perf.energy_plan['freq_scale']:.2f}"))
    rows.append(("hpl/efficiency", eff.wall_s * 1e6,
                 f"gflops={eff.gflops:.2f};resid={eff.residual:.3f};"
                 f"freq={eff.energy_plan['freq_scale']:.2f};"
                 f"energy_j={eff.energy_plan['energy_per_run_j']:.2e}"))
    # apples-to-apples plan comparison on the SAME workload: the efficiency
    # plan derates the clock -> lower power (paper: trade a small perf
    # fraction for better net MFLOPS/W)
    eff_same = linpack_run(base, energy=EnergyConfig(mode="efficiency",
                                                     max_perf_loss=0.05))
    assert (eff_same.energy_plan["freq_scale"]
            <= perf.energy_plan["freq_scale"] + 1e-9)
    assert (eff_same.energy_plan["power_w"]
            <= perf.energy_plan["power_w"] + 1e-9)
    assert eff_same.energy_plan["perf_loss"] <= 0.05
    return rows


# -- §3: Green500 measurement levels ------------------------------------------

def green500_levels() -> List[Row]:
    from repro.core.energy import (level1_exploit, linpack_power_trace,
                                   measure_efficiency)
    from repro.power.green500 import (extrapolation_error,
                                     node_efficiencies)
    rows: List[Row] = []
    tr = linpack_power_trace(56, 1021.0, 5384.0, duration_s=1800.0)
    for lvl in (1, 2, 3):
        r = measure_efficiency(tr, lvl)
        rows.append((f"green500/level{lvl}", 0.0,
                     f"mflops_w={r.mflops_per_w:.1f};power={r.avg_power_w:.0f}"))
    ex = level1_exploit(tr)
    l3 = measure_efficiency(tr, 3)
    over = ex.mflops_per_w / l3.mflops_per_w - 1
    rows.append(("green500/l1_exploit", 0.0,
                 f"mflops_w={ex.mflops_per_w:.1f};overestimate={over:.1%}"))
    assert 0.10 < over < 0.45          # paper: up to ~30%
    rng = np.random.default_rng(0)
    effs = node_efficiencies(rng, 7)
    rows.append(("green500/variability", 0.0,
                 f"spread={np.ptp(effs)/effs.mean():.3%};"
                 f"median_err={extrapolation_error(effs):.3%}"))
    assert extrapolation_error(effs) < 0.01    # paper: <1% off level-3
    return rows


# -- §3–4: the composed node→rack→cluster power stack -------------------------

def cluster_power_trace() -> List[Row]:
    """The headline numbers must fall out of *aggregation*: GPU → node
    (host + 4×S9150 + fans + PSU curve) → rack → cluster (+ switches),
    driven through the telemetry engine — ~1021 W/node, 57.2 kW and
    5271.8 MFLOPS/W within 2%, with every layer accounted."""
    from repro.power import (OperatingPoint, SyntheticHPL, lcsc_cluster,
                             measure_efficiency, node_hpl_gflops, simulate)

    op = OperatingPoint.green500()
    cluster = lcsc_cluster()                       # 56 nodes, racks of 8
    assert cluster.n_nodes == 56 and len(cluster.racks) == 7

    # steady-state composition (load=1): the published operating point
    comps = cluster.component_watts(op)
    compute_w = sum(w for k, w in comps.items() if k != "network")
    node_w = compute_w / cluster.n_nodes
    perf = node_hpl_gflops(op) * cluster.n_nodes
    eff = perf / compute_w * 1000.0
    assert abs(node_w - 1021.0) / 1021.0 < 0.02        # ~1021 W/node
    assert abs(compute_w - 57.2e3) / 57.2e3 < 0.02     # 57.2 kW cluster
    assert abs(eff - 5271.8) / 5271.8 < 0.02           # 5271.8 MFLOPS/W
    # the layers are really there: PSU loss and switches are accounted
    assert comps["psu_loss"] > 0.0
    assert comps["network"] == 257.0
    # rack layer sums to the cluster (aggregation, not hard-coding)
    rack_sum = sum(r.power(op) for r in cluster.racks)
    assert abs(rack_sum + comps["network"]
               - cluster.power(op)) < 1e-6

    # the time-stepped trace through the engine: full-load core phase
    # reproduces the same figures; Level 3 covers the whole run
    t0 = time.time()
    tr = simulate(SyntheticHPL(duration_s=1800.0), op, cluster=cluster)
    sim_us = (time.time() - t0) * 1e6
    core = tr.t < 0.70 * tr.duration                   # pre-tail samples
    p_core = float(np.mean(tr.power_w[core]))
    assert abs(p_core - 57.2e3) / 57.2e3 < 0.02
    l3 = measure_efficiency(tr, 3)
    assert l3.avg_power_w < p_core + 257.0             # tail derates power

    rows: List[Row] = []
    rows.append(("power/node_composed", 0.0,
                 f"W={node_w:.1f};gpu={comps['gpu']/56:.1f};"
                 f"host={comps['host']/56:.1f};fan={comps['fan']/56:.1f};"
                 f"psu_loss={comps['psu_loss']/56:.1f}"))
    rows.append(("power/cluster_composed", 0.0,
                 f"kw={compute_w/1000:.2f};racks={len(cluster.racks)};"
                 f"network_w={comps['network']:.0f};"
                 f"mflops_w={eff:.1f};paper=5271.8"))
    rows.append(("power/cluster_trace", sim_us,
                 f"samples={len(tr.t)};core_kw={p_core/1000:.2f};"
                 f"l3_mflops_w={l3.mflops_per_w:.1f};"
                 f"energy_mj={tr.energy_j()/1e6:.1f}"))
    return rows


# -- §4: final result ---------------------------------------------------------

def result_efficiency() -> List[Row]:
    from repro.power import V_MIN, node_power
    from repro.core.energy.throttle import (HPL_GPU_UTIL,
                                            gpu_power_throttled,
                                            hpl_node_perf)
    perf56 = hpl_node_perf(774, [V_MIN] * 4) * 56
    pw = [gpu_power_throttled(774, V_MIN, util=HPL_GPU_UTIL)] * 4
    power56 = node_power(774, [V_MIN] * 4, gpu_clamped_w=pw) * 56
    eff = perf56 / power56 * 1000
    assert abs(perf56 - 301.5e3) / 301.5e3 < 0.012   # 301.5 TFLOPS
    assert abs(power56 - 57.2e3) / 57.2e3 < 0.012    # 57.2 kW
    assert abs(eff - 5271.8) / 5271.8 < 0.012        # 5271.8 MFLOPS/W
    return [("result/56_nodes", 0.0,
             f"tflops={perf56/1000:.1f};kw={power56/1000:.2f};"
             f"mflops_w={eff:.1f}")]


# -- §4: D-slash efficiency sensitivity (<1.5% at efficiency clocks) ----------

def dslash_bw() -> List[Row]:
    import jax
    import jax.numpy as jnp
    from repro.config import EnergyConfig
    from repro.configs.lcsc_lqcd import (DSLASH_BW_FRACTION,
                                         DSLASH_GFLOPS_PER_S9150,
                                         MULTI_GPU_SLOWDOWN, S9150_BW_GBS)
    from repro.core.energy.dvfs import plan_frequency
    from repro.lqcd import (dslash, dslash_bytes_per_site,
                            dslash_flops_per_site, random_su3_field)
    from repro.roofline import hw

    rows: List[Row] = []
    # wall-clock of the jnp reference on a small thermal lattice (CPU)
    lat = (8, 8, 8, 8)
    U = random_su3_field(jax.random.PRNGKey(0), lat)
    kr, ki = jax.random.split(jax.random.PRNGKey(1))
    psi = (jax.random.normal(kr, lat + (4, 3))
           + 1j * jax.random.normal(ki, lat + (4, 3))).astype(jnp.complex64)
    f = jax.jit(dslash)
    us = _timeit(lambda: jax.block_until_ready(f(U, psi)))
    vol = int(np.prod(lat))
    rows.append(("dslash/jnp_8x8x8x8", us,
                 f"gflops={vol*dslash_flops_per_site()/us/1e3:.2f}"))

    # S9150 bandwidth model: published ~135 GFLOPS at 80% of 320 GB/s
    # (fp64 with CL2QCD's 8-real gauge compression)
    ai = dslash_flops_per_site() / dslash_bytes_per_site(8)
    pred = ai * S9150_BW_GBS * DSLASH_BW_FRACTION
    rows.append(("dslash/s9150_model", 0.0,
                 f"pred_gflops={pred:.0f};paper={DSLASH_GFLOPS_PER_S9150}"))
    assert abs(pred - DSLASH_GFLOPS_PER_S9150) / DSLASH_GFLOPS_PER_S9150 \
        < 0.05

    # multi-chip halo model: T-axis sharding moves 2 boundary spinor slices
    # per chip per application over ICI; published single->multi ~20% loss
    # (PCIe-era). On TPU ICI the predicted loss is smaller — both reported.
    bytes_site = dslash_bytes_per_site(8)
    t_local = 8
    compute_s = bytes_site / (S9150_BW_GBS * 1e9 * DSLASH_BW_FRACTION)
    halo_s = (2 / t_local) * (24 * 8) / 14e9        # PCIe gen3 eff ~14 GB/s
    loss_pcie = halo_s / (compute_s + halo_s)
    halo_tpu = (2 / t_local) * (24 * 8) / hw.ICI_LINK_BW
    compute_tpu = bytes_site / (hw.HBM_BW * DSLASH_BW_FRACTION)
    loss_tpu = halo_tpu / (compute_tpu + halo_tpu)
    rows.append(("dslash/multichip_loss", 0.0,
                 f"pcie={loss_pcie:.1%};tpu_ici={loss_tpu:.1%};"
                 f"paper={MULTI_GPU_SLOWDOWN:.0%}"))
    assert 0.10 < loss_pcie < 0.35                   # ~20% published

    # DVFS derate: memory-bound D-slash loses <1.5% at efficiency clocks
    plan = plan_frequency(0.25, 1.0, 0.0, flops_per_step=1e12,
                          cfg=EnergyConfig(mode="efficiency"))
    rows.append(("dslash/dvfs_derate", 0.0,
                 f"freq={plan.freq_scale:.2f};loss={plan.perf_loss:.3%}"))
    assert plan.perf_loss <= 0.015                   # paper: <1.5%
    return rows


# -- §2–4: the operating-point search itself ----------------------------------

def autotune_operating_point() -> List[Row]:
    """The record was *found*, not configured: the analytic searcher must
    rediscover the paper's published operating point — 774 MHz, minimal
    voltage ID, 40% fan duty, efficiency-mode HPL blocking — from the
    calibrated power/throttle models alone, within tolerance."""
    from repro.autotune import (NB_EFFICIENCY, tune_operating_point)
    from repro.power.model import V_MIN

    t0 = time.time()
    res = tune_operating_point()                  # exhaustive analytic grid
    grid_us = (time.time() - t0) * 1e6
    best = res.best.point
    # published operating point (§2–4)
    assert best["f_mhz"] == 774.0, best
    assert best["vid"] == V_MIN, best             # undervolt to the floor
    assert abs(best["fan"] - 0.40) <= 0.051, best # Fig. 1b optimum duty
    assert best["nb"] == NB_EFFICIENCY, best      # efficiency-mode blocking
    # published efficiency and the ~13–15% Linpack trade
    assert abs(res.best.mflops_per_w - 5271.8) / 5271.8 < 0.02
    assert 0.10 < res.perf_loss < 0.16

    t0 = time.time()
    cd = tune_operating_point(method="coordinate")
    cd_us = (time.time() - t0) * 1e6
    # coordinate descent reaches the same point at a fraction of the evals
    assert cd.best.point == best, cd.best.point
    assert cd.evaluations < res.evaluations / 5

    rows: List[Row] = []
    rows.append(("autotune/grid", grid_us,
                 f"f={best['f_mhz']:.0f}MHz;vid={best['vid']};"
                 f"fan={best['fan']:.2f};nb={best['nb']};"
                 f"la={best['lookahead']}"))
    rows.append(("autotune/efficiency", 0.0,
                 f"mflops_w={res.best.mflops_per_w:.1f};paper=5271.8;"
                 f"perf_loss={res.perf_loss:.1%}"))
    rows.append(("autotune/coordinate_descent", cd_us,
                 f"evals={cd.evaluations};grid_evals={res.evaluations};"
                 f"same_point={cd.best.point == best}"))
    return rows


# -- §1–2: the Workload API + power-aware cluster scheduler -------------------

def cluster_schedule() -> List[Row]:
    """The paper operates L-CSC as a *cluster*: independent lattices
    packed one-per-GPU, multi-node HPL paced by its slowest node, every
    placement judged by MFLOPS/W.  The scheduled batch must reproduce
    the published cluster power (57.2 kW within 2%) by *composition* —
    scheduler placements driven through the PR-3 power layers — and
    chip-local packing must beat naive round-robin sharding on MFLOPS/W
    at the 774 MHz optimum."""
    from repro.cluster import (ClusterTopology, HPLWorkload, Job,
                               LQCDSolveWorkload, ServeWorkload,
                               SyntheticWorkload, TrainWorkload, run)
    from repro.power import OperatingPoint, PowerTrace

    rows: List[Row] = []

    # every workload adapter runs through cluster.run() and returns a
    # WorkloadResult carrying a PowerTrace from the telemetry bus
    adapters = [HPLWorkload(), LQCDSolveWorkload(), TrainWorkload(),
                ServeWorkload(), SyntheticWorkload()]
    t0 = time.time()
    mixed = run(adapters, topology=ClusterTopology(n_nodes=2), dt_s=60.0)
    mixed_us = (time.time() - t0) * 1e6
    assert len(mixed.results) == len(adapters)
    assert all(isinstance(r.power_trace, PowerTrace)
               for r in mixed.results)
    assert all(r.energy_j > 0 for r in mixed.results)
    rows.append(("cluster/adapters", mixed_us,
                 "kinds=" + "+".join(r.kind for r in mixed.results)))

    # the Green500 batch: one lattice-sized job per GPU on the 56-node
    # run topology, chip-local packing at the published operating point
    top = ClusterTopology(n_nodes=56)
    op = OperatingPoint.green500()
    jobs = [Job(f"lat{i}", 13.0, 1800.0) for i in range(top.n_chips)]
    t0 = time.time()
    packed = run(jobs, policy="packed", topology=top, op=op, dt_s=30.0)
    packed_us = (time.time() - t0) * 1e6
    assert all(not p.sharded for p in packed.schedule.placements)
    p_core = float(np.mean(packed.trace.power_w))
    assert abs(p_core - 57.2e3) / 57.2e3 < 0.02      # 57.2 kW by composition
    eff_packed = packed.efficiency(3).mflops_per_w

    # naive baseline: shard everything node-wide, pay the ~20% penalty
    rr = run(jobs, policy="round_robin", topology=top, op=op, dt_s=30.0)
    assert all(p.sharded for p in rr.schedule.placements)
    eff_rr = rr.efficiency(3).mflops_per_w
    assert eff_packed > eff_rr                       # packing wins MFLOPS/W
    assert rr.makespan > packed.makespan             # and wall-clock

    # the 774 MHz operating point beats stock 900 MHz on efficiency
    stock = run(jobs, policy="packed", topology=top,
                op=OperatingPoint(f_mhz=900.0), dt_s=30.0)
    eff_stock = stock.efficiency(3).mflops_per_w
    assert eff_packed > eff_stock

    # a cluster power cap is met by derating down the DPM ladder; the
    # cap covers wall power including the switches
    capped = run(jobs, policy="packed", topology=top, op=op, dt_s=30.0,
                 power_cap_w=50e3)
    assert capped.schedule.derated and capped.op.f_mhz < op.f_mhz
    assert float(np.max(capped.trace.power_w)) \
        + capped.trace.network_w <= 50e3

    rows.append(("cluster/packed_56", packed_us,
                 f"kw={p_core/1000:.2f};paper=57.2;"
                 f"mflops_w={eff_packed:.1f};makespan={packed.makespan:.0f}"))
    rows.append(("cluster/round_robin_56", 0.0,
                 f"mflops_w={eff_rr:.1f};makespan={rr.makespan:.0f};"
                 f"packed_gain={eff_packed / eff_rr - 1:.1%}"))
    rows.append(("cluster/op_774_vs_900", 0.0,
                 f"eff774={eff_packed:.1f};eff900={eff_stock:.1f}"))
    rows.append(("cluster/power_cap_50kw", 0.0,
                 f"f_mhz={capped.op.f_mhz:.0f};"
                 f"kw={float(np.max(capped.trace.power_w))/1000:.2f}"))
    return rows


# -- §1: the full L-CSC at scale through the interval-driven engine -----------

def cluster_scale() -> List[Row]:
    """The production topology, not just the Green500 subset: 160 nodes /
    640 GPUs with a 1000+-job mixed batch through the vectorized
    interval-driven merge.  Two gates: (1) *exactness* — on the 56-node
    Green500 batch the vectorized trace must match the per-tick loop
    oracle bit-for-bit, with a measured ≥20× wall-time speedup; (2)
    *scale* — the full machine with 1200 mixed jobs must evaluate in
    interactive time and still compose the same per-node physics."""
    from repro.cluster import ClusterTopology, Job, run
    from repro.cluster.run import (_merged_trace, _merged_trace_reference)
    from repro.cluster.scheduler import Scheduler
    from repro.power import OperatingPoint
    from repro.power.layers import NodeModel

    op = OperatingPoint.green500()
    rows: List[Row] = []

    # -- 56-node Green500 batch: vectorized vs loop oracle, timed ------------
    top56 = ClusterTopology(n_nodes=56)
    jobs56 = [Job(f"lat{i}", 13.0, 1800.0) for i in range(top56.n_chips)]
    sch56 = Scheduler(top56).schedule(jobs56, op=op)
    sch56.meta["policy"] = "packed"

    t0 = time.perf_counter()
    ref = _merged_trace_reference(sch56, dt_s=5.0, network_w=257.0)
    ref_s = time.perf_counter() - t0
    vec_s = min(_timed(lambda: _merged_trace(sch56, dt_s=5.0,
                                             network_w=257.0))
                for _ in range(3))
    vec = _merged_trace(sch56, dt_s=5.0, network_w=257.0)

    # sample-for-sample, bit-level: same grid, same watts, same flops
    assert np.array_equal(vec.t, ref.t)
    assert sorted(vec.components) == sorted(ref.components)
    for name in vec.components:
        assert np.array_equal(vec.components[name], ref.components[name]), \
            f"vectorized {name} series diverged from the loop oracle"
    assert np.array_equal(vec.flops_rate, ref.flops_rate)
    speedup = ref_s / vec_s
    assert speedup >= 20.0, f"vectorized speedup only {speedup:.1f}x"
    rows.append(("scale/speedup_56", vec_s * 1e6,
                 f"loop_s={ref_s:.3f};vector_s={vec_s:.4f};"
                 f"speedup={speedup:.0f}x;samples={len(vec.t)}"))

    # -- the full 160-node L-CSC with a 1200-job mixed batch -----------------
    rng = np.random.default_rng(42)
    top160 = ClusterTopology(n_nodes=160)
    assert top160.n_chips == 640
    jobs = [Job(f"j{i}", float(rng.choice([13.0, 13.0, 30.0])),
                float(rng.uniform(300.0, 2400.0)))
            for i in range(1200)]
    t0 = time.perf_counter()
    res = run(jobs, policy="packed", topology=top160, op=op, dt_s=5.0)
    full_s = time.perf_counter() - t0
    assert len(res.schedule.placements) == len(jobs)
    # every chip is booked from t=0, so the first sample is the whole
    # machine at full load — the same composed node physics as the
    # 56-node batch, ×160
    expect = NodeModel().power(op) * 160
    assert abs(float(res.trace.power_w[0]) - expect) / expect < 1e-9
    assert float(res.trace.aux["util"][0]) == 1.0
    eff = res.efficiency(3).mflops_per_w
    assert eff > 4000.0
    rows.append(("scale/lcsc_160", full_s * 1e6,
                 f"jobs={len(jobs)};kw={float(res.trace.power_w[0])/1e3:.2f};"
                 f"mflops_w={eff:.1f};makespan={res.makespan:.0f};"
                 f"samples={len(res.trace.t)};wall_s={full_s:.2f}"))
    return rows


# -- §1: online operation — arrival queue, backfill, failures -----------------

def cluster_online() -> List[Row]:
    """L-CSC as a *live* machine through the discrete-event simulator:
    (1) the Green500 batch pushed through the arrival queue reproduces
    the same 57.2 kW trace bit-for-bit (the oracle property at benchmark
    scale); (2) conservative backfill beats plain FCFS on utilization
    over a mixed-width Poisson stream; (3) a simulated week of the full
    160-node machine with Weibull node failures stays interactive and
    inside the full-load power envelope."""
    from repro.cluster import (ClusterTopology, Job, PoissonArrivals, run,
                               simulate)
    from repro.distributed.fault import WeibullFailureModel
    from repro.power import OperatingPoint
    from repro.power.layers import NodeModel

    op = OperatingPoint.green500()
    rows: List[Row] = []

    # -- Green500 batch through the queue: bit-equal to cluster.run() --------
    top56 = ClusterTopology(n_nodes=56)
    jobs56 = [Job(f"lat{i}", 13.0, 1800.0) for i in range(top56.n_chips)]
    batch = run(jobs56, policy="packed", topology=top56, op=op, dt_s=30.0)
    t0 = time.perf_counter()
    online = simulate(jobs56, topology=top56, op=op, dt_s=30.0,
                      backfill=False)
    online_s = time.perf_counter() - t0
    assert np.array_equal(online.trace.t, batch.trace.t)
    for name in online.trace.components:
        assert np.array_equal(online.trace.components[name],
                              batch.trace.components[name]), \
            f"online {name} series diverged from the batch oracle"
    assert np.array_equal(online.trace.flops_rate, batch.trace.flops_rate)
    p_kw = float(np.mean(online.trace.power_w)) / 1e3
    assert abs(p_kw - 57.2) / 57.2 < 0.02            # 57.2 kW, queued
    rows.append(("online/green500_queued", online_s * 1e6,
                 f"kw={p_kw:.2f};paper=57.2;"
                 f"util={online.stats.utilization:.3f};"
                 f"mflops_w={online.efficiency(3).mflops_per_w:.1f}"))

    # -- backfill vs FCFS on a mixed-width open queue ------------------------
    rng = np.random.default_rng(8)
    jobs = [Job(f"j{i}", 52.0 if i % 3 == 0 else 13.0,
                float(rng.uniform(300.0, 2400.0))) for i in range(200)]
    arr = PoissonArrivals(jobs, rate_per_s=1 / 15.0, seed=9)
    top8 = ClusterTopology(n_nodes=8)
    fcfs = simulate(arr, topology=top8, op=op, dt_s=60.0, backfill=False)
    easy = simulate(arr, topology=top8, op=op, dt_s=60.0, backfill=True)
    assert easy.stats.utilization > fcfs.stats.utilization
    assert easy.makespan <= fcfs.makespan
    rows.append(("online/backfill_vs_fcfs", 0.0,
                 f"util_fcfs={fcfs.stats.utilization:.3f};"
                 f"util_easy={easy.stats.utilization:.3f};"
                 f"makespan_gain="
                 f"{1 - easy.makespan / fcfs.makespan:.1%}"))

    # -- a week of the full machine with failures ----------------------------
    rng = np.random.default_rng(10)
    week_jobs = [Job(f"j{i}", 52.0 if i % 5 == 0 else 13.0,
                     float(rng.uniform(1800.0, 4 * 3600.0)))
                 for i in range(3000)]
    warr = PoissonArrivals(week_jobs, rate_per_s=1 / 200.0, seed=11)
    fm = WeibullFailureModel(mtbf_s=1000.0 * 3600.0, repair_s=2 * 3600.0)
    top160 = ClusterTopology(n_nodes=160)
    t0 = time.perf_counter()
    week = simulate(warr, topology=top160, op=op, dt_s=60.0,
                    failure_model=fm, seed=12)
    week_s = time.perf_counter() - t0
    assert week_s < 10.0, f"160-node week took {week_s:.1f}s"
    assert week.stats.node_failures > 0 and week.stats.requeues > 0
    assert week.makespan > 6 * 24 * 3600.0
    # failures only ever *remove* load: never above the flat-out envelope
    env = NodeModel().power(op) * 160 + top160.network_w
    assert float(np.max(week.trace.power_w)) <= env * (1 + 1e-9)
    rows.append(("online/week_160_failures", week_s * 1e6,
                 f"jobs={week.stats.jobs_completed};"
                 f"fails={week.stats.node_failures};"
                 f"requeues={week.stats.requeues};"
                 f"util={week.stats.utilization:.3f};"
                 f"kwh={week.stats.energy_kwh:.0f};"
                 f"cost=${week.stats.cost_usd:.0f};wall_s={week_s:.2f}"))
    return rows


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


# -- §1–2: heterogeneous per-node operating points ----------------------------

def cluster_hetero() -> List[Row]:
    """The paper's headline is a *mixed-frequency* cluster story: each
    workload at its own optimal point — 774 MHz for the Green500 LQCD
    run, higher clocks when Linpack throughput matters.  Gates: (1) a
    mixed HPL@900 + LQCD@774 batch beats the same batch forced to either
    single point on combined MFLOPS/W; (2) the vectorized heterogeneous
    trace is bit-identical to the per-tick loop oracle; (3) the 56-node
    Green500 record batch still reproduces the published 57.13 kW."""
    from repro.cluster import ClusterTopology, Job, run
    from repro.cluster.run import _merged_trace_reference
    from repro.power import OperatingPoint

    rows: List[Row] = []
    op774 = OperatingPoint.green500()
    op900 = OperatingPoint(f_mhz=900.0)
    top = ClusterTopology(n_nodes=56)

    # 8 node-wide HPL jobs (throughput mode: 900 MHz) + 192 one-per-GPU
    # LQCD lattices at the efficiency point fill all 56 nodes
    jobs = [Job(f"hpl{i}", 52.0, 1800.0, preferred_op=op900, kind="hpl")
            for i in range(8)]
    jobs += [Job(f"lat{i}", 13.0, 480.0, preferred_op=op774, kind="lqcd")
             for i in range(192)]

    t0 = time.time()
    mixed = run(jobs, policy="packed", topology=top, dt_s=30.0)
    mixed_us = (time.time() - t0) * 1e6
    assert {p.op.f_mhz for p in mixed.schedule.placements} == {774.0, 900.0}
    assert mixed.trace.meta["heterogeneous"]

    # vectorized heterogeneous trace == per-tick loop oracle, bit-level
    ref = _merged_trace_reference(mixed.schedule, dt_s=30.0,
                                  network_w=float(top.network_w))
    assert np.array_equal(mixed.trace.t, ref.t)
    for name in mixed.trace.components:
        assert np.array_equal(mixed.trace.components[name],
                              ref.components[name]), \
            f"hetero {name} series diverged from the loop oracle"
    assert np.array_equal(mixed.trace.flops_rate, ref.flops_rate)

    # per-workload DVFS beats both homogeneous points on MFLOPS/W: 774
    # everywhere stalls HPL (longer makespan, same idle overheads), 900
    # everywhere burns watts the memory-bound lattices can't use
    eff_mixed = mixed.efficiency(3).mflops_per_w
    all774 = run(jobs, topology=top, op=op774, dt_s=30.0)
    all900 = run(jobs, topology=top, op=op900, dt_s=30.0)
    eff_774 = all774.efficiency(3).mflops_per_w
    eff_900 = all900.efficiency(3).mflops_per_w
    assert eff_mixed > eff_774, "mixed batch must beat uniform 774 MHz"
    assert eff_mixed > eff_900, "mixed batch must beat uniform 900 MHz"
    assert mixed.makespan < all774.makespan        # HPL unstalled

    # the Green500 record batch is untouched by the heterogeneous
    # machinery: still the published 57.13 kW, now to 0.2%
    lat56 = [Job(f"lat{i}", 13.0, 1800.0) for i in range(top.n_chips)]
    record = run(lat56, policy="packed", topology=top, op=op774, dt_s=30.0)
    p_kw = float(np.mean(record.trace.power_w)) / 1e3
    assert abs(p_kw - 57.13) / 57.13 < 0.002, \
        f"Green500 record batch drifted to {p_kw:.3f} kW"

    rows.append(("hetero/mixed_56", mixed_us,
                 f"mflops_w={eff_mixed:.1f};clocks=774+900;"
                 f"makespan={mixed.makespan:.0f}"))
    rows.append(("hetero/uniform_774", 0.0,
                 f"mflops_w={eff_774:.1f};makespan={all774.makespan:.0f};"
                 f"mixed_gain={eff_mixed / eff_774 - 1:.1%}"))
    rows.append(("hetero/uniform_900", 0.0,
                 f"mflops_w={eff_900:.1f};makespan={all900.makespan:.0f};"
                 f"mixed_gain={eff_mixed / eff_900 - 1:.1%}"))
    rows.append(("hetero/green500_record", 0.0,
                 f"kw={p_kw:.2f};paper=57.13"))
    return rows


# -- §1: CG energy-to-solution, plain vs even-odd mixed-precision -------------

def cg_energy_to_solution() -> List[Row]:
    """The solver-level optimization the paper credits for L-CSC's
    efficiency: even-odd preconditioning + reduced-precision inner CG cut
    the number (and byte cost) of normal-op applications, so
    energy-to-solution drops at equal solution quality."""
    import jax
    import jax.numpy as jnp
    from repro.core.energy import solver_energy
    from repro.lqcd import random_su3_field, solve_wilson, solve_wilson_eo

    lat = (8, 8, 8, 8)
    kappa = 0.12
    vol = int(np.prod(lat))
    ku, kr, ki = jax.random.split(jax.random.PRNGKey(0), 3)
    U = random_su3_field(ku, lat)
    b = (jax.random.normal(kr, lat + (4, 3))
         + 1j * jax.random.normal(ki, lat + (4, 3))).astype(jnp.complex64)

    plain = solve_wilson(U, b, kappa, tol=1e-6, max_iters=1000)
    eo = solve_wilson_eo(U, b, kappa, tol=1e-6, max_iters=1000,
                         inner_dtype=jnp.bfloat16)
    assert bool(plain.converged) and eo.converged
    assert eo.rel_residual <= 1e-6
    # preconditioning + mixed precision must SAVE normal-op applications
    assert eo.iters + eo.outer_iters < int(plain.iters)

    e_plain = solver_energy("cg/plain_f32", vol, int(plain.iters),
                            inner_real_bytes=4, even_odd=False)
    e_eo = solver_energy("cg/eo_bf16", vol, eo.iters,
                         outer_ops=eo.outer_iters, inner_real_bytes=2,
                         outer_real_bytes=4, even_odd=True)
    assert e_eo.energy_j < e_plain.energy_j          # the paper's point

    rows: List[Row] = []
    for res, rep in ((plain, e_plain), (eo, e_eo)):
        rows.append((rep.name, 0.0,
                     f"normal_ops={rep.normal_ops};"
                     f"rel_resid={float(res.rel_residual):.1e};"
                     f"energy_j={rep.energy_j:.3e};"
                     f"gflops_w={rep.gflops_per_w:.2f}"))
    rows.append(("cg/eo_vs_plain", 0.0,
                 f"energy_saving={1 - e_eo.energy_j / e_plain.energy_j:.1%};"
                 f"op_saving={1 - e_eo.normal_ops / int(plain.iters):.1%};"
                 f"gflops_w_ratio={e_eo.gflops_per_w / e_plain.gflops_per_w:.2f}"))
    return rows


# -- §1/§5: multi-chip even-odd D-slash with overlapped halo exchange ---------

def dslash_multichip() -> List[Row]:
    """Executed T-sharded even-odd D-slash (repro.lqcd.multichip_eo):
    volume scaling of the sharded normal op, overlapped vs halo-then-
    compute wall clock, the ICI/PCIe overlap roofline, and the measured
    calibration feeding the cluster scheduler.

    Wall-clock rows are reported but not drift-gated (the CI smoke host
    runs 8 virtual CPU devices whose collectives are shared-memory
    memcpys — there is no wire latency to hide, so overlap gains only
    materialize on real interconnects; the roofline rows gate that
    claim deterministically instead).
    """
    import jax
    import jax.numpy as jnp
    from repro.configs.lcsc_lqcd import (DSLASH_BW_FRACTION,
                                         MULTI_GPU_SLOWDOWN, S9150_BW_GBS)
    from repro.distributed.sharding import lattice_mesh
    from repro.lqcd import (dslash_bytes_per_site, dslash_flops_per_site,
                            random_su3_field)
    from repro.lqcd.eo import eo_pack, pack_gauge
    from repro.lqcd.multichip_eo import (ShardedWilsonEO,
                                         analytic_lqcd_calibration,
                                         measured_lqcd_calibration)

    rows: List[Row] = []
    n_dev = jax.device_count()

    def timed_normal(lat, overlap):
        ku, kr, ki = jax.random.split(jax.random.PRNGKey(0), 3)
        U = random_su3_field(ku, lat)
        b = (jax.random.normal(kr, lat + (4, 3))
             + 1j * jax.random.normal(ki, lat + (4, 3))
             ).astype(jnp.complex64)
        U_e, U_o = pack_gauge(U)
        ops = ShardedWilsonEO(U_e, U_o, 0.12, mesh=lattice_mesh(lat[3]),
                              overlap=overlap)
        v = eo_pack(b, 0)
        return _timeit(lambda: jax.block_until_ready(ops.normal(v)))

    # volume scaling: the sharded local problem becomes bandwidth-bound
    # once dispatch overhead amortizes — achieved GB/s must rise with V
    gbs = []
    for lat in [(8, 8, 8, 8), (8, 8, 8, 16), (12, 12, 12, 24)]:
        us = timed_normal(lat, overlap=True)
        vol = int(np.prod(lat))
        gf = 2 * vol * dslash_flops_per_site() / us / 1e3
        bw = 2 * vol * dslash_bytes_per_site(4) / us / 1e3
        gbs.append(bw)
        rows.append((f"dslash_mc/{'x'.join(map(str, lat))}", us,
                     f"n_dev={n_dev};gflops={gf:.2f};wall_gbs={bw:.2f}"))
    # streaming rate must not collapse as volume grows (the larger local
    # problems amortize dispatch overhead toward the bandwidth roof; exact
    # ordering is noise-prone on the shared-core CPU smoke host)
    assert max(gbs[1:]) > 0.8 * gbs[0]

    # overlapped vs halo-then-compute at the largest benchmarked volume
    lat = (12, 12, 12, 24)
    us_noovl = timed_normal(lat, overlap=False)
    us_ovl = timed_normal(lat, overlap=True)
    speedup = us_noovl / us_ovl
    rows.append(("dslash_mc/overlap_vs_baseline", us_ovl,
                 f"us_baseline={us_noovl:.1f};speedup={speedup:.3f}"))
    assert 0.5 < speedup < 2.0       # sanity floor only (see docstring)

    # ICI/PCIe overlap roofline (deterministic gates): spin projection
    # halves halo bytes, and overlapping hides the smaller of compute
    # and halo time — together they bound the paper's ~20% multi-GPU
    # loss band from both sides
    bytes_site = dslash_bytes_per_site(8)
    t_local = 8
    compute_s = bytes_site / (S9150_BW_GBS * 1e9 * DSLASH_BW_FRACTION)
    halo_full = (2 / t_local) * (24 * 8) / 14e9      # PCIe gen3 eff
    halo_proj = halo_full / 2                        # 2 of 4 spin comps
    frac_full = halo_full / (compute_s + halo_full)
    frac_proj = halo_proj / (compute_s + halo_proj)
    model_speedup = (compute_s + halo_proj) / max(compute_s, halo_proj)
    rows.append(("dslash_mc/overlap_model", 0.0,
                 f"comm_frac_full={frac_full:.1%};"
                 f"comm_frac_proj={frac_proj:.1%};"
                 f"model_speedup={model_speedup:.3f};"
                 f"paper_loss={MULTI_GPU_SLOWDOWN:.0%}"))
    assert 0.10 < frac_full < 0.35                   # paper: ~20% loss
    assert frac_proj < frac_full                     # compression helps
    assert 1.05 < model_speedup < 1.35               # overlap recovers it

    # measured calibration -> cluster scheduler (PR-3 telemetry bus)
    cal = measured_lqcd_calibration((8, 8, 8, 16), reps=2)
    rows.append(("dslash_mc/calibration", cal.wall_s * 1e6 / 2,
                 f"n_dev={cal.n_devices};gflops={cal.gflops:.3f};"
                 f"gflops_per_w={cal.gflops_per_w:.2e}"))
    assert cal.energy_j > 0 and cal.trace is not None

    from repro.cluster.workload import LQCDSolveWorkload
    from repro.power.model import OperatingPoint
    op = OperatingPoint.green500()
    ana = analytic_lqcd_calibration(cal.lattice, cal.n_devices)
    res_a = LQCDSolveWorkload(calibration=ana).execute(op)
    res_m = LQCDSolveWorkload(calibration=cal).execute(op)
    rows.append(("dslash_mc/workload_calibrated", 0.0,
                 f"cal_vs_analytic={res_a.details['cal_vs_analytic']:.3f};"
                 f"vs_analytic_gflops="
                 f"{res_m.details['cal_vs_analytic']:.2e}"))
    # an analytic-shaped calibration must reproduce the roofline exactly
    assert abs(res_a.details["cal_vs_analytic"] - 1.0) < 1e-6
    return rows


# -- §5 applied to serving: replayed traffic, batching, autoscaling -----------

def serve_replay() -> List[Row]:
    """Serve-traffic replay gates.  (1) **Oracle**: a full-batch burst
    through the continuous-batching engine reproduces the analytic
    ``ServeWorkload`` plan exactly — same makespan, same joules — so the
    engine, the ``launch.serve`` driver and the cluster scheduler price
    a token identically.  (2) **Autoscaling**: over a seeded diurnal
    day, the SLO-aware autoscaled fleet (derated clocks, replicas
    parked through the trough) beats static flat-out on J/request at
    >= the same p99-SLO compliance, with neither policy ever exceeding
    the wall power cap.  (3) An undersized fleet shows the SLO metric
    binds (compliance visibly below the autoscaled fleet's)."""
    from repro.power.model import OperatingPoint
    from repro.serve import (AutoscalePolicy, ContinuousBatchingEngine,
                             HOST_SHARE_W, ServeCostModel, constant_trace,
                             diurnal_trace, flat_out, run_fleet)
    from repro.serve.engine import Replica

    rows: List[Row] = []
    op = OperatingPoint.green500()

    # (1) constant-rate burst == ServeWorkload analytic plan, exactly
    cost = ServeCostModel("llama3-8b", max_batch=4, prompt_len=64, gen=32)
    burst = constant_trace(4, prompt_len=64, gen_len=32)
    t0 = time.time()
    res = ContinuousBatchingEngine(cost).replay(burst, op=op)
    oracle_us = (time.time() - t0) * 1e6
    ref = cost.workload.execute(op)
    err_wall = abs(res.span_s - ref.wall_s) / ref.wall_s
    err_e = abs(res.stats.energy_j - ref.energy_j) / ref.energy_j
    assert err_wall < 1e-9, f"oracle wall drifted: {err_wall:.2e}"
    assert err_e < 1e-9, f"oracle energy drifted: {err_e:.2e}"
    per_req = sum(res.request_energy_j(i) for i in range(4))
    err_sum = abs(per_req - res.stats.energy_j) / res.stats.energy_j
    assert err_sum < 1e-9, f"per-request energies lost joules: {err_sum:.2e}"
    rows.append(("serve/oracle_burst", oracle_us,
                 f"rel_err_makespan={err_wall:.1e};"
                 f"rel_err_energy={err_e:.1e};rel_err_req_sum={err_sum:.1e};"
                 f"n_req=4"))

    # (2) one diurnal day, static flat-out vs SLO-aware autoscaling
    fleet_cost = ServeCostModel("llama3-8b", max_batch=8, prompt_len=64,
                                gen=32)
    plan, _, _ = fleet_cost.plan()
    t_pre, _ = fleet_cost.prefill_cost(64, 8)
    service = t_pre + 32 * plan.step_time_s
    cap_rps = 8 / service
    n_max = 4
    day = 1500.0 / (0.55 * n_max * cap_rps)
    tr = diurnal_trace(day, rate_peak_per_s=0.75 * n_max * cap_rps,
                       rate_floor_per_s=0.05 * n_max * cap_rps,
                       prompt_lens=(64,), gen_lens=(32,), seed=7)
    probe = Replica(fleet_cost)
    cap_w = n_max * (probe.p_busy + HOST_SHARE_W) + 1.0
    dt_ctrl = day / 288.0
    slo_s = 8.0 * service + 3.0 * dt_ctrl

    t0 = time.time()
    static = run_fleet(fleet_cost, tr, flat_out(n_max, power_cap_w=cap_w),
                       slo_s=slo_s)
    static_us = (time.time() - t0) * 1e6
    t0 = time.time()
    auto = run_fleet(
        fleet_cost, tr,
        AutoscalePolicy(name="autoscaled_derated", n_max=n_max, n_min=1,
                        dt_ctrl_s=dt_ctrl, power_cap_w=cap_w),
        slo_s=slo_s)
    auto_us = (time.time() - t0) * 1e6

    assert static.stats.completed == len(tr) == auto.stats.completed, \
        "requests lost in replay"
    gain = static.stats.j_per_request / auto.stats.j_per_request
    assert gain > 1.0, \
        f"autoscaled fleet must beat static flat-out on J/request " \
        f"({auto.stats.j_per_request:.3g} vs " \
        f"{static.stats.j_per_request:.3g})"
    assert auto.stats.slo_compliance >= static.stats.slo_compliance, \
        "autoscaling must not trade SLO compliance away"
    assert auto.stats.slo_compliance >= 0.99
    for r in (static, auto):
        assert r.stats.peak_power_w <= cap_w + 1e-6, \
            f"{r.policy.name} exceeded the wall power cap"
    rows.append(("serve/static_flat_out", static_us,
                 f"uj_req={static.stats.j_per_request * 1e6:.4g};"
                 f"comp={static.stats.slo_compliance:.4f};"
                 f"peak_w={static.stats.peak_power_w:.1f};"
                 f"n_req={len(tr)};live={static.n_live_peak}"))
    rows.append(("serve/autoscaled_derated", auto_us,
                 f"uj_req={auto.stats.j_per_request * 1e6:.4g};"
                 f"comp={auto.stats.slo_compliance:.4f};"
                 f"peak_w={auto.stats.peak_power_w:.1f};"
                 f"gain={gain:.3f};live_min={auto.n_live_min};"
                 f"live_peak={auto.n_live_peak}"))

    # (3) an undersized fleet can't hold the p99 SLO through the peak:
    # the compliance metric binds (it is not vacuously 1.0)
    under = run_fleet(fleet_cost, tr,
                      AutoscalePolicy(name="undersized", n_max=1, n_min=1,
                                      dt_ctrl_s=dt_ctrl),
                      slo_s=slo_s)
    assert under.stats.slo_compliance < auto.stats.slo_compliance, \
        "undersized fleet should miss the SLO the autoscaled fleet holds"
    rows.append(("serve/undersized", 0.0,
                 f"comp={under.stats.slo_compliance:.4f};"
                 f"uj_req={under.stats.j_per_request * 1e6:.4g};live=1"))
    return rows


# -- §4 (node stability) extended: checkpoint/restart resilience --------------

def cluster_resilience() -> List[Row]:
    """Checkpoint/restart under Weibull node failures.  Gates: (1)
    **no-failure oracle** — with a checkpoint policy armed but MTBF=inf
    the online sim writes zero checkpoints and stays bit-identical to
    the batch ``cluster.run()`` trace (no ``storage`` component); (2)
    the **Daly interval** sqrt(2*delta*MTBF) beats both no-checkpointing
    and naive fixed intervals (16x too frequent / 16x too sparse) on
    energy-to-completion AND goodput under a seeded failure stream; (3)
    a fixed-interval **sweep** around the analytic point has its
    empirical optimum strictly inside the sweep — the measured best
    interval brackets the analytic Daly point."""
    from repro.cluster import (CheckpointPolicy, ClusterTopology, Job,
                               daly_interval_s, run, simulate)
    from repro.distributed.fault import WeibullFailureModel
    from repro.power import OperatingPoint

    rows: List[Row] = []
    op = OperatingPoint.green500()
    top = ClusterTopology(n_nodes=12)
    jobs = [Job(f"lat{i}", 13.0, 30000.0, kind="lqcd") for i in range(36)]
    mtbf_s = 72000.0                     # 20 h/node: pessimistic paper-era
    fm = WeibullFailureModel(mtbf_s=mtbf_s, shape=1.0, repair_s=900.0)
    pol = CheckpointPolicy()             # Daly from the cost model
    delta = pol.write_time_s(jobs[0])
    tau_star = daly_interval_s(delta, mtbf_s)

    # (1) no-failure oracle: policy armed, MTBF=inf -> bit-identical
    batch = run(jobs, topology=top, op=op, dt_s=300.0)
    oracle = simulate(jobs, topology=top, op=op, dt_s=300.0,
                      backfill=False, checkpoint=pol, elastic=True)
    assert np.array_equal(oracle.trace.t, batch.trace.t)
    for name in batch.trace.components:
        assert np.array_equal(oracle.trace.components[name],
                              batch.trace.components[name]), \
            f"oracle {name} series diverged from batch run()"
    assert set(oracle.trace.components) == set(batch.trace.components), \
        "storage component must not appear without checkpoints"
    assert oracle.stats.checkpoints == 0
    assert oracle.stats.wasted_energy_j == 0.0
    assert oracle.stats.goodput == 1.0
    rows.append(("resilience/oracle", 0.0,
                 "bit_identical=1;ckpts=0;wasted_j=0"))

    def attempt(ck, label):
        t0 = time.time()
        r = simulate(jobs, topology=top, op=op, dt_s=300.0,
                     failure_model=fm, seed=0, max_requeues=10,
                     checkpoint=ck)
        us = (time.time() - t0) * 1e6
        assert r.stats.jobs_completed == len(jobs), \
            f"{label}: jobs lost under failures"
        return r, us

    # (2) Daly vs no-checkpoint vs naive fixed intervals
    none, none_us = attempt(None, "no_ckpt")
    daly, daly_us = attempt(pol, "daly")
    assert daly.stats.checkpoints > 0 and daly.stats.node_failures > 0
    assert "storage" in daly.trace.components
    assert daly.stats.energy_j < none.stats.energy_j, \
        "Daly checkpointing must cut energy-to-completion"
    assert daly.stats.goodput > none.stats.goodput, \
        "Daly checkpointing must raise goodput"

    # (3) fixed-interval sweep: the empirical optimum sits strictly
    # inside the sweep, bracketing the analytic Daly point
    sweep = {}
    for mult in (1.0 / 16.0, 1.0 / 4.0, 1.0, 4.0, 16.0):
        r, _ = attempt(CheckpointPolicy(interval_s=tau_star * mult),
                       f"fixed_{mult:g}")
        sweep[mult] = r
    best = min(sweep, key=lambda m: sweep[m].stats.energy_j)
    assert 1.0 / 16.0 < best < 16.0, \
        f"empirical optimum pinned to a sweep endpoint (x{best:g})"
    assert tau_star * best / 4.0 <= tau_star <= tau_star * best * 4.0, \
        "measured best interval does not bracket the analytic Daly point"
    for mult in (1.0 / 16.0, 16.0):      # naive endpoints lose to Daly
        s = sweep[mult].stats
        assert daly.stats.energy_j < s.energy_j, \
            f"Daly must beat the naive x{mult:g} fixed interval on energy"
        assert daly.stats.goodput > s.goodput, \
            f"Daly must beat the naive x{mult:g} fixed interval on goodput"

    rows.append(("resilience/no_ckpt", none_us,
                 f"kwh={none.stats.energy_kwh:.1f};"
                 f"goodput={none.stats.goodput:.3f};"
                 f"fails={none.stats.node_failures};"
                 f"requeues={none.stats.requeues}"))
    rows.append(("resilience/daly", daly_us,
                 f"kwh={daly.stats.energy_kwh:.1f};"
                 f"goodput={daly.stats.goodput:.3f};"
                 f"tau_star={tau_star:.0f};delta={delta:.0f};"
                 f"ckpts={daly.stats.checkpoints};"
                 f"saving={1 - daly.stats.energy_j / none.stats.energy_j:.1%}"))
    rows.append(("resilience/sweep", 0.0,
                 f"best_mult={best:g};"
                 + ";".join(f"x{m:g}={sweep[m].stats.energy_kwh:.1f}kwh"
                            for m in sorted(sweep))))
    return rows
