"""Reproduce the paper's Green500 measurement (§3-4) through the unified
power engine: compose the 56-node cluster layer by layer, simulate the
Linpack run into a PowerTrace, and apply the three measurement levels
plus the Level-1 exploit.

  PYTHONPATH=src python examples/green500_measurement.py
"""
import numpy as np

from repro.power import (OperatingPoint, SyntheticHPL,
                         evaluate_operating_point, lcsc_cluster,
                         level1_exploit, measure_efficiency, simulate)
from repro.power.green500 import (extrapolation_error, node_efficiencies,
                                  select_median_nodes)


def main() -> None:
    # the composed model at the published operating point: GPU -> node
    # (host + 4xS9150 + fans + PSU curve) -> rack -> cluster (+ switches)
    op = OperatingPoint.green500()
    cluster = lcsc_cluster()
    node_gf, node_w = evaluate_operating_point(op)
    comps = cluster.component_watts(op)
    print(f"node:  {node_gf:.0f} GFLOPS @ {node_w:.1f} W  "
          f"(gpu {comps['gpu']/56:.0f} + host {comps['host']/56:.0f} + "
          f"fan {comps['fan']/56:.1f} + psu_loss {comps['psu_loss']/56:.1f})")
    kw = sum(w for k, w in comps.items() if k != "network") / 1000
    print(f"model: 56 nodes -> {node_gf*56/1000:.1f} TFLOPS @ {kw:.2f} kW "
          f"= {node_gf/node_w*1000:.1f} MFLOPS/W "
          f"(+{comps['network']:.0f} W of switches)")
    print("paper:  56 nodes -> 301.5 TFLOPS @ 57.20 kW = 5271.8 MFLOPS/W\n")

    # the time-stepped run and the three measurement levels
    tr = simulate(SyntheticHPL(duration_s=1800.0), op, cluster=cluster)
    for lvl in (1, 2, 3):
        r = measure_efficiency(tr, lvl)
        print(f"Level {lvl}: {r.mflops_per_w:7.1f} MFLOPS/W   ({r.notes})")
    ex = level1_exploit(tr)
    l3 = measure_efficiency(tr, 3)
    print(f"L1 exploit: {ex.mflops_per_w:7.1f} MFLOPS/W  "
          f"(+{ex.mflops_per_w/l3.mflops_per_w-1:.1%} over L3 — the paper "
          f"showed up to +30% and the v2.0 methodology now forbids it)\n")

    rng = np.random.default_rng(0)
    effs = node_efficiencies(rng, 7)
    print("7 sampled nodes [MFLOPS/W]:",
          ", ".join(f"{e:.1f}" for e in effs))
    sel = select_median_nodes(effs, 2)
    print(f"median nodes selected: {sel}; extrapolation error "
          f"{extrapolation_error(effs):.2%} (paper: <1%)")


if __name__ == "__main__":
    main()
