"""Reproduce the paper's Green500 measurement (§3-4): the 56-node Linpack
run, the three measurement levels, and the Level-1 exploit.

  PYTHONPATH=src python examples/green500_measurement.py
"""
import numpy as np

from repro.core.energy import (level1_exploit, linpack_power_trace,
                               measure_efficiency)
from repro.core.energy.green500 import (extrapolation_error,
                                        node_efficiencies,
                                        select_median_nodes)
from repro.core.energy.power_model import V_MIN, node_power
from repro.core.energy.throttle import (HPL_GPU_UTIL, gpu_power_throttled,
                                        hpl_node_perf)


def main() -> None:
    # the calibrated cluster model at the efficiency clock
    node_gf = hpl_node_perf(774, [V_MIN] * 4)
    pw = [gpu_power_throttled(774, V_MIN, util=HPL_GPU_UTIL)] * 4
    node_w = node_power(774, [V_MIN] * 4, gpu_clamped_w=pw)
    print(f"model: 56 nodes -> {node_gf*56/1000:.1f} TFLOPS @ "
          f"{node_w*56/1000:.2f} kW = {node_gf/node_w*1000:.1f} MFLOPS/W")
    print("paper:  56 nodes -> 301.5 TFLOPS @ 57.20 kW = 5271.8 MFLOPS/W\n")

    tr = linpack_power_trace(56, node_w, node_gf, duration_s=1800.0)
    for lvl in (1, 2, 3):
        r = measure_efficiency(tr, lvl)
        print(f"Level {lvl}: {r.mflops_per_w:7.1f} MFLOPS/W   ({r.notes})")
    ex = level1_exploit(tr)
    l3 = measure_efficiency(tr, 3)
    print(f"L1 exploit: {ex.mflops_per_w:7.1f} MFLOPS/W  "
          f"(+{ex.mflops_per_w/l3.mflops_per_w-1:.1%} over L3 — the paper "
          f"showed up to +30% and the v2.0 methodology now forbids it)\n")

    rng = np.random.default_rng(0)
    effs = node_efficiencies(rng, 7)
    print("7 sampled nodes [MFLOPS/W]:",
          ", ".join(f"{e:.1f}" for e in effs))
    sel = select_median_nodes(effs, 2)
    print(f"median nodes selected: {sel}; extrapolation error "
          f"{extrapolation_error(effs):.2%} (paper: <1%)")


if __name__ == "__main__":
    main()
