"""The paper's workload: invert the Wilson-Dirac operator with CG on a
thermal lattice, using the Pallas D-slash kernel, with the energy plan the
framework derives for it (memory-bound -> deep clock derate, <1.5% loss),
and the plain-vs-even-odd mixed-precision energy-to-solution comparison.

  PYTHONPATH=src python examples/lqcd_cg.py
"""
import time

import jax
import jax.numpy as jnp

from repro.config import EnergyConfig
from repro.core.energy import solver_energy
from repro.core.energy.dvfs import plan_frequency
from repro.kernels.dslash import dslash_pallas, dslash_ref
from repro.lqcd import (dslash_bytes_per_site, dslash_flops_per_site,
                        random_su3_field, solve_wilson, solve_wilson_eo)
from repro.roofline import hw


def main() -> None:
    lattice = (8, 8, 8, 8)        # thermal (T > 0) smoke lattice
    kappa = 0.12
    key = jax.random.PRNGKey(0)
    U = random_su3_field(key, lattice)
    kr, ki = jax.random.split(key)
    b = (jax.random.normal(kr, lattice + (4, 3))
         + 1j * jax.random.normal(ki, lattice + (4, 3))
         ).astype(jnp.complex64)

    # Pallas kernel (interpret mode on CPU) cross-check
    got = dslash_pallas(U, b, t_block=4)
    want = dslash_ref(U, b)
    err = float(jnp.max(jnp.abs(got - want)))
    print(f"Pallas D-slash vs oracle: max err {err:.2e}")

    t0 = time.time()
    res = solve_wilson(U, b, kappa, tol=1e-6, max_iters=1000)
    dt = time.time() - t0
    vol = 8 ** 4
    # each CG iteration applies D-slash twice (M and M-dagger)
    gflops = 2 * int(res.iters) * vol * dslash_flops_per_site() / dt / 1e9
    print(f"CG converged={bool(res.converged)} iters={int(res.iters)} "
          f"rel_resid={float(res.rel_residual):.2e} ({dt:.1f}s, "
          f"{gflops:.2f} GFLOPS on CPU)")

    # the paper's solver-level optimization: even-odd Schur CG with a
    # bf16 inner / f32 outer defect-correction loop (CL2QCD strategy)
    t0 = time.time()
    eo = solve_wilson_eo(U, b, kappa, tol=1e-6, max_iters=1000,
                         inner_dtype=jnp.bfloat16)
    dt_eo = time.time() - t0
    print(f"EO mixed CG converged={eo.converged} normal_ops={eo.iters}"
          f"+{eo.outer_iters} (plain: {int(res.iters)}) "
          f"rel_resid={eo.rel_residual:.2e} ({dt_eo:.1f}s)")
    e_plain = solver_energy("plain_f32", vol, int(res.iters))
    e_eo = solver_energy("eo_bf16", vol, eo.iters, outer_ops=eo.outer_iters,
                         inner_real_bytes=2, even_odd=True)
    print(f"energy-to-solution (S9150 model): plain={e_plain.energy_j:.3e} J"
          f" @ {e_plain.gflops_per_w:.2f} GFLOPS/W -> "
          f"eo_bf16={e_eo.energy_j:.3e} J @ {e_eo.gflops_per_w:.2f} GFLOPS/W"
          f" ({1 - e_eo.energy_j / e_plain.energy_j:.0%} saved)")

    # the paper's C5: D-slash is memory-bound -> the DVFS plan derates
    ai = dslash_flops_per_site() / dslash_bytes_per_site(4)
    compute_s = 1.0 / hw.PEAK_BF16_FLOPS
    memory_s = (1.0 / ai) / hw.HBM_BW
    plan = plan_frequency(compute_s, memory_s, 0.0, flops_per_step=1e12,
                          cfg=EnergyConfig(mode="efficiency"))
    print(f"energy plan: dominant={plan.dominant} freq={plan.freq_scale:.2f}"
          f" perf_loss={plan.perf_loss:.3%} (paper: <1.5%)")


if __name__ == "__main__":
    main()
