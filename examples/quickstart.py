"""Quickstart: train a small LM for 40 steps, then greedy-decode from it.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.config import ShapeConfig, TrainConfig, smoke_config
from repro.data import make_batch_iterator
from repro.models import (forward_prefill, forward_decode, init_params)
from repro.optim import adamw_init
from repro.runtime.steps import make_train_step


def main() -> None:
    cfg = smoke_config("llama3-8b")
    shape = ShapeConfig("quick", 128, 8, "train")
    tc = TrainConfig(learning_rate=3e-3, total_steps=40, warmup_steps=4,
                     remat="none")

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, tc))
    data = make_batch_iterator(cfg, shape)

    print(f"training {cfg.name}: "
          f"{sum(x.size for x in jax.tree.leaves(params)):,} params")
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, m = step(params, opt, batch)
        if i % 10 == 0 or i == 39:
            print(f"  step {i:3d}  loss {float(m['loss']):.4f}")

    # generate a few tokens
    prompt = {"tokens": jnp.asarray(next(data)["tokens"][:2, :16])}
    logits, cache = forward_prefill(cfg, params, prompt)
    toks = []
    tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)[:, None]
    for _ in range(8):
        toks.append(int(tok[0, 0]))
        logits, cache = forward_decode(cfg, params, tok.astype(jnp.int32),
                                       cache)
        tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)[:, None]
    print("generated:", toks)


if __name__ == "__main__":
    main()
