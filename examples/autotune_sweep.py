"""Rediscover the paper's Green500 operating point by sweeping the
parameter space (§2–4), then tune the repo's own hot paths with the
same machinery.

  PYTHONPATH=src python examples/autotune_sweep.py [cache.json]

Passing a path persists the winners as a JSON autotune cache that the
``tuned=True`` paths (``linpack_run``, ``dgemm``, ``dslash_pallas``)
will consult via ``REPRO_AUTOTUNE_CACHE``.
"""
import sys

from repro.autotune import (TuneCache, set_default_cache,
                            tune_operating_point, tuned_config)


def main() -> None:
    if len(sys.argv) > 1:
        set_default_cache(TuneCache(sys.argv[1]))

    print("=== node operating-point sweep (analytic, grid) ===")
    res = tune_operating_point()
    top = sorted((c for c in res.trace
                  if c.feasible and c.perf_gflops >= res.perf_floor_gflops),
                 key=lambda c: -c.mflops_per_w)[:5]
    print(f"{'f_MHz':>6} {'vid':>7} {'fan':>5} {'NB':>5} {'la':>3} "
          f"{'GFLOPS':>8} {'W':>7} {'MFLOPS/W':>9}")
    for c in top:
        p = c.point
        print(f"{p['f_mhz']:6.0f} {p['vid']:7.4f} {p['fan']:5.2f} "
              f"{p['nb']:5d} {p['lookahead']:3d} {c.perf_gflops:8.1f} "
              f"{c.power_w:7.1f} {c.mflops_per_w:9.1f}")
    best = res.best.point
    print(f"\nwinner: {best['f_mhz']:.0f} MHz @ vid {best['vid']}, "
          f"fan {best['fan']:.0%}, NB {best['nb']}, "
          f"lookahead {best['lookahead']}")
    print(f"  {res.best.mflops_per_w:.1f} MFLOPS/W "
          f"(paper: 5271.8), giving up {res.perf_loss:.1%} Linpack "
          f"(paper: ~13–15%)")

    cd = tune_operating_point(method="coordinate")
    print(f"  coordinate descent: same point = {cd.best.point == best}, "
          f"{cd.evaluations} vs {res.evaluations} evaluations\n")

    print("=== Pallas kernel + HPL blocking tuning (analytic) ===")
    # tuned_config is the cache-backed entry point the tuned=True paths
    # use — going through it here persists the winners
    d = tuned_config("dgemm", (1024, 1024, 1024))
    print(f"dgemm 1024^3:  tiles {d}")
    s = tuned_config("dslash", (8, 8, 8, 8))
    print(f"dslash 8^4:    t_block {s['t_block']}")
    h = tuned_config("hpl", (1024,))
    print(f"hpl n=1024:    block {h['block']}, lookahead {h['lookahead']}")
    tuned_config("operating_point", ())

    print("\nconsume via the tuned paths, e.g.:")
    print("  linpack_run(HPLConfig(n=1024), tuned=True)")
    print("  dgemm(x, y, tuned=True)")
    if len(sys.argv) > 1:
        from repro.autotune import default_cache
        print(f"\ncache persisted: {sys.argv[1]} "
              f"({len(default_cache())} entries)")


if __name__ == "__main__":
    main()
