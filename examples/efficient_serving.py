"""Energy-efficient serving: batched decode with the int8 KV cache and the
roofline-coupled frequency plan (decode is the framework's D-slash: memory
bound, so the clock derates deeply at <1.5% perf cost).

  PYTHONPATH=src python examples/efficient_serving.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import EnergyConfig, ShapeConfig, SINGLE_POD_MESH, \
    smoke_config
from repro.core.energy.dvfs import plan_frequency
from repro.models import forward_decode, forward_prefill, init_params
from repro.roofline.analytic import cost_for


def main() -> None:
    cfg = smoke_config("qwen1.5-32b")
    B, S, gen = 4, 64, 16
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}

    for quant in (False, True):
        logits, cache = forward_prefill(cfg, params, batch,
                                        quantize_kv_cache=quant)
        tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)[:, None]
        decode = jax.jit(lambda p, t, c: forward_decode(cfg, p, t, c))
        outs = []
        t0 = time.time()
        for _ in range(gen):
            outs.append(np.asarray(tok))
            logits, cache = decode(params, tok.astype(jnp.int32), cache)
            tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)[:, None]
        jax.block_until_ready(logits)
        dt = time.time() - t0
        cache_gb = sum(v.size * v.dtype.itemsize
                       for k, v in cache.items() if k != "pos") / 2**20
        print(f"kv_int8={quant}: {gen*B/dt:6.1f} tok/s, cache {cache_gb:.2f}"
              f" MiB, first tokens {np.concatenate(outs,1)[0][:6]}")

    # the energy plan for the full-size config's decode cell
    full = smoke_config("qwen1.5-32b")
    shape = ShapeConfig("serve", 32768, 128, "decode")
    ac = cost_for(full, shape, SINGLE_POD_MESH, kv_int8=True)
    plan = plan_frequency(ac.compute_s, ac.memory_s, ac.collective_s,
                          flops_per_step=ac.flops,
                          cfg=EnergyConfig(mode="efficiency"))
    print(f"\nfull-scale decode energy plan: dominant={plan.dominant} "
          f"freq={plan.freq_scale:.2f} power={plan.power_w:.0f}W "
          f"perf_loss={plan.perf_loss:.2%}")


if __name__ == "__main__":
    main()
